"""Kernel-vs-oracle correctness: every Pallas kernel against the pure-jnp
reference, with hypothesis sweeping shapes and seeds.

This is the CORE correctness signal for L1: the AOT artifacts are lowered
from exactly these kernels, so agreement here + the Rust runtime's
round-trip test means the whole stack computes the right numbers.
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import elementary as K
from compile.kernels import ref

RT = K.ROW_TILE


def rng_arrays(seed, *shapes):
    rng = np.random.RandomState(seed)
    return [jnp.asarray(rng.uniform(-1, 1, s).astype(np.float32)) for s in shapes]


def assert_close(a, b, tol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


# n must be a multiple of 32 (the paper pads to the element size)
n_vec = st.integers(1, 64).map(lambda k: k * RT)
mn_mat = st.tuples(st.integers(1, 8), st.integers(1, 8)).map(
    lambda t: (t[0] * RT, t[1] * RT)
)
seeds = st.integers(0, 2**31 - 1)
scalars = st.floats(-3.0, 3.0, allow_nan=False).map(lambda v: float(np.float32(v)))


# ---------------------------------------------------------------- BLAS-1


@settings(max_examples=25, deadline=None)
@given(n=n_vec, seed=seeds)
def test_scopy(n, seed):
    (x,) = rng_arrays(seed, (n,))
    assert_close(K.scopy(x), x)


@settings(max_examples=25, deadline=None)
@given(n=n_vec, seed=seeds, alpha=scalars)
def test_sscal(n, seed, alpha):
    (x,) = rng_arrays(seed, (n,))
    assert_close(K.sscal(x, alpha), ref.sscal(x, alpha))


@settings(max_examples=25, deadline=None)
@given(n=n_vec, seed=seeds, alpha=scalars)
def test_saxpy(n, seed, alpha):
    x, y = rng_arrays(seed, (n,), (n,))
    assert_close(K.saxpy(x, y, alpha), alpha * x + y)


@settings(max_examples=25, deadline=None)
@given(n=n_vec, seed=seeds, alpha=scalars, beta=scalars)
def test_waxpby(n, seed, alpha, beta):
    x, y = rng_arrays(seed, (n,), (n,))
    assert_close(K.waxpby(x, y, alpha, beta), ref.waxpby(x, y, alpha, beta))


@settings(max_examples=25, deadline=None)
@given(n=n_vec, seed=seeds)
def test_vadd3(n, seed):
    w, y, z = rng_arrays(seed, (n,), (n,), (n,))
    assert_close(K.vadd3(w, y, z), ref.vadd(w, y, z))


@settings(max_examples=25, deadline=None)
@given(n=n_vec, seed=seeds)
def test_sdot(n, seed):
    x, y = rng_arrays(seed, (n,), (n,))
    got = K.sdot(x, y)
    assert got.shape == (1,)
    assert_close(got[0], x @ y, tol=1e-4 * max(1, n / 256))


@settings(max_examples=25, deadline=None)
@given(n=n_vec, seed=seeds, alpha=scalars)
def test_axpydot_fused(n, seed, alpha):
    w, v, u = rng_arrays(seed, (n,), (n,), (n,))
    z, r = K.axpydot_fused(w, v, u, alpha)
    z_ref, r_ref = ref.axpydot(w, v, u, alpha)
    assert_close(z, z_ref)
    assert_close(r[0], r_ref, tol=1e-4 * max(1, n / 256))


# ---------------------------------------------------------------- BLAS-2


@settings(max_examples=20, deadline=None)
@given(mn=mn_mat, seed=seeds)
def test_mcopy(mn, seed):
    (a,) = rng_arrays(seed, mn)
    assert_close(K.mcopy(a), a)


@settings(max_examples=20, deadline=None)
@given(mn=mn_mat, seed=seeds)
def test_madd(mn, seed):
    a, b = rng_arrays(seed, mn, mn)
    assert_close(K.madd(a, b), ref.madd(a, b))


@settings(max_examples=20, deadline=None)
@given(mn=mn_mat, seed=seeds, alpha=scalars)
def test_sger(mn, seed, alpha):
    m, n = mn
    a, u, v = rng_arrays(seed, mn, (m,), (n,))
    assert_close(K.sger(a, u, v, alpha), a + alpha * jnp.outer(u, v))


@settings(max_examples=20, deadline=None)
@given(mn=mn_mat, seed=seeds)
def test_sger2(mn, seed):
    m, n = mn
    a, u1, v1, u2, v2 = rng_arrays(seed, mn, (m,), (n,), (m,), (n,))
    want = a + jnp.outer(u1, v1) + jnp.outer(u2, v2)
    assert_close(K.sger2(a, u1, v1, u2, v2), want)


@settings(max_examples=20, deadline=None)
@given(mn=mn_mat, seed=seeds, alpha=scalars)
def test_sgemv(mn, seed, alpha):
    m, n = mn
    a, x = rng_arrays(seed, mn, (n,))
    assert_close(K.sgemv(a, x, alpha), alpha * (a @ x), tol=1e-4)


@settings(max_examples=20, deadline=None)
@given(mn=mn_mat, seed=seeds, alpha=scalars, beta=scalars)
def test_sgemvpy(mn, seed, alpha, beta):
    m, n = mn
    a, x, y = rng_arrays(seed, mn, (n,), (m,))
    assert_close(K.sgemvpy(a, x, y, alpha, beta), ref.sgemv(a, x, y, alpha, beta), tol=1e-4)


@settings(max_examples=20, deadline=None)
@given(mn=mn_mat, seed=seeds, alpha=scalars)
def test_sgemtv(mn, seed, alpha):
    m, n = mn
    a, r = rng_arrays(seed, mn, (m,))
    assert_close(K.sgemtv(a, r, alpha), alpha * (a.T @ r), tol=1e-4)


@settings(max_examples=20, deadline=None)
@given(mn=mn_mat, seed=seeds, beta=scalars)
def test_sgemtvpz(mn, seed, beta):
    m, n = mn
    a, y, z = rng_arrays(seed, mn, (m,), (n,))
    assert_close(K.sgemtvpz(a, y, z, beta), beta * (a.T @ y) + z, tol=1e-4)


# ---------------------------------------------------------------- fusions


@settings(max_examples=20, deadline=None)
@given(mn=mn_mat, seed=seeds)
def test_bicgk_fused(mn, seed):
    m, n = mn
    a, p, r = rng_arrays(seed, mn, (n,), (m,))
    q, s = K.bicgk_fused(a, p, r)
    q_ref, s_ref = ref.bicgk(a, p, r)
    assert_close(q, q_ref, tol=1e-4)
    assert_close(s, s_ref, tol=1e-4)


@settings(max_examples=15, deadline=None)
@given(mn=mn_mat, seed=seeds, beta=scalars)
def test_gemver_fused_k1(mn, seed, beta):
    m, n = mn
    a, u1, v1, u2, v2, y, z = rng_arrays(
        seed, mn, (m,), (n,), (m,), (n,), (m,), (n,)
    )
    b, x = K.gemver_fused_k1(a, u1, v1, u2, v2, y, z, beta)
    b_ref = a + jnp.outer(u1, v1) + jnp.outer(u2, v2)
    x_ref = beta * (b_ref.T @ y) + z
    assert_close(b, b_ref, tol=1e-4)
    assert_close(x, x_ref, tol=1e-4)


def test_fused_kernel_is_single_pallas_call():
    """The BiCGK fusion must be ONE kernel: its jaxpr contains exactly
    one pallas_call — the artifact boundary the Rust runtime sees."""
    a = jnp.zeros((64, 64), jnp.float32)
    p = jnp.zeros((64,), jnp.float32)
    r = jnp.zeros((64,), jnp.float32)
    jaxpr = jax.make_jaxpr(K.bicgk_fused)(a, p, r)
    calls = [e for e in jaxpr.eqns if "pallas" in e.primitive.name]
    assert len(calls) == 1, jaxpr
