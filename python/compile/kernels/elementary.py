"""L1: Pallas kernels for the BLAS elementary functions and their fusions.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
kernels tile matrices into 32x32 shared-memory tiles per threadblock.  On
the TPU-shaped Pallas model the analogue is a *row strip per grid step*
held in VMEM via BlockSpec, with cross-step accumulation for the
transposed products (the sequential-grid semantics Pallas guarantees on
TPU and in interpret mode).  ROW_TILE=32 keeps the paper's granularity;
VMEM per step is ROW_TILE*N*4 B, far below a real TPU's ~16 MiB VMEM for
every size in the catalog (the 48 KiB shared-memory budget of the GTX 480
is what forced the 32x32 tiles; VMEM relaxes it to strips).

Every kernel is built with interpret=True: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md).  Correctness is asserted against the
pure-jnp oracles in ref.py by the pytest/hypothesis suite.

Each fused kernel corresponds to one generated kernel of the Rust fusion
compiler; each unfused/elementary kernel is one CUBLAS-baseline kernel
launch.  One pallas_call == one CUDA kernel == one AOT HLO executable.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 32  # the paper's element granularity (sub-vector / tile edge)

# Vector ops process this many elements per grid step (the analogue of
# instances-per-block packing for BLAS-1 kernels).
VEC_BLOCK = 1024


def _vec_grid(n, block=VEC_BLOCK):
    assert n % ROW_TILE == 0, f"n={n} not padded to {ROW_TILE}"
    # largest power-of-two multiple of ROW_TILE that divides n, capped
    b = ROW_TILE
    while b * 2 <= min(block, n) and n % (b * 2) == 0:
        b *= 2
    return n // b, b


# --------------------------------------------------------------------------
# BLAS-1 elementary kernels (depth 1)
# --------------------------------------------------------------------------


def scopy(x):
    """y <- x."""
    g, b = _vec_grid(x.shape[0])

    def kernel(x_ref, y_ref):
        y_ref[...] = x_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


def sscal(x, alpha):
    """y <- alpha * x  (out-of-place SSCAL)."""
    g, b = _vec_grid(x.shape[0])

    def kernel(x_ref, y_ref, *, alpha):
        y_ref[...] = alpha * x_ref[...]

    return pl.pallas_call(
        functools.partial(kernel, alpha=alpha),
        grid=(g,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


def saxpy(x, y, alpha):
    """z <- alpha*x + y (out-of-place SAXPY)."""
    g, b = _vec_grid(x.shape[0])

    def kernel(x_ref, y_ref, z_ref, *, alpha):
        z_ref[...] = alpha * x_ref[...] + y_ref[...]

    return pl.pallas_call(
        functools.partial(kernel, alpha=alpha),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, y)


def waxpby(x, y, alpha, beta):
    """w <- alpha*x + beta*y (WAXPBY; with alpha=1, beta=-a it is
    AXPYDOT's first stage)."""
    g, b = _vec_grid(x.shape[0])

    def kernel(x_ref, y_ref, w_ref, *, alpha, beta):
        w_ref[...] = alpha * x_ref[...] + beta * y_ref[...]

    return pl.pallas_call(
        functools.partial(kernel, alpha=alpha, beta=beta),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, y)


def vadd3(w, y, z):
    """x <- w + y + z (the paper's VADD) as ONE fused kernel."""
    g, b = _vec_grid(w.shape[0])

    def kernel(w_ref, y_ref, z_ref, x_ref):
        x_ref[...] = w_ref[...] + y_ref[...] + z_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))] * 3,
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        interpret=True,
    )(w, y, z)


def sdot(x, y):
    """r <- x^T y. Partial sums accumulate across sequential grid steps
    (the paper's per-block partial reduction + atomicAdd, §3.2.2)."""
    g, b = _vec_grid(x.shape[0])

    def kernel(x_ref, y_ref, r_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            r_ref[...] = jnp.zeros_like(r_ref)

        r_ref[...] += jnp.sum(x_ref[...] * y_ref[...])[None]

    return pl.pallas_call(
        kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=True,
    )(x, y)


def axpydot_fused(w, v, u, alpha):
    """AXPYDOT fused: z = w - alpha*v and r = z^T u in ONE kernel —
    z stays on-chip (registers in the paper's generated code)."""
    g, b = _vec_grid(w.shape[0])

    def kernel(w_ref, v_ref, u_ref, z_ref, r_ref, *, alpha):
        i = pl.program_id(0)
        z = w_ref[...] - alpha * v_ref[...]
        z_ref[...] = z  # z is a program output -> still stored once

        @pl.when(i == 0)
        def _init():
            r_ref[...] = jnp.zeros_like(r_ref)

        r_ref[...] += jnp.sum(z * u_ref[...])[None]

    return pl.pallas_call(
        functools.partial(kernel, alpha=alpha),
        grid=(g,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))] * 3,
        out_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct((1,), w.dtype),
        ],
        interpret=True,
    )(w, v, u)


# --------------------------------------------------------------------------
# BLAS-2 elementary kernels (depth 2: row-strip grid over the matrix)
# --------------------------------------------------------------------------


def _strip_grid(m):
    assert m % ROW_TILE == 0, f"m={m} not padded to {ROW_TILE}"
    return m // ROW_TILE


def mcopy(a):
    """B <- A tile-wise copy (CUBLAS-baseline helper)."""
    m, n = a.shape

    def kernel(a_ref, b_ref):
        b_ref[...] = a_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(_strip_grid(m),),
        in_specs=[pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a)


def madd(a, b):
    """C <- A + B tile-wise (MADD)."""
    m, n = a.shape

    def kernel(a_ref, b_ref, c_ref):
        c_ref[...] = a_ref[...] + b_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(_strip_grid(m),),
        in_specs=[pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0))] * 2,
        out_specs=pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


def sger(a, u, v, alpha):
    """B <- A + alpha * u v^T (rank-1 update)."""
    m, n = a.shape

    def kernel(a_ref, u_ref, v_ref, b_ref, *, alpha):
        b_ref[...] = a_ref[...] + alpha * jnp.outer(u_ref[...], v_ref[...])

    return pl.pallas_call(
        functools.partial(kernel, alpha=alpha),
        grid=(_strip_grid(m),),
        in_specs=[
            pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
            pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, u, v)


def sger2(a, u1, v1, u2, v2):
    """B <- A + u1 v1^T + u2 v2^T (GEMVER stage 1, one kernel — the tile
    is updated twice while resident on-chip)."""
    m, n = a.shape

    def kernel(a_ref, u1_ref, v1_ref, u2_ref, v2_ref, b_ref):
        b_ref[...] = (
            a_ref[...]
            + jnp.outer(u1_ref[...], v1_ref[...])
            + jnp.outer(u2_ref[...], v2_ref[...])
        )

    return pl.pallas_call(
        kernel,
        grid=(_strip_grid(m),),
        in_specs=[
            pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
            pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, u1, v1, u2, v2)


def sgemv(a, x, alpha):
    """y <- alpha * A x (row-strip per grid step, like the paper's gemv
    with serial iterations over column tiles folded into the strip)."""
    m, n = a.shape

    def kernel(a_ref, x_ref, y_ref, *, alpha):
        y_ref[...] = alpha * (a_ref[...] @ x_ref[...])

    return pl.pallas_call(
        functools.partial(kernel, alpha=alpha),
        grid=(_strip_grid(m),),
        in_specs=[
            pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=True,
    )(a, x)


def sgemvpy(a, x, y, alpha, beta):
    """z <- alpha*A x + beta*y (CUBLAS SGEMV semantics, out-of-place)."""
    m, n = a.shape

    def kernel(a_ref, x_ref, y_ref, z_ref, *, alpha, beta):
        z_ref[...] = alpha * (a_ref[...] @ x_ref[...]) + beta * y_ref[...]

    return pl.pallas_call(
        functools.partial(kernel, alpha=alpha, beta=beta),
        grid=(_strip_grid(m),),
        in_specs=[
            pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=True,
    )(a, x, y)


def sgemtv(a, r, alpha):
    """s <- alpha * A^T r. The output is revisited every grid step —
    cross-step accumulation is the paper's partial reduction with the
    final combine done by the sequential grid (global atomicAdd on the
    GTX 480, §3.2.2 option iii)."""
    m, n = a.shape

    def kernel(a_ref, r_ref, s_ref, *, alpha):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            s_ref[...] = jnp.zeros_like(s_ref)

        s_ref[...] += alpha * (a_ref[...].T @ r_ref[...])

    return pl.pallas_call(
        functools.partial(kernel, alpha=alpha),
        grid=(_strip_grid(m),),
        in_specs=[
            pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
            pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, r)


def sgemtvpz(a, y, z, beta):
    """x <- beta * A^T y + z (SGEMVT / GEMVER middle stage,
    out-of-place — no CUBLAS copy kernel needed)."""
    m, n = a.shape

    def kernel(a_ref, y_ref, z_ref, x_ref, *, beta):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            x_ref[...] = z_ref[...]

        x_ref[...] += beta * (a_ref[...].T @ y_ref[...])

    return pl.pallas_call(
        functools.partial(kernel, beta=beta),
        grid=(_strip_grid(m),),
        in_specs=[
            pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
            pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, y, z)


# --------------------------------------------------------------------------
# Fused BLAS-2 kernels (the compiler's multi-function kernels)
# --------------------------------------------------------------------------


def bicgk_fused(a, p, r):
    """BiCGK fused kernel (paper Algorithm 3 / Listing 3): one pass over
    A computing q = A p and s = A^T r simultaneously. A is read ONCE —
    the fusion's entire advantage."""
    m, n = a.shape

    def kernel(a_ref, p_ref, r_ref, q_ref, s_ref):
        i = pl.program_id(0)
        a_strip = a_ref[...]
        q_ref[...] = a_strip @ p_ref[...]

        @pl.when(i == 0)
        def _init():
            s_ref[...] = jnp.zeros_like(s_ref)

        s_ref[...] += a_strip.T @ r_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(_strip_grid(m),),
        in_specs=[
            pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), a.dtype),
            jax.ShapeDtypeStruct((n,), a.dtype),
        ],
        interpret=True,
    )(a, p, r)


def gemver_fused_k1(a, u1, v1, u2, v2, y, z, beta):
    """GEMVER fused kernel 1: B = A + u1 v1^T + u2 v2^T and
    x = beta*B^T y + z in ONE pass — B is built and consumed on-chip,
    stored once (it is a program output). The second GEMVER kernel
    (w = alpha*B x) needs the complete x and stays separate (global
    barrier), exactly as the fusion compiler decides."""
    m, n = a.shape

    def kernel(a_ref, u1_ref, v1_ref, u2_ref, v2_ref, y_ref, z_ref, b_ref, x_ref, *, beta):
        i = pl.program_id(0)
        b = (
            a_ref[...]
            + jnp.outer(u1_ref[...], v1_ref[...])
            + jnp.outer(u2_ref[...], v2_ref[...])
        )
        b_ref[...] = b

        @pl.when(i == 0)
        def _init():
            x_ref[...] = z_ref[...]

        x_ref[...] += beta * (b.T @ y_ref[...])

    return pl.pallas_call(
        functools.partial(kernel, beta=beta),
        grid=(_strip_grid(m),),
        in_specs=[
            pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
            pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((ROW_TILE,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((ROW_TILE, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), a.dtype),
            jax.ShapeDtypeStruct((n,), a.dtype),
        ],
        interpret=True,
    )(a, u1, v1, u2, v2, y, z)
