"""Pure-jnp oracles for every sequence — the correctness ground truth the
Pallas kernels (and therefore the AOT artifacts and the Rust runtime) are
validated against."""

import jax.numpy as jnp


def axpydot(w, v, u, alpha):
    z = w - alpha * v
    r = z @ u
    return z, r


def atax(a, x):
    return a.T @ (a @ x)


def bicgk(a, p, r):
    return a @ p, a.T @ r


def sgemv(a, x, y, alpha, beta):
    return alpha * (a @ x) + beta * y


def sgemvt(a, y, z, alpha, beta):
    x = beta * (a.T @ y) + z
    w = alpha * (a @ x)
    return x, w


def sscal(x, alpha):
    return alpha * x


def gemver(a, u1, v1, u2, v2, y, z, alpha, beta):
    b = a + jnp.outer(u1, v1) + jnp.outer(u2, v2)
    x = beta * (b.T @ y) + z
    w = alpha * (b @ x)
    return b, x, w


def gesummv(a, b, x, alpha, beta):
    return alpha * (a @ x) + beta * (b @ x)


def madd(a, b):
    return a + b


def vadd(w, y, z):
    return w + y + z


def waxpby(x, y, alpha, beta):
    return alpha * x + beta * y
