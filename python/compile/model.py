"""L2: the BLAS sequences as staged JAX computations.

A *stage* is one kernel launch: a jittable function closed over its
scalar coefficients, with named tensor inputs and outputs.  The fused
variant of a sequence uses the kernels the Rust fusion compiler selects
(one pallas_call per generated kernel); the cublas variant reproduces the
CUBLAS call decomposition, including the copy kernels its in-place API
forces (S tag in the paper's Table 1).

Scalar coefficients match rust/src/sequences/mod.rs exactly — the Rust
test-suite cross-checks runtime outputs against the same oracles.

`catalog(...)` enumerates every (sequence, variant, stage, size) —
the unit `aot.py` lowers to one HLO artifact.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import elementary as K

F32 = jnp.float32

# Scalar conventions (keep in sync with rust/src/sequences/mod.rs)
AXPYDOT_ALPHA = 2.5
SGEMV_ALPHA, SGEMV_BETA = 2.0, 0.5
SGEMVT_ALPHA, SGEMVT_BETA = 2.0, 0.5
SSCAL_ALPHA = 2.0
GEMVER_ALPHA, GEMVER_BETA = 2.0, 0.5
GESUMMV_ALPHA, GESUMMV_BETA = 2.0, 0.5
WAXPBY_ALPHA, WAXPBY_BETA = 2.0, 0.5


def _stage(fn, ins, outs):
    """ins/outs: list of (name, shape_key) where shape_key in
    {'mat', 'vm', 'vn', 'scalar'}."""
    return {"fn": fn, "ins": ins, "outs": outs}


def _shape(key, m, n):
    return {
        "mat": (m, n),
        "vm": (m,),
        "vn": (n,),
        "scalar": (1,),
    }[key]


# --------------------------------------------------------------------------
# Sequence definitions: name -> (is_blas2, {variant: [stages]})
# --------------------------------------------------------------------------


def _sequences():
    return {
        "axpydot": (
            False,
            {
                "fused": [
                    _stage(
                        functools.partial(K.axpydot_fused, alpha=AXPYDOT_ALPHA),
                        [("w", "vn"), ("v", "vn"), ("u", "vn")],
                        [("z", "vn"), ("r", "scalar")],
                    )
                ],
                "cublas": [
                    _stage(K.scopy, [("w", "vn")], [("zc", "vn")]),
                    _stage(
                        functools.partial(K.saxpy, alpha=-AXPYDOT_ALPHA),
                        [("v", "vn"), ("zc", "vn")],
                        [("z", "vn")],
                    ),
                    _stage(K.sdot, [("z", "vn"), ("u", "vn")], [("r", "scalar")]),
                ],
            },
        ),
        "atax": (
            True,
            {
                # no fusion possible (global barrier at t) — both variants
                # run the same two kernels
                "fused": [
                    _stage(
                        functools.partial(K.sgemv, alpha=1.0),
                        [("A", "mat"), ("x", "vn")],
                        [("t", "vm")],
                    ),
                    _stage(
                        functools.partial(K.sgemtv, alpha=1.0),
                        [("A", "mat"), ("t", "vm")],
                        [("y", "vn")],
                    ),
                ],
                "cublas": [
                    _stage(
                        functools.partial(K.sgemv, alpha=1.0),
                        [("A", "mat"), ("x", "vn")],
                        [("t", "vm")],
                    ),
                    _stage(
                        functools.partial(K.sgemtv, alpha=1.0),
                        [("A", "mat"), ("t", "vm")],
                        [("y", "vn")],
                    ),
                ],
            },
        ),
        "bicgk": (
            True,
            {
                "fused": [
                    _stage(
                        K.bicgk_fused,
                        [("A", "mat"), ("p", "vn"), ("r", "vm")],
                        [("q", "vm"), ("s", "vn")],
                    )
                ],
                "cublas": [
                    _stage(
                        functools.partial(K.sgemv, alpha=1.0),
                        [("A", "mat"), ("p", "vn")],
                        [("q", "vm")],
                    ),
                    _stage(
                        functools.partial(K.sgemtv, alpha=1.0),
                        [("A", "mat"), ("r", "vm")],
                        [("s", "vn")],
                    ),
                ],
            },
        ),
        "sgemv": (
            True,
            {
                "fused": [
                    _stage(
                        functools.partial(K.sgemvpy, alpha=SGEMV_ALPHA, beta=SGEMV_BETA),
                        [("A", "mat"), ("x", "vn"), ("y", "vm")],
                        [("z", "vm")],
                    )
                ],
                "cublas": [
                    _stage(
                        functools.partial(K.sgemvpy, alpha=SGEMV_ALPHA, beta=SGEMV_BETA),
                        [("A", "mat"), ("x", "vn"), ("y", "vm")],
                        [("z", "vm")],
                    )
                ],
            },
        ),
        "sgemvt": (
            True,
            {
                "fused": [
                    _stage(
                        functools.partial(K.sgemtvpz, beta=SGEMVT_BETA),
                        [("A", "mat"), ("y", "vm"), ("z", "vn")],
                        [("x", "vn")],
                    ),
                    _stage(
                        functools.partial(K.sgemv, alpha=SGEMVT_ALPHA),
                        [("A", "mat"), ("x", "vn")],
                        [("w", "vm")],
                    ),
                ],
                "cublas": [
                    _stage(K.scopy, [("z", "vn")], [("xc", "vn")]),
                    _stage(
                        functools.partial(K.sgemtvpz, beta=SGEMVT_BETA),
                        [("A", "mat"), ("y", "vm"), ("xc", "vn")],
                        [("x", "vn")],
                    ),
                    _stage(
                        functools.partial(K.sgemv, alpha=SGEMVT_ALPHA),
                        [("A", "mat"), ("x", "vn")],
                        [("w", "vm")],
                    ),
                ],
            },
        ),
        "sscal": (
            False,
            {
                "fused": [
                    _stage(
                        functools.partial(K.sscal, alpha=SSCAL_ALPHA),
                        [("x", "vn")],
                        [("y", "vn")],
                    )
                ],
                "cublas": [
                    _stage(
                        functools.partial(K.sscal, alpha=SSCAL_ALPHA),
                        [("x", "vn")],
                        [("y", "vn")],
                    )
                ],
            },
        ),
        "gemver": (
            True,
            {
                "fused": [
                    _stage(
                        functools.partial(K.gemver_fused_k1, beta=GEMVER_BETA),
                        [
                            ("A", "mat"),
                            ("u1", "vm"),
                            ("v1", "vn"),
                            ("u2", "vm"),
                            ("v2", "vn"),
                            ("y", "vm"),
                            ("z", "vn"),
                        ],
                        [("B", "mat"), ("x", "vn")],
                    ),
                    _stage(
                        functools.partial(K.sgemv, alpha=GEMVER_ALPHA),
                        [("B", "mat"), ("x", "vn")],
                        [("w", "vm")],
                    ),
                ],
                "cublas": [
                    _stage(K.mcopy, [("A", "mat")], [("B0", "mat")]),
                    _stage(
                        functools.partial(K.sger, alpha=1.0),
                        [("B0", "mat"), ("u1", "vm"), ("v1", "vn")],
                        [("B1", "mat")],
                    ),
                    _stage(
                        functools.partial(K.sger, alpha=1.0),
                        [("B1", "mat"), ("u2", "vm"), ("v2", "vn")],
                        [("B", "mat")],
                    ),
                    _stage(K.scopy, [("z", "vn")], [("xc", "vn")]),
                    _stage(
                        functools.partial(K.sgemtvpz, beta=GEMVER_BETA),
                        [("B", "mat"), ("y", "vm"), ("xc", "vn")],
                        [("x", "vn")],
                    ),
                    _stage(
                        functools.partial(K.sgemv, alpha=GEMVER_ALPHA),
                        [("B", "mat"), ("x", "vn")],
                        [("w", "vm")],
                    ),
                ],
            },
        ),
        "gesummv": (
            True,
            {
                "fused": [
                    _stage(
                        functools.partial(K.sgemv, alpha=GESUMMV_ALPHA),
                        [("A", "mat"), ("x", "vn")],
                        [("t", "vm")],
                    ),
                    _stage(
                        functools.partial(K.sgemvpy, alpha=GESUMMV_BETA, beta=1.0),
                        [("B", "mat"), ("x", "vn"), ("t", "vm")],
                        [("y", "vm")],
                    ),
                ],
                "cublas": [
                    _stage(
                        functools.partial(K.sgemv, alpha=GESUMMV_ALPHA),
                        [("A", "mat"), ("x", "vn")],
                        [("t", "vm")],
                    ),
                    _stage(
                        functools.partial(K.sgemvpy, alpha=GESUMMV_BETA, beta=1.0),
                        [("B", "mat"), ("x", "vn"), ("t", "vm")],
                        [("y", "vm")],
                    ),
                ],
            },
        ),
        "madd": (
            True,
            {
                "fused": [
                    _stage(K.madd, [("A", "mat"), ("B", "mat")], [("C", "mat")])
                ],
                "cublas": [
                    _stage(K.mcopy, [("A", "mat")], [("Cc", "mat")]),
                    _stage(K.madd, [("Cc", "mat"), ("B", "mat")], [("C", "mat")]),
                ],
            },
        ),
        "vadd": (
            False,
            {
                "fused": [
                    _stage(
                        K.vadd3,
                        [("w", "vn"), ("y", "vn"), ("z", "vn")],
                        [("x", "vn")],
                    )
                ],
                "cublas": [
                    _stage(K.scopy, [("w", "vn")], [("xc", "vn")]),
                    _stage(
                        functools.partial(K.saxpy, alpha=1.0),
                        [("y", "vn"), ("xc", "vn")],
                        [("x1", "vn")],
                    ),
                    _stage(
                        functools.partial(K.saxpy, alpha=1.0),
                        [("z", "vn"), ("x1", "vn")],
                        [("x", "vn")],
                    ),
                ],
            },
        ),
        "waxpby": (
            False,
            {
                "fused": [
                    _stage(
                        functools.partial(K.waxpby, alpha=WAXPBY_ALPHA, beta=WAXPBY_BETA),
                        [("x", "vn"), ("y", "vn")],
                        [("w", "vn")],
                    )
                ],
                "cublas": [
                    _stage(K.scopy, [("y", "vn")], [("wc", "vn")]),
                    _stage(
                        functools.partial(K.sscal, alpha=WAXPBY_BETA),
                        [("wc", "vn")],
                        [("ws", "vn")],
                    ),
                    _stage(
                        functools.partial(K.saxpy, alpha=WAXPBY_ALPHA),
                        [("x", "vn"), ("ws", "vn")],
                        [("w", "vn")],
                    ),
                ],
            },
        ),
    }


# Catalog size points (BLAS-2 square; BLAS-1 vector lengths).
BLAS2_SIZES = [256, 512, 1024]
BLAS1_SIZES = [65536, 1048576]


def catalog(blas2_sizes=None, blas1_sizes=None):
    """Enumerate every artifact: one (sequence, variant, stage, size)."""
    blas2_sizes = blas2_sizes or BLAS2_SIZES
    blas1_sizes = blas1_sizes or BLAS1_SIZES
    out = []
    for seq, (is_blas2, variants) in _sequences().items():
        sizes = blas2_sizes if is_blas2 else blas1_sizes
        for size in sizes:
            m, n = (size, size) if is_blas2 else (32, size)
            for variant, stages in variants.items():
                for si, st in enumerate(stages):
                    key = f"{seq}.{variant}.m{m}n{n}.s{si}"
                    out.append(
                        {
                            "key": key,
                            "seq": seq,
                            "variant": variant,
                            "stage": si,
                            "m": m,
                            "n": n,
                            "fn": st["fn"],
                            "ins": [(nm, _shape(k, m, n)) for nm, k in st["ins"]],
                            "outs": [(nm, _shape(k, m, n)) for nm, k in st["outs"]],
                        }
                    )
    return out


def run_variant(seq, variant, inputs, m, n):
    """Execute all stages of a variant eagerly (test path): `inputs` is a
    dict name -> array; returns the env including every stage output."""
    _, variants = _sequences()[seq]
    env = dict(inputs)
    for st in variants[variant]:
        args = [env[nm] for nm, _ in st["ins"]]
        res = st["fn"](*args)
        if not isinstance(res, (tuple, list)):
            res = (res,)
        for (nm, _), val in zip(st["outs"], res):
            env[nm] = val
    return env


def sequence_names():
    return list(_sequences().keys())


def variant_outputs(seq, variant):
    """Final output names of a variant (the sequence's results)."""
    _, variants = _sequences()[seq]
    produced = []
    consumed = set()
    for st in variants[variant]:
        for nm, _ in st["ins"]:
            consumed.add(nm)
        for nm, _ in st["outs"]:
            produced.append(nm)
    return produced
