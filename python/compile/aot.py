"""AOT lowering: every catalog entry -> HLO text + manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Run once by `make artifacts`; Python never runs on the request path.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(entry) -> str:
    specs = [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in entry["ins"]]
    lowered = jax.jit(entry["fn"]).lower(*specs)
    return to_hlo_text(lowered)


def spec_str(name, shape):
    dims = ",".join(str(d) for d in shape)
    return f"{name}:f32[{dims}]"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--blas2-sizes", default="")
    ap.add_argument("--blas1-sizes", default="")
    ap.add_argument("--only", default="", help="comma-separated sequence filter")
    args = ap.parse_args()

    blas2 = [int(s) for s in args.blas2_sizes.split(",") if s] or None
    blas1 = [int(s) for s in args.blas1_sizes.split(",") if s] or None
    only = {s for s in args.only.split(",") if s}

    os.makedirs(args.out, exist_ok=True)
    entries = model.catalog(blas2, blas1)
    if only:
        entries = [e for e in entries if e["seq"] in only]

    manifest_lines = ["# fusebla artifact manifest v1"]
    for e in entries:
        hlo = lower_entry(e)
        fname = f"{e['key']}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(hlo)
        manifest_lines.append(f"artifact {e['key']}")
        manifest_lines.append(f"  file {fname}")
        manifest_lines.append(f"  seq {e['seq']}")
        manifest_lines.append(f"  variant {e['variant']}")
        manifest_lines.append(f"  stage {e['stage']}")
        for nm, shape in e["ins"]:
            manifest_lines.append(f"  in {spec_str(nm, shape)}")
        for nm, shape in e["outs"]:
            manifest_lines.append(f"  out {spec_str(nm, shape)}")
        manifest_lines.append(f"  m {e['m']}")
        manifest_lines.append(f"  n {e['n']}")
        manifest_lines.append("end")
        print(f"lowered {e['key']} ({len(hlo)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(entries)} artifacts to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
