//! Offline in-tree subset of the `anyhow` API (crates.io is unreachable
//! in this build environment).
//!
//! Implements exactly the surface the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`] and [`bail!`] macros, the [`Context`]
//! extension trait, and typed-root-cause recovery via
//! [`Error::new`]/[`Error::downcast_ref`]/[`Error::is`]. Like the real
//! crate, `Error` deliberately does *not* implement `std::error::Error`,
//! which is what makes the blanket `From<E: std::error::Error>`
//! conversion coherent.
//!
//! `Display` shows the outermost message; the alternate form (`{:#}`)
//! joins the whole context chain with `": "`.

use std::any::Any;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a root cause plus the contexts wrapped around it.
/// When built from a concrete `std::error::Error` value (via
/// [`Error::new`], the blanket `From`, or `?`), the original value is
/// retained and recoverable with [`Error::downcast_ref`] — context
/// layers never hide it.
pub struct Error {
    /// Context chain, outermost first (index 0 is what `Display` shows).
    chain: Vec<String>,
    /// The concrete root-cause value, when the error was built from one
    /// (string-built errors carry no payload).
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Build an error from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
            payload: None,
        }
    }

    /// Build an error from a concrete error value, retaining it for
    /// [`Error::downcast_ref`] (mirrors `anyhow::Error::new`).
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error::from(error)
    }

    /// Wrap the error in one more layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The retained root-cause value, if this error was built from a
    /// concrete `E` (mirrors `anyhow::Error::downcast_ref`). Context
    /// layers added later do not affect the result.
    pub fn downcast_ref<E>(&self) -> Option<&E>
    where
        E: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        self.payload.as_ref()?.downcast_ref::<E>()
    }

    /// Whether the retained root cause is an `E` (mirrors
    /// `anyhow::Error::is`).
    pub fn is<E>(&self) -> bool
    where
        E: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        self.downcast_ref::<E>().is_some()
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        for cause in self.chain.iter().skip(1) {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error {
            chain,
            payload: Some(Box::new(e)),
        }
    }
}

/// Attach context to fallible results (mirrors `anyhow::Context`).
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_shows_outermost_and_alternate_joins() {
        let e: Error = Error::from(io_err()).context("loading manifest");
        assert_eq!(e.to_string(), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing thing");
        assert_eq!(e.root_cause(), "missing thing");
    }

    #[test]
    fn context_trait_wraps_results() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "step 3");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn macros_build_errors() {
        fn fails() -> Result<()> {
            bail!("bad value {}", 7);
        }
        assert_eq!(fails().unwrap_err().to_string(), "bad value 7");
        assert_eq!(anyhow!("plain").to_string(), "plain");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("missing thing"));
    }

    #[test]
    fn downcast_recovers_concrete_root_cause() {
        let e = Error::new(io_err()).context("submitting request");
        // Context layers do not hide the retained payload.
        let io = e.downcast_ref::<std::io::Error>().expect("payload survives context");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        assert!(e.is::<std::io::Error>());
        assert!(!e.is::<std::fmt::Error>());
        // String-built errors carry no payload.
        assert!(anyhow!("plain").downcast_ref::<std::io::Error>().is_none());
    }
}
