//! Compile-only offline stub of the `xla` crate's PJRT surface.
//!
//! The real crate links the XLA C++ runtime, which is unreachable in
//! this build environment. This stub keeps `fusebla::runtime` compiling
//! with the exact call shapes the real bindings expose; every execution
//! entry point returns a clear "backend unavailable" error instead of
//! running. All tests that need real artifact execution gate on the
//! artifact catalog existing, so the stub never executes in CI.
//!
//! Manifest- and file-level failure modes are kept real: loading a
//! missing or non-HLO artifact file fails with the offending path in the
//! message (the failure-injection suite relies on that).

use std::fmt;
use std::marker::PhantomData;

/// Error type of the stubbed bindings (a plain message).
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT backend unavailable in this offline build (stub xla crate)"
    ))
}

/// PJRT client handle. `!Send`, like the real bindings — the runtime
/// pins it to one thread (the coordinator's worker).
pub struct PjRtClient {
    _not_send: PhantomData<*const ()>,
}

impl PjRtClient {
    /// Create the CPU client. Succeeds so manifest-level tooling (listing,
    /// failure injection) works without the real backend.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient {
            _not_send: PhantomData,
        })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

/// Parsed HLO module text.
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    /// Read and minimally validate an HLO text file. Missing files and
    /// non-HLO content both fail with the path in the message.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path).map_err(|e| Error(format!("{path}: {e}")))?;
        if !text.contains("HloModule") {
            return Err(Error(format!("{path}: not an HLO module text")));
        }
        Ok(HloModuleProto { _text: text })
    }
}

/// A computation wrapping an HLO module.
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            _proto: proto.clone(),
        }
    }
}

/// A compiled executable. Never constructible through the stub (compile
/// always fails), so its methods are unreachable in practice.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

/// A device buffer produced by execution.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// A host-side tensor literal (f32 only — all the catalog uses).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    /// Reinterpret the literal at a new shape of equal element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape to {:?} needs {} elements, literal has {}",
                dims,
                count,
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Destructure a tuple literal. Stub literals are never tuples.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("to_tuple"))
    }

    /// Copy the payload out. Unreachable without a real backend.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_file_mentions_path() {
        let err = HloModuleProto::from_text_file("/nonexistent/ghost.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("ghost.hlo.txt"), "{err}");
    }

    #[test]
    fn literal_reshape_checks_count() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(l.dims(), &[4]);
    }

    #[test]
    fn execution_paths_fail_clearly() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-stub");
        let l = Literal::vec1(&[0.0]);
        assert!(l.clone().to_tuple().is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}
