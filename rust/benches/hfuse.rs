//! Horizontal-fusion study — when does combining a drained turn's
//! batches into one block-range-dispatched launch beat back-to-back
//! dispatch, and does the serve path actually take the win?
//!
//! Two parts, both offline-safe:
//!
//! * **Forecast crossover** (pure planning): price candidate turn
//!   pairings with `planner::forecast_hfuse` across mixed-traffic
//!   scenarios — launch-bound BLAS-1 groups at small sizes, where the
//!   elided launch overhead dominates, through large and
//!   geometry-mismatched pairings, where the occupancy/cache
//!   interference penalty eats the savings. The crossover is the
//!   point of the cost model: fusing must win where launches dominate
//!   and stop winning where they do not.
//! * **Served A/B** (real execution): the same mixed workload served
//!   with horizontal fusion on vs off, over registered pipelines —
//!   interpreter-backed resolved plans, so fused turns execute for
//!   real on the stub catalog and the engine's `hfused_batches` /
//!   `hfuse_launch_savings` counters measure the path actually taken.
//!
//! Results merge into `BENCH_hfuse.json`. `cargo bench --bench hfuse`

use fusebla::bench_support::report::update_bench_json;
use fusebla::bench_support::stub_catalog;
use fusebla::coordinator::Context;
use fusebla::fusion::ImplAxes;
use fusebla::ir::elem::ProblemSize;
use fusebla::ir::plan::SeqPlan;
use fusebla::planner::{self, PlannerConfig};
use fusebla::sequences;
use fusebla::util::Json;
use fusebla::{Engine, EngineConfig, SubmitRequest};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

const BENCH_HFUSE_JSON: &str = "BENCH_hfuse.json";
/// Scheduling turns per served configuration.
const ROUNDS: usize = 20;

/// The planner's best plan for a built-in sequence at a size — the
/// same plan the serve path prices fusion with.
fn planned(seq: &str, p: ProblemSize, ctx: &Context) -> SeqPlan {
    let s = sequences::by_name(seq).expect("built-in sequence");
    let (prog, graph, _space) = s.space(&ctx.lib, &ImplAxes::minimal());
    planner::plan(
        &prog,
        &ctx.lib,
        &graph,
        &ctx.db,
        &ImplAxes::minimal(),
        p,
        &PlannerConfig::default(),
    )
    .best
}

/// Price one scenario's turn: fused vs back-to-back, as the scheduler
/// would see it.
fn price(
    name: &str,
    members: &[(&SeqPlan, ProblemSize)],
    ctx: &Context,
) -> (String, Json, bool) {
    let f = planner::forecast_hfuse(members, &ctx.db, &ctx.dev);
    let wins = f.wins();
    println!(
        "{name:24} fused {:9.3} µs  back-to-back {:9.3} µs  ({} launch(es) saved) — {}",
        f.fused * 1e6,
        f.back_to_back * 1e6,
        f.launches_saved,
        if wins { "FUSE" } else { "keep separate" }
    );
    let section = Json::Obj(vec![
        ("fused_us".into(), Json::num(f.fused * 1e6)),
        ("back_to_back_us".into(), Json::num(f.back_to_back * 1e6)),
        ("launches_saved".into(), Json::num(f.launches_saved as f64)),
        ("wins".into(), Json::Bool(wins)),
    ]);
    (name.to_string(), section, wins)
}

fn main() {
    let report = Path::new(BENCH_HFUSE_JSON);
    let ctx = Context::new();

    // ---- Forecast crossover over mixed-traffic turn shapes ----------
    let small = ProblemSize::new(32, 65536);
    let large = ProblemSize::new(32, 1 << 24);
    let waxpby_s = planned("waxpby", small, &ctx);
    let vadd_s = planned("vadd", small, &ctx);
    let sscal_s = planned("sscal", small, &ctx);
    let waxpby_l = planned("waxpby", large, &ctx);
    let vadd_l = planned("vadd", large, &ctx);
    let sgemv = planned("sgemv", ProblemSize::square(4096), &ctx);
    println!("forecast crossover (gtx480 model):");
    let scenarios: Vec<(&str, Vec<(&SeqPlan, ProblemSize)>)> = vec![
        (
            "waxpby_pair_small",
            vec![(&waxpby_s, small), (&waxpby_s, small)],
        ),
        (
            "hetero_blas1_small",
            vec![(&waxpby_s, small), (&vadd_s, small), (&sscal_s, small)],
        ),
        (
            "blas1_pair_large",
            vec![(&waxpby_l, large), (&vadd_l, large)],
        ),
        (
            "blas2_blas1_mismatch",
            vec![(&sgemv, ProblemSize::square(4096)), (&sscal_s, small)],
        ),
    ];
    let mut any_win = false;
    let mut forecast = Vec::new();
    for (name, members) in &scenarios {
        let (key, section, wins) = price(name, members, &ctx);
        any_win |= wins;
        forecast.push((key, section));
    }
    assert!(
        any_win,
        "at least one mixed-traffic scenario must forecast a fusion win"
    );
    update_bench_json(report, "forecast", Json::Obj(forecast)).expect("write BENCH_hfuse.json");

    // ---- Served A/B: the same mixed workload, fusion on vs off ------
    let dir = stub_catalog("bench_hfuse", &["waxpby"]);
    let mut served = Vec::new();
    let mut fused_batches_on = 0.0;
    for hfuse in [true, false] {
        let cfg = EngineConfig {
            batch_window: Duration::from_millis(10),
            max_batch: 256,
            hfuse,
            ..EngineConfig::default()
        };
        let engine =
            Engine::with_config(Arc::new(Context::new()), &dir, cfg).expect("stub engine");
        let client = engine.client();
        client
            .register_pipeline("amx", fusebla::pipelines::examples::ADD_MUL_EXP)
            .expect("register amx");
        client
            .register_pipeline("q8", fusebla::pipelines::examples::QUANTIZE_INT8)
            .expect("register q8");
        // Mixed heterogeneous burst per turn: two pipelines at three
        // sizes — six distinct batch keys drained into one turn.
        let burst: Vec<(&str, usize)> = vec![
            ("amx", 256),
            ("q8", 256),
            ("amx", 1024),
            ("q8", 1024),
            ("amx", 4096),
            ("q8", 4096),
        ];
        let t0 = Instant::now();
        let mut done = 0u64;
        for round in 0..ROUNDS {
            let tickets: Vec<_> = burst
                .iter()
                .map(|&(seq, n)| {
                    client
                        .submit(SubmitRequest::new(seq, 32, n).synth(round as u64))
                        .expect("submit")
                })
                .collect();
            for t in tickets {
                t.wait().expect("registered pipelines execute on the stub");
                done += 1;
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let m = engine.shutdown();
        assert_eq!(m.failures, 0, "pipeline turns execute cleanly");
        if hfuse {
            fused_batches_on = m.hfused_batches as f64;
            assert!(
                m.hfused_batches > 0,
                "fusion-on serving must fuse some launch-bound turns"
            );
        } else {
            assert_eq!(m.hfused_batches, 0, "knob off must never fuse");
        }
        println!(
            "served hfuse={hfuse:5}: {done} requests in {secs:.3} s ({:.0} req/s), \
             {} fused batch(es), {} launch(es) saved",
            done as f64 / secs,
            m.hfused_batches,
            m.hfuse_launch_savings
        );
        let key = if hfuse { "hfuse_on" } else { "hfuse_off" };
        served.push((
            key.to_string(),
            Json::Obj(vec![
                ("requests".into(), Json::num(done as f64)),
                ("seconds".into(), Json::num(secs)),
                ("req_s".into(), Json::num(done as f64 / secs)),
                ("hfused_batches".into(), Json::num(m.hfused_batches as f64)),
                (
                    "hfuse_launch_savings".into(),
                    Json::num(m.hfuse_launch_savings as f64),
                ),
            ]),
        ));
    }
    served.push(("rounds".to_string(), Json::num(ROUNDS as f64)));
    served.push(("fused_batches".to_string(), Json::num(fused_batches_on)));
    update_bench_json(report, "served", Json::Obj(served)).expect("write BENCH_hfuse.json");
    let _ = std::fs::remove_dir_all(&dir);
    println!("wrote {BENCH_HFUSE_JSON}");
}
