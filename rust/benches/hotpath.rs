//! L3 hot-path microbenchmarks (the §Perf substrate): wallclock of the
//! compiler stages and the runtime dispatch path, with mean/median over
//! repeated runs. Criterion is unreachable offline; the in-repo harness
//! (`util::stats`) provides warmup + sampling.
//!
//! `cargo bench --bench hotpath`

use fusebla::autotune;
use fusebla::coordinator::Context;
use fusebla::fusion::{self, ImplAxes};
use fusebla::graph::DepGraph;
use fusebla::ir::elem::ProblemSize;
use fusebla::predict::{predict_seq, RoutineDb};
use fusebla::script::compile_script;
use fusebla::sequences;
use fusebla::sim::{simulate_seq, DeviceModel};
use fusebla::util::stats::{bench, black_box};
use fusebla::util::{Summary, Table};

fn report(t: &mut Table, name: &str, samples: &[f64]) {
    let s = Summary::from_samples(samples);
    t.row(&[
        name.to_string(),
        format!("{:.1}", s.median * 1e6),
        format!("{:.1}", s.mean * 1e6),
        format!("{:.1}", s.min * 1e6),
        format!("{:.1}", s.stddev * 1e6),
        s.n.to_string(),
    ]);
}

fn main() {
    let ctx = Context::new();
    let seq = sequences::by_name("bicgk").unwrap();
    let (prog, graph) = seq.graph(&ctx.lib);
    let p = ProblemSize::square(8192);
    let mut t = Table::new(
        "L3 hot paths (µs)",
        &["stage", "median", "mean", "min", "stddev", "n"],
    );

    // script front-end
    report(
        &mut t,
        "parse+typecheck (bicgk)",
        &bench(10, 200, || {
            black_box(compile_script("bicgk", seq.script, &ctx.lib).unwrap())
        }),
    );
    // graph
    report(
        &mut t,
        "dependency graph",
        &bench(10, 500, || black_box(DepGraph::build(&prog, &ctx.lib))),
    );
    // fusion enumeration
    report(
        &mut t,
        "fusion enumeration",
        &bench(10, 500, || {
            black_box(fusion::enumerate_fusions(&prog, &ctx.lib, &graph))
        }),
    );
    // codegen of one fused kernel
    let fusions = fusion::enumerate_fusions(&prog, &ctx.lib, &graph);
    let fi = fusion::gen_impls(&prog, &ctx.lib, &graph, &fusions[0], &ImplAxes::minimal())
        .into_iter()
        .next()
        .unwrap();
    report(
        &mut t,
        "codegen (fused kernel)",
        &bench(10, 500, || {
            black_box(fusebla::codegen::generate(&prog, &ctx.lib, &fi))
        }),
    );
    // prediction of one plan
    let plan = fusebla::codegen::compile_seq(&prog, &ctx.lib, &[fi.clone()], "bench");
    report(
        &mut t,
        "predict (1 plan)",
        &bench(10, 1000, || black_box(predict_seq(&ctx.db, &plan, p))),
    );
    // simulation of one plan
    report(
        &mut t,
        "simulate (1 plan)",
        &bench(10, 1000, || {
            black_box(simulate_seq(&ctx.dev, &plan, p, 1.0))
        }),
    );
    // compile-first end-to-end
    report(
        &mut t,
        "compile_first (bicgk, full axes)",
        &bench(3, 30, || {
            black_box(autotune::compile_first(
                &prog,
                &ctx.lib,
                &graph,
                &ctx.db,
                &ImplAxes::default(),
                p,
            ))
        }),
    );
    // routine DB calibration (once per architecture)
    report(
        &mut t,
        "RoutineDb::calibrate",
        &bench(1, 10, || {
            black_box(RoutineDb::calibrate(&DeviceModel::gtx480(), &ctx.lib))
        }),
    );
    t.print();

    // runtime dispatch overhead (artifact execution minus kernel work):
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        use fusebla::coordinator::{synth_inputs, Coordinator};
        use std::sync::Arc;
        let coord = Coordinator::new(Arc::new(Context::new()), dir).unwrap();
        let (m, n) = coord.runtime().sizes_of("sscal", "fused")[0];
        coord.runtime().warmup("sscal", "fused", m, n).unwrap();
        let inputs = synth_inputs(coord.runtime(), "sscal", "fused", m, n, 1);
        let samples = bench(5, 50, || {
            black_box(
                coord
                    .runtime()
                    .run_seq("sscal", "fused", m, n, &inputs)
                    .unwrap(),
            )
        });
        let s = Summary::from_samples(&samples);
        println!(
            "runtime dispatch+exec sscal n={n}: median {:.1} µs (includes host<->device copies of {} KiB)",
            s.median * 1e6,
            2 * n * 4 / 1024
        );
    } else {
        println!("(artifacts not built: skipping runtime dispatch bench)");
    }
}
