//! L3 hot-path microbenchmarks (the §Perf substrate): wallclock of the
//! compiler stages and the runtime dispatch path, with mean/median over
//! repeated runs. Criterion is unreachable offline; the in-repo harness
//! (`util::stats`) provides warmup + sampling.
//!
//! The dispatch section compares the seed per-request path (linear
//! manifest scan + `BTreeMap<String, Tensor>` environments) against the
//! resolve-once path (indexed manifest + slot-interned environments +
//! read-locked plan-cache probe) on a synthetic catalog, so the
//! host-side overhead win is measured even without built artifacts.
//! Results merge into `BENCH_hotpath.json` (see
//! `bench_support::report`).
//!
//! `cargo bench --bench hotpath`

use fusebla::autotune;
use fusebla::bench_support::report::{update_bench_json, BENCH_JSON};
use fusebla::coordinator::Context;
use fusebla::fusion::{self, ImplAxes};
use fusebla::graph::DepGraph;
use fusebla::ir::elem::ProblemSize;
use fusebla::predict::{predict_seq, RoutineDb};
use fusebla::runtime::{SlotPlan, Tensor};
use fusebla::script::compile_script;
use fusebla::sequences;
use fusebla::sim::{simulate_seq, DeviceModel};
use fusebla::util::manifest::{ArtifactEntry, Manifest};
use fusebla::util::stats::{bench, black_box};
use fusebla::util::{Json, Summary, Table};
use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::{Arc, RwLock};

fn report(t: &mut Table, name: &str, samples: &[f64]) {
    let s = Summary::from_samples(samples);
    t.row(&[
        name.to_string(),
        format!("{:.1}", s.median * 1e6),
        format!("{:.1}", s.mean * 1e6),
        format!("{:.1}", s.min * 1e6),
        format!("{:.1}", s.stddev * 1e6),
        s.n.to_string(),
    ]);
}

/// Synthetic catalog at a realistic scale: `n_seqs` sequences × 2
/// variants × `n_sizes` sizes × 3 chained stages. Stage tensors are
/// small vectors — the bench measures dispatch bookkeeping, not memcpy.
fn synthetic_manifest(n_seqs: usize, n_sizes: usize) -> Manifest {
    let mut text = String::new();
    for s in 0..n_seqs {
        for variant in ["fused", "cublas"] {
            for k in 0..n_sizes {
                let (m, n) = (32, 1024 << k);
                for (stage, (ins, outs)) in [
                    ("in x:f32[16]\n in y:f32[16]\n", "out t0:f32[16]\n"),
                    ("in t0:f32[16]\n in y:f32[16]\n", "out t1:f32[16]\n"),
                    ("in t1:f32[16]\n in x:f32[16]\n", "out w:f32[16]\n"),
                ]
                .iter()
                .enumerate()
                {
                    text.push_str(&format!(
                        "artifact seq{s}.{variant}.m{m}n{n}.s{stage}\n file f.hlo.txt\n seq seq{s}\n variant {variant}\n stage {stage}\n {ins} {outs} m {m}\n n {n}\nend\n"
                    ));
                }
            }
        }
    }
    Manifest::parse(&text, Path::new(".")).expect("synthetic manifest")
}

/// The seed per-request stage lookup: a full catalog scan with
/// per-entry attr `to_string()` comparisons and entry clones (kept here
/// as the reference the indexed path is measured against).
fn stages_linear(man: &Manifest, seq: &str, variant: &str, m: usize, n: usize) -> Vec<ArtifactEntry> {
    let mut v: Vec<ArtifactEntry> = man
        .entries
        .values()
        .filter(|e| {
            e.seq == seq
                && e.variant == variant
                && e.attrs.get("m").map(|s| s.as_str()) == Some(m.to_string().as_str())
                && e.attrs.get("n").map(|s| s.as_str()) == Some(n.to_string().as_str())
        })
        .cloned()
        .collect();
    v.sort_by_key(|e| e.stage);
    v
}

/// "Execute" one request the seed way: scan the manifest, clone the
/// input map, then per stage look up every input by name and insert
/// every output by name. The kernel itself is simulated by an output
/// allocation — identical work in both paths.
fn dispatch_seed(
    man: &Manifest,
    seq: &str,
    variant: &str,
    m: usize,
    n: usize,
    inputs: &BTreeMap<String, Tensor>,
) -> BTreeMap<String, Tensor> {
    let stages = stages_linear(man, seq, variant, m, n);
    let mut env = inputs.clone();
    for entry in &stages {
        for spec in &entry.inputs {
            let t = env.get(&spec.name).expect("input bound");
            assert_eq!(t.dims, spec.dims);
            black_box(&t.data);
        }
        for spec in &entry.outputs {
            let len: usize = spec.dims.iter().product::<usize>().max(1);
            env.insert(spec.name.clone(), Tensor::new(spec.dims.clone(), vec![0.0; len]));
        }
    }
    env
}

type PlanCache = RwLock<HashMap<(String, String, usize, usize), Arc<SlotPlan>>>;

/// "Execute" one request the resolve-once way: one read-locked
/// plan-cache probe (the only shared state on the hot path), then slot
/// binds/reads/writes and a single materialize at the boundary.
fn dispatch_resolved(
    cache: &PlanCache,
    seq: &str,
    variant: &str,
    m: usize,
    n: usize,
    inputs: &BTreeMap<String, Tensor>,
) -> BTreeMap<String, Tensor> {
    let key = (seq.to_string(), variant.to_string(), m, n);
    let plan = cache.read().unwrap().get(&key).expect("resolved").clone();
    let mut env = plan.bind(inputs);
    for st in plan.stages() {
        for (spec, &slot) in st.entry.inputs.iter().zip(st.input_slots()) {
            let t = env.get(slot).expect("input bound");
            assert_eq!(t.dims, spec.dims);
            black_box(&t.data);
        }
        for (spec, &slot) in st.entry.outputs.iter().zip(st.output_slots()) {
            let len: usize = spec.dims.iter().product::<usize>().max(1);
            env.set(slot, Tensor::new(spec.dims.clone(), vec![0.0; len]));
        }
    }
    plan.materialize(env)
}

fn dispatch_section() -> Json {
    let man = synthetic_manifest(8, 4);
    let (seq, variant, m, n) = ("seq4", "fused", 32, 4096);
    let inputs: BTreeMap<String, Tensor> = [
        ("x".to_string(), Tensor::vector(vec![1.0; 16])),
        ("y".to_string(), Tensor::vector(vec![2.0; 16])),
    ]
    .into_iter()
    .collect();

    // resolve once (what Runtime::resolve does on a miss), then serve
    // every request from the cache
    let cache: PlanCache = RwLock::new(HashMap::new());
    let entries = stages_linear(&man, seq, variant, m, n);
    let n_stages = entries.len();
    cache.write().unwrap().insert(
        (seq.to_string(), variant.to_string(), m, n),
        Arc::new(SlotPlan::build(seq, variant, m, n, entries)),
    );

    // both paths must produce the same env before either is timed
    let a = dispatch_seed(&man, seq, variant, m, n, &inputs);
    let b = dispatch_resolved(&cache, seq, variant, m, n, &inputs);
    assert_eq!(a, b, "dispatch paths disagree");

    let seed = Summary::from_samples(&bench(200, 3000, || {
        black_box(dispatch_seed(&man, seq, variant, m, n, &inputs))
    }));
    let resolved = Summary::from_samples(&bench(200, 3000, || {
        black_box(dispatch_resolved(&cache, seq, variant, m, n, &inputs))
    }));
    let speedup = seed.median / resolved.median;
    println!(
        "dispatch path ({} entries, {} stages/request): seed {:.2} µs, resolved {:.2} µs → {:.1}x ({:.0} vs {:.0} req/s)",
        man.entries.len(),
        n_stages,
        seed.median * 1e6,
        resolved.median * 1e6,
        speedup,
        1.0 / seed.median,
        1.0 / resolved.median,
    );
    Json::Obj(vec![
        ("catalog_entries".into(), Json::num(man.entries.len() as f64)),
        ("stages_per_request".into(), Json::num(n_stages as f64)),
        ("dispatch_us_seed_median".into(), Json::num(seed.median * 1e6)),
        ("dispatch_us_resolved_median".into(), Json::num(resolved.median * 1e6)),
        ("dispatch_speedup".into(), Json::num(speedup)),
        ("requests_per_sec_seed".into(), Json::num(1.0 / seed.median)),
        ("requests_per_sec_resolved".into(), Json::num(1.0 / resolved.median)),
        (
            "per_stage_dispatch_overhead_us".into(),
            Json::num(resolved.median * 1e6 / n_stages.max(1) as f64),
        ),
    ])
}

fn main() {
    let ctx = Context::new();
    let seq = sequences::by_name("bicgk").unwrap();
    let (prog, graph) = seq.graph(&ctx.lib);
    let p = ProblemSize::square(8192);
    let mut t = Table::new(
        "L3 hot paths (µs)",
        &["stage", "median", "mean", "min", "stddev", "n"],
    );

    // script front-end
    report(
        &mut t,
        "parse+typecheck (bicgk)",
        &bench(10, 200, || {
            black_box(compile_script("bicgk", seq.script, &ctx.lib).unwrap())
        }),
    );
    // graph
    report(
        &mut t,
        "dependency graph",
        &bench(10, 500, || black_box(DepGraph::build(&prog, &ctx.lib))),
    );
    // fusion enumeration
    report(
        &mut t,
        "fusion enumeration",
        &bench(10, 500, || {
            black_box(fusion::enumerate_fusions(&prog, &ctx.lib, &graph))
        }),
    );
    // codegen of one fused kernel
    let fusions = fusion::enumerate_fusions(&prog, &ctx.lib, &graph);
    let fi = fusion::gen_impls(&prog, &ctx.lib, &graph, &fusions[0], &ImplAxes::minimal())
        .into_iter()
        .next()
        .unwrap();
    report(
        &mut t,
        "codegen (fused kernel)",
        &bench(10, 500, || {
            black_box(fusebla::codegen::generate(&prog, &ctx.lib, &fi))
        }),
    );
    // prediction of one plan
    let plan = fusebla::codegen::compile_seq(&prog, &ctx.lib, &[fi.clone()], "bench");
    report(
        &mut t,
        "predict (1 plan)",
        &bench(10, 1000, || black_box(predict_seq(&ctx.db, &plan, p))),
    );
    // simulation of one plan
    report(
        &mut t,
        "simulate (1 plan)",
        &bench(10, 1000, || {
            black_box(simulate_seq(&ctx.dev, &plan, p, 1.0))
        }),
    );
    // compile-first end-to-end
    report(
        &mut t,
        "compile_first (bicgk, full axes)",
        &bench(3, 30, || {
            black_box(autotune::compile_first(
                &prog,
                &ctx.lib,
                &graph,
                &ctx.db,
                &ImplAxes::default(),
                p,
            ))
        }),
    );
    // routine DB calibration (once per architecture)
    report(
        &mut t,
        "RoutineDb::calibrate",
        &bench(1, 10, || {
            black_box(RoutineDb::calibrate(&DeviceModel::gtx480(), &ctx.lib))
        }),
    );
    t.print();

    // per-request dispatch overhead: seed path vs resolve-once path
    let mut section = dispatch_section();

    // runtime dispatch overhead (artifact execution minus kernel work):
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        use fusebla::coordinator::{synth_inputs, Coordinator};
        let coord = Coordinator::new(Arc::new(Context::new()), dir).unwrap();
        let (m, n) = coord.runtime().sizes_of("sscal", "fused")[0];
        coord.runtime().warmup("sscal", "fused", m, n).unwrap();
        let inputs = synth_inputs(coord.runtime(), "sscal", "fused", m, n, 1);
        let samples = bench(5, 50, || {
            black_box(
                coord
                    .runtime()
                    .run_seq("sscal", "fused", m, n, &inputs)
                    .unwrap(),
            )
        });
        let s = Summary::from_samples(&samples);
        println!(
            "runtime dispatch+exec sscal n={n}: median {:.1} µs (includes host<->device copies of {} KiB)",
            s.median * 1e6,
            2 * n * 4 / 1024
        );
        section.set("runtime_dispatch_us_sscal", Json::num(s.median * 1e6));
    } else {
        println!("(artifacts not built: skipping runtime dispatch bench)");
    }

    match update_bench_json(Path::new(BENCH_JSON), "hotpath", section) {
        Ok(()) => println!("wrote {BENCH_JSON} (section 'hotpath')"),
        Err(e) => eprintln!("could not write {BENCH_JSON}: {e}"),
    }
}
