//! Serve-path throughput: the drain-and-group scheduler on a
//! repeated-key burst vs request-at-a-time submission (same engine,
//! batching defeated by waiting out each ticket). The delta is the
//! dispatch amortization batching buys — per-batch manifest scans and
//! executable-cache lookups instead of per-request.
//!
//! `make artifacts && cargo bench --bench serve_throughput`

use fusebla::coordinator::Context;
use fusebla::util::fmt_duration;
use fusebla::util::manifest::Manifest;
use fusebla::{Engine, EngineConfig, SubmitRequest};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

const N_REQUESTS: u64 = 64;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("(artifacts not built: skipping serve throughput bench)");
        return;
    }
    // size discovery from the manifest alone; the runtime lives on the
    // engine worker
    let manifest = Manifest::load(&dir.join("manifest.txt")).expect("manifest");
    let entry = manifest
        .entries
        .values()
        .find(|e| e.seq == "waxpby" && e.variant == "fused" && e.stage == 0)
        .expect("waxpby artifacts");
    let m: usize = entry.attrs["m"].parse().unwrap();
    let n: usize = entry.attrs["n"].parse().unwrap();

    let ctx = Arc::new(Context::new());
    println!("serve throughput: {N_REQUESTS} × waxpby @ m{m} n{n}\n");
    for (label, window_ms, burst) in [
        ("request-at-a-time (wait each ticket)", 0u64, false),
        ("batched burst (10 ms window)       ", 10, true),
    ] {
        let cfg = EngineConfig {
            batch_window: Duration::from_millis(window_ms),
            max_batch: N_REQUESTS as usize,
        };
        let engine = Engine::with_config(ctx.clone(), dir, cfg).expect("engine");
        let client = engine.client();
        // warmup: compile the executables once so both modes time
        // dispatch, not XLA compilation
        client
            .submit(SubmitRequest::new("waxpby", m, n).synth(u64::MAX))
            .expect("submit")
            .wait()
            .expect("warmup");
        let t0 = Instant::now();
        if burst {
            let tickets: Vec<_> = (0..N_REQUESTS)
                .map(|seed| {
                    client
                        .submit(SubmitRequest::new("waxpby", m, n).synth(seed))
                        .expect("submit")
                })
                .collect();
            for t in tickets {
                t.wait().expect("request");
            }
        } else {
            for seed in 0..N_REQUESTS {
                client
                    .submit(SubmitRequest::new("waxpby", m, n).synth(seed))
                    .expect("submit")
                    .wait()
                    .expect("request");
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let metrics = engine.shutdown();
        println!(
            "{label}: {} in {} → {:.1} req/s | {} batch(es), mean size {:.1}, max {}",
            N_REQUESTS,
            fmt_duration(dt),
            N_REQUESTS as f64 / dt,
            metrics.batches,
            metrics.mean_batch_size(),
            metrics.max_batch_size
        );
    }
}
