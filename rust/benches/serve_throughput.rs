//! Serve-path throughput: the drain-and-group scheduler on a
//! repeated-key burst vs request-at-a-time submission (same engine,
//! batching defeated by waiting out each ticket). The delta is the
//! dispatch amortization batching buys — and with resolve-once plans,
//! both modes serve repeat keys from the runtime's resolve cache (one
//! read-locked probe per dispatch, no manifest scans, no per-stage
//! executable lookups).
//!
//! Results merge into `BENCH_hotpath.json` (section
//! `serve_throughput`) so the requests/sec trajectory is tracked
//! across PRs.
//!
//! `make artifacts && cargo bench --bench serve_throughput`

use fusebla::bench_support::report::{update_bench_json, BENCH_JSON};
use fusebla::coordinator::Context;
use fusebla::util::fmt_duration;
use fusebla::util::manifest::Manifest;
use fusebla::util::Json;
use fusebla::{Engine, EngineConfig, SubmitRequest};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

const N_REQUESTS: u64 = 64;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("(artifacts not built: skipping serve throughput bench)");
        return;
    }
    // size discovery from the manifest alone; the runtime lives on the
    // engine worker
    let manifest = Manifest::load(&dir.join("manifest.txt")).expect("manifest");
    let Some(&(m, n)) = manifest.sizes("waxpby", "fused").first() else {
        println!("(no waxpby artifacts: skipping serve throughput bench)");
        return;
    };

    let ctx = Arc::new(Context::new());
    println!("serve throughput: {N_REQUESTS} × waxpby @ m{m} n{n}\n");
    let mut section = Json::Obj(vec![(
        "requests".into(),
        Json::num(N_REQUESTS as f64),
    )]);
    let mut req_per_sec = Vec::new();
    for (label, key, window_ms, burst) in [
        ("request-at-a-time (wait each ticket)", "request_at_a_time", 0u64, false),
        ("batched burst (10 ms window)       ", "batched_burst", 10, true),
    ] {
        let cfg = EngineConfig {
            batch_window: Duration::from_millis(window_ms),
            max_batch: N_REQUESTS as usize,
            ..EngineConfig::default()
        };
        let engine = Engine::with_config(ctx.clone(), dir, cfg).expect("engine");
        let client = engine.client();
        // warmup: resolve the plan (compile the executables) once so
        // both modes time dispatch, not XLA compilation
        client
            .submit(SubmitRequest::new("waxpby", m, n).synth(u64::MAX))
            .expect("submit")
            .wait()
            .expect("warmup");
        let t0 = Instant::now();
        if burst {
            let tickets: Vec<_> = (0..N_REQUESTS)
                .map(|seed| {
                    client
                        .submit(SubmitRequest::new("waxpby", m, n).synth(seed))
                        .expect("submit")
                })
                .collect();
            for t in tickets {
                t.wait().expect("request");
            }
        } else {
            for seed in 0..N_REQUESTS {
                client
                    .submit(SubmitRequest::new("waxpby", m, n).synth(seed))
                    .expect("submit")
                    .wait()
                    .expect("request");
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        let metrics = engine.shutdown();
        let rps = N_REQUESTS as f64 / dt;
        req_per_sec.push(rps);
        println!(
            "{label}: {} in {} → {:.1} req/s | {} batch(es), mean size {:.1}, max {} | resolve {} hit(s) / {} miss(es)",
            N_REQUESTS,
            fmt_duration(dt),
            rps,
            metrics.batches,
            metrics.mean_batch_size(),
            metrics.max_batch_size,
            metrics.resolve_hits,
            metrics.resolve_misses,
        );
        section.set(
            key,
            Json::Obj(vec![
                ("req_per_sec".into(), Json::num(rps)),
                ("seconds".into(), Json::num(dt)),
                ("batches".into(), Json::num(metrics.batches as f64)),
                ("mean_batch_size".into(), Json::num(metrics.mean_batch_size())),
                ("max_batch_size".into(), Json::num(metrics.max_batch_size as f64)),
                ("resolve_hits".into(), Json::num(metrics.resolve_hits as f64)),
                ("resolve_misses".into(), Json::num(metrics.resolve_misses as f64)),
                (
                    "executable_compiles".into(),
                    Json::num(metrics.executable_compiles as f64),
                ),
            ]),
        );
    }
    if let [seq_rps, batch_rps] = req_per_sec[..] {
        section.set("batched_speedup", Json::num(batch_rps / seq_rps));
    }
    match update_bench_json(Path::new(BENCH_JSON), "serve_throughput", section) {
        Ok(()) => println!("\nwrote {BENCH_JSON} (section 'serve_throughput')"),
        Err(e) => eprintln!("could not write {BENCH_JSON}: {e}"),
    }
}
