//! Chaos study — serving throughput and tail latency with the fault
//! injector disabled vs. a seeded plan that kills every lane at least
//! once mid-run.
//!
//! Fully offline-safe by construction (same footing as `slo.rs`): the
//! fleet starts over a stub catalog, so execution fails at the offline
//! stub backend, but everything this bench measures — supervision,
//! worker respawn, failover, breaker re-admission and the submit→reply
//! latency histogram — runs for real. The numbers are *control-plane*
//! rates: terminal outcomes per second of wall clock, including the
//! time the supervisor spends rebuilding killed workers.
//!
//! Both modes run the identical seeded schedule: a pinned trigger burst
//! per lane (in chaos mode those turns carry the scripted kills, so
//! each lane provably dies and respawns) followed by a seeded poisson
//! open loop. The acceptance bar from the fault-tolerance work is that
//! chaos-mode throughput stays within 2x of fault-free; the ratio is
//! asserted and recorded in `BENCH_chaos.json`.
//!
//! `cargo bench --bench chaos`

use fusebla::bench_support::report::update_bench_json;
use fusebla::bench_support::stub_catalog;
use fusebla::coordinator::traffic;
use fusebla::sim::DeviceModel;
use fusebla::util::Json;
use fusebla::{DeviceRegistry, Engine, EngineConfig, Fault, FaultPlan, SubmitRequest, Ticket};
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

const BENCH_CHAOS_JSON: &str = "BENCH_chaos.json";
const RATE: f64 = 800.0;
const HORIZON_MS: u64 = 600;
/// Pinned requests sent to each lane before the open loop; in chaos
/// mode these guarantee every lane takes the turns its scripted kills
/// target, independent of how the router spreads the open-loop load.
const TRIGGERS_PER_LANE: u64 = 3;

struct ModeResult {
    throughput_req_s: f64,
    p99_ms: f64,
    submitted: u64,
    worker_restarts: u64,
    failovers: u64,
    worker_lost: u64,
    sheds: u64,
}

fn scratch_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fusebla_bench_{name}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_mode(dir: &Path, cal: &Path, plan: FaultPlan, label: &str) -> ModeResult {
    let registry = Arc::new(
        DeviceRegistry::new(vec![DeviceModel::gtx480(), DeviceModel::gt430()], cal)
            .expect("device registry"),
    );
    let n_lanes = 2u64;
    let cfg = EngineConfig {
        batch_window: Duration::from_millis(2),
        max_batch: 256,
        retry_budget: 3,
        fault_plan: plan,
        ..EngineConfig::default()
    };
    let engine = Engine::start_fleet(registry, dir, cfg).expect("stub fleet");
    let client = engine.client();
    let names: Vec<String> = client.devices().iter().map(|d| d.name().to_string()).collect();

    let t0 = Instant::now();
    // trigger burst: drive each lane through its first few turns so the
    // scripted kills land inside the measured window
    let mut tickets = Vec::new();
    for name in &names {
        for i in 0..TRIGGERS_PER_LANE {
            tickets.push(
                client
                    .submit(SubmitRequest::new("waxpby", 32, 65536).synth(i).pin(name))
                    .expect("pinned trigger admits"),
            );
        }
    }
    let triggers = tickets.len() as u64;
    let _ = tickets.into_iter().map(Ticket::wait).count();

    let spec = traffic::TrafficSpec {
        scenario: traffic::Scenario::Poisson,
        seed: 42,
        rate: RATE,
        horizon: Duration::from_millis(HORIZON_MS),
        keys: vec![
            ("waxpby".into(), 32, 65536),
            ("vadd".into(), 32, 65536),
            ("sscal".into(), 32, 65536),
            ("axpydot".into(), 32, 65536),
        ],
    };
    let rep = traffic::run_open_loop(&client, &spec, &traffic::OpenLoopOptions::default());
    let dt = t0.elapsed().as_secs_f64();

    let fleet = engine.shutdown_fleet();
    assert!(
        fleet.lost.is_empty(),
        "recoverable kills must lose no lane: {:?}",
        fleet.lost
    );
    let m = fleet.aggregate();
    // every submission reaches exactly one terminal outcome
    assert_eq!(
        rep.completed + rep.failed + rep.sheds() + rep.other_errors,
        rep.submitted,
        "lost tickets in {label} mode: {rep:?}"
    );
    let submitted = triggers + rep.submitted;
    let result = ModeResult {
        throughput_req_s: submitted as f64 / dt,
        p99_ms: m.latency.quantile(0.99).map_or(f64::INFINITY, |s| s * 1e3),
        submitted,
        worker_restarts: m.worker_restarts,
        failovers: m.failovers,
        worker_lost: m.worker_lost_sheds,
        sheds: rep.sheds(),
    };
    println!(
        "{label:8}: {} submitted in {:.3} s → {:.0} req/s terminal, p99 {:.3} ms, \
         {} restart(s), {} failover(s), {} worker-lost shed(s)",
        result.submitted,
        dt,
        result.throughput_req_s,
        result.p99_ms,
        result.worker_restarts,
        result.failovers,
        result.worker_lost
    );
    if label == "chaos" {
        assert!(
            result.worker_restarts >= n_lanes,
            "chaos plan must kill and respawn every lane: {} restart(s)",
            result.worker_restarts
        );
    } else {
        assert_eq!(result.worker_restarts, 0, "baseline must not restart");
    }
    result
}

fn section(r: &ModeResult) -> Json {
    Json::Obj(vec![
        ("throughput_req_s".into(), Json::num(r.throughput_req_s)),
        ("p99_ms".into(), Json::num(r.p99_ms)),
        ("submitted".into(), Json::num(r.submitted as f64)),
        ("worker_restarts".into(), Json::num(r.worker_restarts as f64)),
        ("failovers".into(), Json::num(r.failovers as f64)),
        ("worker_lost_sheds".into(), Json::num(r.worker_lost as f64)),
        ("sheds".into(), Json::num(r.sheds as f64)),
    ])
}

fn main() {
    let report = Path::new(BENCH_CHAOS_JSON);
    let dir = stub_catalog("bench_chaos", &["waxpby", "vadd", "sscal", "axpydot"]);
    let cal = scratch_dir("chaos_cal");
    println!(
        "chaos study (stub backend, 2-lane fleet): poisson seed 42 @ {RATE:.0} req/s \
         over {HORIZON_MS} ms, {TRIGGERS_PER_LANE} pinned trigger(s) per lane"
    );

    let baseline = run_mode(&dir, &cal, FaultPlan::default(), "baseline");

    // seeded mix plus one guaranteed kill per lane, timed to land
    // during the trigger burst (turns count from 1, monotonically)
    let mut plan = FaultPlan::seeded(42, 2, 4);
    plan.faults.push(Fault::Kill { lane: 0, turn: 2 });
    plan.faults.push(Fault::Kill { lane: 1, turn: 1 });
    println!("chaos plan: {} fault(s), digest {:016x}", plan.faults.len(), plan.digest());
    let plan_digest = plan.digest();
    let chaos = run_mode(&dir, &cal, plan, "chaos");

    let ratio = baseline.throughput_req_s / chaos.throughput_req_s.max(f64::MIN_POSITIVE);
    let within_2x = ratio <= 2.0;
    println!(
        "throughput under chaos is {:.2}x below fault-free ({})",
        ratio,
        if within_2x { "within the 2x bar" } else { "OVER the 2x bar" }
    );
    assert!(within_2x, "chaos throughput degraded {ratio:.2}x (> 2x bar)");

    update_bench_json(report, "baseline", section(&baseline)).expect("write BENCH_chaos.json");
    update_bench_json(report, "chaos", section(&chaos)).expect("write BENCH_chaos.json");
    update_bench_json(
        report,
        "comparison",
        Json::Obj(vec![
            ("throughput_ratio".into(), Json::num(ratio)),
            ("within_2x".into(), Json::Bool(within_2x)),
            ("plan_digest".into(), Json::Str(format!("{plan_digest:016x}"))),
        ]),
    )
    .expect("write BENCH_chaos.json");
    let _ = fs::remove_dir_all(&cal);
    println!("wrote {BENCH_CHAOS_JSON}");
}
