//! Dynamic pipeline study — what a user-submitted script costs to
//! register and serve, and what fusion buys it, for the two exemplar
//! pipelines.
//!
//! Fully offline-safe: the engine starts over a stub catalog, and
//! registered pipelines execute through their interpreter-backed
//! resolved plans, so register → route → batch → execute runs for real.
//! Measured per pipeline:
//!
//! * `register_ms` — full `Client::register_pipeline` round trip
//!   (client precheck + compile, worker compile + catalog insert,
//!   roster publish).
//! * `first_execute_ms` / `warm_execute_ms` — cold dispatch (plan +
//!   resolve miss) vs steady-state dispatch (both caches hit,
//!   counter-verified before the numbers are written).
//! * `predicted_fused_s` / `predicted_unfused_s` — the planner's
//!   best-variant prediction against the GTX 480 model vs the
//!   per-call CUBLAS-style baseline, i.e. what kernel fusion is
//!   predicted to buy this script (the paper's core claim, applied to
//!   user-submitted sequences).
//!
//! Results merge into `BENCH_pipelines.json`, one section per pipeline.
//!
//! `cargo bench --bench pipelines`

use fusebla::bench_support::report::update_bench_json;
use fusebla::bench_support::stub_catalog;
use fusebla::coordinator::Context;
use fusebla::ir::elem::ProblemSize;
use fusebla::pipelines;
use fusebla::planner::{self, PlannerConfig};
use fusebla::predict::predict_seq;
use fusebla::util::Json;
use fusebla::{Engine, EngineConfig, SubmitRequest};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

const BENCH_JSON: &str = "BENCH_pipelines.json";
const M: usize = 32;
const N: usize = 65536;
const WARM_ITERS: u64 = 8;

fn main() {
    let report = Path::new(BENCH_JSON);
    let dir = stub_catalog("bench_pipelines", &["waxpby", "vadd"]);
    let ctx = Context::new();
    let p = ProblemSize::new(M, N).padded();

    for (name, src) in [
        ("add_mul_exp", pipelines::examples::ADD_MUL_EXP),
        ("quantize_int8", pipelines::examples::QUANTIZE_INT8),
    ] {
        // Fresh engine per pipeline: caches start cold, so the first
        // execute really is the cold path.
        let engine = Engine::with_config(Arc::new(Context::new()), &dir, EngineConfig::default())
            .expect("stub engine");
        let client = engine.client();

        let t0 = Instant::now();
        let fp = client.register_pipeline(name, src).expect("register");
        let register_ms = t0.elapsed().as_secs_f64() * 1e3;

        let t0 = Instant::now();
        let t = client.submit(SubmitRequest::new(name, M, N).synth(1)).expect("submit");
        t.wait().expect("cold execute");
        let first_execute_ms = t0.elapsed().as_secs_f64() * 1e3;

        // steady state: min over a few runs (dispatch jitter dominates)
        let mut warm_execute_ms = f64::INFINITY;
        for seed in 0..WARM_ITERS {
            let t0 = Instant::now();
            let t = client
                .submit(SubmitRequest::new(name, M, N).synth(seed + 2))
                .expect("submit");
            t.wait().expect("warm execute");
            warm_execute_ms = warm_execute_ms.min(t0.elapsed().as_secs_f64() * 1e3);
        }

        let m = engine.shutdown();
        assert_eq!(m.failures, 0, "{name}: every serve must succeed");
        assert_eq!(m.plan_cache_misses, 1, "{name}: exactly the cold execute plans");
        assert_eq!(m.resolve_misses, 1, "{name}: exactly the cold execute resolves");
        assert_eq!(
            m.plan_cache_hits + m.resolve_hits,
            2 * WARM_ITERS,
            "{name}: every warm execute hits both caches"
        );

        // Fused-vs-unfused prediction: the planner's pick over the
        // pipeline's own fusion space vs the per-call baseline plan.
        let c = pipelines::compile(name, src, &ctx.lib).expect("compile");
        let planned = planner::plan_space(
            &c.pipeline.program,
            &c.space,
            &ctx.db,
            p,
            &PlannerConfig::default(),
        );
        let unfused = predict_seq(&ctx.db, &c.baseline, p);
        assert!(
            planned.predicted <= unfused,
            "{name}: the planner never does worse than the baseline"
        );
        println!(
            "{name} ({fp:#018x}): register {register_ms:.2} ms, first execute \
             {first_execute_ms:.3} ms, warm {warm_execute_ms:.3} ms, predicted fused \
             {:.3e} s vs unfused {:.3e} s ({:.2}x)",
            planned.predicted,
            unfused,
            unfused / planned.predicted
        );

        let section = Json::Obj(vec![
            ("m".into(), Json::num(M as f64)),
            ("n".into(), Json::num(N as f64)),
            ("register_ms".into(), Json::num(register_ms)),
            ("first_execute_ms".into(), Json::num(first_execute_ms)),
            ("warm_execute_ms".into(), Json::num(warm_execute_ms)),
            ("predicted_fused_s".into(), Json::num(planned.predicted)),
            ("predicted_unfused_s".into(), Json::num(unfused)),
            ("fusion_speedup".into(), Json::num(unfused / planned.predicted)),
        ]);
        update_bench_json(report, name, section).expect("write BENCH_pipelines.json");
    }
    println!("wrote {BENCH_JSON}");
}
