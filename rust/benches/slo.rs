//! SLO study — max sustainable offered load at a fixed p99 target, per
//! traffic scenario, plus a deterministic saturation probe of the
//! load-shedding path.
//!
//! Fully offline-safe by construction: the engine starts over a stub
//! catalog, so execution fails at the offline stub backend, but
//! everything this bench measures — admission control, EDF batch
//! formation, deadline shedding and the submit→reply latency
//! histogram — runs for real. The numbers are therefore *control-plane*
//! sustainable rates: what the serving machinery itself can absorb
//! while holding the p99 target with zero sheds.
//!
//! Results merge into `BENCH_slo.json`: one section per scenario with
//! `max_sustainable_req_s` (highest rung of the rate ladder that held
//! p99 ≤ target with zero sheds) and the per-rate detail, plus a
//! `saturation` section proving sheds actually fire under overload.
//!
//! `cargo bench --bench slo`

use fusebla::bench_support::report::update_bench_json;
use fusebla::bench_support::stub_catalog;
use fusebla::coordinator::{traffic, Context};
use fusebla::util::Json;
use fusebla::{Engine, EngineConfig, ServeError, SubmitRequest, Ticket};
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

const BENCH_SLO_JSON: &str = "BENCH_slo.json";
/// The p99 completion-latency target a rate must hold to count as
/// sustainable.
const TARGET_P99_MS: f64 = 50.0;
/// Relative deadline stamped on every open-loop request.
const DEADLINE_MS: u64 = 50;
const QUEUE_CAP: usize = 64;
const HORIZON_MS: u64 = 400;
/// Offered-load ladder, requests per second (mean over the horizon).
const RATES: [f64; 5] = [250.0, 500.0, 1000.0, 2000.0, 4000.0];

fn main() {
    let report = Path::new(BENCH_SLO_JSON);
    let seqs = ["waxpby", "vadd", "sscal", "axpydot"];
    let dir = stub_catalog("bench_slo", &seqs);
    let keys: Vec<(String, usize, usize)> =
        seqs.iter().map(|s| (s.to_string(), 32, 65536)).collect();
    println!(
        "SLO ladder (stub backend): p99 target {TARGET_P99_MS} ms, deadline {DEADLINE_MS} ms, \
         queue cap {QUEUE_CAP}, horizon {HORIZON_MS} ms per rung"
    );

    for scenario in traffic::Scenario::all() {
        let mut max_sustainable: Option<f64> = None;
        let mut per_rate = Vec::new();
        for rate in RATES {
            // Fresh engine per rung: metrics and caches start cold, so
            // rungs are independent and the ladder is order-insensitive.
            let cfg = EngineConfig {
                batch_window: Duration::from_millis(2),
                max_batch: 256,
                queue_cap: QUEUE_CAP,
                ..EngineConfig::default()
            };
            let engine =
                Engine::with_config(Arc::new(Context::new()), &dir, cfg).expect("stub engine");
            let client = engine.client();
            let spec = traffic::TrafficSpec {
                scenario,
                seed: 42,
                rate,
                horizon: Duration::from_millis(HORIZON_MS),
                keys: keys.clone(),
            };
            let opts = traffic::OpenLoopOptions {
                deadline: Some(Duration::from_millis(DEADLINE_MS)),
                priority: 0,
            };
            let rep = traffic::run_open_loop(&client, &spec, &opts);
            let m = engine.shutdown_fleet().aggregate();
            let p99_ms = m.latency.quantile(0.99).map_or(f64::INFINITY, |s| s * 1e3);
            // "Sustainable" = the target held and nothing was refused.
            // Execution *failures* are expected offline (stub backend)
            // and don't disqualify a rung — they still complete on time.
            let sustainable =
                rep.sheds() == 0 && rep.other_errors == 0 && p99_ms <= TARGET_P99_MS;
            if sustainable {
                max_sustainable = Some(rate);
            }
            println!(
                "{:8} @ {rate:6.0} req/s: {} submitted, p99 {p99_ms:8.3} ms, \
                 {} queue shed(s), {} deadline shed(s), {} SLO miss(es) — {}",
                scenario.as_str(),
                rep.submitted,
                rep.queue_sheds,
                rep.deadline_sheds,
                m.slo_misses,
                if sustainable { "sustainable" } else { "OVER" }
            );
            per_rate.push((
                format!("r{rate:.0}"),
                Json::Obj(vec![
                    ("submitted".into(), Json::num(rep.submitted as f64)),
                    ("p99_ms".into(), Json::num(p99_ms)),
                    ("queue_sheds".into(), Json::num(rep.queue_sheds as f64)),
                    ("deadline_sheds".into(), Json::num(rep.deadline_sheds as f64)),
                    ("slo_misses".into(), Json::num(m.slo_misses as f64)),
                    ("sustainable".into(), Json::Bool(sustainable)),
                ]),
            ));
        }
        let section = Json::Obj(vec![
            ("target_p99_ms".into(), Json::num(TARGET_P99_MS)),
            ("deadline_ms".into(), Json::num(DEADLINE_MS as f64)),
            ("queue_cap".into(), Json::num(QUEUE_CAP as f64)),
            (
                "max_sustainable_req_s".into(),
                max_sustainable.map_or(Json::Null, Json::num),
            ),
            ("rates".into(), Json::Obj(per_rate)),
        ]);
        update_bench_json(report, scenario.as_str(), section).expect("write BENCH_slo.json");
    }

    // Saturation probe: hold the batch window open (no deadlines, so
    // the EDF drain has no reason to ship early) and offer far more
    // than the queue cap. Admission must refuse exactly the overflow
    // with a typed QueueFull — the deterministic nonzero-shed signal
    // the CI smoke job checks for.
    let cfg = EngineConfig {
        batch_window: Duration::from_millis(150),
        max_batch: 256,
        queue_cap: 8,
        ..EngineConfig::default()
    };
    let engine = Engine::with_config(Arc::new(Context::new()), &dir, cfg).expect("stub engine");
    let client = engine.client();
    let offered = 64u64;
    let mut queue_sheds = 0u64;
    let mut other = 0u64;
    let mut tickets = Vec::new();
    for i in 0..offered {
        match client.submit(SubmitRequest::new("waxpby", 32, 65536).synth(i)) {
            Ok(t) => tickets.push(t),
            Err(e) if matches!(e.downcast_ref::<ServeError>(), Some(ServeError::QueueFull { .. })) => {
                queue_sheds += 1
            }
            Err(_) => other += 1,
        }
    }
    let admitted = tickets.len() as u64;
    // reap so the engine drains before shutdown (stub execution fails;
    // only the admission split matters here)
    let _ = tickets.into_iter().map(Ticket::wait).count();
    engine.shutdown_fleet();
    println!(
        "saturation: {offered} offered against cap 8 with a held 150 ms window → \
         {admitted} admitted, {queue_sheds} queue shed(s), {other} other error(s)"
    );
    assert!(queue_sheds > 0, "saturation must shed");
    let saturation = Json::Obj(vec![
        ("offered".into(), Json::num(offered as f64)),
        ("queue_cap".into(), Json::num(8.0)),
        ("admitted".into(), Json::num(admitted as f64)),
        ("queue_sheds".into(), Json::num(queue_sheds as f64)),
    ]);
    update_bench_json(report, "saturation", saturation).expect("write BENCH_slo.json");
    println!("wrote {BENCH_SLO_JSON}");
}
