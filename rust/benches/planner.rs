//! Planner-vs-exhaustive study: for every sequence, the size of the full
//! combination space, how little of it the pruned planner materializes,
//! the kernel-cost memoization ratio, and the wallclock of both paths.
//!
//! `cargo bench --bench planner`

use fusebla::autotune;
use fusebla::bench_support::{eval_axes, eval_size};
use fusebla::coordinator::Context;
use fusebla::fusion::enumerate_fusions;
use fusebla::fusion::space::Space;
use fusebla::planner::{plan_space, PlannerConfig};
use fusebla::sequences;
use fusebla::util::{fmt_duration, Table};
use std::time::Instant;

fn main() {
    let ctx = Context::new();
    let mut t = Table::new(
        "planner vs exhaustive — combinations materialized and wallclock",
        &[
            "Sequence",
            "Space",
            "Planner combos",
            "Pruned",
            "Kernel costs",
            "Kernel refs",
            "t_exhaustive",
            "t_planner",
        ],
    );
    for seq in sequences::all() {
        let (prog, graph) = seq.graph(&ctx.lib);
        let axes = eval_axes(&seq);
        let p = eval_size(&seq);
        let fusions = enumerate_fusions(&prog, &ctx.lib, &graph);
        let space = Space::build(&prog, &ctx.lib, &graph, &fusions, &axes);

        let t0 = Instant::now();
        let exhaustive = autotune::rank_all(&prog, &ctx.lib, &graph, &ctx.db, &axes, p);
        let t_exhaustive = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let planned = plan_space(&prog, &space, &ctx.db, p, &PlannerConfig::default());
        let t_planner = t1.elapsed().as_secs_f64();

        assert!(
            planned.predicted <= exhaustive[0].predicted,
            "{}: planner worse than exhaustive",
            seq.name
        );
        t.row(&[
            seq.name.to_uppercase(),
            planned.stats.space_combinations.to_string(),
            planned.stats.combos_evaluated.to_string(),
            planned.stats.partitions_pruned.to_string(),
            planned.stats.kernel_evals.to_string(),
            planned.stats.kernel_refs.to_string(),
            fmt_duration(t_exhaustive),
            fmt_duration(t_planner),
        ]);
    }
    t.print();
    println!("TSV:\n{}", t.to_tsv());
}
