//! Multi-GPU scaling study — the paper's §6 future work implemented:
//! strong scaling of each sequence's best fused plan over 1–8 modeled
//! GTX 480s on PCIe 2.0, showing the map-vs-reduce scaling gap and the
//! small-problem crossover the paper anticipates.
//!
//! `cargo bench --bench multigpu`

use fusebla::autotune;
use fusebla::bench_support::eval_size;
use fusebla::coordinator::Context;
use fusebla::fusion::ImplAxes;
use fusebla::ir::elem::ProblemSize;
use fusebla::sequences;
use fusebla::sim::multi::{simulate_seq_multi, Interconnect};
use fusebla::util::Table;

fn main() {
    let ctx = Context::new();
    let link = Interconnect::pcie2_x16();
    let mut t = Table::new(
        "multi-GPU strong scaling — GFlops at G devices (best fused plan)",
        &["Sequence", "G=1", "G=2", "G=4", "G=8", "eff@4"],
    );
    for seq in sequences::all() {
        let p = eval_size(&seq);
        let flops = seq.flops.eval(p);
        let (prog, graph) = seq.graph(&ctx.lib);
        let best =
            autotune::compile_first(&prog, &ctx.lib, &graph, &ctx.db, &ImplAxes::minimal(), p);
        let gf = |g: u32| simulate_seq_multi(&ctx.dev, &link, g, &best.plan, p, flops).gflops;
        let g1 = gf(1);
        let g4 = gf(4);
        t.row(&[
            seq.name.to_uppercase(),
            format!("{g1:.1}"),
            format!("{:.1}", gf(2)),
            format!("{g4:.1}"),
            format!("{:.1}", gf(8)),
            format!("{:.0}%", 100.0 * g4 / g1 / 4.0),
        ]);
    }
    t.print();

    // small-problem crossover for BiCGK
    let mut t2 = Table::new(
        "BiCGK multi-GPU efficiency vs problem size (G=4)",
        &["n", "efficiency"],
    );
    let seq = sequences::by_name("bicgk").unwrap();
    let (prog, graph) = seq.graph(&ctx.lib);
    for n in [512usize, 1024, 2048, 4096, 8192, 16384] {
        let p = ProblemSize::square(n);
        let best =
            autotune::compile_first(&prog, &ctx.lib, &graph, &ctx.db, &ImplAxes::minimal(), p);
        let eff = fusebla::sim::multi::scaling_efficiency(&ctx.dev, &link, 4, &best.plan, p);
        t2.row(&[n.to_string(), format!("{:.0}%", eff * 100.0)]);
    }
    t2.print();
}
