//! Regenerates the paper's Table 3: our speedup vs the BTO BLAS CPU
//! speedup (quoted from the paper — BTO targets CPUs and is not
//! reproducible here) plus the measured kernel bandwidth of our plans.
//!
//! `cargo bench --bench table3`

use fusebla::bench_support::{table3, Evaluator};
use fusebla::coordinator::Context;

fn main() {
    let ctx = Context::new();
    let mut ev = Evaluator::new();
    let table = table3(&ctx, &mut ev);
    table.print();
    println!("TSV:\n{}", table.to_tsv());
}
