//! Regenerates the paper's Table 4: optimization-space size, rank of the
//! empirically best implementation in the predicted order, and the
//! first/worst implementations' relative performance.
//!
//! `cargo bench --bench table4`

use fusebla::bench_support::{table4, Evaluator};
use fusebla::coordinator::Context;

fn main() {
    let ctx = Context::new();
    let mut ev = Evaluator::new();
    let table = table4(&ctx, &mut ev);
    table.print();
    println!("TSV:\n{}", table.to_tsv());
}
