//! Sharded-search study: what sharding the planner's partition range
//! buys, and what the cold-key rewire costs the submitting thread.
//!
//! Two offline-safe sections (planning is pure — no artifacts, no real
//! backend needed; the serve sections run over a stub catalog, since
//! only *execution* needs real artifacts):
//!
//! * `sharded_planner` — plans/sec of `plan_space` unsharded vs
//!   `plan_space_sharded` at K=1/2/4 (in-process: measures the
//!   chunk/merge machinery itself, which must stay cheap for the fleet
//!   scatter to be worth it), plus `Client::search_sharded` wall time
//!   at K=1/2/4 through a live 4-worker fleet.
//! * `cold_key` — submit latency of a *fresh* `(seq, size)` key through
//!   the fleet engine (forecasts scattered to workers) vs the old
//!   submitting-thread path (`CostModel::costs` with no lanes, which
//!   still exists as the fallback), per distinct padded key.
//!
//! Results merge into `BENCH_shard.json` so the shard trajectory stays
//! diffable across PRs.
//!
//! `cargo bench --bench shard`

use fusebla::bench_support::report::update_bench_json;
use fusebla::coordinator::Context;
use fusebla::fleet::CostModel;
use fusebla::fusion::ImplAxes;
use fusebla::ir::elem::ProblemSize;
use fusebla::planner::{plan_space, plan_space_sharded, PlannerConfig};
use fusebla::sequences;
use fusebla::util::stats::{bench, black_box};
use fusebla::util::{Json, Summary};
use fusebla::{DeviceRegistry, Engine, EngineConfig, SubmitRequest};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

const BENCH_SHARD_JSON: &str = "BENCH_shard.json";

fn main() {
    let report = Path::new(BENCH_SHARD_JSON);
    // A stub catalog is enough for the engine sections: planning and
    // the control plane never touch artifact bytes; submits fail at
    // the stub backend *after* routing, which is all the cold-key
    // latency section measures.
    let seqs = ["waxpby", "vadd", "sscal", "axpydot"];
    let dir = fusebla::bench_support::stub_catalog("bench_shard", &seqs);

    // ---- sharded planner, in-process: chunk/merge overhead ----------
    let ctx = Context::new();
    let seq = sequences::by_name("gemver").expect("gemver");
    let (prog, _graph, space) = seq.space(&ctx.lib, &ImplAxes::minimal());
    let p = ProblemSize::square(8192);
    let cfg = PlannerConfig::default();

    let mut planner_section = Vec::new();
    let unsharded = bench(5, 200, || black_box(plan_space(&prog, &space, &ctx.db, p, &cfg)));
    let s = Summary::from_samples(&unsharded);
    println!(
        "plan_space (gemver, unsharded): median {:.1} µs → {:.0} plans/s",
        s.median * 1e6,
        1.0 / s.median
    );
    planner_section.push(("plans_per_sec_unsharded".into(), Json::num(1.0 / s.median)));
    for k in [1usize, 2, 4] {
        let samples = bench(5, 200, || {
            black_box(plan_space_sharded(&prog, &space, &ctx.db, p, &cfg, k))
        });
        let s = Summary::from_samples(&samples);
        println!(
            "plan_space_sharded (gemver, K={k}): median {:.1} µs → {:.0} plans/s",
            s.median * 1e6,
            1.0 / s.median
        );
        planner_section.push((format!("plans_per_sec_k{k}"), Json::num(1.0 / s.median)));
    }
    update_bench_json(report, "sharded_planner", Json::Obj(planner_section))
        .expect("write BENCH_shard.json");

    // ---- sharded search through a live fleet ------------------------
    let registry = Arc::new(DeviceRegistry::simulated(4, &dir));
    let engine = Engine::start_fleet(registry, &dir, EngineConfig::default()).expect("fleet");
    let client = engine.client();
    let device = client.devices()[0].name().to_string();
    let mut fleet_section = Vec::new();
    for k in [1usize, 2, 4] {
        // one warm call builds the workers' space caches, then measure
        let warm = client.search_sharded("gemver", 8192, 8192, k, Some(device.as_str()));
        warm.expect("warm sharded search");
        let samples = bench(2, 50, || {
            let planned = client.search_sharded("gemver", 8192, 8192, k, Some(device.as_str()));
            black_box(planned.unwrap())
        });
        let s = Summary::from_samples(&samples);
        println!(
            "search_sharded (gemver, K={k}, 4 workers): median {:.1} ms → {:.0} plans/s",
            s.median * 1e3,
            1.0 / s.median
        );
        fleet_section.push((format!("fleet_plans_per_sec_k{k}"), Json::num(1.0 / s.median)));
    }
    update_bench_json(report, "sharded_search_fleet", Json::Obj(fleet_section))
        .expect("write BENCH_shard.json");

    // ---- cold-key submit latency ------------------------------------
    // Each measurement uses a genuinely fresh padded key (n stepped by
    // one 32-wide tile), so every submit walks the cold path: forecasts
    // scattered to the four workers, gathered, then the request routed.
    let mix = seqs;
    let mut n_step = 1 << 16;
    let mut worker_samples = Vec::new();
    for i in 0..24usize {
        n_step += 32; // fresh padded key every iteration
        let seqname = mix[i % mix.len()];
        let t0 = Instant::now();
        let ticket = client.submit(SubmitRequest::new(seqname, 32, n_step)).unwrap();
        worker_samples.push(t0.elapsed().as_secs_f64());
        let _ = ticket.wait(); // stub backend error — drain the ticket
    }
    let worker = Summary::from_samples(&worker_samples);
    println!(
        "cold-key submit (worker forecasts, 4 devices): median {:.2} ms",
        worker.median * 1e3
    );

    // the old path for comparison: N planner runs on the calling
    // thread (CostModel::costs with no lanes — today's fallback)
    let local_model = CostModel::new(Arc::new(DeviceRegistry::simulated(4, &dir)));
    let mut local_samples = Vec::new();
    for i in 0..24usize {
        n_step += 32;
        let seqname = mix[i % mix.len()];
        let t0 = Instant::now();
        let _ = black_box(local_model.costs(seqname, 32, n_step)).unwrap();
        local_samples.push(t0.elapsed().as_secs_f64());
    }
    let local = Summary::from_samples(&local_samples);
    println!(
        "cold-key forecast (submitting thread, 4 devices): median {:.2} ms",
        local.median * 1e3
    );
    let stats = client.routing_stats();
    update_bench_json(
        report,
        "cold_key",
        Json::Obj(vec![
            ("submit_ms_worker_forecasts".into(), Json::num(worker.median * 1e3)),
            ("forecast_ms_submitting_thread".into(), Json::num(local.median * 1e3)),
            ("worker_forecasts".into(), Json::num(stats.worker_forecasts as f64)),
            ("local_fallbacks".into(), Json::num(stats.local_forecasts as f64)),
        ]),
    )
    .expect("write BENCH_shard.json");

    let _ = engine.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
    println!("wrote {BENCH_SHARD_JSON}");
}
