//! Ablation study over the design choices DESIGN.md calls out:
//!
//! * **fusion off** — best unfused plan vs best overall (what fusion
//!   alone buys, isolating it from block/iteration tuning);
//! * **serial iterations off** (iters = 1) — the paper's grid-shrinking
//!   trick disabled;
//! * **single loop axis** — forcing the row axis instead of searching
//!   both (Algorithm 3's choice matters for accumulation direction);
//! * **pruning off** — space size without the on-chip domination rule;
//! * **prediction-only selection** — take rank-1 by prediction without
//!   the empirical search (Table 4's "first implementation" column).
//!
//! `cargo bench --bench ablation`

use fusebla::autotune;
use fusebla::bench_support::eval_size;
use fusebla::coordinator::Context;
use fusebla::fusion::{self, ImplAxes};
use fusebla::sequences;
use fusebla::sim::simulate_seq;
use fusebla::util::Table;

fn main() {
    let ctx = Context::new();
    let mut t = Table::new(
        "ablation — simulated GFlops of the chosen plan per configuration",
        &[
            "Sequence", "full search", "no fusion", "iters=1", "row-axis only",
            "prediction-only",
        ],
    );
    for name in ["axpydot", "bicgk", "gemver", "vadd", "waxpby"] {
        let seq = sequences::by_name(name).unwrap();
        let p = eval_size(&seq);
        let flops = seq.flops.eval(p);
        let (prog, graph) = seq.graph(&ctx.lib);
        let gflops_of = |plan: &fusebla::ir::plan::SeqPlan| {
            simulate_seq(&ctx.dev, plan, p, flops).gflops
        };

        let full = autotune::search(
            &prog, &ctx.lib, &graph, &ctx.dev, &ctx.db, &ImplAxes::default(), p,
        );

        // no fusion: singletons only
        let no_fusion = {
            let space = fusion::space::Space::build(&prog, &ctx.lib, &graph, &[], &ImplAxes::default());
            let mut best = f64::MAX;
            let mut best_plan = None;
            for (pi, choice) in space.combinations() {
                let impls: Vec<_> = space
                    .combination(pi, &choice)
                    .iter()
                    .map(|p| p.fi.clone())
                    .collect();
                let plan = fusebla::codegen::compile_seq(&prog, &ctx.lib, &impls, "nofusion");
                let t = simulate_seq(&ctx.dev, &plan, p, flops).seconds;
                if t < best {
                    best = t;
                    best_plan = Some(plan);
                }
            }
            best_plan.unwrap()
        };

        let iters1 = autotune::search(
            &prog,
            &ctx.lib,
            &graph,
            &ctx.dev,
            &ctx.db,
            &ImplAxes {
                iters: vec![1],
                ..ImplAxes::default()
            },
            p,
        );
        let row_only = autotune::search(
            &prog,
            &ctx.lib,
            &graph,
            &ctx.dev,
            &ctx.db,
            &ImplAxes {
                both_iter_dims: false,
                ..ImplAxes::default()
            },
            p,
        );
        let pred_only = autotune::compile_first(
            &prog, &ctx.lib, &graph, &ctx.db, &ImplAxes::default(), p,
        );

        t.row(&[
            name.to_uppercase(),
            format!("{:.1}", gflops_of(&full.best)),
            format!("{:.1}", gflops_of(&no_fusion)),
            format!("{:.1}", gflops_of(&iters1.best)),
            format!("{:.1}", gflops_of(&row_only.best)),
            format!("{:.1}", gflops_of(&pred_only.plan)),
        ]);
    }
    t.print();

    // pruning ablation: space sizes with/without domination pruning
    let mut t2 = Table::new(
        "ablation — pruned vs raw optimization-space size",
        &["Sequence", "pruned combos", "raw impls (largest part)"],
    );
    for name in ["bicgk", "gemver", "waxpby"] {
        let seq = sequences::by_name(name).unwrap();
        let (prog, graph) = seq.graph(&ctx.lib);
        let fusions = fusion::enumerate_fusions(&prog, &ctx.lib, &graph);
        let axes = ImplAxes::default();
        let space = fusion::space::Space::build(&prog, &ctx.lib, &graph, &fusions, &axes);
        let raw_largest = prog
            .call_ids()
            .map(|c| {
                let s = fusion::Fusion::singleton(c, &prog, &ctx.lib);
                fusion::gen_impls(&prog, &ctx.lib, &graph, &s, &axes).len()
            })
            .max()
            .unwrap_or(0);
        t2.row(&[
            name.to_uppercase(),
            space.combination_count().to_string(),
            raw_largest.to_string(),
        ]);
    }
    t2.print();
}
