//! Regenerates the paper's Figure 6: GEMVER GFlops vs matrix size
//! (fused plan vs CUBLAS baseline, GTX 480 model) + a real-execution
//! series over the artifact catalog sizes.
//!
//! `cargo bench --bench fig6`

use fusebla::bench_support::figure;
use fusebla::coordinator::{synth_inputs, Context, Coordinator};
use fusebla::util::Table;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let ctx = Context::new();
    let table = figure(&ctx, "gemver");
    table.print();
    println!("TSV:\n{}", table.to_tsv());

    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("(skip real-execution series: artifacts not built)");
        return;
    }
    let coord = Coordinator::new(Arc::new(Context::new()), dir).expect("coordinator");
    let mut t = Table::new(
        "GEMVER real execution (CPU PJRT)",
        &["n", "fused ms", "cublas ms", "speedup"],
    );
    for (m, n) in coord.runtime().sizes_of("gemver", "fused") {
        let time_of = |variant: &str| {
            coord.runtime().warmup("gemver", variant, m, n).unwrap();
            let inputs = synth_inputs(coord.runtime(), "gemver", variant, m, n, 3);
            let mut samples: Vec<f64> = (0..5)
                .map(|_| {
                    coord
                        .runtime()
                        .run_seq("gemver", variant, m, n, &inputs)
                        .unwrap()
                        .seconds
                })
                .collect();
            samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            samples[2]
        };
        let tf = time_of("fused");
        let tc = time_of("cublas");
        t.row(&[
            n.to_string(),
            format!("{:.2}", tf * 1e3),
            format!("{:.2}", tc * 1e3),
            format!("{:.2}x", tc / tf),
        ]);
    }
    t.print();
}
