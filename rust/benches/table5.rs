//! Regenerates the paper's Table 5: wallclock of compiling the first
//! (best-predicted) implementation, generating all implementations, and
//! the empirical search — on this machine, with the paper's times for
//! reference.
//!
//! `cargo bench --bench table5`

use fusebla::bench_support::{table5, Evaluator};
use fusebla::coordinator::Context;

fn main() {
    let ctx = Context::new();
    let mut ev = Evaluator::new();
    let table = table5(&ctx, &mut ev);
    table.print();
    println!("TSV:\n{}", table.to_tsv());
}
