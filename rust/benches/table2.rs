//! Regenerates the paper's Table 2: GFlops of the compiler's best plan
//! vs the CUBLAS baseline for all eleven sequences, on the GTX 480
//! model, with the paper's numbers alongside.
//!
//! `cargo bench --bench table2`

use fusebla::bench_support::{table2, Evaluator};
use fusebla::coordinator::Context;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let ctx = Context::new();
    let mut ev = Evaluator::new();
    let table = table2(&ctx, &mut ev);
    table.print();
    println!("(generated in {:.2} s)", t0.elapsed().as_secs_f64());
    println!("TSV:\n{}", table.to_tsv());
}
