//! Regenerates the paper's Figure 5: BiCGK GFlops vs matrix size for the
//! fused plan and the CUBLAS baseline (GTX 480 model), plus — when
//! artifacts are built — a real-execution series on the CPU PJRT
//! backend for the catalog sizes.
//!
//! `cargo bench --bench fig5`

use fusebla::bench_support::figure;
use fusebla::coordinator::{synth_inputs, Context, Coordinator};
use fusebla::util::Table;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let ctx = Context::new();
    let table = figure(&ctx, "bicgk");
    table.print();
    println!("TSV:\n{}", table.to_tsv());
    real_series("bicgk");
}

/// Real-execution companion series (wallclock on CPU-PJRT; interpret-
/// mode kernels — correctness substrate, not a GPU-speed proxy).
fn real_series(seq: &str) {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("(skip real-execution series: artifacts not built)");
        return;
    }
    let coord = Coordinator::new(Arc::new(Context::new()), dir).expect("coordinator");
    let mut t = Table::new(
        &format!("{} real execution (CPU PJRT)", seq.to_uppercase()),
        &["n", "fused ms", "cublas ms", "speedup"],
    );
    for (m, n) in coord.runtime().sizes_of(seq, "fused") {
        let time_of = |variant: &str| {
            coord.runtime().warmup(seq, variant, m, n).unwrap();
            let inputs = synth_inputs(coord.runtime(), seq, variant, m, n, 3);
            // median of 5
            let mut samples: Vec<f64> = (0..5)
                .map(|_| {
                    coord
                        .runtime()
                        .run_seq(seq, variant, m, n, &inputs)
                        .unwrap()
                        .seconds
                })
                .collect();
            samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            samples[2]
        };
        let tf = time_of("fused");
        let tc = time_of("cublas");
        t.row(&[
            n.to_string(),
            format!("{:.2}", tf * 1e3),
            format!("{:.2}", tc * 1e3),
            format!("{:.2}x", tc / tf),
        ]);
    }
    t.print();
}
