//! Fleet serving study — the `multigpu` scaling bench's serve-path
//! sibling: end-to-end requests/sec of the engine at 1, 2 and 4
//! simulated devices (heterogeneous profiles, predictor-guided
//! routing), plus the routing-decision overhead itself.
//!
//! The routing section is offline-safe: the cost model is a pure
//! function of the simulated calibrations, so the per-submit decision
//! cost is measured even without built artifacts. The throughput
//! section gates on `artifacts/manifest.txt` like the other execution
//! benches.
//!
//! Results merge into `BENCH_fleet.json` so the fleet trajectory
//! (req/s per device count, routing overhead) stays diffable across
//! PRs.
//!
//! `cargo bench --bench fleet`

use fusebla::bench_support::report::update_bench_json;
use fusebla::fleet::{CostModel, DeviceRegistry};
use fusebla::util::manifest::Manifest;
use fusebla::util::stats::{bench, black_box};
use fusebla::util::{fmt_duration, Json, Summary};
use fusebla::{Engine, EngineConfig, SubmitRequest, Ticket};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The fleet report file (separate from `BENCH_hotpath.json`: device
/// counts, not dispatch paths, are the axis here).
const BENCH_FLEET_JSON: &str = "BENCH_fleet.json";
const N_REQUESTS: usize = 96;

fn main() {
    let dir = Path::new("artifacts");
    let report = Path::new(BENCH_FLEET_JSON);

    // Routing-decision overhead: forecasts are cached per (seq, padded
    // size), so the steady-state per-submit cost is a map probe plus an
    // argmin over the roster — the number a router must keep tiny to
    // stay off the hot path.
    let registry = Arc::new(DeviceRegistry::simulated(4, dir));
    let model = CostModel::new(registry);
    let keys = [("waxpby", 32, 65536), ("vadd", 32, 65536), ("sscal", 32, 65536)];
    for (seq, m, n) in keys {
        let _ = model.route(seq, m, n, &[0, 0, 0, 0]); // warm the forecast cache
    }
    let depths = [3u64, 1, 0, 2];
    let samples = bench(100, 10_000, || {
        let mut acc = 0usize;
        for (seq, m, n) in keys {
            acc += model.route(seq, m, n, &depths);
        }
        black_box(acc)
    });
    let s = Summary::from_samples(&samples);
    let per_decision_ns = s.median / keys.len() as f64 * 1e9;
    println!(
        "routing decision (warm, 4 devices): median {per_decision_ns:.0} ns \
         (mean {:.0} ns over {} samples)",
        s.mean / keys.len() as f64 * 1e9,
        s.n
    );
    let routing = Json::Obj(vec![
        ("devices".into(), Json::num(4.0)),
        ("decision_ns_median".into(), Json::num(per_decision_ns)),
        ("decision_ns_mean".into(), Json::num(s.mean / keys.len() as f64 * 1e9)),
    ]);
    update_bench_json(report, "routing", routing).expect("write BENCH_fleet.json");

    // Throughput scaling: the same mixed-key burst served by fleets of
    // 1, 2 and 4 simulated devices.
    if !dir.join("manifest.txt").exists() {
        println!("(artifacts not built: skipping fleet throughput bench)");
        return;
    }
    let manifest = Manifest::load(&dir.join("manifest.txt")).expect("manifest");
    let mix = ["waxpby", "vadd", "sscal", "axpydot"];
    let mut prepared = Vec::new();
    for seq in mix {
        let Some(&(m, n)) = manifest.sizes(seq, "fused").first() else {
            println!("(no {seq} artifacts: skipping fleet throughput bench)");
            return;
        };
        prepared.push((seq, m, n));
    }

    let mut throughput = Vec::new();
    for g in [1usize, 2, 4] {
        let registry = Arc::new(DeviceRegistry::simulated(g, dir));
        let cfg = EngineConfig {
            batch_window: Duration::from_millis(5),
            max_batch: N_REQUESTS,
            ..EngineConfig::default()
        };
        let engine = Engine::start_fleet(registry, dir, cfg).expect("fleet engine");
        let client = engine.client();
        // warmup: resolve every (key, device) once so the timed burst
        // measures dispatch + routing, not XLA compilation
        for id in client.devices() {
            for (seq, m, n) in &prepared {
                client
                    .submit(SubmitRequest::new(*seq, *m, *n).pin(id.name()))
                    .expect("warmup submit")
                    .wait()
                    .expect("warmup run");
            }
        }
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..N_REQUESTS)
            .map(|i| {
                let (seq, m, n) = prepared[i % prepared.len()];
                client
                    .submit(SubmitRequest::new(seq, m, n).synth(i as u64))
                    .expect("burst submit")
            })
            .collect();
        let ok = tickets.into_iter().map(Ticket::wait).filter(Result::is_ok).count();
        let dt = t0.elapsed().as_secs_f64();
        let fleet = engine.shutdown_fleet();
        assert_eq!(ok, N_REQUESTS, "every burst request must succeed");
        let rps = N_REQUESTS as f64 / dt;
        println!("G={g}: {ok}/{N_REQUESTS} in {} → {rps:.0} req/s", fmt_duration(dt));
        for (id, m) in &fleet.devices {
            println!("  device {id}: {} request(s), {} batch(es)", m.requests, m.batches);
        }
        throughput.push((format!("req_per_sec_g{g}"), Json::num(rps)));
    }
    throughput.push(("requests".into(), Json::num(N_REQUESTS as f64)));
    update_bench_json(report, "throughput", Json::Obj(throughput)).expect("write BENCH_fleet.json");
    println!("wrote {BENCH_FLEET_JSON}");
}
