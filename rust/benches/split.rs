//! Split-serving study — where G-way row-block splitting starts to
//! win, and what the split-aware routing decision costs.
//!
//! Entirely offline-safe: [`CostModel::split_profiles`] is a pure
//! function of the simulated calibrations and the interconnect
//! profile, so the crossover sweep and the per-submit decision cost
//! need no built artifacts.
//!
//! Two axes:
//! - **Crossover**: the smallest square bicgk size whose 2-way split
//!   is forecast faster than single-device execution, on PCIe 2.0 x16
//!   vs NVLink twins — the interconnect moves the crossover, which is
//!   the point of modelling it.
//! - **Decision overhead**: the warm split-aware `decide` cost — the
//!   number that must stay tiny for the router to sit on the submit
//!   path.
//!
//! Results merge into `BENCH_fleet.json` under `split` so the
//! trajectory stays diffable across PRs.
//!
//! `cargo bench --bench split`

use fusebla::bench_support::report::update_bench_json;
use fusebla::fleet::{CostModel, DeviceRegistry, SplitPolicy};
use fusebla::sim::multi::Interconnect;
use fusebla::sim::DeviceModel;
use fusebla::util::stats::{bench, black_box};
use fusebla::util::{Json, Summary};
use std::path::Path;
use std::sync::Arc;

const BENCH_FLEET_JSON: &str = "BENCH_fleet.json";
const SIZES: [usize; 6] = [512, 1024, 2048, 4096, 8192, 16384];

/// Twin GTX 480s over the given link — identical devices, so the
/// forecast ratio isolates the split's own costs (scatter, partial
/// reduces, gather) from heterogeneity.
fn twin_model(tag: &str, link: Interconnect) -> CostModel {
    let dir = std::env::temp_dir().join(format!("fusebla_splitbench_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut twin = DeviceModel::gtx480();
    twin.name = "GeForce GTX 480 (model) #2".into();
    let reg = DeviceRegistry::new(vec![DeviceModel::gtx480(), twin], &dir)
        .expect("twin registry")
        .with_link(link);
    CostModel::new(Arc::new(reg))
}

/// The smallest swept square size whose G-way ratio beats 1.0 on
/// device 0 (0 when splitting never wins in the sweep).
fn crossover(model: &CostModel, g: usize) -> usize {
    for m in SIZES {
        let profiles = model.split_profiles("bicgk", m, m).expect("bicgk is a built-in");
        let Some(p) = profiles.first() else { return 0 };
        if p.ratio(g) < 1.0 {
            return m;
        }
    }
    0
}

fn main() {
    let report = Path::new(BENCH_FLEET_JSON);
    let mut section: Vec<(String, Json)> = Vec::new();

    for (name, link) in [("pcie", Interconnect::pcie2_x16()), ("nvlink", Interconnect::nvlink())] {
        let model = twin_model(name, link);
        for g in [2usize, 4] {
            let at = crossover(&model, g);
            println!("crossover {name} G={g}: m = {at} (0 = never in sweep)");
            section.push((format!("crossover_m_{name}_g{g}"), Json::num(at as f64)));
        }
        let profiles = model.split_profiles("bicgk", 8192, 8192).expect("bicgk is a built-in");
        let p = profiles.first().expect("twin registry has devices");
        println!(
            "{name} @ 8192x8192: ratio(2) = {:.3}, ratio(4) = {:.3}, best G = {}",
            p.ratio(2),
            p.ratio(4),
            p.best_g()
        );
        section.push((format!("ratio_g2_m8192_{name}"), Json::num(p.ratio(2))));
        section.push((format!("ratio_g4_m8192_{name}"), Json::num(p.ratio(4))));
        section.push((format!("best_g_m8192_{name}"), Json::num(p.best_g() as f64)));
    }

    // Warm split-aware decision cost: forecasts cached, so this is the
    // steady-state per-submit price of considering a split at all.
    let model = twin_model("decide", Interconnect::pcie2_x16());
    let policy = Some(SplitPolicy {
        max_g: 2,
        min_rows: 256,
    });
    let _ = model.decide("bicgk", 8192, 8192, &[0, 0], policy); // warm the caches
    let samples = bench(100, 10_000, || {
        black_box(model.decide("bicgk", 8192, 8192, &[0, 0], policy).owner())
    });
    let s = Summary::from_samples(&samples);
    let ns = s.median * 1e9;
    println!("split-aware routing decision (warm, twins): median {ns:.0} ns over {} samples", s.n);
    section.push(("decision_ns_median".into(), Json::num(ns)));

    update_bench_json(report, "split", Json::Obj(section)).expect("write BENCH_fleet.json");
    println!("wrote {BENCH_FLEET_JSON}");
}
