//! End-to-end driver: a full BiConjugate Gradient solver whose per-
//! iteration matrix kernels (q = A p, s = Aᵀ r̃ — the paper's BiCGK
//! sequence, its motivating application) execute as AOT-compiled Pallas
//! artifacts through the serving engine: the solver submits typed
//! requests over a `Client`, never touching channels or the runtime.
//!
//! This proves all three layers compose on a real workload: the L3
//! engine's planner chooses the fused plan (observable on the returned
//! `RunResult::variant`), the L1 fused kernel (lowered once at build
//! time) does the matrix work, and the solver converges to the same
//! answer the unfused (CUBLAS-decomposition) variant produces — while
//! running fewer kernels per iteration.
//!
//! Run: `make artifacts && cargo run --release --example bicg_solver`

use fusebla::coordinator::{Context, PlanChoice};
use fusebla::runtime::Tensor;
use fusebla::util::Prng;
use fusebla::{Client, Engine, SubmitRequest};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

const N: usize = 256;
const MAX_ITERS: usize = 200;
const TOL: f64 = 1e-5;

fn dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum()
}

fn norm(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

/// One BiCG run; the matrix products go through the engine with the
/// given plan choice. Returns (solution, residual history, matvec time,
/// kernel count).
fn bicg(
    client: &Client,
    variant: PlanChoice,
    a: &Tensor,
    b: &[f32],
) -> (Vec<f32>, Vec<f64>, f64, usize) {
    let n = b.len();
    let mut x = vec![0.0f32; n];
    let mut r: Vec<f32> = b.to_vec(); // r = b - A x0 = b
    let mut rt = r.clone();
    let mut p = r.clone();
    let mut pt = rt.clone();
    let mut rho = dot(&r, &rt);
    let mut history = vec![norm(&r) / norm(b)];
    let mut matvec_secs = 0.0;
    let mut kernels = 0usize;

    for _ in 0..MAX_ITERS {
        // q = A p and s = Aᵀ p̃ — the BiCGK sequence, one fused kernel
        // (or two unfused ones for the CUBLAS variant).
        let mut inputs = BTreeMap::new();
        inputs.insert("A".to_string(), a.clone());
        inputs.insert("p".to_string(), Tensor::vector(p.clone()));
        inputs.insert("r".to_string(), Tensor::vector(pt.clone()));
        let t0 = Instant::now();
        let res = client
            .submit(SubmitRequest::new("bicgk", n, n).inputs(inputs).variant(variant))
            .expect("submit")
            .wait()
            .expect("bicgk kernels");
        matvec_secs += t0.elapsed().as_secs_f64();
        kernels += res.stages.len();
        let q = &res.env["q"].data;
        let s = &res.env["s"].data;

        let alpha = rho / dot(&pt, q);
        for i in 0..n {
            x[i] += (alpha * p[i] as f64) as f32;
            r[i] -= (alpha * q[i] as f64) as f32;
            rt[i] -= (alpha * s[i] as f64) as f32;
        }
        let rel = norm(&r) / norm(b);
        history.push(rel);
        if rel < TOL {
            break;
        }
        let rho_new = dot(&r, &rt);
        let beta = rho_new / rho;
        rho = rho_new;
        for i in 0..n {
            p[i] = r[i] + (beta * p[i] as f64) as f32;
            pt[i] = rt[i] + (beta * pt[i] as f64) as f32;
        }
    }
    (x, history, matvec_secs, kernels)
}

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let engine =
        Engine::start(Arc::new(Context::with_calibration_cache(dir)), dir).expect("engine");
    let client = engine.client();

    // A diagonally dominant system (guaranteed convergence), b = A·1.
    let mut rng = Prng::new(2024);
    let mut a = vec![0.0f32; N * N];
    for i in 0..N {
        for j in 0..N {
            a[i * N + j] = 0.05 * rng.f32_pm1();
        }
        a[i * N + i] = 4.0 + rng.f64() as f32;
    }
    let a = Tensor::matrix(N, N, a);
    let mut b = vec![0.0f32; N];
    for i in 0..N {
        b[i] = (0..N).map(|j| a.data[i * N + j]).sum::<f32>();
    }

    // Plan decision by the engine's planner (keyed by the problem size
    // the solver will actually request) — a control query, nothing
    // executes. This also warms the plan cache.
    let choice = client.plan("bicgk", N, N).expect("plan");
    println!("engine plan for bicgk: {}", choice.as_str());
    // warm both variants' executables so the timed loops below measure
    // dispatch + kernels, not first-use XLA compilation
    for v in [PlanChoice::Fused, PlanChoice::Cublas] {
        let mut w = BTreeMap::new();
        w.insert("A".to_string(), a.clone());
        w.insert("p".to_string(), Tensor::vector(b.clone()));
        w.insert("r".to_string(), Tensor::vector(b.clone()));
        client
            .submit(SubmitRequest::new("bicgk", N, N).inputs(w).variant(v))
            .expect("submit")
            .wait()
            .expect("warmup");
    }

    println!("\nsolving {N}x{N} system with BiCG (tol {TOL:.0e})");
    let t0 = Instant::now();
    let (x_fused, hist_f, mv_f, k_f) = bicg(&client, PlanChoice::Fused, &a, &b);
    let t_fused = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let (x_cublas, hist_c, mv_c, k_c) = bicg(&client, PlanChoice::Cublas, &a, &b);
    let t_cublas = t1.elapsed().as_secs_f64();

    // loss-curve style convergence log
    println!("\n  iter   fused rel-resid   unfused rel-resid");
    for i in (0..hist_f.len().max(hist_c.len())).step_by(2) {
        let f = hist_f.get(i).map(|v| format!("{v:.3e}")).unwrap_or_default();
        let c = hist_c.get(i).map(|v| format!("{v:.3e}")).unwrap_or_default();
        println!("  {i:4}   {f:>15}   {c:>15}");
    }

    let err_f = x_fused.iter().map(|v| (v - 1.0).abs()).fold(0.0f32, f32::max);
    let err_c = x_cublas.iter().map(|v| (v - 1.0).abs()).fold(0.0f32, f32::max);
    println!("\nfused   : {} iterations, {} kernel launches, matvec {:.1} ms, total {:.1} ms, |x-1|max {err_f:.2e}",
        hist_f.len() - 1, k_f, mv_f * 1e3, t_fused * 1e3);
    println!("unfused : {} iterations, {} kernel launches, matvec {:.1} ms, total {:.1} ms, |x-1|max {err_c:.2e}",
        hist_c.len() - 1, k_c, mv_c * 1e3, t_cublas * 1e3);
    println!("kernel launches per iteration: fused 1 vs unfused 2 (the paper's point)");
    println!("matvec speedup (this CPU, interpret-mode kernels): {:.2}x", mv_c / mv_f);

    let metrics = engine.shutdown();
    println!(
        "engine served {} requests ({} failures, plan cache {} miss(es))",
        metrics.requests, metrics.failures, metrics.plan_cache_misses
    );

    assert!(*hist_f.last().unwrap() < TOL, "fused solve did not converge");
    assert!(*hist_c.last().unwrap() < TOL, "unfused solve did not converge");
    assert!(err_f < 1e-2 && err_c < 1e-2, "wrong solution");
    assert_eq!(k_f * 2, k_c, "fused must halve the kernel count");
    println!("bicg_solver OK");
}
