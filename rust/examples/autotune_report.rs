//! Optimization-space exploration report for every sequence: space size,
//! prediction accuracy (rank of best / first / worst), and compile +
//! search wallclock — the data behind Tables 4 and 5, printed per
//! sequence with the chosen plan's structure.
//!
//! `Context::new` reloads the routine calibration from
//! `artifacts/calibration.txt` when a catalog is present (keyed by
//! device + library fingerprint), so repeat runs skip the per-process
//! calibration sweep.
//!
//! Run: `cargo run --release --example autotune_report`

use fusebla::autotune;
use fusebla::bench_support::{eval_axes, eval_size};
use fusebla::coordinator::Context;
use fusebla::sequences;
use fusebla::util::{fmt_duration, Table};
use std::time::Instant;

fn main() {
    let t_ctx = Instant::now();
    let ctx = Context::new();
    println!(
        "routine DB ready in {}: {} calibrated entries on {}",
        fmt_duration(t_ctx.elapsed().as_secs_f64()),
        ctx.db.len(),
        ctx.dev.name
    );
    let mut t = Table::new(
        "optimization-space report",
        &[
            "Sequence", "Impls", "Best rank", "First %", "Worst %", "Kernels",
            "t_first", "t_all", "t_search",
        ],
    );
    for seq in sequences::all() {
        let (prog, graph) = seq.graph(&ctx.lib);
        let p = eval_size(&seq);
        // trimmed axes for the widest scripts (GEMVER) keep the report
        // interactive — same policy as bench_support
        let axes = eval_axes(&seq);
        let r = autotune::search(&prog, &ctx.lib, &graph, &ctx.dev, &ctx.db, &axes, p);
        t.row(&[
            seq.name.to_uppercase(),
            r.impl_count.to_string(),
            r.best_rank.to_string(),
            format!("{:.1}", r.first_pct),
            r.worst_pct.map(|w| format!("{w:.1}")).unwrap_or_else(|| "n/a".into()),
            format!(
                "{} ({})",
                r.best.kernels.len(),
                r.best
                    .kernels
                    .iter()
                    .map(|k| k.members.len().to_string())
                    .collect::<Vec<_>>()
                    .join("+")
            ),
            fmt_duration(r.t_first),
            fmt_duration(r.t_all),
            fmt_duration(r.t_search),
        ]);
    }
    t.print();
    println!("Paper reference (Table 4): GEMVER has the largest space (1271), best often not rank 1 (AXPYDOT 4th, SGEMV 14th, GEMVER 54th), worst implementations fall to 29–64 %.");
}
