//! GEMVER pipeline: the paper's biggest win (2.61×) end-to-end, served
//! through the batching `Engine`/`Client` API.
//!
//! Runs the three-statement GEMVER sequence (B = A + u₁v₁ᵀ + u₂v₂ᵀ;
//! x = βBᵀy + z; w = αBx) in both variants:
//!
//! * fused   — 2 kernels (the compiler's plan: {ger2 + gemtv} then gemv)
//! * cublas  — 6 kernels (copy, ger, ger, copy, gemv, gemv — the
//!             in-place CUBLAS API forces the copies)
//!
//! verifies both against the Rust reference oracle, reports the
//! kernel-count reduction, then fires a same-key burst to show the
//! engine grouping requests into multi-input batches.
//!
//! Run: `make artifacts && cargo run --release --example gemver_pipeline`

use fusebla::coordinator::{Context, PlanChoice};
use fusebla::runtime::refcheck;
use fusebla::util::fmt_duration;
use fusebla::{Engine, EngineConfig, SubmitRequest};
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let cfg = EngineConfig {
        batch_window: Duration::from_millis(20),
        max_batch: 64,
        ..EngineConfig::default()
    };
    let ctx = Arc::new(Context::with_calibration_cache(dir));
    let engine = Engine::with_config(ctx, dir, cfg).expect("engine");
    let client = engine.client();
    let (m, n) = (512, 512);

    // warm both variants so the timed runs below measure dispatch +
    // kernels, not first-use XLA compilation
    for &variant in &[PlanChoice::Fused, PlanChoice::Cublas] {
        client
            .submit(SubmitRequest::new("gemver", m, n).synth(9).variant(variant))
            .expect("submit")
            .wait()
            .expect("warmup");
    }

    let mut stage_counts = Vec::new();
    for &variant in &[PlanChoice::Fused, PlanChoice::Cublas] {
        let res = client
            .submit(SubmitRequest::new("gemver", m, n).synth(9).variant(variant))
            .expect("submit")
            .wait()
            .expect("gemver run");
        // the result env keeps the free inputs → it is its own oracle input
        let err = refcheck::max_abs_error("gemver", &res.env, &res.env);
        println!(
            "gemver.{:7} @ {m}x{n}: {} kernel(s), total {}, max abs err {:.2e}",
            res.variant,
            res.stages.len(),
            fmt_duration(res.seconds),
            err
        );
        for s in &res.stages {
            println!("    {:42} {}", s.key, fmt_duration(s.seconds));
        }
        assert!(err < 5e-2, "verification failed: {err}");
        stage_counts.push(res.stages.len());
    }

    // The structural claim of the paper, independent of wallclock:
    println!(
        "\nkernel launches: fused {} vs CUBLAS {} (matrix passes: 3 vs 8 — the 2.61x)",
        stage_counts[0], stage_counts[1]
    );
    assert_eq!(stage_counts[0], 2);
    assert_eq!(stage_counts[1], 6);

    // A same-key burst: the engine drains the queue and executes one
    // multi-input batch per (seq, padded size, device, plan) key.
    // Snapshot the cumulative counters first so the printed numbers are
    // the burst's own, not the singleton runs' above.
    let before = engine.metrics();
    let tickets: Vec<_> = (0..8u64)
        .map(|seed| {
            client
                .submit(SubmitRequest::new("gemver", m, n).synth(seed))
                .expect("submit")
        })
        .collect();
    for t in tickets {
        t.wait().expect("burst request");
    }
    let metrics = engine.shutdown();
    println!(
        "burst of 8 same-key requests: {} batch(es) (max batch size {}); engine totals: {} batches / {} requests",
        metrics.batches.saturating_sub(before.batches),
        metrics.max_batch_size,
        metrics.batches,
        metrics.requests
    );
    println!("gemver_pipeline OK");
}
