//! GEMVER pipeline: the paper's biggest win (2.61×) end-to-end.
//!
//! Runs the three-statement GEMVER sequence (B = A + u₁v₁ᵀ + u₂v₂ᵀ;
//! x = βBᵀy + z; w = αBx) through the coordinator in both variants:
//!
//! * fused   — 2 kernels (the compiler's plan: {ger2 + gemtv} then gemv)
//! * cublas  — 6 kernels (copy, ger, ger, copy, gemv, gemv — the
//!             in-place CUBLAS API forces the copies)
//!
//! and verifies both against the Rust reference oracle, reporting the
//! kernel-count reduction and per-stage timings.
//!
//! Run: `make artifacts && cargo run --release --example gemver_pipeline`

use fusebla::coordinator::{synth_inputs, Context, Coordinator, PlanChoice};
use fusebla::util::fmt_duration;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let mut coord = Coordinator::new(Arc::new(Context::new()), dir).expect("coordinator");
    let (m, n) = (512, 512);

    for &variant in &[PlanChoice::Fused, PlanChoice::Cublas] {
        let inputs = synth_inputs(coord.runtime(), "gemver", variant.as_str(), m, n, 9);
        coord
            .runtime()
            .warmup("gemver", variant.as_str(), m, n)
            .expect("warmup");
        let (res, err) = coord
            .run_checked("gemver", variant, m, n, &inputs)
            .expect("gemver run");
        println!(
            "gemver.{:7} @ {m}x{n}: {} kernel(s), total {}, max abs err {:.2e}",
            variant.as_str(),
            res.stages.len(),
            fmt_duration(res.seconds),
            err
        );
        for s in &res.stages {
            println!("    {:42} {}", s.key, fmt_duration(s.seconds));
        }
        assert!(err < 5e-2, "verification failed: {err}");
    }

    // The structural claim of the paper, independent of wallclock:
    let f = coord
        .runtime()
        .run_seq(
            "gemver",
            "fused",
            m,
            n,
            &synth_inputs(coord.runtime(), "gemver", "fused", m, n, 9),
        )
        .unwrap();
    let c = coord
        .runtime()
        .run_seq(
            "gemver",
            "cublas",
            m,
            n,
            &synth_inputs(coord.runtime(), "gemver", "cublas", m, n, 9),
        )
        .unwrap();
    println!(
        "\nkernel launches: fused {} vs CUBLAS {} (matrix passes: 3 vs 8 — the 2.61x)",
        f.stages.len(),
        c.stages.len()
    );
    assert_eq!(f.stages.len(), 2);
    assert_eq!(c.stages.len(), 6);
    println!("gemver_pipeline OK");
}
