//! Quickstart: the full fusebla pipeline on the BiCGK sequence.
//!
//! 1. compile a script against the elementary-function library;
//! 2. let the fusion compiler search the optimization space;
//! 3. inspect the generated (pseudo-CUDA) fused kernel;
//! 4. compare fused vs unfused on the GTX 480 model;
//! 5. execute the corresponding AOT Pallas artifact through the serving
//!    engine (`Engine::start` + `Client::submit`) and verify against
//!    the reference oracle.
//!
//! Run: `cargo run --release --example quickstart`
//! (needs `make artifacts` for step 5; steps 1–4 work without)

use fusebla::autotune;
use fusebla::bench_support::eval_size;
use fusebla::codegen::cuda::emit_seq;
use fusebla::coordinator::{Context, PlanChoice};
use fusebla::fusion::ImplAxes;
use fusebla::graph::DepGraph;
use fusebla::runtime::refcheck;
use fusebla::script::compile_script;
use fusebla::sequences;
use fusebla::sim::simulate_seq;
use fusebla::{Engine, SubmitRequest};
use std::path::Path;
use std::sync::Arc;

const SCRIPT: &str = "
    # BiCGK: q = A p ; s = A' r   (paper Listing 1)
    matrix<MxN> A;
    vector<N> p, s;
    vector<M> q, r;
    input A, p, r;
    q = sgemv(A, p);
    s = sgemtv(A, r);
    return q, s;
";

fn main() {
    // --- 1. compile the script -------------------------------------------
    let ctx = Context::new();
    let prog = compile_script("bicgk", SCRIPT, &ctx.lib).expect("script compiles");
    let graph = DepGraph::build(&prog, &ctx.lib);
    println!(
        "script 'bicgk': {} calls, {} inputs, {} outputs",
        prog.calls.len(),
        prog.inputs.len(),
        prog.outputs.len()
    );

    // --- 2. search the optimization space ---------------------------------
    let seq = sequences::by_name("bicgk").unwrap();
    let p = eval_size(&seq);
    let report = autotune::search(
        &prog, &ctx.lib, &graph, &ctx.dev, &ctx.db, &ImplAxes::default(), p,
    );
    println!(
        "optimization space: {} implementations; best found at rank {}",
        report.impl_count, report.best_rank
    );

    // --- 3. show the generated kernel --------------------------------------
    println!("\n--- generated kernel (pseudo-CUDA, cf. paper Appendix A) ---");
    println!("{}", emit_seq(&report.best));

    // --- 4. fused vs CUBLAS on the GTX 480 model ---------------------------
    let flops = seq.flops.eval(p);
    let ours = simulate_seq(&ctx.dev, &report.best, p, flops);
    let cublas_prog = seq.cublas_program(&ctx.lib);
    let baseline = autotune::baseline_plan(&cublas_prog, &ctx.lib);
    let base = simulate_seq(&ctx.dev, &baseline, p, flops);
    println!(
        "GTX480 model @ {}x{}: fused {:.1} GFlops vs CUBLAS {:.1} GFlops -> {:.2}x (paper: 1.61x)",
        p.m,
        p.n,
        ours.gflops,
        base.gflops,
        ours.gflops / base.gflops
    );

    // --- 5. run the real AOT artifact through the serving engine ----------
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("\n(artifacts/ not built — run `make artifacts` for the PJRT step)");
        return;
    }
    let engine =
        Engine::start(Arc::new(Context::with_calibration_cache(dir)), dir).expect("engine");
    let client = engine.client();
    let (m, n) = (256, 256);
    let res = client
        .submit(SubmitRequest::new("bicgk", m, n).synth(42).variant(PlanChoice::Fused))
        .expect("submit")
        .wait()
        .expect("run");
    // the result env keeps the free inputs, so it doubles as the
    // oracle's input set
    let err = refcheck::max_abs_error("bicgk", &res.env, &res.env);
    println!(
        "\nengine execution ({} variant): {} stage(s), {:.3} ms, max abs error vs oracle {:.2e}",
        res.variant,
        res.stages.len(),
        res.seconds * 1e3,
        err
    );
    assert!(err < 1e-3, "verification failed");
    let metrics = engine.shutdown();
    assert_eq!(metrics.requests, 1);
    println!("quickstart OK");
}
