//! Script front-end error paths: lexer, parser and typecheck failures
//! must report the offending line and never panic — including on
//! arbitrarily mutated input, which the fuzz-style property at the
//! bottom drives through the whole front end.

use fusebla::coordinator::Context;
use fusebla::pipelines;
use fusebla::script::compile_script;
use fusebla::util::proptest::check;

fn err_of(src: &str) -> fusebla::script::ScriptError {
    let ctx = Context::new();
    compile_script("t", src, &ctx.lib).expect_err("script must be rejected")
}

#[test]
fn lexer_errors_carry_the_offending_line() {
    // stray character on line 3
    let e = err_of("vector<N> x;\ninput x;\ny @ sscal(x);\nreturn y;");
    assert_eq!(e.line, 3);
    assert!(e.msg.contains("unexpected character '@'"), "{e}");
    // malformed number on line 2
    let e = err_of("vector<N> x, y;\ny = sscal(x, alpha=1.2.3);\nreturn y;");
    assert_eq!(e.line, 2);
    assert!(e.msg.contains("bad number"), "{e}");
    // the Display form is the serve-facing message shape
    assert!(e.to_string().starts_with("script line 2: "), "{e}");
}

#[test]
fn parser_errors_carry_the_offending_line() {
    // unterminated call on line 2
    let e = err_of("vector<N> x, y;\ny = sscal(x\nreturn y;");
    assert_eq!(e.line, 2, "{e}");
    assert!(e.msg.contains("expected"), "{e}");
    // structurally empty scripts are whole-script errors (line 0)
    let e = err_of("vector<N> x;\ninput x;");
    assert_eq!((e.line, e.msg.as_str()), (0, "script has no calls"));
    let e = err_of("vector<N> x, y;\ninput x;\ny = sscal(x, alpha=2.0);");
    assert_eq!((e.line, e.msg.as_str()), (0, "script has no return statement"));
}

#[test]
fn typecheck_errors_carry_the_offending_line() {
    let e = err_of("vector<N> x, y;\ninput x;\ny = nosuch(x);\nreturn y;");
    assert_eq!(e.line, 3);
    assert!(e.msg.contains("unknown library function 'nosuch'"), "{e}");
    let e = err_of("vector<N> x;\nvector<N> x;\ninput x;\nx = vexp(x);\nreturn x;");
    assert_eq!(e.line, 2);
    assert!(e.msg.contains("declared twice"), "{e}");
    let e = err_of("vector<N> x, y;\ninput z;\ny = vexp(x);\nreturn y;");
    assert_eq!(e.line, 2);
    assert!(e.msg.contains("undeclared"), "{e}");
}

/// Fuzz-style property over mutated valid scripts: whatever bytes the
/// front end is fed, `compile_script` returns — `Ok` or a `ScriptError`
/// whose line number is within the script — and never panics. A panic
/// anywhere in lexing/parsing/typechecking fails this test directly.
#[test]
fn mutated_scripts_never_panic_and_report_in_range_lines() {
    let ctx = Context::new();
    let seeds = [
        pipelines::examples::ADD_MUL_EXP,
        pipelines::examples::QUANTIZE_INT8,
        "matrix<MxN> A;\nvector<N> p, s;\nvector<M> q, r;\ninput A, p, r;\n\
         q = sgemv(A, p);\ns = sgemtv(A, r);\nreturn q, s;",
    ];
    // characters chosen to hit every lexer class plus structural tokens
    let alphabet: Vec<char> = "abz_109.;,=<>()#@$ \n\te-".chars().collect();
    check("mutated scripts fail typed, with in-range lines", 400, |g| {
        let mut src: Vec<char> = g.choose(&seeds).chars().collect();
        for _ in 0..g.usize(1, 4) {
            let c = *g.choose(&alphabet);
            // g.usize bounds are inclusive
            match g.usize(0, 3) {
                0 if !src.is_empty() => {
                    let i = g.usize(0, src.len() - 1);
                    src[i] = c; // replace
                }
                1 if !src.is_empty() => {
                    let i = g.usize(0, src.len() - 1);
                    src.remove(i); // delete
                }
                _ => {
                    let i = g.usize(0, src.len());
                    src.insert(i, c); // insert
                }
            }
        }
        let src: String = src.into_iter().collect();
        if let Err(e) = compile_script("fuzz", &src, &ctx.lib) {
            // newline count + 1, not lines(): an EOF-adjacent error
            // after a trailing newline legitimately reports the final
            // (empty) line
            let lines = src.chars().filter(|&c| c == '\n').count() + 1;
            assert!(
                e.line <= lines,
                "line {} out of range for a {}-line script: {} — source:\n{src}",
                e.line,
                lines,
                e.msg
            );
        }
    });
}
