//! Integration tests over the whole stack: script → fusion compiler →
//! plan → (a) GTX 480 simulation and (b) real PJRT execution of the AOT
//! Pallas artifacts, verified against the Rust reference oracle.

use fusebla::autotune;
use fusebla::bench_support::{eval_size, table2, Evaluator};
use fusebla::coordinator::{synth_inputs, Context, Coordinator, PlanChoice};
use fusebla::fusion::ImplAxes;
use fusebla::sequences;
use fusebla::sim::simulate_seq;
use std::path::Path;
use std::sync::Arc;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

/// Every sequence: the compiler's best plan must never lose to the
/// CUBLAS baseline on the model, and must win clearly where the paper
/// says fusion/specialization applies.
#[test]
fn compiler_never_loses_to_baseline() {
    let ctx = Context::new();
    let mut ev = Evaluator::new();
    for seq in sequences::all() {
        let e = ev.eval(&ctx, seq.name);
        let speedup = e.ours.gflops / e.cublas.gflops;
        assert!(
            speedup > 0.95,
            "{}: best plan slower than baseline ({speedup:.2}x)",
            seq.name
        );
        if seq.tag.contains('F') && !seq.tag.contains('(') {
            assert!(
                speedup > 1.25,
                "{}: F-tagged but only {speedup:.2}x",
                seq.name
            );
        }
    }
}

/// Table 2 renders with one row per sequence.
#[test]
fn table2_renders() {
    let ctx = Context::new();
    let mut ev = Evaluator::new();
    let t = table2(&ctx, &mut ev);
    assert_eq!(t.n_rows(), 11);
}

/// The searched best plan for every fusible sequence has fewer kernels
/// than calls (fusion actually happened end-to-end through the search).
#[test]
fn search_fuses_the_fusible() {
    let ctx = Context::new();
    for name in ["axpydot", "bicgk", "gemver"] {
        let seq = sequences::by_name(name).unwrap();
        let (prog, graph) = seq.graph(&ctx.lib);
        let p = eval_size(&seq);
        let r = autotune::search(
            &prog, &ctx.lib, &graph, &ctx.dev, &ctx.db, &ImplAxes::minimal(), p,
        );
        assert!(
            r.best.kernels.len() < prog.calls.len(),
            "{name}: best plan did not fuse"
        );
    }
}

/// ATAX/SGEMVT keep one kernel per call (global barrier forbids fusion).
#[test]
fn search_respects_global_barriers() {
    let ctx = Context::new();
    for name in ["atax", "sgemvt"] {
        let seq = sequences::by_name(name).unwrap();
        let (prog, graph) = seq.graph(&ctx.lib);
        let p = eval_size(&seq);
        let r = autotune::search(
            &prog, &ctx.lib, &graph, &ctx.dev, &ctx.db, &ImplAxes::minimal(), p,
        );
        assert_eq!(r.best.kernels.len(), prog.calls.len(), "{name}");
    }
}

/// Real execution: every sequence, both variants, verified against the
/// Rust oracle at the smallest catalog size.
#[test]
fn all_sequences_execute_and_verify() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut coord = Coordinator::new(Arc::new(Context::new()), &dir).unwrap();
    for seq in sequences::all() {
        for variant in [PlanChoice::Fused, PlanChoice::Cublas] {
            let sizes = coord.runtime().sizes_of(seq.name, variant.as_str());
            assert!(!sizes.is_empty(), "{}: no artifacts", seq.name);
            let (m, n) = sizes[0];
            let inputs = synth_inputs(coord.runtime(), seq.name, variant.as_str(), m, n, 11);
            let (res, err) = coord
                .run_checked(seq.name, variant, m, n, &inputs)
                .unwrap_or_else(|e| panic!("{} {}: {e:#}", seq.name, variant.as_str()));
            // f32 accumulation over n=65536 elements: tolerance scales
            let tol = if seq.is_blas2() { 5e-3 } else { 3e-1 };
            assert!(
                err < tol,
                "{} {} m{m} n{n}: max abs err {err}",
                seq.name,
                variant.as_str()
            );
            assert!(!res.stages.is_empty());
        }
    }
}

/// Fused and CUBLAS variants agree with each other on identical inputs
/// (independent of the oracle).
#[test]
fn variants_agree_pairwise() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::new(Arc::new(Context::new()), &dir).unwrap();
    for seq in sequences::all() {
        let (m, n) = coord.runtime().sizes_of(seq.name, "fused")[0];
        let inputs = synth_inputs(coord.runtime(), seq.name, "fused", m, n, 5);
        let f = coord.runtime().run_seq(seq.name, "fused", m, n, &inputs).unwrap();
        let c = coord.runtime().run_seq(seq.name, "cublas", m, n, &inputs).unwrap();
        // compare the outputs both variants produce
        for (name, tf) in &f.env {
            if let Some(tc) = c.env.get(name) {
                if inputs.contains_key(name) {
                    continue;
                }
                let worst = tf
                    .data
                    .iter()
                    .zip(&tc.data)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(
                    worst < 0.3,
                    "{}: '{}' differs between variants by {worst}",
                    seq.name,
                    name
                );
            }
        }
    }
}

/// Fused plans must launch strictly fewer kernels where fusion applies
/// and pay fewer memory passes — the structural claim, exact.
#[test]
fn kernel_counts_match_paper_structure() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::new(Arc::new(Context::new()), &dir).unwrap();
    let expect: &[(&str, usize, usize)] = &[
        ("axpydot", 1, 3),
        ("atax", 2, 2),
        ("bicgk", 1, 2),
        ("sgemv", 1, 1),
        ("sgemvt", 2, 3),
        ("sscal", 1, 1),
        ("gemver", 2, 6),
        ("gesummv", 2, 2),
        ("madd", 1, 2),
        ("vadd", 1, 3),
        ("waxpby", 1, 3),
    ];
    for &(seq, fused_k, cublas_k) in expect {
        let (m, n) = coord.runtime().sizes_of(seq, "fused")[0];
        let inputs = synth_inputs(coord.runtime(), seq, "fused", m, n, 1);
        let f = coord.runtime().run_seq(seq, "fused", m, n, &inputs).unwrap();
        assert_eq!(f.stages.len(), fused_k, "{seq} fused");
        let inputs = synth_inputs(coord.runtime(), seq, "cublas", m, n, 1);
        let c = coord.runtime().run_seq(seq, "cublas", m, n, &inputs).unwrap();
        assert_eq!(c.stages.len(), cublas_k, "{seq} cublas");
    }
}

/// Scaling on the model is monotone-ish and overhead-dominated at small
/// sizes (Figures 5/6 shape).
#[test]
fn scaling_curves_rise() {
    let ctx = Context::new();
    for name in ["bicgk", "gemver"] {
        let seq = sequences::by_name(name).unwrap();
        let (prog, graph) = seq.graph(&ctx.lib);
        let mut prev = 0.0;
        for n in [1024usize, 4096, 16384] {
            let p = fusebla::ir::elem::ProblemSize::square(n);
            let best = autotune::compile_first(
                &prog, &ctx.lib, &graph, &ctx.db, &ImplAxes::minimal(), p,
            );
            let g = simulate_seq(&ctx.dev, &best.plan, p, seq.flops.eval(p)).gflops;
            assert!(g > prev * 0.98, "{name}: GFlops dropped at n={n}");
            prev = g;
        }
    }
}

/// Library-extension sequences (the paper's future work: "more functions
/// from the BLAS standard which are fusible by the compiler") fuse too:
/// a residual-norm step `d = y - x; r = ||d||²` becomes one kernel.
#[test]
fn extension_functions_fuse() {
    let ctx = Context::new();
    let src = "
        vector<N> x, y, d; scalar r;
        input x, y;
        d = waxpby(y, x, alpha=1.0, beta=-1.0);
        r = snrm2sq(d);
        return d, r;
    ";
    let prog = fusebla::script::compile_script("residual", src, &ctx.lib).unwrap();
    let graph = fusebla::graph::DepGraph::build(&prog, &ctx.lib);
    let p = fusebla::ir::elem::ProblemSize::new(32, 1 << 22);
    let r = autotune::search(
        &prog, &ctx.lib, &graph, &ctx.dev, &ctx.db, &ImplAxes::minimal(), p,
    );
    assert_eq!(r.best.kernels.len(), 1, "residual norm must fuse");
    // and an asum-chain cannot consume its own reduction in-kernel
    let src2 = "
        vector<N> x, y; scalar a;
        input x;
        y = sscal(x, alpha=3.0);
        a = sasum(y);
        return a;
    ";
    let prog2 = fusebla::script::compile_script("scaledasum", src2, &ctx.lib).unwrap();
    let graph2 = fusebla::graph::DepGraph::build(&prog2, &ctx.lib);
    let r2 = autotune::search(
        &prog2, &ctx.lib, &graph2, &ctx.dev, &ctx.db, &ImplAxes::minimal(), p,
    );
    assert_eq!(r2.best.kernels.len(), 1, "scal feeds asum's map phase — fusible");
}
