//! Property-based tests over compiler invariants, using the in-repo
//! mini-proptest framework (seeded, replayable).

use fusebla::codegen::{self, smem};
use fusebla::coordinator::Context;
use fusebla::fusion::{self, ImplAxes};
use fusebla::graph::DepGraph;
use fusebla::ir::elem::ProblemSize;
use fusebla::ir::plan::{Hoist, IterDim};
use fusebla::predict::predict_seq;
use fusebla::script::compile_script;
use fusebla::sequences;
use fusebla::sim::simulate_seq;
use fusebla::util::proptest::check;

/// Random implementation of a random sequence's random fusion part.
fn random_impl(
    g: &mut fusebla::util::proptest::Gen,
    ctx: &Context,
) -> (
    fusebla::ir::program::Program,
    fusebla::fusion::FusionImpl,
) {
    let names: Vec<&str> = sequences::all().iter().map(|s| s.name).collect::<Vec<_>>();
    let name = (*g.choose(&names)).to_string();
    let seq = sequences::by_name(&name).unwrap();
    let (prog, graph) = seq.graph(&ctx.lib);
    let mut parts = fusion::enumerate_fusions(&prog, &ctx.lib, &graph);
    for c in prog.call_ids() {
        parts.push(fusion::Fusion::singleton(c, &prog, &ctx.lib));
    }
    let part = g.choose(&parts).clone();
    let impls = fusion::gen_impls(&prog, &ctx.lib, &graph, &part, &ImplAxes::default());
    let fi = g.choose(&impls).clone();
    (prog, fi)
}

/// Shared-memory allocation never overlaps two simultaneously-live slots,
/// for every implementation the generator can produce.
#[test]
fn prop_smem_allocation_sound() {
    let ctx = Context::new();
    check("smem allocation sound", 300, |g| {
        let (prog, fi) = random_impl(g, &ctx);
        let plan = codegen::generate(&prog, &ctx.lib, &fi);
        smem::verify(&plan.smem_slots).unwrap();
        // total allocation covers every slot
        for s in &plan.smem_slots {
            assert!(s.offset + s.words <= plan.smem_words);
        }
    });
}

/// Traffic accounting is non-negative, loads cover every external input
/// touched, and fusing never increases total traffic vs the same calls
/// unfused (at the same configuration).
#[test]
fn prop_fusion_never_adds_traffic() {
    let ctx = Context::new();
    check("fusion traffic dominance", 120, |g| {
        let names = ["axpydot", "bicgk", "gemver", "vadd"];
        let name = *g.choose(&names);
        let seq = sequences::by_name(name).unwrap();
        let (prog, graph) = seq.graph(&ctx.lib);
        let fusions = fusion::enumerate_fusions(&prog, &ctx.lib, &graph);
        if fusions.is_empty() {
            return;
        }
        let f = g.choose(&fusions).clone();
        let impls = fusion::gen_impls(&prog, &ctx.lib, &graph, &f, &ImplAxes::default());
        let fi = g.choose(&impls).clone();
        let fused = codegen::generate(&prog, &ctx.lib, &fi);
        // unfused: same calls as singletons with the same config
        let p = ProblemSize::square(2048);
        let mut unfused_words = 0.0;
        for &c in &fi.order {
            let s = fusion::Fusion::singleton(c, &prog, &ctx.lib);
            let si = fusion::FusionImpl {
                fusion: s,
                order: vec![c],
                variant: vec![fi.variant_of(c)],
                ipb: fi.ipb,
                iters: fi.iters,
                iter_dim: fi.iter_dim,
            };
            let plan = codegen::generate(&prog, &ctx.lib, &si);
            unfused_words += plan.traffic.total_words().eval(p);
        }
        let fused_words = fused.traffic.total_words().eval(p);
        assert!(
            fused_words <= unfused_words * 1.0001,
            "fusion increased traffic: {fused_words} > {unfused_words}"
        );
        assert!(fused.traffic.loads.eval(p) >= 0.0);
        assert!(fused.traffic.stores.eval(p) > 0.0);
    });
}

/// Every generated plan simulates to a positive finite time, bandwidth
/// never exceeds the device peak, and prediction stays within an order
/// of magnitude of simulation.
#[test]
fn prop_simulation_sane() {
    let ctx = Context::new();
    check("simulation sanity", 200, |g| {
        let (prog, fi) = random_impl(g, &ctx);
        // only when the impl covers the whole program
        if fi.fusion.len() != prog.calls.len() {
            return;
        }
        let plan = codegen::compile_seq(
            &prog,
            &ctx.lib,
            &[fi.clone()],
            "prop",
        );
        let n = 32 * g.usize_edgy(1, 128);
        let p = ProblemSize::new(n, n);
        let sim = simulate_seq(&ctx.dev, &plan, p, 1.0);
        assert!(sim.seconds.is_finite() && sim.seconds > 0.0);
        for k in &sim.kernels {
            assert!(
                k.bandwidth_gbs <= ctx.dev.peak_bandwidth / 1e9 + 1e-9,
                "bandwidth {} exceeds peak",
                k.bandwidth_gbs
            );
        }
        let pred = predict_seq(&ctx.db, &plan, p);
        assert!(pred.is_finite() && pred >= 0.0);
        if sim.seconds > 1e-4 {
            let ratio = pred / sim.seconds;
            assert!(
                (0.05..20.0).contains(&ratio),
                "prediction off by {ratio}x"
            );
        }
    });
}

/// Hoisting invariants: with a single iteration nothing changes
/// semantically, and hoisted steps only ever involve loop-invariant or
/// accumulable variables (never the matrix itself).
#[test]
fn prop_hoisting_invariants() {
    let ctx = Context::new();
    check("hoisting invariants", 200, |g| {
        let (prog, fi) = random_impl(g, &ctx);
        let plan = codegen::generate(&prog, &ctx.lib, &fi);
        for s in &plan.steps {
            if s.hoist != Hoist::InLoop {
                if let Some(v) = &s.op.var {
                    let var = prog.var_id(v).unwrap();
                    assert_ne!(
                        prog.var(var).ty,
                        fusebla::ir::elem::VarType::Matrix,
                        "matrix {v} hoisted out of the loop"
                    );
                }
            }
        }
        // barrier flags only on in-loop or hoisted steps that exist
        let _ = plan.barriers_per_iter;
    });
}

/// The script front-end round-trips every sequence deterministically.
#[test]
fn prop_frontend_deterministic() {
    let ctx = Context::new();
    check("frontend deterministic", 50, |g| {
        let names: Vec<&str> = sequences::all().iter().map(|s| s.name).collect();
        let name = (*g.choose(&names)).to_string();
        let seq = sequences::by_name(&name).unwrap();
        let p1 = compile_script(&name, seq.script, &ctx.lib).unwrap();
        let p2 = compile_script(&name, seq.script, &ctx.lib).unwrap();
        assert_eq!(p1.calls.len(), p2.calls.len());
        assert_eq!(p1.vars.len(), p2.vars.len());
        let g1 = DepGraph::build(&p1, &ctx.lib);
        let g2 = DepGraph::build(&p2, &ctx.lib);
        assert_eq!(g1.edges, g2.edges);
    });
}

/// Changing serial iterations or packing never changes *what* a kernel
/// loads/stores, only how often per block (total step set is stable).
#[test]
fn prop_config_changes_preserve_step_set() {
    let ctx = Context::new();
    check("config preserves step set", 150, |g| {
        let (prog, fi) = random_impl(g, &ctx);
        let mut fi2 = fi.clone();
        fi2.iters = *g.choose(&[1u32, 2, 4, 8, 16]);
        if fi.fusion.depth == 1 {
            fi2.ipb = *g.choose(&[1u32, 2, 4, 8]);
        } else {
            fi2.iter_dim = if g.bool() { IterDim::Row } else { IterDim::Col };
        }
        let a = codegen::generate(&prog, &ctx.lib, &fi);
        let b = codegen::generate(&prog, &ctx.lib, &fi2);
        let names = |p: &fusebla::ir::plan::KernelPlan| {
            let mut v: Vec<String> =
                p.steps.iter().map(|s| s.op.routine_name.clone()).collect();
            v.sort();
            v
        };
        assert_eq!(names(&a), names(&b), "step set changed with config");
    });
}
