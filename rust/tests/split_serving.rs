//! Split-serving integration tests — the G-way scatter/partial-reduce/
//! gather path through the *public* engine API: the router's split
//! decision on the anchored twin-GTX 480 configuration serves as ONE
//! ticket end to end, fallback accounting stays consistent on the stub
//! backend, a registered pipeline's results are placement-invariant
//! (ConcatRows combines are order-preserving), and seeded chaos over
//! split-enabled traffic loses no tickets.

use fusebla::fleet::SplitPolicy;
use fusebla::sim::DeviceModel;
use fusebla::{Client, DeviceRegistry, Engine, EngineConfig, Fault, FaultPlan, SubmitRequest};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A row-concat-only pipeline: every output carries a leading `M`, so
/// split and single-device execution are bit-identical wherever the
/// router places the request (interpreter-backed — it executes end to
/// end on the offline stub).
const ROWMAP: &str = "
    matrix<MxN> A; vector<N> x; vector<M> q;
    input A, x;
    q = sgemv(A, x, alpha=2.0);
    return q;
";

/// Twin GTX 480s over a stub catalog — the exact configuration the
/// router unit test anchors `Split([0, 1])` on for bicgk@8192x8192
/// with `SplitPolicy { max_g: 2, min_rows: 256 }`, so the routing
/// decision exercised here is deterministic.
fn twin_fleet(tag: &str, cfg: EngineConfig) -> (PathBuf, Engine) {
    let dir = fusebla::bench_support::stub_catalog(tag, &["waxpby"]);
    let mut twin = DeviceModel::gtx480();
    twin.name = "GeForce GTX 480 (model) #2".into();
    let reg = Arc::new(DeviceRegistry::new(vec![DeviceModel::gtx480(), twin], &dir).unwrap());
    let engine = Engine::start_fleet(reg, &dir, cfg).unwrap();
    (dir, engine)
}

fn split_cfg() -> EngineConfig {
    EngineConfig {
        split: Some(SplitPolicy {
            max_g: 2,
            min_rows: 256,
        }),
        ..EngineConfig::default()
    }
}

/// Every submitted request releases its queue-depth slot on a terminal
/// outcome; scattered split blocks release their peer slots the same
/// way — so after all tickets resolve, the depths must drain to zero.
fn await_drain(client: &Client, lanes: usize) {
    let by = Instant::now() + Duration::from_secs(10);
    while client.queue_depths() != vec![0; lanes] {
        assert!(
            Instant::now() < by,
            "queue depths must drain: {:?}",
            client.queue_depths()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The anchored split decision routes and serves as one ticket on the
/// stub backend: the built-in cannot execute (no artifacts at the
/// block sizes), so the split degrades to the whole-run fallback —
/// counted, surfaced in the error chain, and never a lost ticket.
#[test]
fn routed_split_serves_one_ticket_with_fallback_accounting() {
    let (dir, engine) = twin_fleet("routedsplit", split_cfg());
    let client = engine.client();
    let t = client
        .submit(SubmitRequest::new("bicgk", 8192, 8192).synth(1))
        .unwrap();
    let err = t.wait().err().expect("stub backend cannot execute built-ins");
    assert!(
        format!("{err:#}").contains("whole fallback after"),
        "the fallback chain must be visible: {err:#}"
    );
    assert_eq!(
        client.routing_stats().split_decisions,
        1,
        "the router chose to split the large row-block key"
    );
    await_drain(&client, 2);
    let fleet = engine.shutdown_fleet();
    let agg = fleet.aggregate();
    assert_eq!(agg.splits, 0, "execution failed before a split completed");
    assert_eq!(agg.split_fallbacks, 1, "the failed split fell back to one whole run");
    assert_eq!(agg.requests, 1, "one ticket, one request — blocks never double-count");
    assert_eq!(agg.failures, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A registered map-only pipeline is bit-identical between an unpinned
/// submit — which the split-enabled router may scatter across the
/// twins — and a pinned single-device run: placement must never change
/// the bits of an order-preserving program.
#[test]
fn pipeline_results_are_placement_invariant() {
    let (dir, engine) = twin_fleet("splitpipe", split_cfg());
    let client = engine.client();
    client.register_pipeline("rowmap", ROWMAP).unwrap();
    let pin = client.devices()[0].name().to_string();
    let pinned = client
        .submit(SubmitRequest::new("rowmap", 4096, 128).synth(5).pin(&pin))
        .unwrap()
        .wait()
        .expect("interp execution succeeds on the stub backend");
    let routed = client
        .submit(SubmitRequest::new("rowmap", 4096, 128).synth(5))
        .unwrap()
        .wait()
        .expect("routed execution succeeds wherever it lands");
    assert_eq!(routed.env["q"].dims, pinned.env["q"].dims);
    for (a, b) in routed.env["q"].data.iter().zip(&pinned.env["q"].data) {
        assert_eq!(a.to_bits(), b.to_bits(), "placement changed the bits");
    }
    await_drain(&client, 2);
    let fleet = engine.shutdown_fleet();
    let agg = fleet.aggregate();
    assert_eq!(agg.requests, 2);
    assert_eq!(agg.failures, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Chaos over split-enabled traffic: a lane killed on its second turn
/// while pinned and routed pipeline requests keep arriving. Every
/// ticket reaches a terminal outcome (success or a typed shed — wait()
/// returning IS the property), queue depths drain, and the killed lane
/// respawns.
#[test]
fn split_traffic_survives_lane_kill_without_ticket_loss() {
    let cfg = EngineConfig {
        fault_plan: FaultPlan {
            faults: vec![Fault::Kill { lane: 1, turn: 2 }],
        },
        ..split_cfg()
    };
    let (dir, engine) = twin_fleet("splitchaos", cfg);
    let client = engine.client();
    client.register_pipeline("rowmap", ROWMAP).unwrap();
    let lane1 = client.devices()[1].name().to_string();
    // lane 1's first turn is healthy; its second — guaranteed by the
    // pinned submissions below — is the scripted kill
    client
        .submit(SubmitRequest::new("rowmap", 4096, 128).synth(0).pin(&lane1))
        .unwrap()
        .wait()
        .expect("warmup turn on the doomed lane");
    let tickets: Vec<_> = (1..=8u64)
        .map(|i| {
            let req = SubmitRequest::new("rowmap", 4096, 128).synth(i);
            let req = if i % 2 == 0 { req.pin(&lane1) } else { req };
            client.submit(req).unwrap()
        })
        .collect();
    let mut resolved = 0;
    for t in tickets {
        // Ok, or a typed error (WorkerLost for requests pinned to the
        // dead lane) — either is a terminal outcome, never a hang.
        let _ = t.wait();
        resolved += 1;
    }
    assert_eq!(resolved, 8, "every ticket must resolve");
    await_drain(&client, 2);
    // the salvage replies land before the supervisor bumps the restart
    // counter, so poll rather than assert a snapshot
    let by = Instant::now() + Duration::from_secs(30);
    while engine.fleet_metrics().devices[1].1.worker_restarts < 1 {
        assert!(Instant::now() < by, "the killed lane never respawned");
        std::thread::sleep(Duration::from_millis(10));
    }
    let fleet = engine.shutdown_fleet();
    assert!(fleet.lost.is_empty(), "a recoverable kill never loses the lane");
    let _ = std::fs::remove_dir_all(&dir);
}
