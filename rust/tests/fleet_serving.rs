//! Heterogeneous fleet serving, exercised through the public API only:
//! pinned-submission bit-identity with single-device execution, and
//! the predictor-guided router's device preference.
//!
//! The routing unit tests (cost ordering, queue-depth spillover,
//! forecast caching) live in `src/fleet/router.rs` and run everywhere;
//! the execution tests here gate on `artifacts/manifest.txt` like the
//! rest of the suite — the offline stub backend cannot execute.

use fusebla::coordinator::{synth_inputs, Context, Coordinator, PlanChoice};
use fusebla::sim::DeviceModel;
use fusebla::util::proptest::check;
use fusebla::{DeviceRegistry, Engine, EngineConfig, SubmitRequest};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

/// A GTX 480 + GT 430 fleet whose calibration files live in a scratch
/// directory (so the test never races the catalog's own cache files).
fn two_device_registry(tag: &str) -> (PathBuf, Arc<DeviceRegistry>) {
    let cal = std::env::temp_dir().join(format!("fusebla_fleetsrv_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cal);
    std::fs::create_dir_all(&cal).unwrap();
    let reg = DeviceRegistry::new(vec![DeviceModel::gtx480(), DeviceModel::gt430()], &cal).unwrap();
    (cal, Arc::new(reg))
}

/// The acceptance-criteria property: a pinned submission through the
/// fleet engine is bit-identical to single-device `run_seq_batch` on
/// the same inputs — routing and per-device plan caches change *where*
/// a request runs, never its arithmetic. Holds for every device in the
/// roster, including the deliberately slow heterogeneous one.
#[test]
fn pinned_submissions_bit_identical_to_single_device_batch() {
    let Some(dir) = artifacts_dir() else { return };
    let (cal, registry) = two_device_registry("bitident");
    let ids = registry.ids();
    let engine = Engine::start_fleet(
        registry,
        &dir,
        EngineConfig {
            batch_window: Duration::from_millis(50),
            max_batch: 64,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let client = engine.client();
    // the single-device reference: the plain coordinator's batch path
    let coord = Coordinator::new(Arc::new(Context::new()), &dir).unwrap();
    let rt = coord.runtime();
    check("pinned fleet submissions match run_seq_batch", 12, |g| {
        let seq = *g.choose(&["waxpby", "vadd", "sscal", "axpydot"]);
        let sizes = rt.sizes_of(seq, "fused");
        let (m, n) = *g.choose(&sizes);
        let device = g.choose(&ids).clone();
        let seeds: Vec<u64> = (0..g.usize(1, 4)).map(|_| g.rng().below(1000)).collect();
        let inputs: Vec<_> = seeds
            .iter()
            .map(|&s| synth_inputs(rt, seq, "fused", m, n, s))
            .collect();
        let reference = rt.run_seq_batch(seq, "fused", m, n, inputs.clone());
        let tickets: Vec<_> = inputs
            .iter()
            .map(|input| {
                client
                    .submit(
                        SubmitRequest::new(seq, m, n)
                            .inputs(input.clone())
                            .variant(PlanChoice::Fused)
                            .pin(device.name()),
                    )
                    .unwrap()
            })
            .collect();
        for (t, r) in tickets.into_iter().zip(reference) {
            let fleet_res = t.wait().expect("pinned fleet run");
            let single = r.expect("single-device batch run");
            assert_eq!(fleet_res.env.len(), single.env.len());
            for (name, tf) in &fleet_res.env {
                let ts = &single.env[name];
                assert_eq!(tf.dims, ts.dims, "dims of '{name}' on {device}");
                for (x, y) in tf.data.iter().zip(&ts.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "tensor '{name}' on {device}");
                }
            }
        }
    });
    let fleet = engine.shutdown_fleet();
    let agg = fleet.aggregate();
    assert_eq!(agg.failures, 0, "no pinned request may fail");
    assert!(agg.requests > 0);
    let _ = std::fs::remove_dir_all(&cal);
}

/// With empty queues, the router never places a bandwidth-bound BLAS-1
/// burst on the obviously slower device: every request lands on the
/// GTX 480 and the GT 430's worker stays idle.
#[test]
fn router_prefers_the_cheap_device_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let (cal, registry) = two_device_registry("cheapwins");
    let engine = Engine::start_fleet(registry, &dir, EngineConfig::default()).unwrap();
    let client = engine.client();
    for i in 0..4u64 {
        let t = client
            .submit(SubmitRequest::new("waxpby", 32, 65536).synth(i))
            .unwrap();
        // wait each ticket: queues are empty at every routing decision
        t.wait().expect("routed run");
    }
    let fleet = engine.shutdown_fleet();
    assert_eq!(fleet.devices[0].1.requests, 4, "GTX 480 must take every request");
    assert_eq!(fleet.devices[1].1.requests, 0, "GT 430 must stay idle");
    // the idle device executed nothing, so only the active one holds
    // queued-duration samples
    assert_eq!(fleet.devices[0].1.queued.count(), 4);
    assert_eq!(fleet.devices[1].1.queued.count(), 0);
    let _ = std::fs::remove_dir_all(&cal);
}

/// The cold-key regression (the router's old N+1 tradeoff, now gone):
/// the first unpinned submit of a fresh `(seq, size)` key runs **zero**
/// planner searches on the submitting thread and at most one per device
/// fleet-wide — the forecasts run on the workers and seed their plan
/// caches, so the routed worker's first execution is a plan-cache hit,
/// not a re-plan.
#[test]
fn cold_key_plans_on_workers_not_the_submitting_thread() {
    // A stub catalog is enough: planning and the control plane work
    // end-to-end without built artifacts, and the plan-cache counters
    // this test asserts are recorded before the (stub-failed) execution.
    let dir = fusebla::bench_support::stub_catalog("coldkey", &["waxpby"]);
    let (cal, registry) = two_device_registry("coldkey");
    // a generous forecast deadline: this test pins *where* planning
    // runs, not how fast a loaded CI machine answers
    let cfg = EngineConfig {
        forecast_deadline: Duration::from_secs(60),
        ..EngineConfig::default()
    };
    let engine = Engine::start_fleet(registry, &dir, cfg).unwrap();
    let client = engine.client();

    let ticket = client.submit(SubmitRequest::new("waxpby", 32, 65536)).unwrap();
    let _ = ticket.wait(); // stub backend fails execution — irrelevant here

    let stats = client.routing_stats();
    assert_eq!(stats.cold_keys, 1);
    assert_eq!(
        stats.local_forecasts, 0,
        "the submitting thread must run zero planner searches"
    );
    assert_eq!(stats.worker_forecasts, 2, "one worker forecast per device");

    // a second submit of the same key is a pure cache probe: no new
    // forecasts anywhere
    let _ = client.submit(SubmitRequest::new("waxpby", 32, 65530)).unwrap().wait();
    assert_eq!(client.routing_stats(), stats, "warm keys never re-forecast");

    let fleet = engine.shutdown_fleet();
    let agg = fleet.aggregate();
    assert_eq!(
        agg.planner_on_worker, 2,
        "at most one planner run per device fleet-wide"
    );
    // every device was seeded exactly once by its forecast...
    for (id, m) in &fleet.devices {
        assert_eq!(m.planner_on_worker, 1, "{id}");
        assert_eq!(m.plan_cache_misses, 1, "{id}: the seed records the one miss");
    }
    // ...and the routed worker's executions hit the seeded entry
    let routed: Vec<_> = fleet.devices.iter().filter(|(_, m)| m.requests > 0).collect();
    assert_eq!(routed.len(), 1, "one device took both submits");
    assert_eq!(routed[0].1.requests, 2);
    assert_eq!(
        routed[0].1.plan_cache_hits,
        2,
        "first execution of the key must hit the forecast-seeded plan"
    );
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&cal);
}

/// Per-device calibration files appear side by side after a fleet
/// engine starts — two devices never clobber one `calibration.txt`.
#[test]
fn fleet_start_writes_per_device_calibrations() {
    let Some(dir) = artifacts_dir() else { return };
    let (cal, registry) = two_device_registry("calfiles");
    let engine = Engine::start_fleet(registry, &dir, EngineConfig::default()).unwrap();
    drop(engine);
    let fast = fusebla::predict::calibration_path(&cal, &DeviceModel::gtx480().name);
    let slow = fusebla::predict::calibration_path(&cal, &DeviceModel::gt430().name);
    assert!(fast.exists(), "missing {fast:?}");
    assert!(slow.exists(), "missing {slow:?}");
    assert_ne!(fast, slow);
    let _ = std::fs::remove_dir_all(&cal);
}
