//! Property tests over fusion-enumeration invariants (paper §3.2/§4.2),
//! using the in-repo mini-proptest framework: every `Fusion` the
//! enumerator emits must be single-depth, weakly connected (dependency
//! edges ∪ shared inputs), convex, free of internal reduction edges and
//! must spare global traffic; every partition must cover the calls
//! exactly once with parts drawn from the fusion list ∪ singletons.
//!
//! Programs come from two generators: random depth-1 BLAS-1 DAG scripts
//! (maps and reductions wired through fresh SSA variables) and the
//! eleven paper sequences (which also exercise the depth-2 rules).

use fusebla::fusion::{
    enumerate_fusions, enumerate_partitions, is_fusible, spared_words, Fusion,
};
use fusebla::graph::DepGraph;
use fusebla::ir::program::{CallId, Program};
use fusebla::library::Library;
use fusebla::script::compile_script;
use fusebla::sequences;
use fusebla::util::proptest::{check, Gen};
use std::collections::BTreeSet;

/// Weak connectivity over dependency edges ∪ shared-input links —
/// reimplemented here, independently of the compiler's own check.
fn connected_with_shared_inputs(
    prog: &Program,
    graph: &DepGraph,
    set: &BTreeSet<CallId>,
) -> bool {
    let nodes: Vec<CallId> = set.iter().copied().collect();
    if nodes.is_empty() {
        return false;
    }
    let linked = |a: CallId, b: CallId| {
        graph.successors(a).any(|s| s == b)
            || graph.predecessors(a).any(|x| x == b)
            || prog
                .call(a)
                .args
                .iter()
                .any(|v| prog.call(b).args.contains(v))
    };
    let mut seen: BTreeSet<CallId> = [nodes[0]].into();
    let mut stack = vec![nodes[0]];
    while let Some(c) = stack.pop() {
        for &nb in &nodes {
            if !seen.contains(&nb) && linked(c, nb) {
                seen.insert(nb);
                stack.push(nb);
            }
        }
    }
    seen.len() == set.len()
}

/// A random BLAS-1 DAG script: maps and reductions over fresh SSA
/// variables, every call output returned (so no call is dead code).
fn random_blas1_script(g: &mut Gen) -> String {
    let n_calls = g.usize(1, 5);
    let mut available = vec!["i0".to_string(), "i1".to_string(), "i2".to_string()];
    let mut vec_decls = available.clone();
    let mut scalar_decls: Vec<String> = Vec::new();
    let mut calls = String::new();
    let mut returns: Vec<String> = Vec::new();
    for k in 0..n_calls {
        let funcs: [(&str, usize, bool); 6] = [
            ("sscal", 1, false),
            ("saxpy", 2, false),
            ("waxpby", 2, false),
            ("vadd2", 2, false),
            ("vadd3", 3, false),
            ("sdot", 2, true),
        ];
        let &(name, arity, reduces) = g.choose(&funcs);
        let mut pool = available.clone();
        g.shuffle(&mut pool);
        let args = pool[..arity].join(", ");
        if reduces {
            let out = format!("r{k}");
            scalar_decls.push(out.clone());
            calls.push_str(&format!("{out} = {name}({args});\n"));
            returns.push(out);
        } else {
            let out = format!("o{k}");
            vec_decls.push(out.clone());
            calls.push_str(&format!("{out} = {name}({args});\n"));
            returns.push(out.clone());
            available.push(out);
        }
    }
    let scalars = if scalar_decls.is_empty() {
        String::new()
    } else {
        format!("scalar {};\n", scalar_decls.join(", "))
    };
    format!(
        "vector<N> {};\n{}input i0, i1, i2;\n{}return {};\n",
        vec_decls.join(", "),
        scalars,
        calls,
        returns.join(", ")
    )
}

/// Pick a program: a random depth-1 script or one of the paper's eleven
/// sequences (exercising the depth-2 rules too).
fn random_program(g: &mut Gen, lib: &Library) -> Program {
    if g.bool() {
        let src = random_blas1_script(g);
        compile_script("rand", &src, lib)
            .unwrap_or_else(|e| panic!("generator built invalid script: {e}\n{src}"))
    } else {
        let all = sequences::all();
        let seq = g.choose(&all);
        seq.program(lib)
    }
}

#[test]
fn prop_enumerated_fusions_satisfy_all_invariants() {
    let lib = Library::standard();
    check("fusion enumeration invariants", 200, |g| {
        let prog = random_program(g, &lib);
        let graph = DepGraph::build(&prog, &lib);
        let fusions = enumerate_fusions(&prog, &lib, &graph);
        for f in &fusions {
            assert!(f.len() >= 2, "fusions are multi-call by definition");
            // single nesting depth, consistent with the recorded depth
            let depths: BTreeSet<u8> = f
                .calls
                .iter()
                .map(|&c| lib.get(prog.call(c).func).depth())
                .collect();
            assert_eq!(depths.len(), 1, "mixed-depth fusion emitted");
            assert_eq!(*depths.iter().next().unwrap(), f.depth);
            // no internal reduction edge (would need a global barrier)
            assert!(
                graph.internal_edges(&f.calls).all(|e| !e.reduction),
                "fusion consumes a reduction result internally"
            );
            // convex: no dependency path leaves and re-enters
            assert!(graph.is_convex(&f.calls), "non-convex fusion emitted");
            // weakly connected through edges or shared inputs
            assert!(
                connected_with_shared_inputs(&prog, &graph, &f.calls),
                "disconnected fusion emitted"
            );
            // spares at least one word of global traffic
            assert!(
                !spared_words(&prog, &graph, &f.calls).is_zero(),
                "fusion spares no transfers"
            );
            // and the compiler's own fusibility rule agrees
            assert!(is_fusible(&prog, &lib, &graph, &f.calls));
        }
    });
}

#[test]
fn prop_partitions_cover_calls_exactly_once() {
    let lib = Library::standard();
    check("partition cover invariants", 120, |g| {
        let prog = random_program(g, &lib);
        let graph = DepGraph::build(&prog, &lib);
        let fusions = enumerate_fusions(&prog, &lib, &graph);
        let partitions = enumerate_partitions(&prog, &lib, &fusions);
        assert!(!partitions.is_empty(), "all-singletons is always a partition");
        for partition in &partitions {
            let mut seen: BTreeSet<CallId> = BTreeSet::new();
            for part in &partition.parts {
                assert!(!part.is_empty());
                for &c in &part.calls {
                    assert!(seen.insert(c), "call covered twice");
                }
                // multi-call parts must come from the fusion list;
                // singletons are the degenerate complement
                if !part.is_singleton() {
                    assert!(
                        fusions.contains(part),
                        "partition invented a fusion the enumerator did not emit"
                    );
                }
            }
            assert_eq!(seen.len(), prog.calls.len(), "partition must cover all calls");
        }
        // partitions are pairwise distinct
        let labels: BTreeSet<String> = partitions
            .iter()
            .map(|p| p.label(&prog, &lib))
            .collect();
        assert_eq!(labels.len(), partitions.len(), "duplicate partition emitted");
    });
}

#[test]
fn prop_singletons_are_never_enumerated_as_fusions() {
    let lib = Library::standard();
    check("no singleton fusions", 80, |g| {
        let prog = random_program(g, &lib);
        let graph = DepGraph::build(&prog, &lib);
        for f in enumerate_fusions(&prog, &lib, &graph) {
            assert!(!f.is_singleton());
        }
        // singleton helper stays consistent with the library's depths
        for c in prog.call_ids() {
            let s = Fusion::singleton(c, &prog, &lib);
            assert!(s.is_singleton());
            assert_eq!(s.depth, lib.get(prog.call(c).func).depth());
        }
    });
}
