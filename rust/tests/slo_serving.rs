//! SLO serving tests: deadlines, EDF batch formation, admission
//! control and the seeded open-loop traffic harness — all over a stub
//! catalog, so planning and the whole control plane run for real while
//! execution fails (fast) at the offline stub backend. What these tests
//! pin is the *serving policy*: who gets shed, when batches ship, and
//! that a seeded run replays with identical counters.

use fusebla::bench_support::stub_catalog;
use fusebla::coordinator::{traffic, Context};
use fusebla::{Engine, EngineConfig, ServeError, SubmitRequest, Ticket};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn engine(tag: &str, cfg: EngineConfig) -> Engine {
    let dir = stub_catalog(tag, &["waxpby"]);
    Engine::with_config(Arc::new(Context::new()), &dir, cfg).expect("stub engine")
}

/// Acceptance gate of the SLO layer: a request whose deadline passes
/// while it queues is *shed* — typed error to the caller, shed counter
/// in the metrics — never executed late. The batch window is far longer
/// than the deadline, so without shedding the request would simply
/// execute after 30 s.
#[test]
fn over_deadline_request_is_shed_with_typed_error_not_executed() {
    let eng = engine(
        "slo_shed",
        EngineConfig {
            batch_window: Duration::from_secs(30),
            deadline_slack: Duration::ZERO,
            ..EngineConfig::default()
        },
    );
    let client = eng.client();
    let t0 = Instant::now();
    let ticket = client
        .submit(
            SubmitRequest::new("waxpby", 32, 65536)
                .synth(1)
                .deadline(Duration::from_millis(30)),
        )
        .expect("submit is admitted");
    let err = ticket.wait().err().expect("a late request must not succeed");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "the shed must happen near the deadline, not after the 30 s window"
    );
    match err.downcast_ref::<ServeError>() {
        Some(ServeError::DeadlineExpired { late_by }) => {
            assert!(*late_by > Duration::ZERO, "late_by must be positive")
        }
        other => panic!("expected DeadlineExpired, got {other:?}: {err:#}"),
    }
    let m = eng.shutdown_fleet().aggregate();
    assert_eq!(m.deadline_sheds, 1, "the shed must be counted");
    assert_eq!(m.slo_misses, 1, "a shed deadline request is an SLO miss");
    assert_eq!(m.deadline_requests, 1);
    assert_eq!(m.batches, 0, "nothing may execute");
}

/// Admission control under a held batch window: the queue fills to the
/// cap, every further best-effort submit is refused with a typed
/// `QueueFull`, and the engine-side shed counter lands in the metrics
/// snapshot.
#[test]
fn queue_cap_sheds_overflow_with_typed_error() {
    let eng = engine(
        "slo_cap",
        EngineConfig {
            batch_window: Duration::from_millis(300),
            queue_cap: 2,
            ..EngineConfig::default()
        },
    );
    let client = eng.client();
    let mut tickets = Vec::new();
    let mut sheds = 0u64;
    // no deadlines, so the EDF drain has no reason to ship before the
    // 300 ms window — depth cannot drain mid-burst and the split is
    // deterministic: 2 admitted, 4 refused
    for i in 0..6u64 {
        match client.submit(SubmitRequest::new("waxpby", 32, 65536).synth(i)) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                assert!(
                    matches!(
                        e.downcast_ref::<ServeError>(),
                        Some(ServeError::QueueFull { cap: 2, .. })
                    ),
                    "overflow must be a typed QueueFull: {e:#}"
                );
                sheds += 1;
            }
        }
    }
    assert_eq!(tickets.len(), 2, "exactly the cap is admitted");
    assert_eq!(sheds, 4);
    for t in tickets {
        // stub backend: admitted requests execute and fail there — an
        // error, but specifically *not* a shed
        let err = t.wait().err().expect("stub execution fails");
        assert!(err.downcast_ref::<ServeError>().is_none(), "{err:#}");
    }
    let m = eng.shutdown_fleet().aggregate();
    assert_eq!(m.queue_sheds, 4, "engine-side sheds appear in the snapshot");
    assert_eq!(m.requests, 2, "shed requests never reach the worker");
}

/// Zero batch window means pure drain: a lone request must ship
/// immediately, not wait for a timeout that can never usefully expire.
/// (Regression: the drain loop used to be able to park in
/// `recv_timeout` with a request already in hand.)
#[test]
fn zero_batch_window_ships_a_lone_request_immediately() {
    let eng = engine(
        "slo_zerowin",
        EngineConfig {
            batch_window: Duration::ZERO,
            ..EngineConfig::default()
        },
    );
    let client = eng.client();
    let t0 = Instant::now();
    let ticket = client
        .submit(SubmitRequest::new("waxpby", 32, 65536).synth(7))
        .expect("submit");
    let _ = ticket.wait(); // stub execution fails; only promptness matters
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "pure drain must not sleep with a request in hand (took {:?})",
        t0.elapsed()
    );
    eng.shutdown_fleet();
}

/// EDF batch formation ships when the most urgent in-hand deadline
/// (less slack) nears — a deadline request must not wait out a long
/// batch window and miss its SLO inside an idle engine.
#[test]
fn deadline_ships_request_long_before_the_batch_window() {
    // slack 1.9 s of a 2 s deadline: the drain ships ~100 ms in, and
    // execution keeps a wide budget so a loaded CI machine cannot turn
    // the early ship into a spurious SLO miss
    let eng = engine(
        "slo_edf",
        EngineConfig {
            batch_window: Duration::from_secs(30),
            deadline_slack: Duration::from_millis(1900),
            ..EngineConfig::default()
        },
    );
    let client = eng.client();
    let t0 = Instant::now();
    let ticket = client
        .submit(
            SubmitRequest::new("waxpby", 32, 65536)
                .synth(2)
                .deadline(Duration::from_secs(2)),
        )
        .expect("submit");
    let err = ticket.wait().err().expect("stub execution fails");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "EDF must ship at deadline − slack, not the 30 s window (took {:?})",
        t0.elapsed()
    );
    assert!(
        err.downcast_ref::<ServeError>().is_none(),
        "the request executed (stub failure), it was not shed: {err:#}"
    );
    let m = eng.shutdown_fleet().aggregate();
    assert_eq!(m.batches, 1, "the request executed as a batch");
    assert_eq!(m.deadline_requests, 1);
    assert_eq!(m.slo_misses, 0, "it shipped within its deadline");
}

/// Deterministic replay, end to end: the same seed yields a
/// byte-identical arrival schedule, and — with the engine configured so
/// shedding depends only on the schedule, not on scheduler timing — two
/// runs land identical shed and SLO-miss counters.
#[test]
fn same_seed_replays_schedule_and_counters_identically() {
    let spec = traffic::TrafficSpec {
        scenario: traffic::Scenario::Poisson,
        seed: 7,
        rate: 2000.0,
        horizon: Duration::from_millis(150),
        keys: vec![("waxpby".into(), 32, 65536)],
    };
    let a = traffic::schedule(&spec);
    let b = traffic::schedule(&spec);
    assert_eq!(a, b, "same seed must replay the schedule byte-identically");
    assert_eq!(traffic::digest(&a), traffic::digest(&b));
    assert!(a.len() > 8, "the run must actually oversubscribe the cap");

    // The window (400 ms) outlasts the horizon (150 ms) and no request
    // carries a deadline, so nothing drains mid-run: exactly the first
    // `queue_cap` arrivals are admitted and every later one is a queue
    // shed, independent of thread timing.
    let run = || {
        let eng = engine(
            "slo_replay",
            EngineConfig {
                batch_window: Duration::from_millis(400),
                queue_cap: 4,
                ..EngineConfig::default()
            },
        );
        let report = traffic::run_open_loop(&eng.client(), &spec, &traffic::OpenLoopOptions::default());
        let m = eng.shutdown_fleet().aggregate();
        (report, m.slo_misses, m.queue_sheds)
    };
    let (r1, miss1, qs1) = run();
    let (r2, miss2, qs2) = run();
    assert_eq!(r1, r2, "outcome counters must replay identically");
    assert_eq!(miss1, miss2);
    assert_eq!(qs1, qs2);
    assert_eq!(r1.submitted, a.len() as u64);
    assert_eq!(r1.queue_sheds, a.len() as u64 - 4, "all but the cap shed");
    assert_eq!(qs1, r1.queue_sheds, "client and engine agree on sheds");
    assert_eq!(miss1, 0, "no deadlines → no SLO misses");
}

/// Priority headroom: when best-effort traffic is already shed at the
/// cap, a priority submit still gets in (2× headroom) — overload hits
/// best-effort traffic first.
#[test]
fn priority_traffic_survives_best_effort_shedding() {
    let eng = engine(
        "slo_prio",
        EngineConfig {
            batch_window: Duration::from_millis(300),
            queue_cap: 1,
            ..EngineConfig::default()
        },
    );
    let client = eng.client();
    let first = client
        .submit(SubmitRequest::new("waxpby", 32, 65536).synth(0))
        .expect("first submit fills the cap");
    let shed = client.submit(SubmitRequest::new("waxpby", 32, 65536).synth(1));
    assert!(
        matches!(
            shed.as_ref().err().and_then(|e| e.downcast_ref::<ServeError>()),
            Some(ServeError::QueueFull { .. })
        ),
        "best-effort overflow is shed"
    );
    let prio = client
        .submit(SubmitRequest::new("waxpby", 32, 65536).synth(2).priority(1))
        .expect("priority submit fits in the 2x headroom");
    let _ = first.wait();
    let _ = prio.wait();
    let m = eng.shutdown_fleet().aggregate();
    assert_eq!(m.queue_sheds, 1);
    assert_eq!(m.requests, 2, "both admitted requests reached the worker");
}
