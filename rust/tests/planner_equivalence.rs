//! Planner-vs-exhaustive equivalence over the paper's eleven sequences:
//! the pruned/beam planner must return a plan whose predicted time is no
//! worse than the exhaustive ranking's best, must return the *identical*
//! plan when the beam is unbounded, and must do so while materializing
//! strictly fewer candidate combinations than the exhaustive sweep —
//! the acceptance criteria of the planner subsystem.

use fusebla::autotune;
use fusebla::bench_support::eval_size;
use fusebla::coordinator::Context;
use fusebla::fusion::space::Space;
use fusebla::fusion::{enumerate_fusions, ImplAxes};
use fusebla::ir::elem::ProblemSize;
use fusebla::ir::plan::SeqPlan;
use fusebla::planner::{
    chunk_ranges, plan_space, plan_space_sharded, rank_top_k, shard, PlannerConfig,
};
use fusebla::sequences;
use fusebla::util::proptest::check;

fn kernel_names(plan: &SeqPlan) -> Vec<String> {
    plan.kernels.iter().map(|k| k.name.clone()).collect()
}

#[test]
fn planner_matches_exhaustive_on_all_eleven_sequences() {
    let ctx = Context::new();
    let axes = ImplAxes::minimal();
    let all = sequences::all();
    assert_eq!(all.len(), 11);
    for seq in all {
        let (prog, graph) = seq.graph(&ctx.lib);
        let p = eval_size(&seq);
        let fusions = enumerate_fusions(&prog, &ctx.lib, &graph);
        let space = Space::build(&prog, &ctx.lib, &graph, &fusions, &axes);
        let total = space.combination_count();
        assert!(total >= 2, "{}: space too small to exercise pruning", seq.name);

        let exhaustive = autotune::rank_all(&prog, &ctx.lib, &graph, &ctx.db, &axes, p);
        assert_eq!(exhaustive.len(), total, "{}", seq.name);
        let best = &exhaustive[0];

        // Unbounded beam: identical plan, bit-identical prediction.
        let planned = plan_space(&prog, &space, &ctx.db, p, &PlannerConfig::default());
        assert!(
            planned.predicted <= best.predicted,
            "{}: planner predicted {} > exhaustive best {}",
            seq.name,
            planned.predicted,
            best.predicted
        );
        assert_eq!(
            planned.best.variant, best.plan.variant,
            "{}: planner chose a different combination",
            seq.name
        );
        assert_eq!(
            kernel_names(&planned.best),
            kernel_names(&best.plan),
            "{}: planner kernels differ",
            seq.name
        );

        // Strictly fewer candidate combinations evaluated than the
        // exhaustive sweep — the whole point of the subsystem.
        assert!(
            planned.stats.combos_evaluated < total,
            "{}: planner evaluated {} combinations, space has {}",
            seq.name,
            planned.stats.combos_evaluated,
            total
        );
        assert_eq!(
            planned.stats.combos_evaluated + planned.stats.partitions_pruned,
            space.partitions.len(),
            "{}",
            seq.name
        );
        assert_eq!(planned.stats.space_combinations, total, "{}", seq.name);

        // A bounded beam still finds a combination no worse than the
        // exhaustive best (any width ≥ 1 keeps each part's argmin —
        // separability). The beam lives on the ranked-expansion path,
        // so exercise it through rank_top_k.
        for beam in [1usize, 2] {
            let beamed = rank_top_k(
                &space,
                &ctx.db,
                p,
                1,
                &PlannerConfig {
                    beam: Some(beam),
                    threads: 1,
                },
            );
            assert!(
                beamed[0].predicted <= best.predicted,
                "{}: beam {} predicted {} > exhaustive best {}",
                seq.name,
                beam,
                beamed[0].predicted,
                best.predicted
            );
        }
    }
}

#[test]
fn ranked_top_k_matches_exhaustive_head() {
    // The bounded ranked expansion must reproduce the head of the
    // exhaustive ranking (predicted values; tie order may differ).
    let ctx = Context::new();
    let axes = ImplAxes::minimal();
    for name in ["bicgk", "axpydot", "atax", "waxpby"] {
        let seq = sequences::by_name(name).unwrap();
        let (prog, graph) = seq.graph(&ctx.lib);
        let p = eval_size(&seq);
        let fusions = enumerate_fusions(&prog, &ctx.lib, &graph);
        let space = Space::build(&prog, &ctx.lib, &graph, &fusions, &axes);
        let exhaustive = autotune::rank_all(&prog, &ctx.lib, &graph, &ctx.db, &axes, p);
        let k = 8.min(exhaustive.len());
        let top = rank_top_k(&space, &ctx.db, p, k, &PlannerConfig::default());
        assert_eq!(top.len(), k, "{name}");
        for (i, combo) in top.iter().enumerate() {
            assert!(
                (combo.predicted - exhaustive[i].predicted).abs() <= 1e-15,
                "{name}: rank {} predicted {} vs exhaustive {}",
                i + 1,
                combo.predicted,
                exhaustive[i].predicted
            );
        }
    }
}

#[test]
fn planner_memoizes_shared_parts_across_partitions() {
    // GEMVER's singleton gemv part appears both in the all-singleton
    // partition and next to the {ger2, gemtvpz} fusion — the memo table
    // must predict it once, not once per partition.
    let ctx = Context::new();
    let seq = sequences::by_name("gemver").unwrap();
    let (prog, graph) = seq.graph(&ctx.lib);
    let p = eval_size(&seq);
    let axes = ImplAxes::minimal();
    let fusions = enumerate_fusions(&prog, &ctx.lib, &graph);
    let space = Space::build(&prog, &ctx.lib, &graph, &fusions, &axes);
    assert!(space.partitions.len() >= 2, "gemver must have a fused partition");
    let planned = plan_space(&prog, &space, &ctx.db, p, &PlannerConfig::default());
    assert!(
        planned.stats.kernel_evals < planned.stats.kernel_refs,
        "no sharing: {} evals for {} refs",
        planned.stats.kernel_evals,
        planned.stats.kernel_refs
    );
}

/// The shard-equivalence property: over randomized sequences, problem
/// sizes and shard counts K ∈ {1..5} — including K larger than the
/// partition count, which produces empty chunks — the merged sharded
/// result is byte-identical to unsharded `plan_space`: same plan label
/// and kernels, bit-identical predicted seconds, and stats totals that
/// sum exactly (shared implementations across chunks counted once).
/// Chunks are also merged in shuffled arrival order, since the fleet's
/// workers answer in whatever order they drain.
#[test]
fn sharded_plan_space_is_byte_identical_to_unsharded() {
    let ctx = Context::new();
    let axes = ImplAxes::minimal();
    let all = sequences::all();
    let cfg = PlannerConfig::default();
    check("sharded plan_space equals unsharded", 20, |g| {
        let seq = g.choose(&all);
        let (prog, graph) = seq.graph(&ctx.lib);
        let (m, n) = if seq.is_blas2() {
            (g.usize_edgy(1, 8192), g.usize_edgy(1, 8192))
        } else {
            (g.usize_edgy(1, 64), g.usize_edgy(1, 1 << 20))
        };
        let p = ProblemSize::new(m, n).padded();
        let fusions = enumerate_fusions(&prog, &ctx.lib, &graph);
        let space = Space::build(&prog, &ctx.lib, &graph, &fusions, &axes);
        let reference = plan_space(&prog, &space, &ctx.db, p, &cfg);
        for k in 1..=5usize {
            let sharded = plan_space_sharded(&prog, &space, &ctx.db, p, &cfg, k);
            assert_eq!(sharded.best.variant, reference.best.variant, "{} k={k}", seq.name);
            assert_eq!(
                kernel_names(&sharded.best),
                kernel_names(&reference.best),
                "{} k={k}",
                seq.name
            );
            assert_eq!(
                sharded.predicted.to_bits(),
                reference.predicted.to_bits(),
                "{} k={k}",
                seq.name
            );
            let (s, r) = (&sharded.stats, &reference.stats);
            assert_eq!(s.space_combinations, r.space_combinations, "{} k={k}", seq.name);
            assert_eq!(s.combos_evaluated, r.combos_evaluated, "{} k={k}", seq.name);
            assert_eq!(s.partitions_pruned, r.partitions_pruned, "{} k={k}", seq.name);
            assert_eq!(s.kernel_evals, r.kernel_evals, "{} k={k}", seq.name);
            assert_eq!(s.kernel_refs, r.kernel_refs, "{} k={k}", seq.name);
        }
        // chunks evaluated independently and merged out of order must
        // reassemble to the identical answer (merge sorts by range)
        let k = g.usize(2, 5);
        let mut chunks: Vec<shard::ShardEval> = chunk_ranges(space.partitions.len(), k)
            .into_iter()
            .map(|r| shard::eval_chunk(&space, &ctx.db, p, &cfg, r))
            .collect();
        g.shuffle(&mut chunks);
        let merged = shard::merge(&prog, &space, chunks);
        assert_eq!(merged.best.variant, reference.best.variant, "{}", seq.name);
        assert_eq!(merged.predicted.to_bits(), reference.predicted.to_bits(), "{}", seq.name);
        assert_eq!(merged.stats.combos_evaluated, reference.stats.combos_evaluated);
    });
}

#[test]
fn parallel_planner_is_deterministic() {
    let ctx = Context::new();
    let seq = sequences::by_name("gemver").unwrap();
    let (prog, graph) = seq.graph(&ctx.lib);
    let p = eval_size(&seq);
    let axes = ImplAxes::minimal();
    let fusions = enumerate_fusions(&prog, &ctx.lib, &graph);
    let space = Space::build(&prog, &ctx.lib, &graph, &fusions, &axes);
    let serial = plan_space(
        &prog,
        &space,
        &ctx.db,
        p,
        &PlannerConfig {
            beam: None,
            threads: 1,
        },
    );
    for threads in [2usize, 4, 8] {
        let parallel = plan_space(
            &prog,
            &space,
            &ctx.db,
            p,
            &PlannerConfig {
                beam: None,
                threads,
            },
        );
        assert_eq!(serial.predicted, parallel.predicted, "threads={threads}");
        assert_eq!(serial.best.variant, parallel.best.variant, "threads={threads}");
    }
}
