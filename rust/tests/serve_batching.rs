//! Batched serve path, exercised through the public API only: grouping
//! metrics, engine lifecycle, and — when artifacts are built — the
//! bit-identity of batched execution vs per-request execution.
//!
//! The stub-manifest tests run everywhere (planning and scheduling work
//! without the real PJRT backend); the execution tests gate on
//! `artifacts/manifest.txt` like the rest of the suite.

use fusebla::coordinator::{synth_inputs, Context, Coordinator, PlanChoice};
use fusebla::{Engine, EngineConfig, SubmitRequest};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

/// Batched execution must be bit-identical to per-request sequential
/// execution on the same inputs — batching shares dispatch bookkeeping,
/// never arithmetic.
#[test]
fn batched_results_bit_identical_to_sequential() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::new(Arc::new(Context::new()), &dir).unwrap();
    let rt = coord.runtime();
    let inputs: Vec<_> = (0..4)
        .map(|seed| synth_inputs(rt, "waxpby", "fused", 32, 65536, seed))
        .collect();
    let batched = rt.run_seq_batch("waxpby", "fused", 32, 65536, inputs.clone());
    assert_eq!(batched.len(), 4);
    for (input, b) in inputs.iter().zip(batched) {
        let b = b.expect("batched run");
        let s = rt.run_seq("waxpby", "fused", 32, 65536, input).expect("sequential run");
        assert_eq!(b.env.len(), s.env.len());
        assert_eq!(b.stages.len(), s.stages.len());
        for (name, tb) in &b.env {
            let ts = &s.env[name];
            assert_eq!(tb.dims, ts.dims, "dims of '{name}'");
            for (x, y) in tb.data.iter().zip(&ts.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "tensor '{name}' differs");
            }
        }
    }
}

/// A repeated-key burst through the engine executes fewer batches than
/// requests, and every batched result matches the per-request run for
/// the same seed bit-for-bit.
#[test]
fn engine_burst_batches_and_matches_sequential() {
    let Some(dir) = artifacts_dir() else { return };
    let ctx = Arc::new(Context::new());
    let cfg = EngineConfig {
        batch_window: Duration::from_millis(250),
        max_batch: 64,
        ..EngineConfig::default()
    };
    let engine = Engine::with_config(ctx.clone(), &dir, cfg).unwrap();
    let client = engine.client();
    let n = 12u64;
    let tickets: Vec<_> = (0..n)
        .map(|seed| {
            client
                .submit(
                    SubmitRequest::new("waxpby", 32, 65536)
                        .synth(seed)
                        .variant(PlanChoice::Fused),
                )
                .unwrap()
        })
        .collect();
    let results: Vec<_> = tickets
        .into_iter()
        .map(|t| t.wait().expect("burst request"))
        .collect();
    let metrics = engine.shutdown();
    assert_eq!(metrics.requests, n);
    assert_eq!(metrics.failures, 0);
    assert_eq!(metrics.batch_size_sum, n);
    assert!(
        metrics.batches < n,
        "a same-key burst must group: {} batches for {n} requests",
        metrics.batches
    );
    assert!(metrics.max_batch_size >= 2);
    assert!(metrics.mean_batch_size() > 1.0);

    let coord = Coordinator::new(ctx, &dir).unwrap();
    for (seed, res) in results.iter().enumerate() {
        let inputs = synth_inputs(coord.runtime(), "waxpby", "fused", 32, 65536, seed as u64);
        let seq = coord
            .runtime()
            .run_seq("waxpby", "fused", 32, 65536, &inputs)
            .unwrap();
        for (name, tb) in &res.env {
            let ts = &seq.env[name];
            for (x, y) in tb.data.iter().zip(&ts.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "seed {seed}: tensor '{name}' differs");
            }
        }
    }
}

/// `run_seq_batch` on a size with no artifacts fails every slot with the
/// catalog-listing error, instead of failing the call shape itself.
/// Runs without real artifacts (stub manifest).
#[test]
fn batch_of_missing_size_fails_per_slot() {
    let dir = std::env::temp_dir().join(format!("fusebla_batchmiss_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "artifact waxpby.fused.m32n65536.s0\n file waxpby.hlo.txt\n seq waxpby\n variant fused\n stage 0\n in x:f32[65536]\n in y:f32[65536]\n out w:f32[65536]\n m 32\n n 65536\nend\n",
    )
    .unwrap();
    let coord = Coordinator::new(Arc::new(Context::new()), &dir).unwrap();
    let inputs = vec![Default::default(), Default::default()];
    let results = coord.runtime().run_seq_batch("waxpby", "fused", 32, 1024, inputs);
    assert_eq!(results.len(), 2);
    for r in results {
        let err = r.err().expect("must fail").to_string();
        assert!(err.contains("no artifacts"), "{err}");
        assert!(err.contains("65536"), "should list catalog sizes: {err}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
