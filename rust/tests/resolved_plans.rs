//! The resolve-once execution-plan subsystem, tested without a real
//! backend: the indexed manifest must return byte-identical stage lists
//! to the seed's linear catalog scan, slot-interned execution must
//! produce exactly the env the seed `BTreeMap` path produced, and the
//! runtime's resolve-cache counters must tell failures from hits.

use fusebla::runtime::{Runtime, SlotPlan, Tensor};
use fusebla::util::manifest::{ArtifactEntry, Manifest, TensorSpec};
use fusebla::util::proptest::{check, Gen};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// The seed `Runtime::stages_of` lookup, kept verbatim as the reference
/// the index is checked against: a full scan comparing size attrs as
/// strings, cloning matches, sorting by stage.
fn stages_reference(man: &Manifest, seq: &str, variant: &str, m: usize, n: usize) -> Vec<ArtifactEntry> {
    let mut v: Vec<ArtifactEntry> = man
        .entries
        .values()
        .filter(|e| {
            e.seq == seq
                && e.variant == variant
                && e.attrs.get("m").map(|s| s.as_str()) == Some(m.to_string().as_str())
                && e.attrs.get("n").map(|s| s.as_str()) == Some(n.to_string().as_str())
        })
        .cloned()
        .collect();
    v.sort_by_key(|e| e.stage);
    v
}

/// The seed `Runtime::sizes_of` scan, kept verbatim as the reference.
fn sizes_reference(man: &Manifest, seq: &str, variant: &str) -> Vec<(usize, usize)> {
    let mut sizes: Vec<(usize, usize)> = man
        .entries
        .values()
        .filter(|e| e.seq == seq && e.variant == variant && e.stage == 0)
        .filter_map(|e| {
            Some((
                e.attrs.get("m")?.parse().ok()?,
                e.attrs.get("n")?.parse().ok()?,
            ))
        })
        .collect();
    sizes.sort_unstable();
    sizes.dedup();
    sizes
}

/// A catalog exercising every indexing edge: several sequences,
/// variants, sizes and stages, entries with missing/non-numeric size
/// attrs, and a non-canonical `m 032` that string comparison rejects.
fn tricky_catalog() -> Manifest {
    let mut text = String::new();
    for seq in ["alpha", "beta", "gamma"] {
        for variant in ["fused", "cublas"] {
            for (m, n) in [(32, 1024), (32, 65536), (256, 256)] {
                let n_stages = if variant == "fused" { 1 } else { 3 };
                for stage in 0..n_stages {
                    text.push_str(&format!(
                        "artifact {seq}.{variant}.m{m}n{n}.s{stage}\n file f.hlo.txt\n seq {seq}\n variant {variant}\n stage {stage}\n in x:f32[{n}]\n out y:f32[{n}]\n m {m}\n n {n}\nend\n"
                    ));
                }
            }
        }
    }
    // oddballs the scan ignores (and the index must too)
    text.push_str(
        "artifact alpha.fused.nosize\n file f.hlo.txt\n seq alpha\n variant fused\n stage 0\nend\n",
    );
    text.push_str(
        "artifact alpha.fused.badm\n file f.hlo.txt\n seq alpha\n variant fused\n stage 0\n m lots\n n 1024\nend\n",
    );
    text.push_str(
        "artifact beta.fused.noncanon\n file f.hlo.txt\n seq beta\n variant fused\n stage 0\n m 032\n n 1024\nend\n",
    );
    Manifest::parse(&text, Path::new(".")).expect("tricky catalog")
}

#[test]
fn indexed_stages_match_reference_scan_over_whole_catalog() {
    let man = tricky_catalog();
    // every (seq, variant) × every size mentioned anywhere, plus sizes
    // and names the catalog does not have
    let mut sizes: Vec<(usize, usize)> = man
        .entries
        .values()
        .filter_map(|e| Some((e.attrs.get("m")?.parse().ok()?, e.attrs.get("n")?.parse().ok()?)))
        .collect();
    sizes.push((7, 7));
    sizes.push((32, 32));
    let mut checked = 0;
    for seq in ["alpha", "beta", "gamma", "ghost"] {
        for variant in ["fused", "cublas", "ghost"] {
            for &(m, n) in &sizes {
                let reference = stages_reference(&man, seq, variant, m, n);
                let indexed = man.stages(seq, variant, m, n);
                let ref_keys: Vec<&str> = reference.iter().map(|e| e.key.as_str()).collect();
                let idx_keys: Vec<&str> = indexed.iter().map(|e| e.key.as_str()).collect();
                assert_eq!(ref_keys, idx_keys, "{seq}.{variant} m{m} n{n}");
                // identical entries, not just identical keys
                for (a, b) in reference.iter().zip(&indexed) {
                    assert_eq!(a.key, b.key);
                    assert_eq!(a.stage, b.stage);
                    assert_eq!(a.inputs, b.inputs);
                    assert_eq!(a.outputs, b.outputs);
                    assert_eq!(a.attrs, b.attrs);
                }
                checked += 1;
            }
        }
    }
    assert!(checked > 100, "query sweep must cover the catalog ({checked})");
}

#[test]
fn indexed_sizes_match_reference_scan() {
    let man = tricky_catalog();
    for seq in ["alpha", "beta", "gamma", "ghost"] {
        for variant in ["fused", "cublas", "ghost"] {
            assert_eq!(
                sizes_reference(&man, seq, variant),
                man.sizes(seq, variant).to_vec(),
                "{seq}.{variant}"
            );
        }
    }
    // the non-canonical `m 032` entry is a stage-0 size (lenient parse,
    // as the seed scan had it) but never a stage-list match
    assert!(man.sizes("beta", "fused").contains(&(32, 1024)));
    assert!(!man
        .stages("beta", "fused", 32, 1024)
        .iter()
        .any(|e| e.key == "beta.fused.noncanon"));
}

fn spec(name: &str, dims: &[usize]) -> TensorSpec {
    TensorSpec {
        name: name.to_string(),
        dtype: fusebla::util::manifest::DType::F32,
        dims: dims.to_vec(),
    }
}

fn entry(stage: usize, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>) -> ArtifactEntry {
    ArtifactEntry {
        key: format!("prop.fused.s{stage}"),
        file: PathBuf::from("f.hlo.txt"),
        seq: "prop".to_string(),
        variant: "fused".to_string(),
        stage,
        inputs,
        outputs,
        attrs: BTreeMap::new(),
        m: Some(8),
        n: Some(8),
    }
}

/// A deterministic stand-in for stage execution: every output element
/// is a pure function of the stage index, the output's position and all
/// input tensors — evaluated identically by both environment
/// implementations, so any divergence is the environment's fault.
fn fake_output(stage: usize, j: usize, out_len: usize, dims: &[usize], ins: &[&Tensor]) -> Tensor {
    let mut data = vec![0.0f32; out_len];
    for (k, x) in data.iter_mut().enumerate() {
        let mut acc = (stage * 31 + j * 7) as f32;
        for t in ins {
            acc += t.data[k % t.data.len()];
        }
        *x = acc;
    }
    Tensor::new(dims.to_vec(), data)
}

/// Slot-interned execution must produce exactly the `RunResult.env` the
/// seed `BTreeMap<String, Tensor>` path produced — same names, same
/// dims, bit-identical data — including pass-through of inputs no stage
/// touches.
#[test]
fn slot_env_matches_btreemap_env() {
    check("slot env equivalence", 128, |g: &mut Gen| {
        // a fixed name pool with per-name dims, so specs stay coherent
        let names: Vec<String> = (0..10).map(|i| format!("t{i}")).collect();
        let dims: Vec<Vec<usize>> = (0..names.len()).map(|_| vec![g.usize(1, 6)]).collect();
        let n_stages = g.usize(1, 5);
        let mut entries = Vec::new();
        for stage in 0..n_stages {
            let n_in = g.usize(1, 3);
            let n_out = g.usize(1, 2);
            let pick = |g: &mut Gen| -> usize { g.usize(0, names.len() - 1) };
            let inputs: Vec<TensorSpec> = (0..n_in)
                .map(|_| {
                    let i = pick(g);
                    spec(&names[i], &dims[i])
                })
                .collect();
            let outputs: Vec<TensorSpec> = (0..n_out)
                .map(|_| {
                    let i = pick(g);
                    spec(&names[i], &dims[i])
                })
                .collect();
            entries.push(entry(stage, inputs, outputs));
        }

        // free inputs: names read before any stage produces them
        let mut produced: Vec<&str> = Vec::new();
        let mut inputs: BTreeMap<String, Tensor> = BTreeMap::new();
        for e in &entries {
            for s in &e.inputs {
                if !produced.contains(&s.name.as_str()) && !inputs.contains_key(&s.name) {
                    let len = s.dims.iter().product::<usize>().max(1);
                    inputs.insert(s.name.clone(), Tensor::new(s.dims.clone(), g.f32_vec(len)));
                }
            }
            for s in &e.outputs {
                produced.push(s.name.as_str());
            }
        }
        if g.bool() {
            // an input no stage touches must pass through both paths
            inputs.insert("spare".to_string(), Tensor::vector(g.f32_vec(3)));
        }

        // reference: the seed semantics — clone the named map, read
        // inputs by name, insert outputs by name
        let mut env_ref = inputs.clone();
        for e in &entries {
            let ins: Vec<&Tensor> = e.inputs.iter().map(|s| &env_ref[&s.name]).collect();
            let outs: Vec<(String, Tensor)> = e
                .outputs
                .iter()
                .enumerate()
                .map(|(j, s)| {
                    let len = s.dims.iter().product::<usize>().max(1);
                    (s.name.clone(), fake_output(e.stage, j, len, &s.dims, &ins))
                })
                .collect();
            for (name, t) in outs {
                env_ref.insert(name, t);
            }
        }

        // slot path: bind once, execute by slot index, materialize once
        let plan = SlotPlan::build("prop", "fused", 8, 8, entries.clone());
        assert_eq!(plan.stage_count(), entries.len());
        let mut env = plan.bind(&inputs);
        for st in plan.stages() {
            let ins: Vec<&Tensor> = st
                .input_slots()
                .iter()
                .map(|&slot| env.get(slot).expect("bound input"))
                .collect();
            let outs: Vec<(usize, Tensor)> = st
                .entry
                .outputs
                .iter()
                .zip(st.output_slots())
                .enumerate()
                .map(|(j, (s, &slot))| {
                    let len = s.dims.iter().product::<usize>().max(1);
                    (slot, fake_output(st.entry.stage, j, len, &s.dims, &ins))
                })
                .collect();
            drop(ins);
            for (slot, t) in outs {
                env.set(slot, t);
            }
        }
        let env_slots = plan.materialize(env);

        assert_eq!(env_ref.len(), env_slots.len());
        for (name, a) in &env_ref {
            let b = &env_slots[name];
            assert_eq!(a.dims, b.dims, "dims of '{name}'");
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "tensor '{name}' differs");
            }
        }
    });
}

fn scratch_catalog(tag: &str, manifest: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fusebla_rp_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
    dir
}

/// A failed resolve is re-attempted (never cached) and the counters
/// report it as a miss each time; nothing compiles.
#[test]
fn failed_resolves_are_not_cached_and_count_misses() {
    let dir = scratch_catalog(
        "failmiss",
        "artifact w.fused.m32n64.s0\n file missing.hlo.txt\n seq w\n variant fused\n stage 0\n in x:f32[64]\n out y:f32[64]\n m 32\n n 64\nend\n",
    );
    let rt = Runtime::load(&dir).expect("manifest parses");
    assert!(rt.resolve("w", "fused", 32, 64).is_err(), "missing HLO file");
    let c0 = rt.counters();
    assert_eq!(c0.resolve_misses, 1);
    assert_eq!(c0.resolve_hits, 0);
    assert_eq!(c0.executable_compiles, 0);
    assert!(rt.resolve("w", "fused", 32, 64).is_err(), "still failing");
    let c1 = rt.counters();
    assert_eq!(c1.resolve_misses, 2, "failures must not be cached");
    assert_eq!(c1.resolve_hits, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Resolving a size the catalog lacks fails with the catalog's actual
/// size points in the message (the operator-facing breadcrumb).
#[test]
fn resolve_of_missing_size_lists_available_sizes() {
    let dir = scratch_catalog(
        "nosize",
        "artifact w.fused.m32n64.s0\n file f.hlo.txt\n seq w\n variant fused\n stage 0\n in x:f32[64]\n out y:f32[64]\n m 32\n n 64\nend\n",
    );
    let rt = Runtime::load(&dir).expect("manifest parses");
    let err = rt.resolve("w", "fused", 5, 5).err().expect("must fail").to_string();
    assert!(err.contains("no artifacts"), "{err}");
    assert!(err.contains("(32, 64)"), "should list catalog sizes: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}
