//! Artifact-catalog validation: everything the CI artifact job can
//! prove about `make artifacts` output *without* a real PJRT backend
//! (the vendored `xla` crate is a compile-only stub; execution-level
//! tests additionally need an XLA-backed build).
//!
//! Gated on `artifacts/manifest.txt` existing, like the execution tests.

use fusebla::runtime::Runtime;
use fusebla::sequences;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

/// Every sequence has both variants catalogued at at least one size,
/// and the runtime's size discovery sees them.
#[test]
fn catalog_covers_every_sequence_and_variant() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime loads the manifest");
    for seq in sequences::all() {
        for variant in ["fused", "cublas"] {
            let sizes = rt.sizes_of(seq.name, variant);
            assert!(
                !sizes.is_empty(),
                "{}.{variant}: no catalogued sizes",
                seq.name
            );
        }
    }
}

/// Every manifest entry points at an HLO text file that exists and
/// parses as an HLO module (the stub backend does real file validation
/// even though it cannot execute).
#[test]
fn every_artifact_file_exists_and_is_hlo() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    for entry in rt.manifest.entries.values() {
        let path = rt.manifest.path_of(entry);
        assert!(path.exists(), "{}: file {} missing", entry.key, path.display());
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", entry.key));
        assert!(
            text.contains("HloModule"),
            "{}: {} is not HLO module text",
            entry.key,
            path.display()
        );
    }
}

/// Stages of each (seq, variant, size) group are numbered contiguously
/// from 0, and every entry declares its inputs, outputs, and size attrs.
#[test]
fn stage_numbering_is_contiguous_and_entries_are_complete() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let mut groups: BTreeMap<(String, String, String, String), Vec<usize>> = BTreeMap::new();
    for entry in rt.manifest.entries.values() {
        assert!(!entry.inputs.is_empty(), "{}: no inputs", entry.key);
        assert!(!entry.outputs.is_empty(), "{}: no outputs", entry.key);
        let m = entry.attrs.get("m").unwrap_or_else(|| panic!("{}: no m attr", entry.key));
        let n = entry.attrs.get("n").unwrap_or_else(|| panic!("{}: no n attr", entry.key));
        groups
            .entry((entry.seq.clone(), entry.variant.clone(), m.clone(), n.clone()))
            .or_default()
            .push(entry.stage);
    }
    for ((seq, variant, m, n), mut stages) in groups {
        stages.sort_unstable();
        let expect: Vec<usize> = (0..stages.len()).collect();
        assert_eq!(
            stages, expect,
            "{seq}.{variant} m{m} n{n}: stages not contiguous"
        );
    }
}
