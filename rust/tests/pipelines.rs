//! Dynamic pipeline registration, exercised through the public API
//! only: a client-submitted script becomes a first-class servable
//! sequence — registered fleet-wide, routed, plan-cached and executed —
//! and the served bits are identical to the offline reference
//! interpretation of the same compiled pipeline.
//!
//! Everything here runs over a stub catalog with no built artifacts:
//! built-in execution fails at the offline stub backend, but registered
//! pipelines execute for real through their interpreter-backed resolved
//! plans, so the full register → route → batch → execute path is
//! testable offline.

use fusebla::bench_support::stub_catalog;
use fusebla::coordinator::Context;
use fusebla::pipelines;
use fusebla::util::proptest::check;
use fusebla::{Engine, EngineConfig, ServeError, SubmitRequest};
use std::sync::Arc;
use std::time::Duration;

fn engine_over_stub(tag: &str, cfg: EngineConfig) -> (std::path::PathBuf, Engine) {
    let dir = stub_catalog(&format!("pipelines_{tag}"), &["waxpby", "vadd"]);
    let engine = Engine::with_config(Arc::new(Context::new()), &dir, cfg).unwrap();
    (dir, engine)
}

/// The acceptance-criteria property: for both exemplar pipelines,
/// random sizes and seeds, a registered pipeline served through the
/// fleet produces bit-identical output tensors to the offline
/// `pipelines::compile` + `run_offline` reference on the same explicit
/// inputs — and the serve path reports which variant it picked, so the
/// reference runs the same one.
#[test]
fn served_pipeline_is_bit_identical_to_offline_reference() {
    let cfg = EngineConfig {
        batch_window: Duration::from_millis(2),
        ..EngineConfig::default()
    };
    let (dir, engine) = engine_over_stub("prop", cfg);
    let client = engine.client();
    client.register_pipeline("amx", pipelines::examples::ADD_MUL_EXP).unwrap();
    client.register_pipeline("q8", pipelines::examples::QUANTIZE_INT8).unwrap();
    // independent offline compile — shares nothing with the engine
    let ctx = Context::new();
    let amx = pipelines::compile("amx", pipelines::examples::ADD_MUL_EXP, &ctx.lib).unwrap();
    let q8 = pipelines::compile("q8", pipelines::examples::QUANTIZE_INT8, &ctx.lib).unwrap();
    check("served pipeline output matches the offline reference bitwise", 10, |g| {
        let (name, c) = if g.bool() { ("amx", &amx) } else { ("q8", &q8) };
        let n = *g.choose(&[64usize, 256, 1024]);
        let seed = g.usize(0, 1 << 16) as u64;
        let inputs = c.pipeline.synth_inputs(32, n, seed).unwrap();
        let t = client
            .submit(SubmitRequest::new(name, 32, n).inputs(inputs.clone()))
            .unwrap();
        let res = t.wait().expect("registered pipelines execute on the stub backend");
        let offline = c.pipeline.run_offline(&res.variant, 32, n, &inputs).unwrap();
        for &v in &c.pipeline.program.outputs {
            let out = &c.pipeline.program.var(v).name;
            assert_eq!(
                res.env.get(out),
                offline.get(out),
                "{name} n={n} seed={seed}: served '{out}' must match offline bits"
            );
        }
    });
    let m = engine.shutdown();
    assert_eq!(m.failures, 0, "every served pipeline execution succeeded");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Second execution of the same key is a plan-cache hit *and* a
/// resolve-cache hit: registered pipelines ride the same caches as
/// built-ins, counter-asserted.
#[test]
fn warm_pipeline_execute_hits_plan_and_resolve_caches() {
    let (dir, engine) = engine_over_stub("warm", EngineConfig::default());
    let client = engine.client();
    client.register_pipeline("amx", pipelines::examples::ADD_MUL_EXP).unwrap();
    for seed in [1u64, 2] {
        let t = client.submit(SubmitRequest::new("amx", 32, 256).synth(seed)).unwrap();
        let res = t.wait().expect("pipeline executes");
        assert!(res.env.contains_key("z"));
    }
    let m = engine.shutdown();
    assert_eq!(m.requests, 2);
    assert_eq!(m.failures, 0);
    assert_eq!(m.plan_cache_misses, 1, "first execute plans");
    assert_eq!(m.plan_cache_hits, 1, "second execute reuses the plan");
    assert_eq!(m.resolve_misses, 1, "first execute resolves and caches");
    assert_eq!(m.resolve_hits, 1, "second execute is resolve-once");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Registration quota and the typed rejection surface: invalid scripts
/// report their line, duplicates and built-in collisions are refused,
/// over-quota registration is refused — and none of it perturbs the
/// already-registered pipeline or the built-in serve path.
#[test]
fn typed_rejections_leave_serving_state_untouched() {
    let cfg = EngineConfig {
        pipeline_quota: 1,
        ..EngineConfig::default()
    };
    let (dir, engine) = engine_over_stub("typed", cfg);
    let client = engine.client();
    let fp = client.register_pipeline("amx", pipelines::examples::ADD_MUL_EXP).unwrap();

    // invalid script: typed, with the offending line
    let bad = "vector<N> x;\ninput x;\ny = nosuch(x);\nreturn y;";
    let err = client.register_pipeline("bad", bad).err().expect("invalid script");
    match err.downcast_ref::<ServeError>() {
        Some(ServeError::InvalidScript { line, msg }) => {
            assert_eq!(*line, 3, "the bad call is on line 3");
            assert!(msg.contains("unknown library function"), "{msg}");
        }
        other => panic!("expected InvalidScript, got {other:?}"),
    }
    // same name, different source: duplicate
    let err = client
        .register_pipeline("amx", pipelines::examples::QUANTIZE_INT8)
        .err()
        .expect("name taken");
    assert!(matches!(
        err.downcast_ref::<ServeError>(),
        Some(ServeError::DuplicatePipeline { .. })
    ));
    // identical source: idempotent, same fingerprint, not an error
    assert_eq!(
        client.register_pipeline("amx", pipelines::examples::ADD_MUL_EXP).unwrap(),
        fp
    );
    // built-in names are never shadowable
    let err = client
        .register_pipeline("waxpby", pipelines::examples::ADD_MUL_EXP)
        .err()
        .expect("built-in collision");
    assert!(matches!(
        err.downcast_ref::<ServeError>(),
        Some(ServeError::DuplicatePipeline { .. })
    ));
    // quota of 1 is spent on 'amx'
    let err = client
        .register_pipeline("q8", pipelines::examples::QUANTIZE_INT8)
        .err()
        .expect("over quota");
    match err.downcast_ref::<ServeError>() {
        Some(ServeError::PipelineQuota { count, quota }) => assert_eq!((*count, *quota), (1, 1)),
        other => panic!("expected PipelineQuota, got {other:?}"),
    }

    // nothing above perturbed serving: queues are idle, the registered
    // pipeline still executes, and built-ins still route and deliver
    assert_eq!(client.queue_depths().iter().sum::<u64>(), 0);
    let t = client.submit(SubmitRequest::new("amx", 32, 256).synth(3)).unwrap();
    assert!(t.wait().is_ok(), "registered pipeline unaffected by rejections");
    let t = client.submit(SubmitRequest::new("waxpby", 32, 65536).synth(3)).unwrap();
    let e = t.wait().err().expect("stub backend fails builtin execution");
    assert!(e.downcast_ref::<ServeError>().is_none(), "delivered, not shed: {e:#}");

    // unregistration frees the name and the quota slot
    assert!(client.unregister_pipeline("amx"));
    assert!(!client.unregister_pipeline("amx"), "second removal is a no-op");
    let t = client.submit(SubmitRequest::new("amx", 32, 256).synth(4)).unwrap();
    assert!(t.wait().is_err(), "unregistered name no longer serves");
    client
        .register_pipeline("q8", pipelines::examples::QUANTIZE_INT8)
        .expect("quota slot freed by unregistration");
    let m = engine.shutdown();
    assert!(m.pipeline_registrations >= 2);
    assert!(m.pipeline_rejections >= 1, "the worker-side quota rejection counted");
    let _ = std::fs::remove_dir_all(&dir);
}
