//! Failure-injection tests: the runtime must fail *cleanly and
//! specifically* when artifacts are missing, corrupt, or mismatched —
//! a deployment requirement the paper's compiler (which controls its own
//! binaries) never faced, but ours (AOT catalog + separate runtime) does.

use fusebla::coordinator::traffic;
use fusebla::fusion::ImplAxes;
use fusebla::ir::elem::ProblemSize;
use fusebla::planner::{plan_space, PlannerConfig};
use fusebla::runtime::{Runtime, Tensor};
use fusebla::sequences;
use fusebla::sim::DeviceModel;
use fusebla::{DeviceRegistry, Engine, EngineConfig, Fault, FaultPlan, ServeError, SubmitRequest};
use std::collections::BTreeMap;
use std::fs;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn scratch_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fusebla_fi_{name}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn real_artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

#[test]
fn missing_manifest_mentions_make_artifacts() {
    let dir = scratch_dir("nomanifest");
    let err = Runtime::load(&dir).err().expect("must fail").to_string();
    assert!(err.contains("make artifacts"), "{err}");
}

#[test]
fn manifest_referencing_missing_file_fails_at_compile_with_key() {
    let dir = scratch_dir("missingfile");
    fs::write(
        dir.join("manifest.txt"),
        "artifact ghost.fused.m32n32.s0\n file ghost.hlo.txt\n seq ghost\n variant fused\n stage 0\n in x:f32[32]\n out y:f32[32]\n m 32\n n 32\nend\n",
    )
    .unwrap();
    let rt = Runtime::load(&dir).expect("manifest parses");
    let err = rt
        .executable("ghost.fused.m32n32.s0")
        .err()
        .expect("must fail")
        .to_string();
    assert!(
        err.contains("ghost.hlo.txt") || err.contains("parsing"),
        "{err}"
    );
}

#[test]
fn corrupt_hlo_text_fails_with_context() {
    let dir = scratch_dir("corrupt");
    fs::write(dir.join("bad.hlo.txt"), "this is not HLO at all {{{").unwrap();
    fs::write(
        dir.join("manifest.txt"),
        "artifact bad.fused.m32n32.s0\n file bad.hlo.txt\n seq bad\n variant fused\n stage 0\n in x:f32[32]\n out y:f32[32]\n m 32\n n 32\nend\n",
    )
    .unwrap();
    let rt = Runtime::load(&dir).expect("manifest parses");
    let err = rt
        .executable("bad.fused.m32n32.s0")
        .err()
        .expect("must fail")
        .to_string();
    assert!(err.contains("bad.hlo.txt") || err.contains("parsing"), "{err}");
}

#[test]
fn wrong_input_dims_rejected_before_execution() {
    let Some(dir) = real_artifacts() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let entry = rt.manifest.get("sscal.fused.m32n65536.s0").unwrap().clone();
    // bind every declared input at its right shape, then corrupt x
    let mut env = BTreeMap::new();
    for spec in &entry.inputs {
        let len = spec.dims.iter().product::<usize>().max(1);
        env.insert(spec.name.clone(), Tensor::new(spec.dims.clone(), vec![1.0; len]));
    }
    env.insert("x".to_string(), Tensor::vector(vec![1.0; 64])); // wrong size
    let err = rt
        .run_seq("sscal", "fused", 32, 65536, &env)
        .err()
        .expect("must fail")
        .to_string();
    assert!(err.contains("dims"), "{err}");
}

#[test]
fn unknown_key_lists_available_sizes() {
    let Some(dir) = real_artifacts() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let err = rt
        .run_seq("bicgk", "fused", 12345, 12345, &BTreeMap::new())
        .err()
        .expect("must fail")
        .to_string();
    assert!(err.contains("available"), "{err}");
    assert!(err.contains("256"), "should list catalog sizes: {err}");
}

#[test]
fn truncated_manifest_rejected() {
    let dir = scratch_dir("truncated");
    fs::write(
        dir.join("manifest.txt"),
        "artifact t.fused.m32n32.s0\n file t.hlo.txt\n",
    )
    .unwrap();
    let err = Runtime::load(&dir).err().expect("must fail").to_string();
    assert!(err.contains("truncated"), "{err}");
}

/// Shard failure injection: a worker that is gone (engine shut down
/// under a live client) or wedged past the shard deadline (deadline
/// zero — every gather times out mid-`PlanShard`) makes the submitter
/// plan the affected chunks locally. The final plan is identical in
/// every case — same label, bit-identical predicted seconds, same
/// stats — and the search neither hangs nor merges a partial range.
#[test]
fn shard_chunks_fall_back_locally_on_wedged_or_dead_workers() {
    // stub catalog: the manifest parses, so the engine starts; only
    // execution would need real artifacts, and nothing executes here
    let dir = fusebla::bench_support::stub_catalog("shardfb", &["waxpby"]);
    let cal = scratch_dir("shardfb_cal");
    let registry = Arc::new(
        DeviceRegistry::new(vec![DeviceModel::gtx480(), DeviceModel::gt430()], &cal).unwrap(),
    );

    // the unsharded local reference, on device 0's own calibration
    let lib = registry.library().clone();
    let seq = sequences::by_name("gemver").unwrap();
    let (prog, _graph, space) = seq.space(&lib, &ImplAxes::minimal());
    let p = ProblemSize::new(4096, 4096).padded();
    let reference = plan_space(
        &prog,
        &space,
        &registry.context(0).db,
        p,
        &PlannerConfig::default(),
    );
    let device0 = registry.id(0).name().to_string();
    let same = |planned: &fusebla::planner::Planned, label: &str| {
        assert_eq!(planned.best.variant, reference.best.variant, "{label}");
        assert_eq!(
            planned.predicted.to_bits(),
            reference.predicted.to_bits(),
            "{label}"
        );
        assert_eq!(
            planned.stats.combos_evaluated, reference.stats.combos_evaluated,
            "{label}"
        );
        assert_eq!(planned.stats.kernel_evals, reference.stats.kernel_evals, "{label}");
    };

    // 1. healthy fleet: chunks served by the workers
    let engine = Engine::start_fleet(registry.clone(), &dir, EngineConfig::default()).unwrap();
    let client = engine.client();
    let healthy = client
        .search_sharded("gemver", 4096, 4096, 4, Some(device0.as_str()))
        .unwrap();
    same(&healthy, "healthy fleet");
    let live = engine.metrics();
    assert_eq!(live.shard_requests, 4, "every chunk reached a worker");
    assert_eq!(live.shard_served, 4);

    // 2. workers gone: shut the engine down but keep the client — every
    // PlanShard send fails, every chunk plans locally, nothing hangs
    let _ = engine.shutdown();
    let dead = client
        .search_sharded("gemver", 4096, 4096, 4, Some(device0.as_str()))
        .unwrap();
    same(&dead, "dead workers");

    // 3. wedged past the deadline: a zero shard deadline times every
    // gather out mid-PlanShard; the submitter falls back chunk by chunk
    // and still merges the full range
    let wedged_engine = Engine::start_fleet(
        registry.clone(),
        &dir,
        EngineConfig {
            shard_deadline: Duration::ZERO,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let wedged = wedged_engine
        .client()
        .search_sharded("gemver", 4096, 4096, 3, Some(device0.as_str()))
        .unwrap();
    same(&wedged, "wedged workers");
    let _ = wedged_engine.shutdown();

    let _ = fs::remove_dir_all(&dir);
    let _ = fs::remove_dir_all(&cal);
}

/// Router depth-leak regression: the per-device queue depths must
/// return to zero after a burst in which *every* terminal outcome is an
/// error — stub execution failures, admission-control sheds and
/// deadline sheds all mixed. The depth slot is released by the reply's
/// RAII guard on any terminal path; before that guard, error paths that
/// dropped the request without replying leaked the slot and the router
/// permanently saw phantom backlog.
#[test]
fn queue_depths_return_to_zero_after_all_error_burst() {
    let dir = fusebla::bench_support::stub_catalog("depthleak", &["waxpby"]);
    let cal = scratch_dir("depthleak_cal");
    let registry = Arc::new(
        DeviceRegistry::new(vec![DeviceModel::gtx480(), DeviceModel::gt430()], &cal).unwrap(),
    );
    let engine = Engine::start_fleet(
        registry,
        &dir,
        EngineConfig {
            batch_window: Duration::from_millis(100),
            queue_cap: 3,
            deadline_slack: Duration::ZERO,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let client = engine.client();
    let mut tickets = Vec::new();
    let mut queue_sheds = 0usize;
    for i in 0..12u64 {
        // a 30 ms deadline under a 100 ms window: admitted requests
        // either get shed at the turn boundary (DeadlineExpired) or
        // execute and fail at the stub backend — every outcome errors
        let req = fusebla::SubmitRequest::new("waxpby", 32, 65536)
            .synth(i)
            .deadline(Duration::from_millis(30));
        match client.submit(req) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                assert!(
                    matches!(
                        e.downcast_ref::<fusebla::ServeError>(),
                        Some(fusebla::ServeError::QueueFull { .. })
                    ),
                    "submit-path errors in this burst are sheds: {e:#}"
                );
                queue_sheds += 1;
            }
        }
    }
    assert!(!tickets.is_empty(), "some requests must be admitted");
    for t in tickets {
        assert!(t.wait().is_err(), "every outcome of this burst is an error");
    }
    // every slot released: replies release before sending, so after all
    // waits return the depths are deterministically back to zero
    let depths = client.queue_depths();
    assert_eq!(depths, vec![0, 0], "{queue_sheds} queue shed(s), depths {depths:?}");
    let _ = engine.shutdown_fleet();
    let _ = fs::remove_dir_all(&dir);
    let _ = fs::remove_dir_all(&cal);
}

/// A two-lane chaos fleet (GTX 480 + GT 430) over a stub catalog, with
/// the given fault plan active from the first turn.
fn chaos_fleet(tag: &str, plan: FaultPlan, cfg: EngineConfig) -> (PathBuf, PathBuf, Engine) {
    let dir = fusebla::bench_support::stub_catalog(tag, &["waxpby", "vadd"]);
    let cal = scratch_dir(&format!("{tag}_cal"));
    let registry = Arc::new(
        DeviceRegistry::new(vec![DeviceModel::gtx480(), DeviceModel::gt430()], &cal).unwrap(),
    );
    let engine = Engine::start_fleet(
        registry,
        &dir,
        EngineConfig {
            fault_plan: plan,
            ..cfg
        },
    )
    .unwrap();
    (dir, cal, engine)
}

/// Block until the lane's supervisor has respawned its worker at least
/// `want` times (the restart counter is overlaid onto the per-device
/// metrics snapshot).
fn await_restarts(engine: &Engine, lane: usize, want: u64) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if engine.fleet_metrics().devices[lane].1.worker_restarts >= want {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "lane {lane} never reached {want} restart(s)"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// The acceptance scenario: a seeded fault plan that kills *every* lane
/// at least once while a seeded poisson schedule keeps arriving. Zero
/// lost tickets — every submission reaches a terminal outcome (the
/// open-loop harness waits them all), the accounting adds up, queue
/// depths return to zero, both lanes restarted, and the engine shuts
/// down without a panic.
#[test]
fn seeded_chaos_kills_every_lane_and_loses_no_tickets() {
    let mut plan = FaultPlan::seeded(0xC0FFEE, 2, 4);
    // guarantee the "each lane dies at least once" coverage on top of
    // the seeded mix (still deterministic — the plan is plain data)
    plan.faults.push(Fault::Kill { lane: 0, turn: 2 });
    plan.faults.push(Fault::Kill { lane: 1, turn: 1 });
    let (dir, cal, engine) = chaos_fleet(
        "chaoscore",
        plan,
        EngineConfig {
            batch_window: Duration::from_millis(5),
            retry_budget: 3,
            ..EngineConfig::default()
        },
    );
    let client = engine.client();
    // seed lane 1 with a pinned request so the weak device takes its
    // first (fatal) turn even if the router would starve it; pinned
    // requests never migrate, so this one sheds typed
    let gt430 = client.devices()[1].name().to_string();
    let pinned = client
        .submit(SubmitRequest::new("waxpby", 32, 65536).pin(&gt430))
        .unwrap();
    let err = pinned.wait().err().expect("pinned to a killed lane");
    match err.downcast_ref::<ServeError>() {
        Some(ServeError::WorkerLost { device, attempts }) => {
            assert_eq!(device, &gt430);
            assert_eq!(*attempts, 0);
        }
        other => panic!("expected WorkerLost, got {other:?} ({err:#})"),
    }
    let spec = traffic::TrafficSpec {
        scenario: traffic::Scenario::Poisson,
        seed: 42,
        rate: 400.0,
        horizon: Duration::from_millis(500),
        keys: vec![("waxpby".into(), 32, 65536), ("vadd".into(), 32, 65536)],
    };
    let report = traffic::run_open_loop(&client, &spec, &traffic::OpenLoopOptions::default());
    assert!(report.submitted > 0);
    // zero lost tickets: every submission is accounted for by exactly
    // one terminal outcome
    assert_eq!(
        report.completed + report.failed + report.sheds() + report.other_errors,
        report.submitted,
        "{report:?}"
    );
    assert_eq!(client.queue_depths(), vec![0, 0], "depths must drain to zero");
    let fleet = engine.shutdown_fleet();
    assert!(fleet.lost.is_empty(), "recoverable kills lose no lane: {:?}", fleet.lost);
    let agg = fleet.aggregate();
    assert!(
        agg.worker_restarts >= 2,
        "both lanes must die and respawn at least once: {} restart(s)",
        agg.worker_restarts
    );
    assert!(agg.breaker_transitions >= 4, "open + re-admit per kill");
    let _ = fs::remove_dir_all(&dir);
    let _ = fs::remove_dir_all(&cal);
}

/// A restarted lane must serve registered pipelines bit-identically to
/// a lane that never died: the supervisor replays the persisted catalog
/// onto the rebuilt coordinator and verifies each fingerprint.
#[test]
fn restarted_lane_serves_pipelines_bit_identically() {
    let plan = FaultPlan {
        faults: vec![Fault::Kill { lane: 1, turn: 1 }],
    };
    let (dir, cal, engine) = chaos_fleet("chaosident", plan, EngineConfig::default());
    let client = engine.client();
    let fp = client
        .register_pipeline("amx", fusebla::pipelines::examples::ADD_MUL_EXP)
        .unwrap();
    assert_ne!(fp, 0);
    assert!(
        dir.join("pipelines.catalog.txt").exists(),
        "registration must persist beside the artifacts"
    );
    let names: Vec<String> = client.devices().iter().map(|d| d.name().to_string()).collect();
    // first turn on lane 1 is scripted fatal; the pinned trigger sheds
    let trigger = client
        .submit(SubmitRequest::new("amx", 32, 256).synth(7).pin(&names[1]))
        .unwrap();
    assert!(matches!(
        trigger.wait().err().expect("killed lane").downcast_ref::<ServeError>(),
        Some(ServeError::WorkerLost { .. })
    ));
    await_restarts(&engine, 1, 1);
    // same key, same synthetic seed, on the respawned lane and on a
    // never-killed lane: the interpreter-backed pipeline runs on both
    let on_restarted = client
        .submit(SubmitRequest::new("amx", 32, 256).synth(7).pin(&names[1]))
        .unwrap()
        .wait()
        .expect("respawned lane serves the replayed pipeline");
    let on_survivor = client
        .submit(SubmitRequest::new("amx", 32, 256).synth(7).pin(&names[0]))
        .unwrap()
        .wait()
        .expect("surviving lane serves the pipeline");
    let (a, b) = (&on_restarted.env["z"], &on_survivor.env["z"]);
    assert_eq!(a.dims, b.dims);
    assert_eq!(a.data.len(), b.data.len());
    for (x, y) in a.data.iter().zip(&b.data) {
        assert_eq!(x.to_bits(), y.to_bits(), "restart must not change results");
    }
    let fleet = engine.shutdown_fleet();
    assert!(fleet.lost.is_empty());
    assert_eq!(fleet.devices[1].1.worker_restarts, 1);
    let _ = fs::remove_dir_all(&dir);
    let _ = fs::remove_dir_all(&cal);
}

/// Satellite regression: `shutdown_fleet` used to panic when a worker
/// thread had died. A scripted hard kill leaves lane 1 dead for real;
/// shutdown must return partial metrics with the lane reported in
/// `lost`, and the surviving lane's counters intact.
#[test]
fn hard_kill_reports_partial_fleet_metrics_at_shutdown() {
    let plan = FaultPlan {
        faults: vec![Fault::HardKill { lane: 1, turn: 1 }],
    };
    let (dir, cal, engine) = chaos_fleet("chaoshard", plan, EngineConfig::default());
    let client = engine.client();
    let names: Vec<String> = client.devices().iter().map(|d| d.name().to_string()).collect();
    // the surviving lane works before and after the neighbour dies
    let t0 = client
        .submit(SubmitRequest::new("waxpby", 32, 65536).pin(&names[0]))
        .unwrap();
    assert!(t0.wait().is_err(), "stub backend fails execution, typed-free");
    let trigger = client
        .submit(SubmitRequest::new("waxpby", 32, 65536).pin(&names[1]))
        .unwrap();
    let err = trigger.wait().err().expect("hard-killed lane");
    assert!(matches!(
        err.downcast_ref::<ServeError>(),
        Some(ServeError::WorkerLost { .. })
    ));
    // wait until the lane's receiver is gone — submits to it fail at
    // send — so shutdown deterministically joins a dead thread
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match client.submit(SubmitRequest::new("waxpby", 32, 65536).pin(&names[1])) {
            Err(_) => break,
            Ok(t) => {
                let _ = t.wait();
            }
        }
        assert!(Instant::now() < deadline, "lane 1 never died for real");
        std::thread::sleep(Duration::from_millis(5));
    }
    let fleet = engine.shutdown_fleet();
    assert_eq!(fleet.devices.len(), 2, "partial metrics keep the full roster");
    assert_eq!(fleet.lost.len(), 1, "exactly one lane died: {:?}", fleet.lost);
    assert_eq!(fleet.lost[0].name(), names[1]);
    assert_eq!(fleet.devices[0].1.requests, 1, "survivor's counters intact");
    let _ = fs::remove_dir_all(&dir);
    let _ = fs::remove_dir_all(&cal);
}

/// Chaos property: under randomized (but seeded) fault plans every
/// submitted ticket terminates and the queue depths return to zero —
/// across several seeds, on one shared registry (calibration is paid
/// once and reloaded).
#[test]
fn randomized_fault_plans_terminate_every_ticket() {
    let dir = fusebla::bench_support::stub_catalog("chaosprop", &["waxpby", "vadd"]);
    let cal = scratch_dir("chaosprop_cal");
    let registry = Arc::new(
        DeviceRegistry::new(vec![DeviceModel::gtx480(), DeviceModel::gt430()], &cal).unwrap(),
    );
    for seed in [1u64, 2, 5] {
        let plan = FaultPlan::seeded(seed, 2, 5);
        assert_eq!(plan.faults, FaultPlan::seeded(seed, 2, 5).faults, "plans replay");
        let engine = Engine::start_fleet(
            registry.clone(),
            &dir,
            EngineConfig {
                batch_window: Duration::from_millis(2),
                fault_plan: plan,
                ..EngineConfig::default()
            },
        )
        .unwrap();
        let client = engine.client();
        let tickets: Vec<_> = (0..16u64)
            .map(|i| {
                let seq = if i % 2 == 0 { "waxpby" } else { "vadd" };
                client.submit(SubmitRequest::new(seq, 32, 65536).synth(i)).unwrap()
            })
            .collect();
        // termination is the property: every wait returns (the typed
        // shed, the stub execution error, or a disconnect — never a hang)
        for t in tickets {
            let _ = t.wait();
        }
        assert_eq!(client.queue_depths(), vec![0, 0], "seed {seed}");
        let fleet = engine.shutdown_fleet();
        assert!(fleet.lost.is_empty(), "seeded plans are recoverable: seed {seed}");
    }
    let _ = fs::remove_dir_all(&dir);
    let _ = fs::remove_dir_all(&cal);
}

/// A scripted wedge (stall without a panic) must trip the watchdog:
/// the stale heartbeat under queued work opens the lane's breaker, and
/// the detector closes it again once the lane's beat advances — no
/// respawn, because the worker never died.
#[test]
fn wedge_detector_opens_and_closes_the_breaker() {
    let plan = FaultPlan {
        faults: vec![Fault::Wedge {
            lane: 0,
            turn: 1,
            hold: Duration::from_millis(400),
        }],
    };
    let (dir, cal, engine) = chaos_fleet(
        "chaoswedge",
        plan,
        EngineConfig {
            wedge_timeout: Some(Duration::from_millis(50)),
            ..EngineConfig::default()
        },
    );
    let client = engine.client();
    let name0 = client.devices()[0].name().to_string();
    let t = client
        .submit(SubmitRequest::new("waxpby", 32, 65536).pin(&name0))
        .unwrap();
    // the wedged turn finishes late but finishes: the stub execution
    // error arrives after the 400 ms stall, never a hang
    assert!(t.wait().is_err());
    // open (stale beat under load) + close (beat advanced) = 2
    // transitions; poll because the close happens on the detector's
    // clock, not the reply's
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let m = &engine.fleet_metrics().devices[0].1;
        if m.breaker_transitions >= 2 {
            assert_eq!(m.worker_restarts, 0, "a wedge is not a death");
            break;
        }
        assert!(Instant::now() < deadline, "detector never cycled the breaker");
        std::thread::sleep(Duration::from_millis(10));
    }
    let fleet = engine.shutdown_fleet();
    assert!(fleet.lost.is_empty());
    let _ = fs::remove_dir_all(&dir);
    let _ = fs::remove_dir_all(&cal);
}

/// Satellite: the dynamic pipeline catalog survives engine restarts —
/// a registration made by one engine is served by the next engine over
/// the same artifacts directory, and an unregistration sticks too.
#[test]
fn pipeline_catalog_persists_across_engine_restarts() {
    let dir = fusebla::bench_support::stub_catalog("catpersist", &["waxpby"]);
    let cal = scratch_dir("catpersist_cal");
    let fresh_engine = || {
        let registry = Arc::new(
            DeviceRegistry::new(vec![DeviceModel::gtx480(), DeviceModel::gt430()], &cal).unwrap(),
        );
        Engine::start_fleet(registry, &dir, EngineConfig::default()).unwrap()
    };
    let a = fresh_engine();
    let fp = a
        .client()
        .register_pipeline("amx", fusebla::pipelines::examples::ADD_MUL_EXP)
        .unwrap();
    assert!(dir.join("pipelines.catalog.txt").exists());
    let _ = a.shutdown_fleet();
    // a brand-new engine re-registers the persisted entry at start and
    // serves it without any client-side registration
    let b = fresh_engine();
    let res = b
        .client()
        .submit(SubmitRequest::new("amx", 32, 256).synth(3))
        .unwrap()
        .wait()
        .expect("persisted pipeline serves after restart");
    assert!(res.env.contains_key("z"));
    // re-registering identical source is an idempotent dedup with the
    // same fingerprint — proof the replay restored the same program
    assert_eq!(
        b.client()
            .register_pipeline("amx", fusebla::pipelines::examples::ADD_MUL_EXP)
            .unwrap(),
        fp
    );
    assert!(b.client().unregister_pipeline("amx"));
    let _ = b.shutdown_fleet();
    // the unregistration persisted: the next engine knows nothing of it
    // (pinned, so the unknown name reaches a worker instead of the router)
    let c = fresh_engine();
    let pin = c.client().devices()[0].name().to_string();
    let err = c
        .client()
        .submit(SubmitRequest::new("amx", 32, 256).synth(3).pin(&pin))
        .unwrap()
        .wait()
        .err()
        .expect("unregistered pipeline is gone after restart");
    assert!(format!("{err:#}").contains("unknown sequence"), "{err:#}");
    let _ = c.shutdown_fleet();
    let _ = fs::remove_dir_all(&dir);
    let _ = fs::remove_dir_all(&cal);
}

#[test]
fn duplicate_artifact_keys_rejected() {
    let dir = scratch_dir("dup");
    let stanza = "artifact a.fused.m32n32.s0\n file f.hlo.txt\n seq a\n variant fused\n stage 0\nend\n";
    fs::write(dir.join("manifest.txt"), format!("{stanza}{stanza}")).unwrap();
    let err = Runtime::load(&dir).err().expect("must fail").to_string();
    assert!(err.contains("duplicate"), "{err}");
}
