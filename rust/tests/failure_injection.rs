//! Failure-injection tests: the runtime must fail *cleanly and
//! specifically* when artifacts are missing, corrupt, or mismatched —
//! a deployment requirement the paper's compiler (which controls its own
//! binaries) never faced, but ours (AOT catalog + separate runtime) does.

use fusebla::fusion::ImplAxes;
use fusebla::ir::elem::ProblemSize;
use fusebla::planner::{plan_space, PlannerConfig};
use fusebla::runtime::{Runtime, Tensor};
use fusebla::sequences;
use fusebla::sim::DeviceModel;
use fusebla::{DeviceRegistry, Engine, EngineConfig};
use std::collections::BTreeMap;
use std::fs;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn scratch_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fusebla_fi_{name}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn real_artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

#[test]
fn missing_manifest_mentions_make_artifacts() {
    let dir = scratch_dir("nomanifest");
    let err = Runtime::load(&dir).err().expect("must fail").to_string();
    assert!(err.contains("make artifacts"), "{err}");
}

#[test]
fn manifest_referencing_missing_file_fails_at_compile_with_key() {
    let dir = scratch_dir("missingfile");
    fs::write(
        dir.join("manifest.txt"),
        "artifact ghost.fused.m32n32.s0\n file ghost.hlo.txt\n seq ghost\n variant fused\n stage 0\n in x:f32[32]\n out y:f32[32]\n m 32\n n 32\nend\n",
    )
    .unwrap();
    let rt = Runtime::load(&dir).expect("manifest parses");
    let err = rt
        .executable("ghost.fused.m32n32.s0")
        .err()
        .expect("must fail")
        .to_string();
    assert!(
        err.contains("ghost.hlo.txt") || err.contains("parsing"),
        "{err}"
    );
}

#[test]
fn corrupt_hlo_text_fails_with_context() {
    let dir = scratch_dir("corrupt");
    fs::write(dir.join("bad.hlo.txt"), "this is not HLO at all {{{").unwrap();
    fs::write(
        dir.join("manifest.txt"),
        "artifact bad.fused.m32n32.s0\n file bad.hlo.txt\n seq bad\n variant fused\n stage 0\n in x:f32[32]\n out y:f32[32]\n m 32\n n 32\nend\n",
    )
    .unwrap();
    let rt = Runtime::load(&dir).expect("manifest parses");
    let err = rt
        .executable("bad.fused.m32n32.s0")
        .err()
        .expect("must fail")
        .to_string();
    assert!(err.contains("bad.hlo.txt") || err.contains("parsing"), "{err}");
}

#[test]
fn wrong_input_dims_rejected_before_execution() {
    let Some(dir) = real_artifacts() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let entry = rt.manifest.get("sscal.fused.m32n65536.s0").unwrap().clone();
    // bind every declared input at its right shape, then corrupt x
    let mut env = BTreeMap::new();
    for spec in &entry.inputs {
        let len = spec.dims.iter().product::<usize>().max(1);
        env.insert(spec.name.clone(), Tensor::new(spec.dims.clone(), vec![1.0; len]));
    }
    env.insert("x".to_string(), Tensor::vector(vec![1.0; 64])); // wrong size
    let err = rt
        .run_seq("sscal", "fused", 32, 65536, &env)
        .err()
        .expect("must fail")
        .to_string();
    assert!(err.contains("dims"), "{err}");
}

#[test]
fn unknown_key_lists_available_sizes() {
    let Some(dir) = real_artifacts() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let err = rt
        .run_seq("bicgk", "fused", 12345, 12345, &BTreeMap::new())
        .err()
        .expect("must fail")
        .to_string();
    assert!(err.contains("available"), "{err}");
    assert!(err.contains("256"), "should list catalog sizes: {err}");
}

#[test]
fn truncated_manifest_rejected() {
    let dir = scratch_dir("truncated");
    fs::write(
        dir.join("manifest.txt"),
        "artifact t.fused.m32n32.s0\n file t.hlo.txt\n",
    )
    .unwrap();
    let err = Runtime::load(&dir).err().expect("must fail").to_string();
    assert!(err.contains("truncated"), "{err}");
}

/// Shard failure injection: a worker that is gone (engine shut down
/// under a live client) or wedged past the shard deadline (deadline
/// zero — every gather times out mid-`PlanShard`) makes the submitter
/// plan the affected chunks locally. The final plan is identical in
/// every case — same label, bit-identical predicted seconds, same
/// stats — and the search neither hangs nor merges a partial range.
#[test]
fn shard_chunks_fall_back_locally_on_wedged_or_dead_workers() {
    // stub catalog: the manifest parses, so the engine starts; only
    // execution would need real artifacts, and nothing executes here
    let dir = fusebla::bench_support::stub_catalog("shardfb", &["waxpby"]);
    let cal = scratch_dir("shardfb_cal");
    let registry = Arc::new(
        DeviceRegistry::new(vec![DeviceModel::gtx480(), DeviceModel::gt430()], &cal).unwrap(),
    );

    // the unsharded local reference, on device 0's own calibration
    let lib = registry.library().clone();
    let seq = sequences::by_name("gemver").unwrap();
    let (prog, _graph, space) = seq.space(&lib, &ImplAxes::minimal());
    let p = ProblemSize::new(4096, 4096).padded();
    let reference = plan_space(
        &prog,
        &space,
        &registry.context(0).db,
        p,
        &PlannerConfig::default(),
    );
    let device0 = registry.id(0).name().to_string();
    let same = |planned: &fusebla::planner::Planned, label: &str| {
        assert_eq!(planned.best.variant, reference.best.variant, "{label}");
        assert_eq!(
            planned.predicted.to_bits(),
            reference.predicted.to_bits(),
            "{label}"
        );
        assert_eq!(
            planned.stats.combos_evaluated, reference.stats.combos_evaluated,
            "{label}"
        );
        assert_eq!(planned.stats.kernel_evals, reference.stats.kernel_evals, "{label}");
    };

    // 1. healthy fleet: chunks served by the workers
    let engine = Engine::start_fleet(registry.clone(), &dir, EngineConfig::default()).unwrap();
    let client = engine.client();
    let healthy = client
        .search_sharded("gemver", 4096, 4096, 4, Some(device0.as_str()))
        .unwrap();
    same(&healthy, "healthy fleet");
    let live = engine.metrics();
    assert_eq!(live.shard_requests, 4, "every chunk reached a worker");
    assert_eq!(live.shard_served, 4);

    // 2. workers gone: shut the engine down but keep the client — every
    // PlanShard send fails, every chunk plans locally, nothing hangs
    let _ = engine.shutdown();
    let dead = client
        .search_sharded("gemver", 4096, 4096, 4, Some(device0.as_str()))
        .unwrap();
    same(&dead, "dead workers");

    // 3. wedged past the deadline: a zero shard deadline times every
    // gather out mid-PlanShard; the submitter falls back chunk by chunk
    // and still merges the full range
    let wedged_engine = Engine::start_fleet(
        registry.clone(),
        &dir,
        EngineConfig {
            shard_deadline: Duration::ZERO,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let wedged = wedged_engine
        .client()
        .search_sharded("gemver", 4096, 4096, 3, Some(device0.as_str()))
        .unwrap();
    same(&wedged, "wedged workers");
    let _ = wedged_engine.shutdown();

    let _ = fs::remove_dir_all(&dir);
    let _ = fs::remove_dir_all(&cal);
}

/// Router depth-leak regression: the per-device queue depths must
/// return to zero after a burst in which *every* terminal outcome is an
/// error — stub execution failures, admission-control sheds and
/// deadline sheds all mixed. The depth slot is released by the reply's
/// RAII guard on any terminal path; before that guard, error paths that
/// dropped the request without replying leaked the slot and the router
/// permanently saw phantom backlog.
#[test]
fn queue_depths_return_to_zero_after_all_error_burst() {
    let dir = fusebla::bench_support::stub_catalog("depthleak", &["waxpby"]);
    let cal = scratch_dir("depthleak_cal");
    let registry = Arc::new(
        DeviceRegistry::new(vec![DeviceModel::gtx480(), DeviceModel::gt430()], &cal).unwrap(),
    );
    let engine = Engine::start_fleet(
        registry,
        &dir,
        EngineConfig {
            batch_window: Duration::from_millis(100),
            queue_cap: 3,
            deadline_slack: Duration::ZERO,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let client = engine.client();
    let mut tickets = Vec::new();
    let mut queue_sheds = 0usize;
    for i in 0..12u64 {
        // a 30 ms deadline under a 100 ms window: admitted requests
        // either get shed at the turn boundary (DeadlineExpired) or
        // execute and fail at the stub backend — every outcome errors
        let req = fusebla::SubmitRequest::new("waxpby", 32, 65536)
            .synth(i)
            .deadline(Duration::from_millis(30));
        match client.submit(req) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                assert!(
                    matches!(
                        e.downcast_ref::<fusebla::ServeError>(),
                        Some(fusebla::ServeError::QueueFull { .. })
                    ),
                    "submit-path errors in this burst are sheds: {e:#}"
                );
                queue_sheds += 1;
            }
        }
    }
    assert!(!tickets.is_empty(), "some requests must be admitted");
    for t in tickets {
        assert!(t.wait().is_err(), "every outcome of this burst is an error");
    }
    // every slot released: replies release before sending, so after all
    // waits return the depths are deterministically back to zero
    let depths = client.queue_depths();
    assert_eq!(depths, vec![0, 0], "{queue_sheds} queue shed(s), depths {depths:?}");
    let _ = engine.shutdown_fleet();
    let _ = fs::remove_dir_all(&dir);
    let _ = fs::remove_dir_all(&cal);
}

#[test]
fn duplicate_artifact_keys_rejected() {
    let dir = scratch_dir("dup");
    let stanza = "artifact a.fused.m32n32.s0\n file f.hlo.txt\n seq a\n variant fused\n stage 0\nend\n";
    fs::write(dir.join("manifest.txt"), format!("{stanza}{stanza}")).unwrap();
    let err = Runtime::load(&dir).err().expect("must fail").to_string();
    assert!(err.contains("duplicate"), "{err}");
}
