//! PJRT runtime: load AOT HLO artifacts and execute them from Rust.
//!
//! One artifact = one kernel = one PJRT executable. Executing a sequence
//! runs its stages back-to-back with host-visible buffers between — the
//! executable boundary models the CUDA kernel boundary (a forced global
//! memory round trip), so a fused variant with fewer stages is exactly a
//! fused kernel with fewer passes over memory.
//!
//! Python is never on this path: artifacts are HLO text produced once by
//! `make artifacts`; this module compiles them on first use and caches
//! the executables.

pub mod refcheck;

use crate::util::manifest::{ArtifactEntry, Manifest};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

/// A host tensor (f32, row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            dims.iter().product::<usize>().max(1),
            data.len(),
            "dims/data mismatch"
        );
        Tensor { dims, data }
    }

    pub fn vector(data: Vec<f32>) -> Tensor {
        Tensor {
            dims: vec![data.len()],
            data,
        }
    }

    pub fn matrix(m: usize, n: usize, data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), m * n);
        Tensor {
            dims: vec![m, n],
            data,
        }
    }
}

/// Timing of one executed stage.
#[derive(Clone, Debug)]
pub struct StageStats {
    pub key: String,
    pub seconds: f64,
}

/// Result of running a sequence variant.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// All produced tensors by name (sequence outputs included). The
    /// free inputs stay in the map too, so the result is self-contained
    /// enough to re-verify against the reference oracle.
    pub env: BTreeMap<String, Tensor>,
    pub stages: Vec<StageStats>,
    pub seconds: f64,
    /// Which artifact variant actually executed ("fused"/"cublas") —
    /// lets clients observe the coordinator's plan decision.
    pub variant: String,
}

/// The PJRT-backed executor.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: std::sync::Mutex<BTreeMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load the artifact manifest and create the PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest_path = artifacts_dir.join("manifest.txt");
        let manifest = Manifest::load(&manifest_path)
            .map_err(|e| anyhow!("{e} — run `make artifacts` first"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: std::sync::Mutex::new(BTreeMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for an artifact key.
    pub fn executable(&self, key: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(key) {
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .get(key)
            .ok_or_else(|| anyhow!("no artifact '{key}' in manifest (rebuild artifacts?)"))?;
        let path = self.manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {key}"))?,
        );
        self.cache
            .lock()
            .unwrap()
            .insert(key.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile all stages of a (seq, variant, size) so timing runs
    /// measure execution only.
    pub fn warmup(&self, seq: &str, variant: &str, m: usize, n: usize) -> Result<usize> {
        let stages = self.stages_of(seq, variant, m, n);
        if stages.is_empty() {
            bail!("no artifacts for {seq}.{variant} m{m} n{n}");
        }
        let keys: Vec<String> = stages.iter().map(|e| e.key.clone()).collect();
        for key in &keys {
            self.executable(key)?;
        }
        Ok(keys.len())
    }

    fn stages_of(&self, seq: &str, variant: &str, m: usize, n: usize) -> Vec<ArtifactEntry> {
        let mut v: Vec<ArtifactEntry> = self
            .manifest
            .entries
            .values()
            .filter(|e| {
                e.seq == seq
                    && e.variant == variant
                    && e.attrs.get("m").map(|s| s.as_str()) == Some(m.to_string().as_str())
                    && e.attrs.get("n").map(|s| s.as_str()) == Some(n.to_string().as_str())
            })
            .cloned()
            .collect();
        v.sort_by_key(|e| e.stage);
        v
    }

    /// Available (m, n) size points of a sequence variant in the catalog.
    pub fn sizes_of(&self, seq: &str, variant: &str) -> Vec<(usize, usize)> {
        let mut sizes: Vec<(usize, usize)> = self
            .manifest
            .entries
            .values()
            .filter(|e| e.seq == seq && e.variant == variant && e.stage == 0)
            .filter_map(|e| {
                Some((
                    e.attrs.get("m")?.parse().ok()?,
                    e.attrs.get("n")?.parse().ok()?,
                ))
            })
            .collect();
        sizes.sort_unstable();
        sizes.dedup();
        sizes
    }

    /// Execute one stage: bind named inputs from `env`, run, put named
    /// outputs back into `env`.
    pub fn run_stage(&self, entry: &ArtifactEntry, env: &mut BTreeMap<String, Tensor>) -> Result<f64> {
        let exe = self.executable(&entry.key)?;
        self.run_stage_exec(&exe, entry, env)
    }

    /// Stage execution against an already-resolved executable (the batch
    /// path pins executables once per stage instead of once per request).
    fn run_stage_exec(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        entry: &ArtifactEntry,
        env: &mut BTreeMap<String, Tensor>,
    ) -> Result<f64> {
        let mut literals = Vec::with_capacity(entry.inputs.len());
        for spec in &entry.inputs {
            let t = env
                .get(&spec.name)
                .ok_or_else(|| anyhow!("stage {} needs '{}' (not in env)", entry.key, spec.name))?;
            if t.dims != spec.dims {
                bail!(
                    "stage {}: '{}' has dims {:?}, artifact expects {:?}",
                    entry.key,
                    spec.name,
                    t.dims,
                    spec.dims
                );
            }
            let lit = xla::Literal::vec1(&t.data);
            let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
            literals.push(lit.reshape(&dims)?);
        }
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let seconds = t0.elapsed().as_secs_f64();
        // aot.py lowers with return_tuple=True → always a tuple.
        let outs = result.to_tuple()?;
        if outs.len() != entry.outputs.len() {
            bail!(
                "stage {}: got {} outputs, manifest says {}",
                entry.key,
                outs.len(),
                entry.outputs.len()
            );
        }
        for (spec, lit) in entry.outputs.iter().zip(outs) {
            let data = lit.to_vec::<f32>()?;
            env.insert(spec.name.clone(), Tensor::new(spec.dims.clone(), data));
        }
        Ok(seconds)
    }

    /// Execute all stages of a sequence variant.
    pub fn run_seq(
        &self,
        seq: &str,
        variant: &str,
        m: usize,
        n: usize,
        inputs: &BTreeMap<String, Tensor>,
    ) -> Result<RunResult> {
        let stages = self.stages_of(seq, variant, m, n);
        if stages.is_empty() {
            bail!(
                "no artifacts for {seq}.{variant} at m{m} n{n}; available: {:?}",
                self.sizes_of(seq, variant)
            );
        }
        let mut env = inputs.clone();
        let mut stats = Vec::with_capacity(stages.len());
        let t0 = Instant::now();
        for entry in &stages {
            let secs = self.run_stage(entry, &mut env)?;
            stats.push(StageStats {
                key: entry.key.clone(),
                seconds: secs,
            });
        }
        Ok(RunResult {
            env,
            stages: stats,
            seconds: t0.elapsed().as_secs_f64(),
            variant: variant.to_string(),
        })
    }

    /// Execute all stages of a sequence variant for several independent
    /// input sets in one dispatch. The manifest scan and the
    /// executable-cache lookups happen once per *stage* instead of once
    /// per request — that is the launch-overhead amortization batching
    /// buys on this runtime. Input sets are consumed (each becomes its
    /// request's environment in place, no copy); results are
    /// bit-identical to calling [`Runtime::run_seq`] once per input
    /// set, and per-request failures (e.g. a missing input tensor) fail
    /// only that slot.
    pub fn run_seq_batch(
        &self,
        seq: &str,
        variant: &str,
        m: usize,
        n: usize,
        inputs: Vec<BTreeMap<String, Tensor>>,
    ) -> Vec<Result<RunResult>> {
        let stages = self.stages_of(seq, variant, m, n);
        if stages.is_empty() {
            let msg = format!(
                "no artifacts for {seq}.{variant} at m{m} n{n}; available: {:?}",
                self.sizes_of(seq, variant)
            );
            return inputs.iter().map(|_| Err(anyhow!("{msg}"))).collect();
        }
        let mut exes = Vec::with_capacity(stages.len());
        for entry in &stages {
            match self.executable(&entry.key) {
                Ok(e) => exes.push(e),
                Err(e) => {
                    // A missing/corrupt artifact fails the whole batch —
                    // every request would have hit the same artifact.
                    let msg = format!("{e:#}");
                    return inputs.iter().map(|_| Err(anyhow!("{msg}"))).collect();
                }
            }
        }
        inputs
            .into_iter()
            .map(|input| -> Result<RunResult> {
                let mut env = input;
                let mut stats = Vec::with_capacity(stages.len());
                let t0 = Instant::now();
                for (entry, exe) in stages.iter().zip(&exes) {
                    let secs = self.run_stage_exec(exe, entry, &mut env)?;
                    stats.push(StageStats {
                        key: entry.key.clone(),
                        seconds: secs,
                    });
                }
                Ok(RunResult {
                    env,
                    stages: stats,
                    seconds: t0.elapsed().as_secs_f64(),
                    variant: variant.to_string(),
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn runtime() -> Option<Runtime> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping runtime test: artifacts not built");
            return None;
        }
        Some(Runtime::load(&dir).expect("runtime"))
    }

    fn inputs_for(rt: &Runtime, seq: &str, variant: &str, m: usize, n: usize) -> BTreeMap<String, Tensor> {
        // free inputs = names consumed before production
        let stages = rt.stages_of(seq, variant, m, n);
        let mut produced: Vec<String> = vec![];
        let mut inputs = BTreeMap::new();
        let mut rng = Prng::new(42);
        for e in &stages {
            for spec in &e.inputs {
                if !produced.contains(&spec.name) && !inputs.contains_key(&spec.name) {
                    let len: usize = spec.dims.iter().product::<usize>().max(1);
                    inputs.insert(spec.name.clone(), Tensor::new(spec.dims.clone(), rng.f32_vec(len)));
                }
            }
            for spec in &e.outputs {
                produced.push(spec.name.clone());
            }
        }
        inputs
    }

    #[test]
    fn bicgk_fused_matches_cublas_variant() {
        let Some(rt) = runtime() else { return };
        let (m, n) = (256, 256);
        let inputs = inputs_for(&rt, "bicgk", "fused", m, n);
        let fused = rt.run_seq("bicgk", "fused", m, n, &inputs).unwrap();
        let cublas = rt.run_seq("bicgk", "cublas", m, n, &inputs).unwrap();
        let qf = &fused.env["q"];
        let qc = &cublas.env["q"];
        for (a, b) in qf.data.iter().zip(qc.data.iter()) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        assert_eq!(fused.stages.len(), 1, "fused BiCGK must be one kernel");
        assert_eq!(cublas.stages.len(), 2);
    }

    #[test]
    fn missing_artifact_reports_cleanly() {
        let Some(rt) = runtime() else { return };
        let err = rt
            .run_seq("bicgk", "fused", 31, 31, &BTreeMap::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("no artifacts"), "{err}");
    }

    #[test]
    fn missing_input_reports_name() {
        let Some(rt) = runtime() else { return };
        let err = rt
            .run_seq("bicgk", "fused", 256, 256, &BTreeMap::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("needs"), "{err}");
    }

    #[test]
    fn executables_are_cached() {
        let Some(rt) = runtime() else { return };
        let n = rt.warmup("vadd", "fused", 32, 65536).unwrap();
        assert_eq!(n, 1);
        let t0 = Instant::now();
        let _ = rt.executable("vadd.fused.m32n65536.s0").unwrap();
        assert!(t0.elapsed().as_secs_f64() < 0.01, "cache miss on second lookup");
    }
}
