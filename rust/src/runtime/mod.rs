//! PJRT runtime: load AOT HLO artifacts and execute them from Rust.
//!
//! One artifact = one kernel = one PJRT executable. Executing a sequence
//! runs its stages back-to-back with host-visible buffers between — the
//! executable boundary models the CUDA kernel boundary (a forced global
//! memory round trip), so a fused variant with fewer stages is exactly a
//! fused kernel with fewer passes over memory.
//!
//! Python is never on this path: artifacts are HLO text produced once by
//! `make artifacts`; this module compiles them on first use and caches
//! the executables.
//!
//! # The resolve-once hot path
//!
//! Serving short, memory-bound kernels makes host-side dispatch
//! overhead proportionally huge (the paper's premise, inverted), so
//! everything per-request is resolved exactly once per
//! `(seq, variant, m, n)` key:
//!
//! * the indexed manifest ([`Manifest::stages`]) replaces every linear
//!   catalog scan;
//! * a [`SlotPlan`] interns the sequence's tensor names into integer
//!   *slots*, so stage execution binds inputs/outputs through
//!   `Vec<Option<Tensor>>` indices instead of `BTreeMap<String, _>`
//!   lookups — the named `env` map is materialized exactly once, at the
//!   [`RunResult`] boundary;
//! * a [`ResolvedSeq`] pins the per-stage executables, and both the
//!   executable cache and the resolve cache are read-mostly
//!   (`RwLock` + per-key `Arc`, misses compiled outside the lock), so
//!   cache hits never contend on a writer lock. Today the PJRT client
//!   (and with it the whole `Runtime`) is `!Sync` and lives on the
//!   engine's single worker thread, so nothing actually races yet; the
//!   locking regime is what makes a multi-worker serve path safe to
//!   add once a `Send`/`Sync` XLA backend replaces the offline stub.
//!
//! [`Runtime::counters`] exposes resolve/compile hit-miss counts for
//! the engine's metrics and the cache tests.

pub mod refcheck;

use crate::pipelines::{InterpStage, Pipeline};
use crate::util::manifest::{ArtifactEntry, Manifest, TensorSpec};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

/// A host tensor (f32, row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            dims.iter().product::<usize>().max(1),
            data.len(),
            "dims/data mismatch"
        );
        Tensor { dims, data }
    }

    pub fn vector(data: Vec<f32>) -> Tensor {
        Tensor {
            dims: vec![data.len()],
            data,
        }
    }

    pub fn matrix(m: usize, n: usize, data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), m * n);
        Tensor {
            dims: vec![m, n],
            data,
        }
    }
}

/// Timing of one executed stage.
#[derive(Clone, Debug)]
pub struct StageStats {
    pub key: String,
    pub seconds: f64,
}

/// Result of running a sequence variant.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// All produced tensors by name (sequence outputs included). The
    /// free inputs stay in the map too, so the result is self-contained
    /// enough to re-verify against the reference oracle.
    pub env: BTreeMap<String, Tensor>,
    pub stages: Vec<StageStats>,
    pub seconds: f64,
    /// Which artifact variant actually executed ("fused"/"cublas") —
    /// lets clients observe the coordinator's plan decision.
    pub variant: String,
}

/// One stage of a [`SlotPlan`]: the manifest entry plus its parameter
/// names pre-resolved to slot indices (parallel to `entry.inputs` /
/// `entry.outputs`).
pub struct StageSlots {
    pub entry: ArtifactEntry,
    input_slots: Vec<usize>,
    output_slots: Vec<usize>,
}

impl StageSlots {
    /// Slot of each stage input, parallel to `entry.inputs`.
    pub fn input_slots(&self) -> &[usize] {
        &self.input_slots
    }

    /// Slot of each stage output, parallel to `entry.outputs`.
    pub fn output_slots(&self) -> &[usize] {
        &self.output_slots
    }
}

/// The backend-free half of a resolved sequence: tensor names interned
/// into dense slot indices (computed once), plus the per-stage slot
/// bindings. Execution reads and writes a `Vec<Option<Tensor>>` by
/// index; names only appear at the request boundary ([`SlotPlan::bind`]
/// / [`SlotPlan::materialize`]).
pub struct SlotPlan {
    seq: String,
    variant: String,
    m: usize,
    n: usize,
    /// Slot index → tensor name (the interning table).
    slot_names: Vec<String>,
    /// Tensor name → slot, used only when binding a named input map.
    slot_of: BTreeMap<String, usize>,
    stages: Vec<StageSlots>,
}

impl SlotPlan {
    /// Intern every tensor name of the ordered stage list. Slots are
    /// assigned in first-appearance order (stage by stage, inputs
    /// before outputs), so plan construction is deterministic.
    pub fn build(
        seq: &str,
        variant: &str,
        m: usize,
        n: usize,
        entries: Vec<ArtifactEntry>,
    ) -> SlotPlan {
        fn intern(
            specs: &[TensorSpec],
            slot_names: &mut Vec<String>,
            slot_of: &mut BTreeMap<String, usize>,
        ) -> Vec<usize> {
            specs
                .iter()
                .map(|s| match slot_of.get(&s.name) {
                    Some(&i) => i,
                    None => {
                        let i = slot_names.len();
                        slot_names.push(s.name.clone());
                        slot_of.insert(s.name.clone(), i);
                        i
                    }
                })
                .collect()
        }
        let mut slot_names: Vec<String> = Vec::new();
        let mut slot_of: BTreeMap<String, usize> = BTreeMap::new();
        let mut stages = Vec::with_capacity(entries.len());
        for entry in entries {
            let input_slots = intern(&entry.inputs, &mut slot_names, &mut slot_of);
            let output_slots = intern(&entry.outputs, &mut slot_names, &mut slot_of);
            stages.push(StageSlots {
                entry,
                input_slots,
                output_slots,
            });
        }
        SlotPlan {
            seq: seq.to_string(),
            variant: variant.to_string(),
            m,
            n,
            slot_names,
            slot_of,
            stages,
        }
    }

    pub fn seq(&self) -> &str {
        &self.seq
    }

    pub fn variant(&self) -> &str {
        &self.variant
    }

    pub fn size(&self) -> (usize, usize) {
        (self.m, self.n)
    }

    pub fn stages(&self) -> &[StageSlots] {
        &self.stages
    }

    pub fn stage_count(&self) -> usize {
        self.stages.len()
    }

    pub fn slot_count(&self) -> usize {
        self.slot_names.len()
    }

    /// Bind a named input map into a slot environment, cloning the
    /// tensors. Names with no slot (inputs no stage touches) are kept
    /// aside and passed through to the result env untouched, exactly as
    /// the map-based path carried them.
    pub fn bind(&self, inputs: &BTreeMap<String, Tensor>) -> SlotEnv {
        let mut env = self.empty_env();
        for (name, t) in inputs {
            match self.slot_of.get(name) {
                Some(&i) => env.slots[i] = Some(t.clone()),
                None => env.extra.push((name.clone(), t.clone())),
            }
        }
        env
    }

    /// [`SlotPlan::bind`] without the clone: the input map is consumed
    /// and its tensors move into the environment.
    pub fn bind_owned(&self, inputs: BTreeMap<String, Tensor>) -> SlotEnv {
        let mut env = self.empty_env();
        for (name, t) in inputs {
            match self.slot_of.get(&name) {
                Some(&i) => env.slots[i] = Some(t),
                None => env.extra.push((name, t)),
            }
        }
        env
    }

    fn empty_env(&self) -> SlotEnv {
        SlotEnv {
            slots: vec![None; self.slot_names.len()],
            extra: Vec::new(),
        }
    }

    /// Materialize the named env map — called exactly once per request,
    /// at the [`RunResult`] boundary. Inputs, intermediates and outputs
    /// all appear, matching the map-based execution path bit-for-bit.
    pub fn materialize(&self, env: SlotEnv) -> BTreeMap<String, Tensor> {
        let mut out = BTreeMap::new();
        for (name, slot) in self.slot_names.iter().zip(env.slots) {
            if let Some(t) = slot {
                out.insert(name.clone(), t);
            }
        }
        for (name, t) in env.extra {
            out.insert(name, t);
        }
        out
    }
}

/// A request's tensor environment, indexed by plan slot instead of
/// name. Lives from [`SlotPlan::bind`] to [`SlotPlan::materialize`].
pub struct SlotEnv {
    slots: Vec<Option<Tensor>>,
    /// Input tensors whose names no stage reads or writes; carried
    /// through to the materialized env.
    extra: Vec<(String, Tensor)>,
}

impl SlotEnv {
    pub fn get(&self, slot: usize) -> Option<&Tensor> {
        self.slots[slot].as_ref()
    }

    pub fn set(&mut self, slot: usize, t: Tensor) {
        self.slots[slot] = Some(t);
    }
}

/// The executable form of one resolved stage: an AOT artifact compiled
/// through PJRT (built-in catalog entries), or a pure-Rust interpreter
/// stage (dynamically registered pipelines — the offline stub cannot
/// execute HLO, and the interpreter keeps the same kernel boundaries).
pub enum StageExe {
    Pjrt(Arc<xla::PjRtLoadedExecutable>),
    Interp(Arc<InterpStage>),
}

/// A fully resolved execution plan: the slot plan plus the pinned
/// per-stage executables. Once a request holds one of these (behind an
/// `Arc` from the resolve cache), executing it touches no lock, no
/// catalog scan and no string-keyed map.
pub struct ResolvedSeq {
    plan: SlotPlan,
    /// Pinned executables, parallel to `plan.stages()`.
    exes: Vec<StageExe>,
}

impl ResolvedSeq {
    pub fn plan(&self) -> &SlotPlan {
        &self.plan
    }

    pub fn stage_count(&self) -> usize {
        self.plan.stage_count()
    }
}

/// Point-in-time snapshot of the runtime's hot-path counters (all
/// maintained with relaxed atomics — cheap enough for the hot path).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RuntimeCounters {
    /// Executables compiled fresh (executable-cache misses that reached
    /// the compiler successfully).
    pub executable_compiles: u64,
    /// Executable-cache hits (read-lock only, no compilation).
    pub executable_cache_hits: u64,
    /// Resolve-cache hits: requests that reused a pinned
    /// [`ResolvedSeq`].
    pub resolve_hits: u64,
    /// Resolve-cache misses: plans built (or attempted — failed
    /// resolves are not cached and count a miss each time).
    pub resolve_misses: u64,
}

#[derive(Default)]
struct RuntimeStats {
    executable_compiles: AtomicU64,
    executable_cache_hits: AtomicU64,
    resolve_hits: AtomicU64,
    resolve_misses: AtomicU64,
}

/// The PJRT-backed executor.
pub struct Runtime {
    client: xla::PjRtClient,
    /// Shared with the engine's other per-device runtimes: a fleet of N
    /// workers parses the catalog once ([`Runtime::load_manifest`]).
    pub manifest: Arc<Manifest>,
    /// Artifact key → compiled executable. Read-mostly: hits take the
    /// read lock only; misses compile *outside* the lock and insert
    /// after (a concurrent duplicate compile keeps the first insert).
    exe_cache: RwLock<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// (seq, variant, m, n) → resolved plan, same read-mostly regime.
    plan_cache: RwLock<HashMap<(String, String, usize, usize), Arc<ResolvedSeq>>>,
    /// The dynamic half of the catalog: pipelines registered at runtime
    /// ([`Runtime::register_pipeline`]). Resolve consults it when the
    /// parsed manifest has no entries for a sequence, so registered
    /// pipelines flow through the same plan/resolve caches as built-ins.
    pipelines: RwLock<BTreeMap<String, Arc<Pipeline>>>,
    stats: RuntimeStats,
}

impl Runtime {
    /// Load the artifact manifest and create the PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        Self::with_manifest(Self::load_manifest(artifacts_dir)?)
    }

    /// Parse the catalog manifest once, for sharing across runtimes
    /// (each fleet worker owns a runtime — the PJRT client is thread
    /// bound — but the parsed catalog is immutable and shared).
    pub fn load_manifest(artifacts_dir: &Path) -> Result<Arc<Manifest>> {
        let manifest_path = artifacts_dir.join("manifest.txt");
        let manifest = Manifest::load(&manifest_path)
            .map_err(|e| anyhow!("{e} — run `make artifacts` first"))?;
        Ok(Arc::new(manifest))
    }

    /// Build a runtime over an already-parsed manifest.
    pub fn with_manifest(manifest: Arc<Manifest>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            exe_cache: RwLock::new(HashMap::new()),
            plan_cache: RwLock::new(HashMap::new()),
            pipelines: RwLock::new(BTreeMap::new()),
            stats: RuntimeStats::default(),
        })
    }

    /// Register (or replace) a dynamic pipeline. Stale resolved plans
    /// for the name are purged so a re-registration with different
    /// content can never serve the old stage list.
    pub fn register_pipeline(&self, p: Arc<Pipeline>) {
        self.plan_cache.write().unwrap().retain(|k, _| k.0 != p.name);
        self.pipelines.write().unwrap().insert(p.name.clone(), p);
    }

    /// Remove a dynamic pipeline and its resolved plans. Returns whether
    /// the name was registered.
    pub fn unregister_pipeline(&self, name: &str) -> bool {
        self.plan_cache.write().unwrap().retain(|k, _| k.0 != name);
        self.pipelines.write().unwrap().remove(name).is_some()
    }

    /// Look up a registered pipeline by name.
    pub fn pipeline(&self, name: &str) -> Option<Arc<Pipeline>> {
        self.pipelines.read().unwrap().get(name).cloned()
    }

    /// Names of all registered pipelines.
    pub fn pipeline_names(&self) -> Vec<String> {
        self.pipelines.read().unwrap().keys().cloned().collect()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Snapshot the hot-path counters.
    pub fn counters(&self) -> RuntimeCounters {
        RuntimeCounters {
            executable_compiles: self.stats.executable_compiles.load(Ordering::Relaxed),
            executable_cache_hits: self.stats.executable_cache_hits.load(Ordering::Relaxed),
            resolve_hits: self.stats.resolve_hits.load(Ordering::Relaxed),
            resolve_misses: self.stats.resolve_misses.load(Ordering::Relaxed),
        }
    }

    /// Compile (or fetch from cache) the executable for an artifact key.
    /// Hits take the read lock only; a miss compiles outside any lock.
    pub fn executable(&self, key: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exe_cache.read().unwrap().get(key) {
            self.stats.executable_cache_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .get(key)
            .ok_or_else(|| anyhow!("no artifact '{key}' in manifest (rebuild artifacts?)"))?;
        let path = self.manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {key}"))?,
        );
        self.stats.executable_compiles.fetch_add(1, Ordering::Relaxed);
        // Two threads can race past the read lock and compile the same
        // key; the first insert wins so every caller shares one Arc.
        let mut cache = self.exe_cache.write().unwrap();
        Ok(cache.entry(key.to_string()).or_insert(exe).clone())
    }

    /// Resolve (or fetch from cache) the execution plan of a
    /// `(seq, variant, m, n)` key: the indexed stage list, the interned
    /// slot plan, and the pinned executables. Everything a request needs
    /// beyond this is slot-indexed — repeat requests do one read-locked
    /// map probe here and touch no other shared state.
    pub fn resolve(
        &self,
        seq: &str,
        variant: &str,
        m: usize,
        n: usize,
    ) -> Result<Arc<ResolvedSeq>> {
        let key = (seq.to_string(), variant.to_string(), m, n);
        if let Some(r) = self.plan_cache.read().unwrap().get(&key) {
            self.stats.resolve_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(r.clone());
        }
        self.stats.resolve_misses.fetch_add(1, Ordering::Relaxed);
        // Build outside the lock: indexed stage lookup, slot interning,
        // then compiling/pinning every stage executable. Failures are
        // not cached — a rebuilt catalog can succeed on retry.
        let entries: Vec<ArtifactEntry> = self
            .manifest
            .stages(seq, variant, m, n)
            .into_iter()
            .cloned()
            .collect();
        let (plan, exes) = if entries.is_empty() {
            // Not in the parsed manifest — try the dynamic catalog. A
            // registered pipeline synthesizes its stage entries for any
            // size, executed on the interpreter backend.
            let pipeline = self.pipelines.read().unwrap().get(seq).cloned();
            let Some(p) = pipeline else {
                bail!(
                    "no artifacts for {seq}.{variant} at m{m} n{n}; available: {:?}",
                    self.sizes_of(seq, variant)
                );
            };
            let (entries, stages): (Vec<_>, Vec<_>) =
                p.stage_entries(variant, m, n)?.into_iter().unzip();
            let exes = stages
                .into_iter()
                .map(|s: InterpStage| StageExe::Interp(Arc::new(s)))
                .collect();
            (SlotPlan::build(seq, variant, m, n, entries), exes)
        } else {
            let plan = SlotPlan::build(seq, variant, m, n, entries);
            let mut exes = Vec::with_capacity(plan.stage_count());
            for st in plan.stages() {
                exes.push(StageExe::Pjrt(self.executable(&st.entry.key)?));
            }
            (plan, exes)
        };
        let resolved = Arc::new(ResolvedSeq { plan, exes });
        let mut cache = self.plan_cache.write().unwrap();
        Ok(cache.entry(key).or_insert(resolved).clone())
    }

    /// Pre-resolve a (seq, variant, size) — compiling all its stages —
    /// so timing runs measure execution only. Returns the stage count.
    pub fn warmup(&self, seq: &str, variant: &str, m: usize, n: usize) -> Result<usize> {
        Ok(self.resolve(seq, variant, m, n)?.stage_count())
    }

    /// Available (m, n) size points of a sequence variant in the catalog.
    pub fn sizes_of(&self, seq: &str, variant: &str) -> Vec<(usize, usize)> {
        self.manifest.sizes(seq, variant).to_vec()
    }

    /// Execute one stage against the slot environment: inputs are read
    /// by slot index, outputs written by slot index — no name lookups.
    fn run_stage_slots(
        &self,
        st: &StageSlots,
        exe: &xla::PjRtLoadedExecutable,
        env: &mut SlotEnv,
    ) -> Result<f64> {
        let entry = &st.entry;
        let mut literals = Vec::with_capacity(entry.inputs.len());
        for (spec, &slot) in entry.inputs.iter().zip(&st.input_slots) {
            let t = env
                .get(slot)
                .ok_or_else(|| anyhow!("stage {} needs '{}' (not in env)", entry.key, spec.name))?;
            if t.dims != spec.dims {
                bail!(
                    "stage {}: '{}' has dims {:?}, artifact expects {:?}",
                    entry.key,
                    spec.name,
                    t.dims,
                    spec.dims
                );
            }
            let lit = xla::Literal::vec1(&t.data);
            let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
            literals.push(lit.reshape(&dims)?);
        }
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let seconds = t0.elapsed().as_secs_f64();
        // aot.py lowers with return_tuple=True → always a tuple.
        let outs = result.to_tuple()?;
        if outs.len() != entry.outputs.len() {
            bail!(
                "stage {}: got {} outputs, manifest says {}",
                entry.key,
                outs.len(),
                entry.outputs.len()
            );
        }
        for ((spec, &slot), lit) in entry.outputs.iter().zip(&st.output_slots).zip(outs) {
            let data = lit.to_vec::<f32>()?;
            env.set(slot, Tensor::new(spec.dims.clone(), data));
        }
        Ok(seconds)
    }

    /// Execute one interpreter-backed stage: materialize the stage's
    /// named environment from the slots, run the fused call group, and
    /// write the declared outputs back by slot index. Same input/output
    /// dim validation as the PJRT path.
    fn run_stage_interp(
        &self,
        st: &StageSlots,
        stage: &InterpStage,
        env: &mut SlotEnv,
    ) -> Result<f64> {
        let entry = &st.entry;
        let mut locals: BTreeMap<String, Tensor> = BTreeMap::new();
        for (spec, &slot) in entry.inputs.iter().zip(&st.input_slots) {
            let t = env
                .get(slot)
                .ok_or_else(|| anyhow!("stage {} needs '{}' (not in env)", entry.key, spec.name))?;
            if t.dims != spec.dims {
                bail!(
                    "stage {}: '{}' has dims {:?}, artifact expects {:?}",
                    entry.key,
                    spec.name,
                    t.dims,
                    spec.dims
                );
            }
            locals.insert(spec.name.clone(), t.clone());
        }
        let t0 = Instant::now();
        stage.run(&mut locals)?;
        let seconds = t0.elapsed().as_secs_f64();
        for (spec, &slot) in entry.outputs.iter().zip(&st.output_slots) {
            let t = locals.remove(&spec.name).ok_or_else(|| {
                anyhow!("stage {}: interpreter produced no '{}'", entry.key, spec.name)
            })?;
            if t.dims != spec.dims {
                bail!(
                    "stage {}: interpreter output '{}' has dims {:?}, expected {:?}",
                    entry.key,
                    spec.name,
                    t.dims,
                    spec.dims
                );
            }
            env.set(slot, t);
        }
        Ok(seconds)
    }

    /// Execute every stage of a resolved plan over a bound environment
    /// and materialize the result. The per-request hot path: slot reads,
    /// slot writes, pinned executables — no locks, scans or name maps.
    fn run_bound(&self, r: &ResolvedSeq, mut env: SlotEnv) -> Result<RunResult> {
        let mut stats = Vec::with_capacity(r.plan.stage_count());
        let t0 = Instant::now();
        for (st, exe) in r.plan.stages().iter().zip(&r.exes) {
            let secs = match exe {
                StageExe::Pjrt(e) => self.run_stage_slots(st, e, &mut env)?,
                StageExe::Interp(s) => self.run_stage_interp(st, s, &mut env)?,
            };
            stats.push(StageStats {
                key: st.entry.key.clone(),
                seconds: secs,
            });
        }
        Ok(RunResult {
            env: r.plan.materialize(env),
            stages: stats,
            seconds: t0.elapsed().as_secs_f64(),
            variant: r.plan.variant.clone(),
        })
    }

    /// Execute a resolved plan on one named input set.
    pub fn run_resolved(
        &self,
        r: &ResolvedSeq,
        inputs: &BTreeMap<String, Tensor>,
    ) -> Result<RunResult> {
        self.run_bound(r, r.plan.bind(inputs))
    }

    /// Execute a resolved plan on several independent input sets in one
    /// dispatch. Input sets are consumed (tensors move into the slot
    /// environments, no copy); results are bit-identical to running
    /// each set alone, and per-request failures (e.g. a missing input
    /// tensor) fail only that slot.
    pub fn run_resolved_batch(
        &self,
        r: &ResolvedSeq,
        inputs: Vec<BTreeMap<String, Tensor>>,
    ) -> Vec<Result<RunResult>> {
        inputs
            .into_iter()
            .map(|input| self.run_bound(r, r.plan.bind_owned(input)))
            .collect()
    }

    /// Execute all stages of a sequence variant (resolve-once: repeat
    /// keys reuse the cached [`ResolvedSeq`]).
    pub fn run_seq(
        &self,
        seq: &str,
        variant: &str,
        m: usize,
        n: usize,
        inputs: &BTreeMap<String, Tensor>,
    ) -> Result<RunResult> {
        let r = self.resolve(seq, variant, m, n)?;
        self.run_resolved(&r, inputs)
    }

    /// Execute several *different* resolved plans as one horizontally
    /// fused dispatch ([`crate::codegen::horizontal`]): combined stage
    /// `s` runs stage `s` of every member's environments, in member
    /// order, before any member advances to stage `s + 1` — the stub's
    /// semantics of a block-range-dispatched combined launch, whose
    /// fragments all complete before the next combined launch begins.
    /// Members shorter than the longest sit out the remaining stages.
    ///
    /// Results are bit-identical to running each member alone
    /// ([`Runtime::run_resolved_batch`]): members bind disjoint
    /// environments and stages only read/write their own slots, so the
    /// interleaving cannot be observed. Per-environment failures fail
    /// only that slot; later stages of a failed environment are
    /// skipped. `RunResult::seconds` of each slot sums its *own* stage
    /// seconds (unlike `run_bound`'s wall clock — other fragments'
    /// stages interleave on this thread and must not be billed to it).
    pub fn run_hfused(
        &self,
        members: Vec<(Arc<ResolvedSeq>, Vec<BTreeMap<String, Tensor>>)>,
    ) -> Vec<Vec<Result<RunResult>>> {
        struct Lane {
            member: usize,
            env: Option<SlotEnv>,
            err: Option<anyhow::Error>,
            stats: Vec<StageStats>,
            seconds: f64,
        }
        let mut resolved: Vec<Arc<ResolvedSeq>> = Vec::with_capacity(members.len());
        let mut counts: Vec<usize> = Vec::with_capacity(members.len());
        let mut lanes: Vec<Lane> = Vec::new();
        for (mi, (r, inputs)) in members.into_iter().enumerate() {
            counts.push(inputs.len());
            for input in inputs {
                lanes.push(Lane {
                    member: mi,
                    env: Some(r.plan.bind_owned(input)),
                    err: None,
                    stats: Vec::with_capacity(r.stage_count()),
                    seconds: 0.0,
                });
            }
            resolved.push(r);
        }
        let max_stages = resolved.iter().map(|r| r.stage_count()).max().unwrap_or(0);
        for s in 0..max_stages {
            for lane in &mut lanes {
                let r = &resolved[lane.member];
                if lane.err.is_some() || s >= r.stage_count() {
                    continue;
                }
                let st = &r.plan.stages()[s];
                let env = lane.env.as_mut().expect("env present until failure");
                let res = match &r.exes[s] {
                    StageExe::Pjrt(e) => self.run_stage_slots(st, e, env),
                    StageExe::Interp(i) => self.run_stage_interp(st, i, env),
                };
                match res {
                    Ok(secs) => {
                        lane.seconds += secs;
                        lane.stats.push(StageStats {
                            key: st.entry.key.clone(),
                            seconds: secs,
                        });
                    }
                    Err(e) => {
                        lane.err = Some(e);
                        lane.env = None;
                    }
                }
            }
        }
        let mut out: Vec<Vec<Result<RunResult>>> =
            counts.iter().map(|&c| Vec::with_capacity(c)).collect();
        for lane in lanes {
            let r = &resolved[lane.member];
            let res = match lane.err {
                Some(e) => Err(e),
                None => Ok(RunResult {
                    env: r.plan.materialize(lane.env.expect("unfailed lane keeps its env")),
                    stages: lane.stats,
                    seconds: lane.seconds,
                    variant: r.plan.variant.clone(),
                }),
            };
            out[lane.member].push(res);
        }
        out
    }

    /// Execute all stages of a sequence variant for several independent
    /// input sets in one dispatch — [`Runtime::resolve`] once, then
    /// [`Runtime::run_resolved_batch`]. A failed resolve (missing size,
    /// corrupt artifact) fails every slot with the same error: each
    /// request would have hit the same artifact.
    pub fn run_seq_batch(
        &self,
        seq: &str,
        variant: &str,
        m: usize,
        n: usize,
        inputs: Vec<BTreeMap<String, Tensor>>,
    ) -> Vec<Result<RunResult>> {
        match self.resolve(seq, variant, m, n) {
            Ok(r) => self.run_resolved_batch(&r, inputs),
            Err(e) => {
                let msg = format!("{e:#}");
                inputs.iter().map(|_| Err(anyhow!("{msg}"))).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn runtime() -> Option<Runtime> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping runtime test: artifacts not built");
            return None;
        }
        Some(Runtime::load(&dir).expect("runtime"))
    }

    fn inputs_for(rt: &Runtime, seq: &str, variant: &str, m: usize, n: usize) -> BTreeMap<String, Tensor> {
        // free inputs = names consumed before production
        let stages = rt.manifest.stages(seq, variant, m, n);
        let mut produced: Vec<String> = vec![];
        let mut inputs = BTreeMap::new();
        let mut rng = Prng::new(42);
        for e in &stages {
            for spec in &e.inputs {
                if !produced.contains(&spec.name) && !inputs.contains_key(&spec.name) {
                    let len: usize = spec.dims.iter().product::<usize>().max(1);
                    inputs.insert(spec.name.clone(), Tensor::new(spec.dims.clone(), rng.f32_vec(len)));
                }
            }
            for spec in &e.outputs {
                produced.push(spec.name.clone());
            }
        }
        inputs
    }

    #[test]
    fn bicgk_fused_matches_cublas_variant() {
        let Some(rt) = runtime() else { return };
        let (m, n) = (256, 256);
        let inputs = inputs_for(&rt, "bicgk", "fused", m, n);
        let fused = rt.run_seq("bicgk", "fused", m, n, &inputs).unwrap();
        let cublas = rt.run_seq("bicgk", "cublas", m, n, &inputs).unwrap();
        let qf = &fused.env["q"];
        let qc = &cublas.env["q"];
        for (a, b) in qf.data.iter().zip(qc.data.iter()) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        assert_eq!(fused.stages.len(), 1, "fused BiCGK must be one kernel");
        assert_eq!(cublas.stages.len(), 2);
    }

    #[test]
    fn hfused_dispatch_is_bit_identical_to_back_to_back() {
        let Some(rt) = runtime() else { return };
        let (m, n) = (256, 256);
        let ra = rt.resolve("bicgk", "fused", m, n).unwrap();
        let rb = rt.resolve("bicgk", "cublas", m, n).unwrap();
        let ia = inputs_for(&rt, "bicgk", "fused", m, n);
        let ib = inputs_for(&rt, "bicgk", "cublas", m, n);
        let solo_a = rt.run_resolved_batch(&ra, vec![ia.clone(), ia.clone()]);
        let solo_b = rt.run_resolved_batch(&rb, vec![ib.clone()]);
        // one combined dispatch over both members (plus a lane with no
        // inputs, which must fail alone without poisoning the others)
        let combined = rt.run_hfused(vec![
            (ra.clone(), vec![ia.clone(), ia]),
            (rb.clone(), vec![ib, BTreeMap::new()]),
        ]);
        assert_eq!(combined.len(), 2);
        assert!(combined[1][1].is_err(), "empty lane must fail alone");
        for (solo, fused) in [
            (&solo_a[..], &combined[0][..]),
            (&solo_b[..], &combined[1][..1]),
        ] {
            assert_eq!(solo.len(), fused.len());
            for (s, c) in solo.iter().zip(fused.iter()) {
                let (s, c) = (s.as_ref().unwrap(), c.as_ref().unwrap());
                assert_eq!(s.variant, c.variant);
                assert_eq!(s.stages.len(), c.stages.len());
                assert_eq!(s.env.len(), c.env.len());
                for (k, t) in &s.env {
                    let u = &c.env[k];
                    assert_eq!(t.dims, u.dims, "{k}");
                    for (a, b) in t.data.iter().zip(&u.data) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{k}");
                    }
                }
            }
        }
    }

    #[test]
    fn missing_artifact_reports_cleanly() {
        let Some(rt) = runtime() else { return };
        let err = rt
            .run_seq("bicgk", "fused", 31, 31, &BTreeMap::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("no artifacts"), "{err}");
    }

    #[test]
    fn missing_input_reports_name() {
        let Some(rt) = runtime() else { return };
        let err = rt
            .run_seq("bicgk", "fused", 256, 256, &BTreeMap::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("needs"), "{err}");
    }

    #[test]
    fn executables_are_cached() {
        let Some(rt) = runtime() else { return };
        let n = rt.warmup("vadd", "fused", 32, 65536).unwrap();
        assert_eq!(n, 1);
        let before = rt.counters();
        assert_eq!(before.executable_compiles, 1, "warmup compiles the one stage");
        let _ = rt.executable("vadd.fused.m32n65536.s0").unwrap();
        let after = rt.counters();
        assert_eq!(
            after.executable_compiles, before.executable_compiles,
            "cache miss on second lookup"
        );
        assert_eq!(after.executable_cache_hits, before.executable_cache_hits + 1);
    }

    #[test]
    fn repeat_requests_hit_the_resolve_cache() {
        let Some(rt) = runtime() else { return };
        let (m, n) = (256, 256);
        let inputs = inputs_for(&rt, "bicgk", "fused", m, n);
        let a = rt.run_seq("bicgk", "fused", m, n, &inputs).unwrap();
        let c0 = rt.counters();
        assert_eq!(c0.resolve_misses, 1);
        assert_eq!(c0.resolve_hits, 0);
        let b = rt.run_seq("bicgk", "fused", m, n, &inputs).unwrap();
        let c1 = rt.counters();
        assert_eq!(c1.resolve_misses, 1, "second request must not re-resolve");
        assert_eq!(c1.resolve_hits, 1);
        assert_eq!(
            c1.executable_compiles, c0.executable_compiles,
            "pinned executables never recompile"
        );
        // resolve-once shares bookkeeping, never changes arithmetic
        for (name, ta) in &a.env {
            let tb = &b.env[name];
            for (x, y) in ta.data.iter().zip(&tb.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "tensor '{name}' differs");
            }
        }
    }

    // ---- dynamic pipeline catalog (interpreter backend; no artifacts
    // or PJRT compilation involved, so these always run) ----

    fn empty_runtime() -> Runtime {
        Runtime::with_manifest(Arc::new(Manifest::default())).expect("runtime")
    }

    fn registered(rt: &Runtime, name: &str, src: &str) -> Arc<crate::pipelines::Pipeline> {
        let lib = crate::library::Library::standard();
        let c = crate::pipelines::compile(name, src, &lib).expect("compile");
        rt.register_pipeline(c.pipeline.clone());
        c.pipeline
    }

    #[test]
    fn registered_pipeline_executes_through_run_seq() {
        let rt = empty_runtime();
        let p = registered(&rt, "amx", crate::pipelines::examples::ADD_MUL_EXP);
        let (m, n) = (32, 64);
        let inputs = p.synth_inputs(m, n, 11).unwrap();
        let got = rt.run_seq("amx", "fused", m, n, &inputs).unwrap();
        let want = p.run_offline("fused", m, n, &inputs).unwrap();
        assert_eq!(got.variant, "fused");
        for (x, y) in got.env["z"].data.iter().zip(&want["z"].data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn pipeline_repeat_requests_hit_the_resolve_cache() {
        let rt = empty_runtime();
        let p = registered(&rt, "q8", crate::pipelines::examples::QUANTIZE_INT8);
        let (m, n) = (32, 128);
        let inputs = p.synth_inputs(m, n, 5).unwrap();
        let a = rt.run_seq("q8", "fused", m, n, &inputs).unwrap();
        let c0 = rt.counters();
        assert_eq!(c0.resolve_misses, 1);
        assert_eq!(c0.resolve_hits, 0);
        let b = rt.run_seq("q8", "fused", m, n, &inputs).unwrap();
        let c1 = rt.counters();
        assert_eq!(c1.resolve_misses, 1, "second request must not re-resolve");
        assert_eq!(c1.resolve_hits, 1);
        for (x, y) in a.env["q"].data.iter().zip(&b.env["q"].data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn unregister_purges_resolved_plans() {
        let rt = empty_runtime();
        let p = registered(&rt, "amx", crate::pipelines::examples::ADD_MUL_EXP);
        let (m, n) = (32, 64);
        let inputs = p.synth_inputs(m, n, 2).unwrap();
        rt.run_seq("amx", "fused", m, n, &inputs).unwrap();
        assert!(rt.unregister_pipeline("amx"));
        assert!(!rt.unregister_pipeline("amx"), "second remove is a no-op");
        let err = rt.run_seq("amx", "fused", m, n, &inputs).unwrap_err().to_string();
        assert!(err.contains("no artifacts"), "{err}");
    }

    #[test]
    fn pipeline_unknown_size_mismatch_reports() {
        let rt = empty_runtime();
        let p = registered(&rt, "amx", crate::pipelines::examples::ADD_MUL_EXP);
        // inputs synthesized for a different n than requested → dim check
        let inputs = p.synth_inputs(32, 64, 2).unwrap();
        let err = rt.run_seq("amx", "fused", 32, 256, &inputs).unwrap_err().to_string();
        assert!(err.contains("dims"), "{err}");
    }
}
