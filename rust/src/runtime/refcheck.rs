//! Rust-side reference implementations of all eleven sequences — the
//! runtime's independent correctness oracle (mirrors python ref.py, so
//! the AOT artifacts are validated twice: pytest against jnp and here
//! against naive Rust).
//!
//! Scalar conventions must match `python/compile/model.py` and
//! `rust/src/sequences/mod.rs`.

use super::Tensor;
use std::collections::BTreeMap;

pub const AXPYDOT_ALPHA: f32 = 2.5;
pub const SGEMV_ALPHA: f32 = 2.0;
pub const SGEMV_BETA: f32 = 0.5;
pub const SGEMVT_ALPHA: f32 = 2.0;
pub const SGEMVT_BETA: f32 = 0.5;
pub const SSCAL_ALPHA: f32 = 2.0;
pub const GEMVER_ALPHA: f32 = 2.0;
pub const GEMVER_BETA: f32 = 0.5;
pub const GESUMMV_ALPHA: f32 = 2.0;
pub const GESUMMV_BETA: f32 = 0.5;
pub const WAXPBY_ALPHA: f32 = 2.0;
pub const WAXPBY_BETA: f32 = 0.5;

fn matvec(a: &Tensor, x: &[f32]) -> Vec<f32> {
    let (m, n) = (a.dims[0], a.dims[1]);
    assert_eq!(x.len(), n);
    (0..m)
        .map(|i| {
            let row = &a.data[i * n..(i + 1) * n];
            row.iter().zip(x).map(|(r, v)| r * v).sum()
        })
        .collect()
}

fn matvec_t(a: &Tensor, y: &[f32]) -> Vec<f32> {
    let (m, n) = (a.dims[0], a.dims[1]);
    assert_eq!(y.len(), m);
    let mut out = vec![0.0f32; n];
    for i in 0..m {
        let row = &a.data[i * n..(i + 1) * n];
        for j in 0..n {
            out[j] += row[j] * y[i];
        }
    }
    out
}

/// Compute the reference outputs of a sequence from its free inputs.
/// Returns name → tensor for every final output.
pub fn reference(seq: &str, inputs: &BTreeMap<String, Tensor>) -> BTreeMap<String, Tensor> {
    let v = |k: &str| -> &Tensor { &inputs[k] };
    let mut out = BTreeMap::new();
    match seq {
        "axpydot" => {
            let z: Vec<f32> = v("w")
                .data
                .iter()
                .zip(&v("v").data)
                .map(|(w, vv)| w - AXPYDOT_ALPHA * vv)
                .collect();
            let r: f32 = z.iter().zip(&v("u").data).map(|(a, b)| a * b).sum();
            out.insert("z".into(), Tensor::vector(z));
            out.insert("r".into(), Tensor::new(vec![1], vec![r]));
        }
        "atax" => {
            let t = matvec(v("A"), &v("x").data);
            out.insert("y".into(), Tensor::vector(matvec_t(v("A"), &t)));
        }
        "bicgk" => {
            out.insert("q".into(), Tensor::vector(matvec(v("A"), &v("p").data)));
            out.insert("s".into(), Tensor::vector(matvec_t(v("A"), &v("r").data)));
        }
        "sgemv" => {
            let ax = matvec(v("A"), &v("x").data);
            let z: Vec<f32> = ax
                .iter()
                .zip(&v("y").data)
                .map(|(a, y)| SGEMV_ALPHA * a + SGEMV_BETA * y)
                .collect();
            out.insert("z".into(), Tensor::vector(z));
        }
        "sgemvt" => {
            let aty = matvec_t(v("A"), &v("y").data);
            let x: Vec<f32> = aty
                .iter()
                .zip(&v("z").data)
                .map(|(a, z)| SGEMVT_BETA * a + z)
                .collect();
            let w: Vec<f32> = matvec(v("A"), &x)
                .into_iter()
                .map(|a| SGEMVT_ALPHA * a)
                .collect();
            out.insert("x".into(), Tensor::vector(x));
            out.insert("w".into(), Tensor::vector(w));
        }
        "sscal" => {
            out.insert(
                "y".into(),
                Tensor::vector(v("x").data.iter().map(|x| SSCAL_ALPHA * x).collect()),
            );
        }
        "gemver" => {
            let a = v("A");
            let (m, n) = (a.dims[0], a.dims[1]);
            let (u1, v1) = (&v("u1").data, &v("v1").data);
            let (u2, v2) = (&v("u2").data, &v("v2").data);
            let mut b = a.data.clone();
            for i in 0..m {
                for j in 0..n {
                    b[i * n + j] += u1[i] * v1[j] + u2[i] * v2[j];
                }
            }
            let bt = Tensor::matrix(m, n, b);
            let bty = matvec_t(&bt, &v("y").data);
            let x: Vec<f32> = bty
                .iter()
                .zip(&v("z").data)
                .map(|(a, z)| GEMVER_BETA * a + z)
                .collect();
            let w: Vec<f32> = matvec(&bt, &x)
                .into_iter()
                .map(|a| GEMVER_ALPHA * a)
                .collect();
            out.insert("B".into(), bt);
            out.insert("x".into(), Tensor::vector(x));
            out.insert("w".into(), Tensor::vector(w));
        }
        "gesummv" => {
            let ax = matvec(v("A"), &v("x").data);
            let bx = matvec(v("B"), &v("x").data);
            let y: Vec<f32> = ax
                .iter()
                .zip(&bx)
                .map(|(a, b)| GESUMMV_ALPHA * a + GESUMMV_BETA * b)
                .collect();
            out.insert("y".into(), Tensor::vector(y));
        }
        "madd" => {
            let c: Vec<f32> = v("A")
                .data
                .iter()
                .zip(&v("B").data)
                .map(|(a, b)| a + b)
                .collect();
            out.insert("C".into(), Tensor::new(v("A").dims.clone(), c));
        }
        "vadd" => {
            let x: Vec<f32> = v("w")
                .data
                .iter()
                .zip(&v("y").data)
                .zip(&v("z").data)
                .map(|((w, y), z)| w + y + z)
                .collect();
            out.insert("x".into(), Tensor::vector(x));
        }
        "waxpby" => {
            let w: Vec<f32> = v("x")
                .data
                .iter()
                .zip(&v("y").data)
                .map(|(x, y)| WAXPBY_ALPHA * x + WAXPBY_BETA * y)
                .collect();
            out.insert("w".into(), Tensor::vector(w));
        }
        other => panic!("no reference for sequence '{other}'"),
    }
    out
}

/// Max |a−b| across the outputs the reference defines. `got` may contain
/// extra intermediates — only reference keys are compared.
pub fn max_abs_error(
    seq: &str,
    inputs: &BTreeMap<String, Tensor>,
    got: &BTreeMap<String, Tensor>,
) -> f32 {
    let want = reference(seq, inputs);
    let mut worst: f32 = 0.0;
    for (name, w) in &want {
        let g = got
            .get(name)
            .unwrap_or_else(|| panic!("output '{name}' missing from run result"));
        assert_eq!(g.dims, w.dims, "dims of '{name}'");
        for (a, b) in g.data.iter().zip(&w.data) {
            worst = worst.max((a - b).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn env(pairs: &[(&str, Tensor)]) -> BTreeMap<String, Tensor> {
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect()
    }

    #[test]
    fn bicgk_reference_small() {
        // A = [[1,2],[3,4]], p = [1,1], r = [1,2]
        let a = Tensor::matrix(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let inputs = env(&[
            ("A", a),
            ("p", Tensor::vector(vec![1.0, 1.0])),
            ("r", Tensor::vector(vec![1.0, 2.0])),
        ]);
        let out = reference("bicgk", &inputs);
        assert_eq!(out["q"].data, vec![3.0, 7.0]); // A p
        assert_eq!(out["s"].data, vec![7.0, 10.0]); // Aᵀ r
    }

    #[test]
    fn axpydot_reference_small() {
        let inputs = env(&[
            ("w", Tensor::vector(vec![1.0, 2.0])),
            ("v", Tensor::vector(vec![0.0, 1.0])),
            ("u", Tensor::vector(vec![1.0, 1.0])),
        ]);
        let out = reference("axpydot", &inputs);
        // z = w − 2.5 v = [1, −0.5]; r = 0.5
        assert_eq!(out["z"].data, vec![1.0, -0.5]);
        assert!((out["r"].data[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn gemver_reference_shapes() {
        let mut rng = Prng::new(1);
        let (m, n) = (4, 3);
        let inputs = env(&[
            ("A", Tensor::matrix(m, n, rng.f32_vec(m * n))),
            ("u1", Tensor::vector(rng.f32_vec(m))),
            ("v1", Tensor::vector(rng.f32_vec(n))),
            ("u2", Tensor::vector(rng.f32_vec(m))),
            ("v2", Tensor::vector(rng.f32_vec(n))),
            ("y", Tensor::vector(rng.f32_vec(m))),
            ("z", Tensor::vector(rng.f32_vec(n))),
        ]);
        let out = reference("gemver", &inputs);
        assert_eq!(out["B"].dims, vec![m, n]);
        assert_eq!(out["x"].dims, vec![n]);
        assert_eq!(out["w"].dims, vec![m]);
    }

    #[test]
    fn max_abs_error_detects_mismatch() {
        let inputs = env(&[("x", Tensor::vector(vec![1.0, 2.0]))]);
        let mut got = BTreeMap::new();
        got.insert("y".to_string(), Tensor::vector(vec![2.0, 4.5]));
        let err = max_abs_error("sscal", &inputs, &got);
        assert!((err - 0.5).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "no reference")]
    fn unknown_sequence_panics() {
        reference("nope", &BTreeMap::new());
    }
}
