//! Performance prediction (paper §4.2): "sum previously benchmarked
//! running times of routines according to the fusion implementation …
//! The time of data transfers t_t and computation t_c are summed
//! separately and the predicted runtime is computed as max(t_t, t_c)".
//!
//! Routines are benchmarked **once per architecture** in a *simulated
//! fusion environment*: a grid over instances-per-block, serial
//! iterations and additionally-allocated shared memory (which stands in
//! for the other data a fusion keeps on-chip and costs occupancy).
//!
//! The predictor intentionally reproduces the paper's systematic errors:
//! it ignores kernel startup overhead, the serial residue between
//! transfer and compute, atomics and barrier interactions between
//! routines of different functions. The gap between this estimate and
//! the full simulator is what produces the non-trivial best-rank column
//! of Table 4.

use crate::ir::elem::ProblemSize;
use crate::ir::func::{ElemFunc, Routine, RoutineKind};
use crate::ir::plan::{GridPlan, Hoist, IterDim, KernelPlan, Poly2, SeqPlan, Traffic};
use crate::library::Library;
use crate::sim::{simulate_kernel, DeviceModel};
use std::collections::BTreeMap;

/// Environment bucket a routine was benchmarked under.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct EnvKey {
    /// log2(instances per block), capped.
    pub ipb_log2: u8,
    /// log2(serial iterations), capped.
    pub iters_log2: u8,
    /// Extra shared memory bucket (0, ≤1K, ≤2K, ≤4K, ≤8K, more words).
    pub smem_bucket: u8,
}

impl EnvKey {
    pub fn new(ipb: u32, iters: u32, extra_smem_words: u32) -> EnvKey {
        EnvKey {
            ipb_log2: (31 - ipb.max(1).leading_zeros()).min(4) as u8,
            iters_log2: (31 - iters.max(1).leading_zeros()).min(4) as u8,
            smem_bucket: match extra_smem_words {
                0 => 0,
                w if w <= 1024 => 1,
                w if w <= 2048 => 2,
                w if w <= 4096 => 3,
                w if w <= 8192 => 4,
                _ => 5,
            },
        }
    }
}

/// Benchmarked per-instance routine times.
#[derive(Clone, Debug, Default)]
pub struct RoutineDb {
    /// routine name → env → seconds per instance (two-level map so the
    /// hot lookup borrows the name instead of allocating a String).
    map: BTreeMap<String, BTreeMap<EnvKey, f64>>,
}

/// The environment grid used for calibration (matches EnvKey buckets).
fn env_grid() -> Vec<(u32, u32, u32)> {
    let mut envs = Vec::new();
    for ipb in [1u32, 2, 4, 8, 16] {
        for iters in [1u32, 2, 4, 8, 16] {
            for smem in [0u32, 1024, 2048, 4096, 8192, 12288] {
                envs.push((ipb, iters, smem));
            }
        }
    }
    envs
}

/// Build the micro-kernel plan that benchmarks one routine in one
/// environment (the paper's per-routine measurement harness).
fn micro_plan(func: &ElemFunc, r: &Routine, ipb: u32, iters: u32, extra_smem: u32) -> KernelPlan {
    let depth = func.depth();
    let words = r.global_words as f64;
    let (instances, traffic_poly, flops_poly) = if depth == 2 {
        (
            Poly2::mn(1.0 / 1024.0),
            Poly2::mn(words / 1024.0),
            Poly2::mn(r.flops as f64 / 1024.0),
        )
    } else {
        (
            Poly2::n(1.0 / 32.0),
            Poly2::n(words / 32.0),
            Poly2::n(r.flops as f64 / 32.0),
        )
    };
    let own_smem = func.outputs[0].elem.smem_words_padded() as u32;
    let (loads, stores) = match r.kind {
        RoutineKind::Load { .. } => (traffic_poly, Poly2::ZERO),
        RoutineKind::Store { .. } => (Poly2::ZERO, traffic_poly),
        RoutineKind::Compute => (Poly2::ZERO, Poly2::ZERO),
    };
    KernelPlan {
        name: format!("bench_{}", r.name),
        members: vec![],
        grid: GridPlan {
            depth,
            block: if depth == 2 {
                (32, 4)
            } else {
                (r.threads.0.max(1), ipb)
            },
            instances_per_block: if depth == 2 { 1 } else { ipb },
            iters,
            iter_dim: if depth == 2 {
                IterDim::Row
            } else {
                IterDim::Elem
            },
        },
        smem_words: own_smem + extra_smem,
        regs_per_thread: 20,
        smem_slots: vec![],
        steps: vec![],
        instances,
        traffic: Traffic {
            loads,
            stores,
            atomic_words: Poly2::ZERO,
        },
        flops: flops_poly,
        compute_efficiency: 1.0,
        barriers_per_iter: 0,
    }
}

impl RoutineDb {
    /// Benchmark every routine of every library function across the
    /// environment grid. Done once per device — the paper's "once per
    /// routine per GPU architecture".
    pub fn calibrate(dev: &DeviceModel, lib: &Library) -> RoutineDb {
        let mut map = BTreeMap::new();
        let p_ref = ProblemSize::square(4096);
        for name in lib.names().map(str::to_string).collect::<Vec<_>>() {
            let f = lib.by_name(&name);
            for r in &f.routines {
                for (ipb, iters, smem) in env_grid() {
                    let plan = micro_plan(f, r, ipb, iters, smem);
                    let t = simulate_kernel(dev, &plan, p_ref);
                    let n_inst = plan.instances.eval(p_ref).max(1.0);
                    map.entry(r.name.clone())
                        .or_insert_with(BTreeMap::new)
                        .insert(EnvKey::new(ipb, iters, smem), t.seconds / n_inst);
                }
            }
        }
        RoutineDb { map }
    }

    pub fn len(&self) -> usize {
        self.map.values().map(|m| m.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    fn lookup(&self, routine: &str, env: EnvKey) -> Option<f64> {
        self.map.get(routine).and_then(|m| m.get(&env)).copied()
    }
}

/// Predicted runtime of one kernel: `max(Σ t_transfer, Σ t_compute)`.
pub fn predict_kernel(db: &RoutineDb, plan: &KernelPlan, p: ProblemSize) -> f64 {
    let instances = plan.instances.eval(p).max(0.0);
    let env = EnvKey::new(
        plan.grid.instances_per_block,
        plan.grid.iters,
        plan.smem_words,
    );
    let mut t_t = 0.0;
    let mut t_c = 0.0;
    for s in &plan.steps {
        let per_inst = db
            .lookup(&s.op.routine_name, env)
            .unwrap_or_else(|| panic!("routine '{}' not calibrated", s.op.routine_name));
        // hoisted steps run once per block instead of once per instance
        let count = match s.hoist {
            Hoist::InLoop => instances,
            _ => instances / (plan.grid.iters as f64 * plan.grid.instances_per_block as f64),
        };
        if s.op.kind.is_transfer() {
            t_t += per_inst * count;
        } else {
            t_c += per_inst * count;
        }
    }
    t_t.max(t_c)
}

/// Predicted runtime of a sequence. Deliberately ignores launch overhead
/// (the paper's acknowledged systematic error that misranks AXPYDOT).
pub fn predict_seq(db: &RoutineDb, plan: &SeqPlan, p: ProblemSize) -> f64 {
    plan.kernels
        .iter()
        .map(|k| predict_kernel(db, k, p))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen;
    use crate::fusion::{enumerate_fusions, gen_impls, Fusion, FusionImpl, ImplAxes};
    use crate::graph::DepGraph;
    use crate::script::compile_script;
    use crate::sim::simulate_seq;

    fn db() -> (DeviceModel, Library, RoutineDb) {
        let dev = DeviceModel::gtx480();
        let lib = Library::standard();
        let db = RoutineDb::calibrate(&dev, &lib);
        (dev, lib, db)
    }

    #[test]
    fn calibration_covers_all_routines() {
        let (_, lib, db) = db();
        let n_routines: usize = lib
            .names()
            .map(|n| lib.by_name(n).routines.len())
            .sum();
        // 5 ipb × 5 iters × 6 smem = 150 envs per routine
        assert_eq!(db.len(), n_routines * 150);
    }

    #[test]
    fn calibration_is_shareable_across_threads() {
        // The planner's cost fan-out shares one RoutineDb and the
        // per-impl KernelPlans across scoped worker threads; keep that
        // contract explicit at compile time.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<RoutineDb>();
        assert_send_sync::<KernelPlan>();
        assert_send_sync::<crate::ir::elem::ProblemSize>();
    }

    #[test]
    fn env_bucketing() {
        assert_eq!(EnvKey::new(1, 1, 0), EnvKey::new(1, 1, 0));
        assert_ne!(EnvKey::new(1, 1, 0), EnvKey::new(2, 1, 0));
        assert_eq!(EnvKey::new(4, 8, 3000).smem_bucket, 3);
        assert_eq!(EnvKey::new(16, 16, 20000).smem_bucket, 5);
    }

    #[test]
    fn prediction_correlates_with_simulation() {
        // Prediction must get the big call right: fused BiCGK faster
        // than unfused (its whole purpose in the paper).
        let (dev, lib, db) = db();
        let src = "
            matrix<MxN> A; vector<N> p, s; vector<M> q, r;
            input A, p, r;
            q = sgemv(A, p);
            s = sgemtv(A, r);
            return q, s;
        ";
        let prog = compile_script("bicgk", src, &lib).unwrap();
        let g = DepGraph::build(&prog, &lib);
        let p = ProblemSize::square(8192);

        let f = enumerate_fusions(&prog, &lib, &g).remove(0);
        let fi = gen_impls(&prog, &lib, &g, &f, &ImplAxes::default())
            .into_iter()
            .find(|i| i.iters == 8 && i.variant == vec![0, 0])
            .unwrap();
        let fused = codegen::compile_seq(&prog, &lib, &[fi], "fused");
        let singles: Vec<FusionImpl> = prog
            .call_ids()
            .map(|c| FusionImpl {
                fusion: Fusion::singleton(c, &prog, &lib),
                order: vec![c],
                variant: vec![0],
                ipb: 1,
                iters: 8,
                iter_dim: crate::ir::plan::IterDim::Col,
            })
            .collect();
        let unfused = codegen::compile_seq(&prog, &lib, &singles, "unfused");

        let pf = predict_seq(&db, &fused, p);
        let pu = predict_seq(&db, &unfused, p);
        assert!(pf < pu, "prediction must favor fusion: {pf} vs {pu}");

        // and the prediction should be within 2x of the simulator
        let sf = simulate_seq(&dev, &fused, p, 1.0).seconds;
        assert!(pf / sf > 0.4 && pf / sf < 1.6, "pred {pf} vs sim {sf}");
    }

    #[test]
    fn prediction_ignores_launch_overhead() {
        // Two kernels of near-zero size: prediction ≈ 0, simulation pays
        // launch overhead — the documented AXPYDOT error source.
        let (dev, lib, db) = db();
        let src = "
            vector<N> x, y, z; input x;
            y = sscal(x, alpha=2.0);
            z = sscal(y, alpha=3.0);
            return z;
        ";
        let prog = compile_script("t", src, &lib).unwrap();
        let singles: Vec<FusionImpl> = prog
            .call_ids()
            .map(|c| FusionImpl {
                fusion: Fusion::singleton(c, &prog, &lib),
                order: vec![c],
                variant: vec![0],
                ipb: 4,
                iters: 1,
                iter_dim: crate::ir::plan::IterDim::Elem,
            })
            .collect();
        let plan = codegen::compile_seq(&prog, &lib, &singles, "u");
        let p = ProblemSize::new(32, 1024);
        let pred = predict_seq(&db, &plan, p);
        let sim = simulate_seq(&dev, &plan, p, 1.0).seconds;
        assert!(pred < sim, "prediction should undercut (no launch cost)");
    }

    #[test]
    fn more_smem_predicts_slower_or_equal() {
        // extra shared memory lowers occupancy -> per-instance times in
        // bigger buckets must not be faster
        let (dev, lib, _) = db();
        let f = lib.by_name("sgemv");
        let r = f.load_routine(0);
        let p_ref = ProblemSize::square(4096);
        let t_small = simulate_kernel(&dev, &micro_plan(f, r, 1, 4, 0), p_ref).seconds;
        let t_big = simulate_kernel(&dev, &micro_plan(f, r, 1, 4, 12288), p_ref).seconds;
        assert!(t_big >= t_small);
    }
}
