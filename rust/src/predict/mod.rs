//! Performance prediction (paper §4.2): "sum previously benchmarked
//! running times of routines according to the fusion implementation …
//! The time of data transfers t_t and computation t_c are summed
//! separately and the predicted runtime is computed as max(t_t, t_c)".
//!
//! Routines are benchmarked **once per architecture** in a *simulated
//! fusion environment*: a grid over instances-per-block, serial
//! iterations and additionally-allocated shared memory (which stands in
//! for the other data a fusion keeps on-chip and costs occupancy).
//!
//! The predictor intentionally reproduces the paper's systematic errors:
//! it ignores kernel startup overhead, the serial residue between
//! transfer and compute, atomics and barrier interactions between
//! routines of different functions. The gap between this estimate and
//! the full simulator is what produces the non-trivial best-rank column
//! of Table 4.

use crate::ir::elem::ProblemSize;
use crate::ir::func::{ElemFunc, Routine, RoutineKind};
use crate::ir::plan::{GridPlan, Hoist, IterDim, KernelPlan, Poly2, SeqPlan, Traffic};
use crate::library::Library;
use crate::sim::{simulate_kernel, DeviceModel};
use std::collections::BTreeMap;
use std::path::Path;

/// Environment bucket a routine was benchmarked under.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct EnvKey {
    /// log2(instances per block), capped.
    pub ipb_log2: u8,
    /// log2(serial iterations), capped.
    pub iters_log2: u8,
    /// Extra shared memory bucket (0, ≤1K, ≤2K, ≤4K, ≤8K, more words).
    pub smem_bucket: u8,
}

impl EnvKey {
    pub fn new(ipb: u32, iters: u32, extra_smem_words: u32) -> EnvKey {
        EnvKey {
            ipb_log2: (31 - ipb.max(1).leading_zeros()).min(4) as u8,
            iters_log2: (31 - iters.max(1).leading_zeros()).min(4) as u8,
            smem_bucket: match extra_smem_words {
                0 => 0,
                w if w <= 1024 => 1,
                w if w <= 2048 => 2,
                w if w <= 4096 => 3,
                w if w <= 8192 => 4,
                _ => 5,
            },
        }
    }
}

/// Benchmarked per-instance routine times.
#[derive(Clone, Debug, Default)]
pub struct RoutineDb {
    /// routine name → env → seconds per instance (two-level map so the
    /// hot lookup borrows the name instead of allocating a String).
    map: BTreeMap<String, BTreeMap<EnvKey, f64>>,
}

/// The environment grid used for calibration (matches EnvKey buckets).
fn env_grid() -> Vec<(u32, u32, u32)> {
    let mut envs = Vec::new();
    for ipb in [1u32, 2, 4, 8, 16] {
        for iters in [1u32, 2, 4, 8, 16] {
            for smem in [0u32, 1024, 2048, 4096, 8192, 12288] {
                envs.push((ipb, iters, smem));
            }
        }
    }
    envs
}

/// Build the micro-kernel plan that benchmarks one routine in one
/// environment (the paper's per-routine measurement harness).
fn micro_plan(func: &ElemFunc, r: &Routine, ipb: u32, iters: u32, extra_smem: u32) -> KernelPlan {
    let depth = func.depth();
    let words = r.global_words as f64;
    let (instances, traffic_poly, flops_poly) = if depth == 2 {
        (
            Poly2::mn(1.0 / 1024.0),
            Poly2::mn(words / 1024.0),
            Poly2::mn(r.flops as f64 / 1024.0),
        )
    } else {
        (
            Poly2::n(1.0 / 32.0),
            Poly2::n(words / 32.0),
            Poly2::n(r.flops as f64 / 32.0),
        )
    };
    let own_smem = func.outputs[0].elem.smem_words_padded() as u32;
    let (loads, stores) = match r.kind {
        RoutineKind::Load { .. } => (traffic_poly, Poly2::ZERO),
        RoutineKind::Store { .. } => (Poly2::ZERO, traffic_poly),
        RoutineKind::Compute => (Poly2::ZERO, Poly2::ZERO),
    };
    KernelPlan {
        name: format!("bench_{}", r.name),
        members: vec![],
        grid: GridPlan {
            depth,
            block: if depth == 2 {
                (32, 4)
            } else {
                (r.threads.0.max(1), ipb)
            },
            instances_per_block: if depth == 2 { 1 } else { ipb },
            iters,
            iter_dim: if depth == 2 {
                IterDim::Row
            } else {
                IterDim::Elem
            },
        },
        smem_words: own_smem + extra_smem,
        regs_per_thread: 20,
        smem_slots: vec![],
        steps: vec![],
        instances,
        traffic: Traffic {
            loads,
            stores,
            atomic_words: Poly2::ZERO,
        },
        flops: flops_poly,
        compute_efficiency: 1.0,
        barriers_per_iter: 0,
    }
}

impl RoutineDb {
    /// Benchmark every routine of every library function across the
    /// environment grid. Done once per device — the paper's "once per
    /// routine per GPU architecture".
    pub fn calibrate(dev: &DeviceModel, lib: &Library) -> RoutineDb {
        let mut map = BTreeMap::new();
        let p_ref = ProblemSize::square(4096);
        for name in lib.names().map(str::to_string).collect::<Vec<_>>() {
            let f = lib.by_name(&name);
            for r in &f.routines {
                for (ipb, iters, smem) in env_grid() {
                    let plan = micro_plan(f, r, ipb, iters, smem);
                    let t = simulate_kernel(dev, &plan, p_ref);
                    let n_inst = plan.instances.eval(p_ref).max(1.0);
                    map.entry(r.name.clone())
                        .or_insert_with(BTreeMap::new)
                        .insert(EnvKey::new(ipb, iters, smem), t.seconds / n_inst);
                }
            }
        }
        RoutineDb { map }
    }

    pub fn len(&self) -> usize {
        self.map.values().map(|m| m.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    fn lookup(&self, routine: &str, env: EnvKey) -> Option<f64> {
        self.map.get(routine).and_then(|m| m.get(&env)).copied()
    }

    /// Persist the calibration next to the artifact catalog, keyed by
    /// device name + library fingerprint. Seconds are stored as raw f64
    /// bits, so a reload is bit-identical to the calibration it cached.
    /// The write goes through a temp file + rename so concurrent
    /// processes never observe a torn file.
    pub fn save(&self, path: &Path, device: &str, library_fingerprint: u64) -> std::io::Result<()> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static SAVE_SEQ: AtomicU64 = AtomicU64::new(0);
        let mut out = String::new();
        out.push_str(CALIBRATION_HEADER);
        out.push('\n');
        out.push_str(&format!("device {device}\n"));
        out.push_str(&format!("library {library_fingerprint:016x}\n"));
        for (routine, envs) in &self.map {
            out.push_str(&format!("routine {routine}\n"));
            for (k, secs) in envs {
                out.push_str(&format!(
                    "env {} {} {} {:016x}\n",
                    k.ipb_log2,
                    k.iters_log2,
                    k.smem_bucket,
                    secs.to_bits()
                ));
            }
        }
        let tmp = path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            SAVE_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, out)?;
        std::fs::rename(&tmp, path)
    }

    /// Load the persistent calibration of one device from `dir`, or
    /// calibrate fresh and persist it. The lookup order is the fleet
    /// layout first — one file per device
    /// ([`calibration_path`]: `calibration.<sanitized-name>.txt`), so
    /// two devices' caches live side by side instead of clobbering one
    /// shared file — then the legacy pre-fleet `calibration.txt`
    /// (whose header already records which device wrote it, so it is
    /// only trusted for that device) as a migration path; a legacy hit
    /// is rewritten into the per-device file. Nothing is written when
    /// `dir` does not exist.
    pub fn load_or_calibrate(dir: &Path, dev: &DeviceModel, lib: &Library) -> RoutineDb {
        let fp = lib.fingerprint();
        let path = calibration_path(dir, &dev.name);
        if let Some(db) = Self::load_cached(&path, &dev.name, fp) {
            return db;
        }
        let migrated = Self::load_cached(&dir.join("calibration.txt"), &dev.name, fp);
        let db = migrated.unwrap_or_else(|| Self::calibrate(dev, lib));
        if dir.is_dir() {
            let _ = db.save(&path, &dev.name, fp);
        }
        db
    }

    /// Reload a calibration cached by [`RoutineDb::save`]. Returns
    /// `None` when the file is missing, malformed, or was recorded for a
    /// different device or library fingerprint — callers then fall back
    /// to a fresh [`RoutineDb::calibrate`].
    pub fn load_cached(path: &Path, device: &str, library_fingerprint: u64) -> Option<RoutineDb> {
        let text = std::fs::read_to_string(path).ok()?;
        let mut lines = text.lines();
        if lines.next()? != CALIBRATION_HEADER {
            return None;
        }
        if lines.next()? != format!("device {device}") {
            return None;
        }
        if lines.next()? != format!("library {library_fingerprint:016x}") {
            return None;
        }
        let mut map: BTreeMap<String, BTreeMap<EnvKey, f64>> = BTreeMap::new();
        let mut current: Option<String> = None;
        for line in lines {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("routine ") {
                current = Some(name.to_string());
                map.entry(name.to_string()).or_default();
            } else if let Some(rest) = line.strip_prefix("env ") {
                let routine = current.as_ref()?;
                let mut parts = rest.split_whitespace();
                let ipb_log2: u8 = parts.next()?.parse().ok()?;
                let iters_log2: u8 = parts.next()?.parse().ok()?;
                let smem_bucket: u8 = parts.next()?.parse().ok()?;
                let bits = u64::from_str_radix(parts.next()?, 16).ok()?;
                if parts.next().is_some() {
                    return None;
                }
                map.get_mut(routine)?.insert(
                    EnvKey {
                        ipb_log2,
                        iters_log2,
                        smem_bucket,
                    },
                    f64::from_bits(bits),
                );
            } else {
                return None;
            }
        }
        if map.is_empty() {
            return None;
        }
        Some(RoutineDb { map })
    }
}

/// First line of the calibration cache. The version bumps whenever the
/// calibration *algorithm* (micro-plans, environment grid, simulator)
/// changes in a way the library fingerprint cannot see.
const CALIBRATION_HEADER: &str = "# fusebla calibration v1";

/// File-name-safe form of a device name: lowercase, runs of
/// non-alphanumerics collapsed to single dashes
/// (`"GeForce GTX 480 (model)"` → `"geforce-gtx-480-model"`). Distinct
/// device names can collide here ("GTX 480" vs "gtx-480") — a fleet
/// registry rejects such rosters up front, since colliding files would
/// ping-pong each other's caches.
pub fn sanitize_device(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for c in name.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c.to_ascii_lowercase());
        } else if !out.is_empty() && !out.ends_with('-') {
            out.push('-');
        }
    }
    while out.ends_with('-') {
        out.pop();
    }
    if out.is_empty() {
        out.push_str("device");
    }
    out
}

/// Per-device calibration cache file: `dir/calibration.<sanitized>.txt`.
pub fn calibration_path(dir: &Path, device: &str) -> std::path::PathBuf {
    dir.join(format!("calibration.{}.txt", sanitize_device(device)))
}

/// Predicted runtime of one kernel: `max(Σ t_transfer, Σ t_compute)`.
pub fn predict_kernel(db: &RoutineDb, plan: &KernelPlan, p: ProblemSize) -> f64 {
    let instances = plan.instances.eval(p).max(0.0);
    let env = EnvKey::new(
        plan.grid.instances_per_block,
        plan.grid.iters,
        plan.smem_words,
    );
    let mut t_t = 0.0;
    let mut t_c = 0.0;
    for s in &plan.steps {
        let per_inst = db
            .lookup(&s.op.routine_name, env)
            .unwrap_or_else(|| panic!("routine '{}' not calibrated", s.op.routine_name));
        // hoisted steps run once per block instead of once per instance
        let count = match s.hoist {
            Hoist::InLoop => instances,
            _ => instances / (plan.grid.iters as f64 * plan.grid.instances_per_block as f64),
        };
        if s.op.kind.is_transfer() {
            t_t += per_inst * count;
        } else {
            t_c += per_inst * count;
        }
    }
    t_t.max(t_c)
}

/// Predicted runtime of a sequence. Deliberately ignores launch overhead
/// (the paper's acknowledged systematic error that misranks AXPYDOT).
pub fn predict_seq(db: &RoutineDb, plan: &SeqPlan, p: ProblemSize) -> f64 {
    plan.kernels
        .iter()
        .map(|k| predict_kernel(db, k, p))
        .sum()
}

// ----- Cross-kernel cost terms (horizontal fusion) ----------------------
//
// These break the per-kernel additivity `predict_seq` relies on: the cost
// of a combined launch depends on *which* kernels share the grid, because
// padding the block geometry to the widest fragment and sizing shared
// memory / registers to the max can lower occupancy for every fragment.
// The planner therefore treats horizontal pairing as a separate
// segmentation problem (see `planner::forecast_hfuse`), with
// `PlannerConfig::beam` bounding how many pairings are priced.

/// Multiplicative slowdown a member kernel suffers inside a combined
/// launch whose padded resource footprint is `combined` (see
/// `codegen::horizontal::HKernel::footprint`): the ratio of the
/// bandwidth it achieves alone to the bandwidth at the combined
/// occupancy, floored at 1 — sharing a launch never speeds the
/// memory pipeline up, it can only cost occupancy and cache locality.
pub fn hfuse_interference(dev: &DeviceModel, member: &KernelPlan, combined: &KernelPlan) -> f64 {
    let occ_alone = dev.occupancy(member).occupancy;
    let occ_combined = dev.occupancy(combined).occupancy;
    let bw_alone = dev.effective_bandwidth(occ_alone, member.barriers_per_iter);
    let bw_combined = dev.effective_bandwidth(occ_combined, member.barriers_per_iter);
    if bw_combined <= 0.0 || bw_combined.is_nan() {
        return f64::INFINITY;
    }
    (bw_alone / bw_combined).max(1.0)
}

/// Predicted runtime of one combined (horizontally fused) launch: each
/// member's standalone prediction inflated by its interference penalty.
/// The fragments occupy disjoint block ranges of one grid, but on a
/// bandwidth-bound device they drain one shared memory pipeline, so
/// fragment times add — the win over back-to-back comes from the saved
/// launch overheads and driver gaps, not from overlap.
pub fn predict_hfused_stage(
    db: &RoutineDb,
    dev: &DeviceModel,
    combined: &KernelPlan,
    members: &[(&KernelPlan, ProblemSize)],
) -> f64 {
    members
        .iter()
        .map(|&(k, p)| predict_kernel(db, k, p) * hfuse_interference(dev, k, combined))
        .sum()
}

/// Launch-side seconds of issuing `launches` kernels back-to-back:
/// per-launch overhead plus the driver gap between consecutive
/// launches. This is the term `predict_seq` deliberately ignores; the
/// horizontal-fusion forecast must not, because saved launches are the
/// entire upside of combining small kernels.
pub fn launch_seconds(dev: &DeviceModel, launches: u64) -> f64 {
    if launches == 0 {
        return 0.0;
    }
    launches as f64 * dev.launch_overhead + (launches - 1) as f64 * dev.kernel_gap
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen;
    use crate::fusion::{enumerate_fusions, gen_impls, Fusion, FusionImpl, ImplAxes};
    use crate::graph::DepGraph;
    use crate::script::compile_script;
    use crate::sim::simulate_seq;

    fn db() -> (DeviceModel, Library, RoutineDb) {
        let dev = DeviceModel::gtx480();
        let lib = Library::standard();
        let db = RoutineDb::calibrate(&dev, &lib);
        (dev, lib, db)
    }

    #[test]
    fn calibration_covers_all_routines() {
        let (_, lib, db) = db();
        let n_routines: usize = lib
            .names()
            .map(|n| lib.by_name(n).routines.len())
            .sum();
        // 5 ipb × 5 iters × 6 smem = 150 envs per routine
        assert_eq!(db.len(), n_routines * 150);
    }

    #[test]
    fn calibration_is_shareable_across_threads() {
        // The planner's cost fan-out shares one RoutineDb and the
        // per-impl KernelPlans across scoped worker threads; keep that
        // contract explicit at compile time.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<RoutineDb>();
        assert_send_sync::<KernelPlan>();
        assert_send_sync::<crate::ir::elem::ProblemSize>();
    }

    #[test]
    fn calibration_cache_roundtrips_bit_identical() {
        let (dev, _, db) = db();
        let dir = std::env::temp_dir().join(format!("fusebla_cal_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("calibration.txt");
        db.save(&path, &dev.name, 0x1234).unwrap();
        let loaded = RoutineDb::load_cached(&path, &dev.name, 0x1234).expect("cache loads");
        assert_eq!(loaded.len(), db.len());
        for (routine, envs) in &db.map {
            for (k, secs) in envs {
                assert_eq!(
                    loaded.map[routine][k].to_bits(),
                    secs.to_bits(),
                    "{routine}: cached seconds must be bit-identical"
                );
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn calibration_cache_rejects_mismatched_keys() {
        let (dev, _, db) = db();
        let dir = std::env::temp_dir().join(format!("fusebla_calkey_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("calibration.txt");
        db.save(&path, &dev.name, 7).unwrap();
        // wrong device, wrong fingerprint, missing file → all None
        assert!(RoutineDb::load_cached(&path, "some other GPU", 7).is_none());
        assert!(RoutineDb::load_cached(&path, &dev.name, 8).is_none());
        assert!(RoutineDb::load_cached(&dir.join("nope.txt"), &dev.name, 7).is_none());
        // corrupt payload → None (fall back to recalibration)
        std::fs::write(&path, "# fusebla calibration v1\ngarbage\n").unwrap();
        assert!(RoutineDb::load_cached(&path, &dev.name, 7).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn device_name_sanitization() {
        assert_eq!(sanitize_device("GeForce GTX 480 (model)"), "geforce-gtx-480-model");
        assert_eq!(sanitize_device("GeForce GTX 480 (model) #2"), "geforce-gtx-480-model-2");
        assert_eq!(sanitize_device("___"), "device");
        assert_eq!(sanitize_device(""), "device");
    }

    /// The fleet contract: two devices' calibrations persist side by
    /// side in one directory, round-trip bit-identically, and never
    /// overwrite each other.
    #[test]
    fn per_device_caches_roundtrip_side_by_side() {
        let lib = Library::standard();
        let fast = DeviceModel::gtx480();
        let slow = DeviceModel::gt430();
        let dir = std::env::temp_dir().join(format!("fusebla_calfleet_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let db_fast = RoutineDb::load_or_calibrate(&dir, &fast, &lib);
        let db_slow = RoutineDb::load_or_calibrate(&dir, &slow, &lib);
        assert!(calibration_path(&dir, &fast.name).exists());
        assert!(calibration_path(&dir, &slow.name).exists());
        // reload both — each must be bit-identical to its own
        // calibration, not the other device's
        let re_fast = RoutineDb::load_or_calibrate(&dir, &fast, &lib);
        let re_slow = RoutineDb::load_or_calibrate(&dir, &slow, &lib);
        for (db, re) in [(&db_fast, &re_fast), (&db_slow, &re_slow)] {
            assert_eq!(db.len(), re.len());
            for (routine, envs) in &db.map {
                for (k, secs) in envs {
                    assert_eq!(re.map[routine][k].to_bits(), secs.to_bits(), "{routine}");
                }
            }
        }
        // the devices genuinely calibrated differently (the slow part
        // must not silently share the fast part's numbers)
        let probe = db_fast.map.iter().next().map(|(r, _)| r.clone()).unwrap();
        assert!(
            db_fast.map[&probe] != db_slow.map[&probe],
            "distinct devices must calibrate distinctly"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Migration: a pre-fleet shared `calibration.txt` still loads for
    /// the device that wrote it, and the first load rewrites it into
    /// the per-device layout.
    #[test]
    fn legacy_shared_cache_migrates() {
        let lib = Library::standard();
        let dev = DeviceModel::gtx480();
        let dir = std::env::temp_dir().join(format!("fusebla_calmig_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let db = RoutineDb::calibrate(&dev, &lib);
        db.save(&dir.join("calibration.txt"), &dev.name, lib.fingerprint()).unwrap();
        let loaded = RoutineDb::load_or_calibrate(&dir, &dev, &lib);
        assert_eq!(loaded.len(), db.len());
        assert!(
            calibration_path(&dir, &dev.name).exists(),
            "legacy hit must be rewritten into the per-device file"
        );
        // a *different* device never trusts the legacy file
        let other = DeviceModel::gt430();
        let other_db = RoutineDb::load_or_calibrate(&dir, &other, &lib);
        let probe = db.map.iter().next().map(|(r, _)| r.clone()).unwrap();
        assert!(other_db.map[&probe] != db.map[&probe]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn env_bucketing() {
        assert_eq!(EnvKey::new(1, 1, 0), EnvKey::new(1, 1, 0));
        assert_ne!(EnvKey::new(1, 1, 0), EnvKey::new(2, 1, 0));
        assert_eq!(EnvKey::new(4, 8, 3000).smem_bucket, 3);
        assert_eq!(EnvKey::new(16, 16, 20000).smem_bucket, 5);
    }

    #[test]
    fn prediction_correlates_with_simulation() {
        // Prediction must get the big call right: fused BiCGK faster
        // than unfused (its whole purpose in the paper).
        let (dev, lib, db) = db();
        let src = "
            matrix<MxN> A; vector<N> p, s; vector<M> q, r;
            input A, p, r;
            q = sgemv(A, p);
            s = sgemtv(A, r);
            return q, s;
        ";
        let prog = compile_script("bicgk", src, &lib).unwrap();
        let g = DepGraph::build(&prog, &lib);
        let p = ProblemSize::square(8192);

        let f = enumerate_fusions(&prog, &lib, &g).remove(0);
        let fi = gen_impls(&prog, &lib, &g, &f, &ImplAxes::default())
            .into_iter()
            .find(|i| i.iters == 8 && i.variant == vec![0, 0])
            .unwrap();
        let fused = codegen::compile_seq(&prog, &lib, &[fi], "fused");
        let singles: Vec<FusionImpl> = prog
            .call_ids()
            .map(|c| FusionImpl {
                fusion: Fusion::singleton(c, &prog, &lib),
                order: vec![c],
                variant: vec![0],
                ipb: 1,
                iters: 8,
                iter_dim: crate::ir::plan::IterDim::Col,
            })
            .collect();
        let unfused = codegen::compile_seq(&prog, &lib, &singles, "unfused");

        let pf = predict_seq(&db, &fused, p);
        let pu = predict_seq(&db, &unfused, p);
        assert!(pf < pu, "prediction must favor fusion: {pf} vs {pu}");

        // and the prediction should be within 2x of the simulator
        let sf = simulate_seq(&dev, &fused, p, 1.0).seconds;
        assert!(pf / sf > 0.4 && pf / sf < 1.6, "pred {pf} vs sim {sf}");
    }

    #[test]
    fn prediction_ignores_launch_overhead() {
        // Two kernels of near-zero size: prediction ≈ 0, simulation pays
        // launch overhead — the documented AXPYDOT error source.
        let (dev, lib, db) = db();
        let src = "
            vector<N> x, y, z; input x;
            y = sscal(x, alpha=2.0);
            z = sscal(y, alpha=3.0);
            return z;
        ";
        let prog = compile_script("t", src, &lib).unwrap();
        let singles: Vec<FusionImpl> = prog
            .call_ids()
            .map(|c| FusionImpl {
                fusion: Fusion::singleton(c, &prog, &lib),
                order: vec![c],
                variant: vec![0],
                ipb: 4,
                iters: 1,
                iter_dim: crate::ir::plan::IterDim::Elem,
            })
            .collect();
        let plan = codegen::compile_seq(&prog, &lib, &singles, "u");
        let p = ProblemSize::new(32, 1024);
        let pred = predict_seq(&db, &plan, p);
        let sim = simulate_seq(&dev, &plan, p, 1.0).seconds;
        assert!(pred < sim, "prediction should undercut (no launch cost)");
    }

    #[test]
    fn more_smem_predicts_slower_or_equal() {
        // extra shared memory lowers occupancy -> per-instance times in
        // bigger buckets must not be faster
        let (dev, lib, _) = db();
        let f = lib.by_name("sgemv");
        let r = f.load_routine(0);
        let p_ref = ProblemSize::square(4096);
        let t_small = simulate_kernel(&dev, &micro_plan(f, r, 1, 4, 0), p_ref).seconds;
        let t_big = simulate_kernel(&dev, &micro_plan(f, r, 1, 4, 12288), p_ref).seconds;
        assert!(t_big >= t_small);
    }

    fn footprint_plan(threads: (u32, u32), smem_words: u32, regs: u32) -> KernelPlan {
        KernelPlan {
            name: "hf".into(),
            members: vec![],
            grid: GridPlan {
                depth: 1,
                block: threads,
                instances_per_block: 1,
                iters: 1,
                iter_dim: IterDim::Elem,
            },
            smem_words,
            regs_per_thread: regs,
            smem_slots: vec![],
            steps: vec![],
            instances: Poly2::n(1.0 / 32.0),
            traffic: Traffic::default(),
            flops: Poly2::ZERO,
            compute_efficiency: 1.0,
            barriers_per_iter: 0,
        }
    }

    #[test]
    fn hfuse_interference_floors_at_one_for_matching_geometry() {
        let dev = DeviceModel::gtx480();
        let k = footprint_plan((128, 1), 256, 16);
        // combined footprint identical to the member: no penalty
        let pen = hfuse_interference(&dev, &k, &k);
        assert!((pen - 1.0).abs() < 1e-12, "penalty {pen}");
    }

    #[test]
    fn hfuse_interference_penalizes_occupancy_loss() {
        let dev = DeviceModel::gtx480();
        let member = footprint_plan((128, 1), 256, 16);
        // combined launch padded to a fat fragment: 20 KiB smem caps the
        // SM at one resident block, strangling the member's bandwidth
        let combined = footprint_plan((32, 16), 5 * 1024, 40);
        let pen = hfuse_interference(&dev, &member, &combined);
        assert!(pen > 1.0, "mismatched geometry must cost: {pen}");
        // and the penalty is never a speedup, whichever way round
        assert!(hfuse_interference(&dev, &combined, &member) >= 1.0);
    }

    #[test]
    fn hfused_stage_cost_adds_members_with_penalties() {
        let (dev, lib, db) = db();
        let src = "vector<N> x, y; input x; y = sscal(x, alpha=2.0); return y;";
        let prog = compile_script("t", src, &lib).unwrap();
        let singles: Vec<FusionImpl> = prog
            .call_ids()
            .map(|c| FusionImpl {
                fusion: Fusion::singleton(c, &prog, &lib),
                order: vec![c],
                variant: vec![0],
                ipb: 4,
                iters: 1,
                iter_dim: crate::ir::plan::IterDim::Elem,
            })
            .collect();
        let plan = codegen::compile_seq(&prog, &lib, &singles, "u");
        let k = &plan.kernels[0];
        let p = ProblemSize::new(1, 65536);
        let alone = predict_kernel(&db, k, p);
        // identical fragments share a launch: cost ≈ 2× one fragment
        let two = predict_hfused_stage(&db, &dev, k, &[(k, p), (k, p)]);
        assert!((two - 2.0 * alone).abs() < 1e-12 * two.max(1.0), "{two} vs {alone}");
        // a hostile combined footprint only ever raises the cost
        let fat = footprint_plan((32, 16), 5 * 1024, 40);
        let strained = predict_hfused_stage(&db, &dev, &fat, &[(k, p), (k, p)]);
        assert!(strained >= two);
    }

    #[test]
    fn launch_seconds_counts_overheads_and_gaps() {
        let dev = DeviceModel::gtx480();
        assert_eq!(launch_seconds(&dev, 0), 0.0);
        assert_eq!(launch_seconds(&dev, 1), dev.launch_overhead);
        let three = launch_seconds(&dev, 3);
        assert!((three - (3.0 * dev.launch_overhead + 2.0 * dev.kernel_gap)).abs() < 1e-18);
        // saving a launch saves overhead + gap — the hfuse upside
        assert!(launch_seconds(&dev, 3) > launch_seconds(&dev, 2));
    }
}
