//! Recursive-descent parser producing a raw AST (resolution against the
//! library happens in [`super::typecheck`]).

use super::lexer::{Lexer, Token, TokenKind};
use super::ScriptError;

/// Declared surface type of a variable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AstType {
    Scalar,
    /// `vector<M>` / `vector<N>`; `None` = `subvector32` (dims inferred).
    Vector(Option<String>),
    /// `matrix<MxN>`; `None` = `TILE32x32` (defaults to M×N).
    Matrix(Option<(String, String)>),
}

#[derive(Clone, Debug)]
pub struct AstDecl {
    pub ty: AstType,
    pub names: Vec<String>,
    pub line: usize,
}

#[derive(Clone, Debug)]
pub struct AstCall {
    pub out: String,
    pub func: String,
    pub args: Vec<String>,
    /// `name = literal` scalar bindings, in call order.
    pub scalars: Vec<(String, f32)>,
    pub line: usize,
}

#[derive(Clone, Debug, Default)]
pub struct Ast {
    pub decls: Vec<AstDecl>,
    pub inputs: Vec<(String, usize)>,
    pub calls: Vec<AstCall>,
    pub returns: Vec<(String, usize)>,
}

struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Token {
        &self.toks[self.pos]
    }

    fn bump(&mut self) -> Token {
        let t = self.toks[self.pos].clone();
        if self.pos + 1 < self.toks.len() {
            self.pos += 1;
        }
        t
    }

    fn expect(&mut self, kind: &TokenKind, what: &str) -> Result<Token, ScriptError> {
        let t = self.bump();
        if std::mem::discriminant(&t.kind) == std::mem::discriminant(kind) {
            Ok(t)
        } else {
            Err(ScriptError::new(
                t.line,
                format!("expected {what}, found {:?}", t.kind),
            ))
        }
    }

    fn ident(&mut self, what: &str) -> Result<(String, usize), ScriptError> {
        let t = self.bump();
        match t.kind {
            TokenKind::Ident(s) => Ok((s, t.line)),
            other => Err(ScriptError::new(
                t.line,
                format!("expected {what}, found {other:?}"),
            )),
        }
    }

    fn ident_list(&mut self) -> Result<Vec<(String, usize)>, ScriptError> {
        let mut out = vec![self.ident("identifier")?];
        while self.peek().kind == TokenKind::Comma {
            self.bump();
            out.push(self.ident("identifier")?);
        }
        Ok(out)
    }

    /// Parse `<X>` or `<XxY>` dimension annotation.
    fn angle_dims(&mut self) -> Result<(String, usize), ScriptError> {
        self.expect(&TokenKind::LAngle, "'<'")?;
        let (dims, line) = self.ident("dimension")?;
        self.expect(&TokenKind::RAngle, "'>'")?;
        Ok((dims, line))
    }

    fn parse_decl_or_call(&mut self, ast: &mut Ast) -> Result<(), ScriptError> {
        let (word, line) = self.ident("statement")?;
        match word.as_str() {
            "scalar" => {
                let names = self.ident_list()?;
                self.expect(&TokenKind::Semi, "';'")?;
                ast.decls.push(AstDecl {
                    ty: AstType::Scalar,
                    names: names.into_iter().map(|(n, _)| n).collect(),
                    line,
                });
            }
            "vector" => {
                let (d, dline) = self.angle_dims()?;
                if d != "M" && d != "N" {
                    return Err(ScriptError::new(
                        dline,
                        format!("vector dimension must be M or N, got '{d}'"),
                    ));
                }
                let names = self.ident_list()?;
                self.expect(&TokenKind::Semi, "';'")?;
                ast.decls.push(AstDecl {
                    ty: AstType::Vector(Some(d)),
                    names: names.into_iter().map(|(n, _)| n).collect(),
                    line,
                });
            }
            "subvector32" => {
                let names = self.ident_list()?;
                self.expect(&TokenKind::Semi, "';'")?;
                ast.decls.push(AstDecl {
                    ty: AstType::Vector(None),
                    names: names.into_iter().map(|(n, _)| n).collect(),
                    line,
                });
            }
            "matrix" => {
                let (d, dline) = self.angle_dims()?;
                let parts: Vec<&str> = d.split('x').collect();
                if parts.len() != 2 || parts.iter().any(|p| *p != "M" && *p != "N") {
                    return Err(ScriptError::new(
                        dline,
                        format!("matrix dims must be like MxN, got '{d}'"),
                    ));
                }
                let names = self.ident_list()?;
                self.expect(&TokenKind::Semi, "';'")?;
                ast.decls.push(AstDecl {
                    ty: AstType::Matrix(Some((parts[0].into(), parts[1].into()))),
                    names: names.into_iter().map(|(n, _)| n).collect(),
                    line,
                });
            }
            "TILE32x32" => {
                let names = self.ident_list()?;
                self.expect(&TokenKind::Semi, "';'")?;
                ast.decls.push(AstDecl {
                    ty: AstType::Matrix(None),
                    names: names.into_iter().map(|(n, _)| n).collect(),
                    line,
                });
            }
            "input" => {
                let names = self.ident_list()?;
                self.expect(&TokenKind::Semi, "';'")?;
                ast.inputs.extend(names);
            }
            "return" => {
                let names = self.ident_list()?;
                self.expect(&TokenKind::Semi, "';'")?;
                ast.returns.extend(names);
            }
            out_var => {
                // assignment: out = func(args…);
                self.expect(&TokenKind::Eq, "'='")?;
                let (func, _) = self.ident("function name")?;
                self.expect(&TokenKind::LParen, "'('")?;
                let mut args = Vec::new();
                let mut scalars = Vec::new();
                if self.peek().kind != TokenKind::RParen {
                    loop {
                        let (name, aline) = self.ident("argument")?;
                        if self.peek().kind == TokenKind::Eq {
                            self.bump();
                            let t = self.bump();
                            match t.kind {
                                TokenKind::Number(v) => scalars.push((name, v)),
                                other => {
                                    return Err(ScriptError::new(
                                        t.line,
                                        format!("scalar binding needs a number, found {other:?}"),
                                    ))
                                }
                            }
                        } else {
                            if !scalars.is_empty() {
                                return Err(ScriptError::new(
                                    aline,
                                    "positional argument after scalar binding".to_string(),
                                ));
                            }
                            args.push(name);
                        }
                        match self.bump() {
                            Token {
                                kind: TokenKind::Comma,
                                ..
                            } => continue,
                            Token {
                                kind: TokenKind::RParen,
                                ..
                            } => break,
                            t => {
                                return Err(ScriptError::new(
                                    t.line,
                                    format!("expected ',' or ')', found {:?}", t.kind),
                                ))
                            }
                        }
                    }
                } else {
                    self.bump(); // ')'
                }
                self.expect(&TokenKind::Semi, "';'")?;
                ast.calls.push(AstCall {
                    out: out_var.to_string(),
                    func,
                    args,
                    scalars,
                    line,
                });
            }
        }
        Ok(())
    }
}

/// Parse a script source into an AST.
pub fn parse(src: &str) -> Result<Ast, ScriptError> {
    let toks = Lexer::new(src).tokenize()?;
    let mut p = Parser { toks, pos: 0 };
    let mut ast = Ast::default();
    while p.peek().kind != TokenKind::Eof {
        p.parse_decl_or_call(&mut ast)?;
    }
    if ast.calls.is_empty() {
        return Err(ScriptError::new(0, "script has no calls"));
    }
    if ast.returns.is_empty() {
        return Err(ScriptError::new(0, "script has no return statement"));
    }
    Ok(ast)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_bicgk() {
        let ast = parse(
            "matrix<MxN> A; vector<N> p, s; vector<M> q, r;
             input A, p, r;
             q = sgemv(A, p);
             s = sgemtv(A, r);
             return q, s;",
        )
        .unwrap();
        assert_eq!(ast.decls.len(), 3);
        assert_eq!(ast.calls.len(), 2);
        assert_eq!(ast.inputs.len(), 3);
        assert_eq!(ast.returns.len(), 2);
        assert_eq!(ast.calls[0].func, "sgemv");
        assert_eq!(ast.calls[0].args, vec!["A", "p"]);
    }

    #[test]
    fn parses_scalar_bindings() {
        let ast = parse(
            "vector<N> w, v, z; input w, v;
             z = waxpby(w, v, alpha=1.0, beta=-2.5);
             return z;",
        )
        .unwrap();
        assert_eq!(
            ast.calls[0].scalars,
            vec![("alpha".into(), 1.0), ("beta".into(), -2.5)]
        );
    }

    #[test]
    fn positional_after_scalar_rejected() {
        let err = parse(
            "vector<N> a, b, c; input a, b;
             c = waxpby(a, alpha=1.0, b); return c;",
        )
        .unwrap_err();
        assert!(err.msg.contains("positional"), "{err}");
    }

    #[test]
    fn empty_script_rejected() {
        assert!(parse("").is_err());
        assert!(parse("vector<N> x; input x;").is_err()); // no calls
    }

    #[test]
    fn missing_return_rejected() {
        let err = parse("vector<N> x, y; input x; y = sscal(x, alpha=2.0);").unwrap_err();
        assert!(err.msg.contains("return"), "{err}");
    }

    #[test]
    fn bad_matrix_dims_rejected() {
        let err = parse("matrix<MxK> A; input A; b = f(A); return b;").unwrap_err();
        assert!(err.msg.contains("MxN"), "{err}");
    }

    #[test]
    fn tile_alias_accepted() {
        let ast = parse("TILE32x32 A; subvector32 x, y; input A, x; y = sgemv(A, x); return y;")
            .unwrap();
        assert_eq!(ast.decls[0].ty, AstType::Matrix(None));
        assert_eq!(ast.decls[1].ty, AstType::Vector(None));
    }
}
