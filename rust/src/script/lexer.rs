//! Tokenizer for the script DSL. Hash comments run to end of line.

use super::ScriptError;

#[derive(Clone, Debug, PartialEq)]
pub enum TokenKind {
    Ident(String),
    Number(f32),
    LParen,
    RParen,
    LAngle,
    RAngle,
    Comma,
    Semi,
    Eq,
    Eof,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub kind: TokenKind,
    pub line: usize,
}

pub struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: usize,
}

impl<'a> Lexer<'a> {
    pub fn new(src: &'a str) -> Self {
        Lexer {
            src: src.as_bytes(),
            pos: 0,
            line: 1,
        }
    }

    pub fn tokenize(mut self) -> Result<Vec<Token>, ScriptError> {
        let mut out = Vec::new();
        loop {
            let t = self.next_token()?;
            let eof = t.kind == TokenKind::Eof;
            out.push(t);
            if eof {
                return Ok(out);
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        if c == b'\n' {
            self.line += 1;
        }
        Some(c)
    }

    fn skip_trivia(&mut self) {
        loop {
            match self.peek() {
                Some(c) if c.is_ascii_whitespace() => {
                    self.bump();
                }
                Some(b'#') => {
                    while let Some(c) = self.peek() {
                        if c == b'\n' {
                            break;
                        }
                        self.bump();
                    }
                }
                _ => return,
            }
        }
    }

    fn next_token(&mut self) -> Result<Token, ScriptError> {
        self.skip_trivia();
        let line = self.line;
        let tok = |kind| Ok(Token { kind, line });
        let c = match self.peek() {
            None => return tok(TokenKind::Eof),
            Some(c) => c,
        };
        match c {
            b'(' => {
                self.bump();
                tok(TokenKind::LParen)
            }
            b')' => {
                self.bump();
                tok(TokenKind::RParen)
            }
            b'<' => {
                self.bump();
                tok(TokenKind::LAngle)
            }
            b'>' => {
                self.bump();
                tok(TokenKind::RAngle)
            }
            b',' => {
                self.bump();
                tok(TokenKind::Comma)
            }
            b';' => {
                self.bump();
                tok(TokenKind::Semi)
            }
            b'=' => {
                self.bump();
                tok(TokenKind::Eq)
            }
            c if c.is_ascii_alphabetic() || c == b'_' => {
                let start = self.pos;
                while let Some(c) = self.peek() {
                    if c.is_ascii_alphanumeric() || c == b'_' {
                        self.bump();
                    } else {
                        break;
                    }
                }
                let s = std::str::from_utf8(&self.src[start..self.pos])
                    .unwrap()
                    .to_string();
                tok(TokenKind::Ident(s))
            }
            c if c.is_ascii_digit() || c == b'-' || c == b'.' => {
                let start = self.pos;
                self.bump();
                while let Some(c) = self.peek() {
                    if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' {
                        self.bump();
                    } else if (c == b'-' || c == b'+')
                        && matches!(self.src.get(self.pos - 1), Some(b'e') | Some(b'E'))
                    {
                        self.bump();
                    } else {
                        break;
                    }
                }
                let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
                let v: f32 = text
                    .parse()
                    .map_err(|_| ScriptError::new(line, format!("bad number '{text}'")))?;
                tok(TokenKind::Number(v))
            }
            other => Err(ScriptError::new(
                line,
                format!("unexpected character '{}'", other as char),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokenKind> {
        Lexer::new(src)
            .tokenize()
            .unwrap()
            .into_iter()
            .map(|t| t.kind)
            .collect()
    }

    #[test]
    fn lexes_declaration() {
        let k = kinds("matrix<MxN> A;");
        assert_eq!(
            k,
            vec![
                TokenKind::Ident("matrix".into()),
                TokenKind::LAngle,
                TokenKind::Ident("MxN".into()),
                TokenKind::RAngle,
                TokenKind::Ident("A".into()),
                TokenKind::Semi,
                TokenKind::Eof
            ]
        );
    }

    #[test]
    fn lexes_call_with_scalars() {
        let k = kinds("z = waxpby(w, v, beta=-2.5);");
        assert!(k.contains(&TokenKind::Number(-2.5)));
        assert!(k.contains(&TokenKind::Eq));
    }

    #[test]
    fn comments_are_skipped() {
        let k = kinds("# a comment\nx; # trailing\n");
        assert_eq!(
            k,
            vec![
                TokenKind::Ident("x".into()),
                TokenKind::Semi,
                TokenKind::Eof
            ]
        );
    }

    #[test]
    fn line_numbers_advance() {
        let toks = Lexer::new("a\nb\n\nc").tokenize().unwrap();
        let lines: Vec<usize> = toks.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4, 4]);
    }

    #[test]
    fn scientific_notation() {
        assert_eq!(kinds("1.5e-3")[0], TokenKind::Number(0.0015));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Lexer::new("a @ b").tokenize().is_err());
    }
}
