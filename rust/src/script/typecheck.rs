//! Resolve an AST against the library: bind calls to elementary
//! functions, check SSA discipline and element types, and infer the
//! symbolic dimension (`M`/`N`) of every vector variable from the
//! function signatures.

use super::parser::{Ast, AstType};
use super::ScriptError;
use crate::ir::elem::{DimSym, VarType};
use crate::ir::func::{ElemFunc, Ix};
use crate::ir::program::{Call, Program, VarDecl, VarId};
use crate::library::Library;
use std::collections::{BTreeMap, BTreeSet};

/// Local dimension slots of a function signature: depth-2 functions use
/// Row/Col; depth-1 functions use a single Elem slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
enum Slot {
    Row,
    Col,
    Elem,
}

fn param_slots(f: &ElemFunc, ix: Ix) -> Vec<Slot> {
    match (f.depth(), ix) {
        (_, Ix::None) => vec![],
        (1, _) => vec![Slot::Elem],
        (2, Ix::Row) => vec![Slot::Row],
        (2, Ix::Col) => vec![Slot::Col],
        (2, Ix::Both) => vec![Slot::Row, Slot::Col],
        _ => unreachable!("validated in library"),
    }
}

pub fn typecheck(name: &str, ast: &Ast, lib: &Library) -> Result<Program, ScriptError> {
    let mut prog = Program {
        name: name.to_string(),
        ..Default::default()
    };
    let mut declared: BTreeMap<String, VarId> = BTreeMap::new();

    // 1. Declarations. Dims may start unknown (paper-style aliases).
    for d in &ast.decls {
        for n in &d.names {
            if declared.contains_key(n) {
                return Err(ScriptError::new(d.line, format!("'{n}' declared twice")));
            }
            let (ty, dims) = match &d.ty {
                AstType::Scalar => (VarType::Scalar, vec![]),
                AstType::Vector(Some(dim)) => (VarType::Vector, vec![DimSym::new(dim)]),
                AstType::Vector(None) => (VarType::Vector, vec![]), // inferred
                AstType::Matrix(Some((r, c))) => {
                    (VarType::Matrix, vec![DimSym::new(r), DimSym::new(c)])
                }
                AstType::Matrix(None) => {
                    (VarType::Matrix, vec![DimSym::new("M"), DimSym::new("N")])
                }
            };
            let id = VarId(prog.vars.len());
            prog.vars.push(VarDecl {
                name: n.clone(),
                ty,
                dims,
            });
            declared.insert(n.clone(), id);
        }
    }

    // 2. Inputs.
    for (n, line) in &ast.inputs {
        let id = *declared
            .get(n)
            .ok_or_else(|| ScriptError::new(*line, format!("input '{n}' undeclared")))?;
        if prog.inputs.contains(&id) {
            return Err(ScriptError::new(*line, format!("input '{n}' listed twice")));
        }
        prog.inputs.push(id);
    }

    // 3. Calls: resolve, type-check, infer dims.
    let mut produced: BTreeSet<VarId> = BTreeSet::new();
    for c in &ast.calls {
        let fid = lib.lookup(&c.func).ok_or_else(|| {
            ScriptError::new(c.line, format!("unknown library function '{}'", c.func))
        })?;
        let f = lib.get(fid);

        let out_id = *declared
            .get(&c.out)
            .ok_or_else(|| ScriptError::new(c.line, format!("undeclared output '{}'", c.out)))?;
        if produced.contains(&out_id) {
            return Err(ScriptError::new(
                c.line,
                format!("'{}' assigned more than once (scripts are SSA)", c.out),
            ));
        }
        if prog.inputs.contains(&out_id) {
            return Err(ScriptError::new(
                c.line,
                format!("'{}' is an input and cannot be assigned", c.out),
            ));
        }
        if c.args.len() != f.inputs.len() {
            return Err(ScriptError::new(
                c.line,
                format!(
                    "{} takes {} arguments, got {}",
                    f.name,
                    f.inputs.len(),
                    c.args.len()
                ),
            ));
        }
        if f.outputs.len() != 1 {
            return Err(ScriptError::new(
                c.line,
                format!("{} must have exactly one output", f.name),
            ));
        }

        let mut arg_ids = Vec::with_capacity(c.args.len());
        for (a, p) in c.args.iter().zip(f.inputs.iter()) {
            let id = *declared
                .get(a)
                .ok_or_else(|| ScriptError::new(c.line, format!("undeclared variable '{a}'")))?;
            let v = prog.var(id);
            if v.ty.elem() != p.elem {
                return Err(ScriptError::new(
                    c.line,
                    format!(
                        "argument '{a}' of {} must be {}, got {}",
                        f.name,
                        p.elem,
                        v.ty.elem()
                    ),
                ));
            }
            if !prog.inputs.contains(&id) && !produced.contains(&id) {
                return Err(ScriptError::new(
                    c.line,
                    format!("'{a}' is neither an input nor produced by an earlier call"),
                ));
            }
            arg_ids.push(id);
        }
        let outp = &f.outputs[0];
        if prog.var(out_id).ty.elem() != outp.elem {
            return Err(ScriptError::new(
                c.line,
                format!(
                    "output '{}' of {} must be {}, got {}",
                    c.out,
                    f.name,
                    outp.elem,
                    prog.var(out_id).ty.elem()
                ),
            ));
        }

        // Dimension inference: bind Row/Col/Elem slots from known dims,
        // then write back to unknown dims.
        let mut slot_bind: BTreeMap<Slot, DimSym> = BTreeMap::new();
        let all: Vec<(VarId, Vec<Slot>)> = arg_ids
            .iter()
            .zip(f.inputs.iter())
            .map(|(&id, p)| (id, param_slots(f, p.ix)))
            .chain(std::iter::once((out_id, param_slots(f, outp.ix))))
            .collect();
        // pass 1: bind from knowns
        for (id, slots) in &all {
            let v = prog.var(*id);
            if v.dims.len() == slots.len() {
                for (slot, dim) in slots.iter().zip(v.dims.iter()) {
                    if let Some(prev) = slot_bind.get(slot) {
                        if prev != dim {
                            return Err(ScriptError::new(
                                c.line,
                                format!(
                                    "dimension mismatch in call to {}: '{}' wants {} where {} was bound",
                                    f.name, v.name, dim, prev
                                ),
                            ));
                        }
                    } else {
                        slot_bind.insert(*slot, dim.clone());
                    }
                }
            }
        }
        // default unbound depth-1 elem slot to N (pure BLAS-1 scripts)
        slot_bind.entry(Slot::Elem).or_insert_with(|| DimSym::new("N"));
        // pass 2: write back to unknowns
        for (id, slots) in &all {
            if prog.var(*id).dims.is_empty() && !slots.is_empty() {
                let mut dims = Vec::with_capacity(slots.len());
                for slot in slots {
                    let d = slot_bind.get(slot).ok_or_else(|| {
                        ScriptError::new(
                            c.line,
                            format!(
                                "cannot infer dimension of '{}' in call to {}",
                                prog.var(*id).name,
                                f.name
                            ),
                        )
                    })?;
                    dims.push(d.clone());
                }
                prog.vars[id.0].dims = dims;
            }
        }
        // pass 3: re-verify all now-known dims agree (conflict detection
        // for vars that were known all along)
        for (id, slots) in &all {
            let v = prog.var(*id);
            if v.dims.len() != slots.len() {
                return Err(ScriptError::new(
                    c.line,
                    format!(
                        "'{}' has rank {} but {} expects rank {}",
                        v.name,
                        v.dims.len(),
                        f.name,
                        slots.len()
                    ),
                ));
            }
            for (slot, dim) in slots.iter().zip(v.dims.iter()) {
                if slot_bind.get(slot) != Some(dim) {
                    return Err(ScriptError::new(
                        c.line,
                        format!(
                            "dimension mismatch: '{}' is {}-dimensioned, inconsistent with call to {}",
                            v.name, dim, f.name
                        ),
                    ));
                }
            }
        }

        // Scalar bindings: every named scalar must exist; unbound default 1.0.
        let mut scalar_args = BTreeMap::new();
        for (sname, val) in &c.scalars {
            if !f.scalars.contains(sname) {
                return Err(ScriptError::new(
                    c.line,
                    format!("{} has no scalar parameter '{sname}'", f.name),
                ));
            }
            if scalar_args.insert(sname.clone(), *val).is_some() {
                return Err(ScriptError::new(
                    c.line,
                    format!("scalar '{sname}' bound twice"),
                ));
            }
        }
        for s in &f.scalars {
            scalar_args.entry(s.clone()).or_insert(1.0);
        }

        produced.insert(out_id);
        prog.calls.push(Call {
            func: fid,
            args: arg_ids,
            outs: vec![out_id],
            scalar_args,
        });
    }

    // 4. Returns.
    for (n, line) in &ast.returns {
        let id = *declared
            .get(n)
            .ok_or_else(|| ScriptError::new(*line, format!("returned '{n}' undeclared")))?;
        if !produced.contains(&id) && !prog.inputs.contains(&id) {
            return Err(ScriptError::new(
                *line,
                format!("returned '{n}' is never produced"),
            ));
        }
        prog.outputs.push(id);
    }

    // 5. Dead code: every call must (transitively) feed a return.
    let mut live: BTreeSet<VarId> = prog.outputs.iter().copied().collect();
    for c in prog.calls.iter().rev() {
        if c.outs.iter().any(|o| live.contains(o)) {
            live.extend(c.args.iter().copied());
        }
    }
    for (i, c) in prog.calls.iter().enumerate() {
        if !c.outs.iter().any(|o| live.contains(o)) {
            return Err(ScriptError::new(
                ast.calls[i].line,
                format!(
                    "result of call to {} is never used",
                    lib.get(c.func).name
                ),
            ));
        }
    }

    Ok(prog)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::script::parse;

    fn check(src: &str) -> Result<Program, ScriptError> {
        let lib = Library::standard();
        typecheck("t", &parse(src).unwrap(), &lib)
    }

    #[test]
    fn infers_vector_dims_from_gemv() {
        let p = check(
            "TILE32x32 A; subvector32 x, y; input A, x;
             y = sgemv(A, x); return y;",
        )
        .unwrap();
        assert_eq!(p.var(p.var_id("x").unwrap()).dims[0].0, "N");
        assert_eq!(p.var(p.var_id("y").unwrap()).dims[0].0, "M");
    }

    #[test]
    fn blas1_defaults_to_n() {
        let p = check(
            "subvector32 w, y, z, x; input w, y, z;
             x = vadd3(w, y, z); return x;",
        )
        .unwrap();
        assert_eq!(p.var(p.var_id("x").unwrap()).dims[0].0, "N");
    }

    #[test]
    fn ssa_violation_rejected() {
        let err = check(
            "vector<N> x, y; input x;
             y = sscal(x, alpha=2.0);
             y = sscal(x, alpha=3.0);
             return y;",
        )
        .unwrap_err();
        assert!(err.msg.contains("SSA"), "{err}");
    }

    #[test]
    fn assigning_input_rejected() {
        let err = check(
            "vector<N> x, y; input x, y;
             y = sscal(x, alpha=2.0); return y;",
        )
        .unwrap_err();
        assert!(err.msg.contains("cannot be assigned"), "{err}");
    }

    #[test]
    fn elem_type_mismatch_rejected() {
        let err = check(
            "matrix<MxN> A, B; vector<N> x; input A, x;
             B = sscal(A, alpha=2.0); return B;",
        )
        .unwrap_err();
        assert!(err.msg.contains("must be subvector32"), "{err}");
    }

    #[test]
    fn arity_mismatch_rejected() {
        let err = check(
            "vector<N> x, y; input x;
             y = vadd2(x); return y;",
        )
        .unwrap_err();
        assert!(err.msg.contains("takes 2 arguments"), "{err}");
    }

    #[test]
    fn unknown_scalar_rejected() {
        let err = check(
            "vector<N> x, y; input x;
             y = sscal(x, gamma=2.0); return y;",
        )
        .unwrap_err();
        assert!(err.msg.contains("no scalar parameter"), "{err}");
    }

    #[test]
    fn scalars_default_to_one() {
        let p = check(
            "matrix<MxN> A; vector<N> x; vector<M> y; input A, x;
             y = sgemv(A, x); return y;",
        )
        .unwrap();
        assert_eq!(p.calls[0].scalar_args["alpha"], 1.0);
    }

    #[test]
    fn dead_call_rejected() {
        let err = check(
            "vector<N> x, y, z; input x;
             y = sscal(x, alpha=2.0);
             z = sscal(x, alpha=3.0);
             return z;",
        )
        .unwrap_err();
        assert!(err.msg.contains("never used"), "{err}");
    }

    #[test]
    fn dot_produces_scalar() {
        let p = check(
            "vector<N> x, y; scalar r; input x, y;
             r = sdot(x, y); return r;",
        )
        .unwrap();
        assert_eq!(p.var(p.var_id("r").unwrap()).ty, VarType::Scalar);
        assert!(p.var(p.var_id("r").unwrap()).dims.is_empty());
    }

    #[test]
    fn transposed_dims_infer() {
        // ATAX: t = A x (t: M), y = Aᵀ t (y: N)
        let p = check(
            "matrix<MxN> A; subvector32 x, t, y; input A, x;
             t = sgemv(A, x);
             y = sgemtv(A, t);
             return y;",
        )
        .unwrap();
        assert_eq!(p.var(p.var_id("t").unwrap()).dims[0].0, "M");
        assert_eq!(p.var(p.var_id("y").unwrap()).dims[0].0, "N");
    }
}
