//! The input script language (paper Listing 1).
//!
//! A script declares typed variables, marks inputs, calls elementary
//! functions from the [`crate::library::Library`], and returns results:
//!
//! ```text
//! # BiCGK sequence
//! matrix<MxN> A;
//! vector<N> p, s;
//! vector<M> q, r;
//!
//! input A, p, r;
//! q = sgemv(A, p);
//! s = sgemtv(A, r);
//! return q, s;
//! ```
//!
//! The paper's surface syntax (`TILE32x32 A; subvector32 p;`) is accepted
//! as aliases; vector dimensions are then inferred from the function
//! signatures (GEMV forces its input to `N` and output to `M`, etc.).
//!
//! Scalar coefficients are bound by name inside calls:
//! `z = waxpby(w, v, alpha=1.0, beta=-2.5);`.

mod lexer;
mod parser;
mod typecheck;

pub use lexer::{Lexer, Token, TokenKind};
pub use parser::{parse, Ast, AstCall, AstDecl, AstType};
pub use typecheck::typecheck;

use crate::ir::program::Program;
use crate::library::Library;

/// Parse and typecheck a script against a library.
pub fn compile_script(name: &str, src: &str, lib: &Library) -> Result<Program, ScriptError> {
    let ast = parse(src)?;
    typecheck(name, &ast, lib)
}

/// A script-level error with a line number.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScriptError {
    pub line: usize,
    pub msg: String,
}

impl ScriptError {
    pub fn new(line: usize, msg: impl Into<String>) -> Self {
        ScriptError {
            line,
            msg: msg.into(),
        }
    }
}

impl std::fmt::Display for ScriptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "script line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ScriptError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::elem::VarType;

    const BICGK: &str = "
        matrix<MxN> A;
        vector<N> p, s;
        vector<M> q, r;
        input A, p, r;
        q = sgemv(A, p);
        s = sgemtv(A, r);
        return q, s;
    ";

    #[test]
    fn bicgk_compiles() {
        let lib = Library::standard();
        let p = compile_script("bicgk", BICGK, &lib).unwrap();
        assert_eq!(p.calls.len(), 2);
        assert_eq!(p.inputs.len(), 3);
        assert_eq!(p.outputs.len(), 2);
        assert_eq!(p.var(p.var_id("A").unwrap()).ty, VarType::Matrix);
    }

    #[test]
    fn paper_style_aliases() {
        let lib = Library::standard();
        let src = "
            TILE32x32 A;
            subvector32 p, q, r, s;
            input A, p, r;
            q = sgemv(A, p);
            s = sgemtv(A, r);
            return q, s;
        ";
        let p = compile_script("bicgk", src, &lib).unwrap();
        // Dims inferred: q is M-dim (gemv output), s is N-dim.
        let q = p.var(p.var_id("q").unwrap());
        let s = p.var(p.var_id("s").unwrap());
        assert_eq!(q.dims[0].0, "M");
        assert_eq!(s.dims[0].0, "N");
    }

    #[test]
    fn scalar_binding() {
        let lib = Library::standard();
        let src = "
            vector<N> w, v, z;
            input w, v;
            z = waxpby(w, v, alpha=1.0, beta=-2.5);
            return z;
        ";
        let p = compile_script("t", src, &lib).unwrap();
        assert_eq!(p.calls[0].scalar_args["beta"], -2.5);
    }

    #[test]
    fn undeclared_variable_rejected() {
        let lib = Library::standard();
        let src = "
            vector<N> x;
            input x;
            y = sscal(x, alpha=2.0);
            return y;
        ";
        let err = compile_script("t", src, &lib).unwrap_err();
        assert!(err.msg.contains("undeclared"), "{err}");
    }

    #[test]
    fn use_before_def_rejected() {
        let lib = Library::standard();
        let src = "
            vector<N> x, y, z;
            input x;
            z = vadd2(x, y);
            return z;
        ";
        let err = compile_script("t", src, &lib).unwrap_err();
        assert!(err.msg.contains("neither an input nor produced"), "{err}");
    }

    #[test]
    fn dim_conflict_rejected() {
        let lib = Library::standard();
        // q declared N-dim but gemv output must be M-dim.
        let src = "
            matrix<MxN> A;
            vector<N> p, q;
            input A, p;
            q = sgemv(A, p);
            return q;
        ";
        let err = compile_script("t", src, &lib).unwrap_err();
        assert!(err.msg.contains("dimension"), "{err}");
    }
}
