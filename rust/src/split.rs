//! Row-block splitting of one request across G devices (the paper §6
//! future-work direction the serve path executes, not just simulates).
//!
//! A BLAS-2 sequence whose matrix/vector operands carry their `M`
//! dimension *leading* can be row-blocked: each of G lanes executes the
//! full sequence over an `m/G`-row slab, and the owning lane combines.
//! What combines how is exactly the paper's map/reduce distinction:
//!
//! * outputs with a leading `M` partition cleanly — concatenate the
//!   per-lane row blocks in block order (**bit-identical** to
//!   single-device execution: the N-reduction inside each row is
//!   untouched);
//! * `M`-free outputs *derived from* `M`-bearing data are per-lane
//!   partials of a reduction over rows (`sgemtv`, dot-over-M) —
//!   summed in fixed block order, so the result is deterministic but
//!   may differ from single-device execution in the last bits (a
//!   different, equally valid reduction order);
//! * `M`-free outputs derived only from replicated inputs are computed
//!   identically on every lane — any one copy serves.
//!
//! [`analyze`] refuses programs where a partial result would flow back
//! into later calls (GEMVER: its `N`-vector `x` is an `M`-reduction fed
//! into a second GEMV — combining per-lane partials mid-sequence would
//! need an all-gather barrier the execution path does not have).

use crate::ir::elem::{DimSym, TILE};
use crate::ir::program::Program;
use crate::runtime::Tensor;
use anyhow::{bail, Result};
use std::collections::{BTreeMap, BTreeSet};

/// How one variable participates in a row-block split along `M`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Input with leading `M`: each lane receives its row slab.
    SliceRows,
    /// `M`-free input: replicated whole to every lane.
    Replicate,
    /// Output with leading `M`: per-lane blocks concatenate in block
    /// order (order-preserving — bit-identical to unsplit execution).
    ConcatRows,
    /// `M`-free output reduced over rows: per-lane partials sum in
    /// fixed block order (deterministic, reduction order differs).
    PartialSum,
    /// `M`-free output independent of `M`-bearing data: every lane
    /// computes the same value; the first block's copy serves.
    TakeOwner,
}

/// The split recipe of one program: per-input slicing and per-output
/// combining roles, in declaration order.
#[derive(Clone, Debug)]
pub struct SplitSpec {
    pub inputs: Vec<(String, Role)>,
    pub outputs: Vec<(String, Role)>,
}

impl SplitSpec {
    /// Does every output combine order-preservingly (no [`Role::PartialSum`])?
    /// Only then is split execution bit-identical to single-device.
    pub fn order_preserving(&self) -> bool {
        self.outputs.iter().all(|(_, r)| *r != Role::PartialSum)
    }
}

fn leading_m(dims: &[DimSym]) -> bool {
    dims.first().map(|d| d.0 == "M").unwrap_or(false)
}

/// Decide whether `prog` row-blocks along `M`, and how. `None` means
/// the program must serve on a single device:
///
/// * no input carries a leading `M` (nothing to slice), or
/// * `M` appears as a non-leading dimension (column-split territory), or
/// * a dimension symbol other than `M`/`N` appears, or
/// * an `M`-free value derived from `M`-bearing data is consumed by a
///   later call — it would be a per-lane partial where the program
///   needs the combined total (GEMVER's shape).
pub fn analyze(prog: &Program) -> Option<SplitSpec> {
    for v in &prog.vars {
        for (i, d) in v.dims.iter().enumerate() {
            match d.0.as_str() {
                "M" if i > 0 => return None,
                "M" | "N" => {}
                _ => return None,
            }
        }
    }
    // Taint: does a variable's value depend (transitively) on any
    // M-bearing variable? Calls are in execution order and scripts are
    // SSA-like, so one forward pass settles it.
    let mut tainted: BTreeSet<usize> = prog
        .vars
        .iter()
        .enumerate()
        .filter(|(_, v)| leading_m(&v.dims))
        .map(|(i, _)| i)
        .collect();
    for call in &prog.calls {
        if call.args.iter().any(|a| tainted.contains(&a.0)) {
            for o in &call.outs {
                tainted.insert(o.0);
            }
        }
    }
    // An M-free tainted value is a per-lane partial; feeding it to a
    // later call would compute on the partial where the full reduction
    // is meant.
    for call in &prog.calls {
        for a in &call.args {
            if tainted.contains(&a.0) && !leading_m(&prog.var(*a).dims) {
                return None;
            }
        }
    }
    let inputs: Vec<(String, Role)> = prog
        .inputs
        .iter()
        .map(|&v| {
            let decl = prog.var(v);
            let role = if leading_m(&decl.dims) {
                Role::SliceRows
            } else {
                Role::Replicate
            };
            (decl.name.clone(), role)
        })
        .collect();
    if !inputs.iter().any(|(_, r)| *r == Role::SliceRows) {
        return None;
    }
    let outputs = prog
        .outputs
        .iter()
        .map(|&v| {
            let decl = prog.var(v);
            let role = if leading_m(&decl.dims) {
                Role::ConcatRows
            } else if tainted.contains(&v.0) {
                Role::PartialSum
            } else {
                Role::TakeOwner
            };
            (decl.name.clone(), role)
        })
        .collect();
    Some(SplitSpec { inputs, outputs })
}

/// Partition `m` rows into at most `g` contiguous blocks, tile-aligned
/// at every cut (only the final block may be a partial tile): returns
/// `(start_row, rows)` pairs covering `0..m` exactly. Fewer than `g`
/// blocks come back when `m` has fewer than `g` tiles.
pub fn block_rows(m: usize, g: usize) -> Vec<(usize, usize)> {
    if m == 0 || g == 0 {
        return Vec::new();
    }
    let tiles = m.div_ceil(TILE);
    let per = tiles.div_ceil(g.min(tiles)) * TILE;
    let mut out = Vec::new();
    let mut start = 0;
    while start < m {
        let rows = per.min(m - start);
        out.push((start, rows));
        start += rows;
    }
    out
}

/// The leading-dimension slice `start..start+rows` of a vector or
/// matrix tensor (row-major).
pub fn slice_rows(t: &Tensor, start: usize, rows: usize) -> Result<Tensor> {
    let Some(&lead) = t.dims.first() else {
        bail!("cannot row-slice a dimensionless tensor");
    };
    if start + rows > lead {
        bail!("row slice {start}+{rows} exceeds leading dim {lead}");
    }
    let stride: usize = t.dims[1..].iter().product::<usize>().max(1);
    let mut dims = t.dims.clone();
    dims[0] = rows;
    Ok(Tensor::new(
        dims,
        t.data[start * stride..(start + rows) * stride].to_vec(),
    ))
}

/// Build one block's input environment: sliced rows for
/// [`Role::SliceRows`] inputs, shared clones for the rest.
pub fn slice_inputs(
    spec: &SplitSpec,
    inputs: &BTreeMap<String, Tensor>,
    start: usize,
    rows: usize,
) -> Result<BTreeMap<String, Tensor>> {
    let mut out = BTreeMap::new();
    for (name, role) in &spec.inputs {
        let Some(t) = inputs.get(name) else {
            bail!("split input '{name}' missing from the request environment");
        };
        let block = match role {
            Role::SliceRows => slice_rows(t, start, rows)?,
            _ => t.clone(),
        };
        out.insert(name.clone(), block);
    }
    Ok(out)
}

/// Combine per-block output environments (in block order) into the
/// request's outputs: concatenation for [`Role::ConcatRows`],
/// fixed-order elementwise sum for [`Role::PartialSum`], the first
/// block's copy for [`Role::TakeOwner`].
pub fn combine_outputs(
    spec: &SplitSpec,
    envs: &[BTreeMap<String, Tensor>],
) -> Result<BTreeMap<String, Tensor>> {
    if envs.is_empty() {
        bail!("no block results to combine");
    }
    let mut out = BTreeMap::new();
    for (name, role) in &spec.outputs {
        let parts: Vec<&Tensor> = envs
            .iter()
            .map(|e| {
                e.get(name)
                    .ok_or_else(|| anyhow::anyhow!("block result lacks output '{name}'"))
            })
            .collect::<Result<_>>()?;
        let combined = match role {
            Role::ConcatRows => {
                let mut dims = parts[0].dims.clone();
                if dims.is_empty() {
                    bail!("row-concat output '{name}' is dimensionless");
                }
                dims[0] = parts.iter().map(|t| t.dims[0]).sum();
                let mut data = Vec::with_capacity(parts.iter().map(|t| t.data.len()).sum());
                for p in &parts {
                    if p.dims[1..] != parts[0].dims[1..] {
                        bail!("row-concat output '{name}' has mismatched trailing dims");
                    }
                    data.extend_from_slice(&p.data);
                }
                Tensor::new(dims, data)
            }
            Role::PartialSum => {
                let mut acc = parts[0].clone();
                for p in &parts[1..] {
                    if p.dims != acc.dims {
                        bail!("partial-sum output '{name}' has mismatched dims");
                    }
                    for (a, b) in acc.data.iter_mut().zip(&p.data) {
                        *a += b;
                    }
                }
                acc
            }
            Role::TakeOwner => parts[0].clone(),
            Role::SliceRows | Role::Replicate => {
                bail!("input role on output '{name}'")
            }
        };
        out.insert(name.clone(), combined);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::library::Library;
    use crate::pipelines;
    use crate::sequences;

    fn program(seq: &str) -> Program {
        sequences::by_name(seq).unwrap().program(&Library::standard())
    }

    #[test]
    fn gemv_is_an_order_preserving_split() {
        let spec = analyze(&program("sgemv")).expect("sgemv must split");
        assert!(spec.order_preserving());
        let roles: BTreeMap<_, _> = spec.inputs.iter().cloned().collect();
        assert_eq!(roles["A"], Role::SliceRows);
        assert_eq!(roles["x"], Role::Replicate);
        assert_eq!(roles["y"], Role::SliceRows);
        assert_eq!(spec.outputs, vec![("z".to_string(), Role::ConcatRows)]);
    }

    #[test]
    fn bicgk_partial_reduces_its_transposed_half() {
        let spec = analyze(&program("bicgk")).expect("bicgk must split");
        assert!(!spec.order_preserving());
        let outs: BTreeMap<_, _> = spec.outputs.iter().cloned().collect();
        assert_eq!(outs["q"], Role::ConcatRows);
        assert_eq!(outs["s"], Role::PartialSum);
    }

    #[test]
    fn gemver_and_blas1_refuse_to_split() {
        // gemver feeds an M-reduction (x) back into a second gemv — a
        // per-lane partial would poison the downstream call
        assert!(analyze(&program("gemver")).is_none());
        // sgemvt has the same partial-into-gemv shape
        assert!(analyze(&program("sgemvt")).is_none());
        // pure BLAS-1 sequences have no M input to slice
        assert!(analyze(&program("waxpby")).is_none());
        assert!(analyze(&program("vadd")).is_none());
    }

    #[test]
    fn block_rows_cover_exactly_and_tile_align() {
        for (m, g) in [(256, 2), (256, 3), (100, 4), (32, 8), (8192, 4), (33, 2)] {
            let blocks = block_rows(m, g);
            assert!(!blocks.is_empty());
            assert!(blocks.len() <= g, "m={m} g={g}: {blocks:?}");
            let mut next = 0;
            for (i, &(start, rows)) in blocks.iter().enumerate() {
                assert_eq!(start, next, "m={m} g={g}");
                assert!(rows > 0);
                assert_eq!(start % TILE, 0, "cuts are tile-aligned");
                if i + 1 < blocks.len() {
                    assert_eq!(rows % TILE, 0, "only the last block may be partial");
                }
                next = start + rows;
            }
            assert_eq!(next, m, "blocks cover all rows");
        }
        assert!(block_rows(0, 2).is_empty());
        assert!(block_rows(128, 0).is_empty());
    }

    #[test]
    fn slice_concat_roundtrip_is_bit_identical() {
        let t = Tensor::matrix(6, 3, (0..18).map(|v| v as f32 * 0.5).collect());
        let a = slice_rows(&t, 0, 2).unwrap();
        let b = slice_rows(&t, 2, 4).unwrap();
        assert_eq!(a.dims, vec![2, 3]);
        assert_eq!(b.dims, vec![4, 3]);
        let spec = SplitSpec {
            inputs: vec![],
            outputs: vec![("t".to_string(), Role::ConcatRows)],
        };
        let envs = vec![
            BTreeMap::from([("t".to_string(), a)]),
            BTreeMap::from([("t".to_string(), b)]),
        ];
        let back = combine_outputs(&spec, &envs).unwrap();
        assert_eq!(back["t"].dims, t.dims);
        for (x, y) in back["t"].data.iter().zip(&t.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert!(slice_rows(&t, 4, 4).is_err(), "overrun must be refused");
    }

    /// The property the serve-path split rests on: running a pipeline
    /// per row block and combining equals running it whole — bitwise
    /// for order-preserving programs, deterministically (fixed combine
    /// order, close to the unsplit value) for partial reductions.
    #[test]
    fn split_offline_execution_matches_whole() {
        let lib = Library::standard();
        let gemv = sequences::by_name("sgemv").unwrap();
        let bicgk = sequences::by_name("bicgk").unwrap();
        for (seq, bitwise) in [(&gemv, true), (&bicgk, false)] {
            let c = pipelines::compile(seq.name, seq.script, &lib).unwrap();
            let spec = analyze(&c.pipeline.program).unwrap();
            let (m, n) = (96, 64);
            let inputs = c.pipeline.synth_inputs(m, n, 11).unwrap();
            let whole = c.pipeline.run_offline("fused", m, n, &inputs).unwrap();
            let run_split = || -> BTreeMap<String, Tensor> {
                let envs: Vec<_> = block_rows(m, 3)
                    .into_iter()
                    .map(|(start, rows)| {
                        let block = slice_inputs(&spec, &inputs, start, rows).unwrap();
                        c.pipeline.run_offline("fused", rows, n, &block).unwrap()
                    })
                    .collect();
                combine_outputs(&spec, &envs).unwrap()
            };
            let combined = run_split();
            let again = run_split();
            for (name, _) in &spec.outputs {
                assert_eq!(combined[name].dims, whole[name].dims, "{}/{name}", seq.name);
                for (i, (a, b)) in combined[name].data.iter().zip(&whole[name].data).enumerate() {
                    if bitwise {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{}/{name}[{i}] must be bit-identical",
                            seq.name
                        );
                    } else {
                        assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "{}/{name}[{i}]", seq.name);
                    }
                }
                // fixed-order combine: split execution is deterministic
                // even where it is not bit-identical to unsplit
                for (a, b) in combined[name].data.iter().zip(&again[name].data) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{}/{name} replays", seq.name);
                }
            }
        }
    }
}
