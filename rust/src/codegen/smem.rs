//! Shared-memory allocation with live-range overlap (paper §4.3.2:
//! "Elements in shared memory can overlap when possible to spare shared
//! memory usage. This is technically realized by allocating one large
//! array and creating pointers into this array").
//!
//! Slots whose live ranges (over the kernel's step sequence) are disjoint
//! may share addresses. First-fit over a size-descending order — the
//! classic interval-allocation heuristic; optimal for the small slot
//! counts kernels have.

use crate::ir::plan::SmemSlot;

/// An allocation request: variable name, padded words, live range in
/// step indices (inclusive). Steps inside the serial loop should all
/// share the loop's span — a value live across the loop back-edge is
/// live for the whole loop body.
#[derive(Clone, Debug)]
pub struct SmemReq {
    pub var: String,
    pub words: u32,
    pub live: (usize, usize),
}

fn ranges_overlap(a: (usize, usize), b: (usize, usize)) -> bool {
    a.0 <= b.1 && b.0 <= a.1
}

/// Allocate all requests; returns the placed slots and total words.
pub fn allocate(reqs: &[SmemReq]) -> (Vec<SmemSlot>, u32) {
    // Deterministic order: size descending, then name (stable output for
    // artifact keys and tests).
    let mut order: Vec<usize> = (0..reqs.len()).collect();
    order.sort_by(|&a, &b| {
        reqs[b]
            .words
            .cmp(&reqs[a].words)
            .then_with(|| reqs[a].var.cmp(&reqs[b].var))
    });

    let mut placed: Vec<SmemSlot> = Vec::with_capacity(reqs.len());
    let mut total: u32 = 0;
    for &i in &order {
        let r = &reqs[i];
        // Candidate offsets: 0 and the end of every conflicting slot.
        let conflicts: Vec<&SmemSlot> = placed
            .iter()
            .filter(|s| ranges_overlap(s.live, r.live))
            .collect();
        let mut cands: Vec<u32> = std::iter::once(0)
            .chain(conflicts.iter().map(|s| s.offset + s.words))
            .collect();
        cands.sort_unstable();
        let offset = cands
            .into_iter()
            .find(|&off| {
                conflicts
                    .iter()
                    .all(|s| off + r.words <= s.offset || off >= s.offset + s.words)
            })
            .expect("first-fit always finds an offset");
        total = total.max(offset + r.words);
        placed.push(SmemSlot {
            var: r.var.clone(),
            offset,
            words: r.words,
            live: r.live,
        });
    }
    // Restore request order for readable output.
    placed.sort_by_key(|s| {
        reqs.iter()
            .position(|r| r.var == s.var && r.words == s.words && r.live == s.live)
            .unwrap()
    });
    (placed, total)
}

/// Verify an allocation: no two *simultaneously live* slots overlap in
/// address space. Used by tests and the property suite.
pub fn verify(slots: &[SmemSlot]) -> Result<(), String> {
    for (i, a) in slots.iter().enumerate() {
        for b in slots.iter().skip(i + 1) {
            if ranges_overlap(a.live, b.live) {
                let addr_overlap = a.offset < b.offset + b.words && b.offset < a.offset + a.words;
                if addr_overlap {
                    return Err(format!(
                        "slots '{}' [{}..{}) and '{}' [{}..{}) overlap while both live",
                        a.var,
                        a.offset,
                        a.offset + a.words,
                        b.var,
                        b.offset,
                        b.offset + b.words
                    ));
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(var: &str, words: u32, live: (usize, usize)) -> SmemReq {
        SmemReq {
            var: var.into(),
            words,
            live,
        }
    }

    #[test]
    fn disjoint_live_ranges_share_memory() {
        // Mirrors the paper's generated BiCGK kernel: r (loaded early in
        // the loop) and q (produced late) share one 32-word slot.
        let reqs = vec![
            req("A", 1056, (0, 9)),
            req("p", 32, (0, 9)),
            req("s", 32, (0, 9)),
            req("r", 32, (1, 3)),
            req("q", 32, (5, 8)),
        ];
        let (slots, total) = allocate(&reqs);
        verify(&slots).unwrap();
        // 1056 + 32 + 32 + 32 (r and q overlapped) = 1152 — exactly the
        // `__shared__ float s_fusion[1152]` of the paper's Listing 3.
        assert_eq!(total, 1152);
        let r = slots.iter().find(|s| s.var == "r").unwrap();
        let q = slots.iter().find(|s| s.var == "q").unwrap();
        assert_eq!(r.offset, q.offset);
    }

    #[test]
    fn live_conflicts_get_distinct_addresses() {
        let reqs = vec![req("a", 64, (0, 5)), req("b", 64, (3, 8))];
        let (slots, total) = allocate(&reqs);
        verify(&slots).unwrap();
        assert_eq!(total, 128);
    }

    #[test]
    fn empty_allocation() {
        let (slots, total) = allocate(&[]);
        assert!(slots.is_empty());
        assert_eq!(total, 0);
    }

    #[test]
    fn single_slot_at_zero() {
        let (slots, total) = allocate(&[req("x", 100, (0, 0))]);
        assert_eq!(slots[0].offset, 0);
        assert_eq!(total, 100);
    }

    #[test]
    fn verify_catches_bad_layout() {
        let bad = vec![
            SmemSlot {
                var: "a".into(),
                offset: 0,
                words: 64,
                live: (0, 5),
            },
            SmemSlot {
                var: "b".into(),
                offset: 32,
                words: 64,
                live: (2, 6),
            },
        ];
        assert!(verify(&bad).is_err());
    }

    #[test]
    fn chain_of_disjoint_slots_all_at_zero() {
        let reqs = vec![
            req("a", 50, (0, 1)),
            req("b", 40, (2, 3)),
            req("c", 60, (4, 5)),
        ];
        let (slots, total) = allocate(&reqs);
        verify(&slots).unwrap();
        assert_eq!(total, 60);
        assert!(slots.iter().all(|s| s.offset == 0));
    }
}
