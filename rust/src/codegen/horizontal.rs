//! Horizontal fusion: combine the kernels of two or more *different*
//! drained batch groups (different seqs / sizes / plans) into one launch
//! with block-range dispatch.
//!
//! The paper's vertical fusion merges producer/consumer calls *within*
//! one sequence; this module is the serve-side dual ("Automatic
//! Horizontal Fusion for GPU Kernels", PAPERS.md): independent kernels
//! that would launch back-to-back are packed side by side into one
//! grid. Each source kernel owns a contiguous block-ID range of the
//! combined grid; the thread geometry is reconciled by padding every
//! block to the widest fragment's block size (narrower fragments mask
//! off the excess lanes), and shared memory / registers are sized to
//! the maximum across fragments because blocks of every fragment
//! coexist on the SMs.
//!
//! Two source *sequences* are zipped stage-wise: combined stage `k`
//! fuses the `k`-th kernel of every member that still has one, so a
//! 2-kernel member and a 3-kernel member produce 3 combined launches
//! instead of 5. The combined plan is documentation + accounting output
//! (like [`super::emit_cuda`]); the executable form on the offline stub
//! is the interpreter running each fragment's stages in the combined
//! launch order, which is bit-identical to back-to-back execution
//! because the fragments touch disjoint tensors.

use crate::ir::elem::ProblemSize;
use crate::ir::plan::{KernelPlan, SeqPlan};
use anyhow::{bail, Result};
use std::ops::Range;

/// One source kernel inside a combined launch.
#[derive(Clone, Debug)]
pub struct HFragment {
    /// Index of the source member (turn batch) this fragment came from.
    pub member: usize,
    /// The source kernel, unchanged.
    pub plan: KernelPlan,
    /// Problem size the fragment runs at.
    pub p: ProblemSize,
    /// Contiguous block IDs this fragment owns in the combined grid.
    pub blocks: Range<u64>,
    /// Threads per block the fragment actually uses (≤ the combined
    /// padded block size; the rest are masked off).
    pub active_threads: u32,
}

/// One combined kernel: every fragment's blocks laid out contiguously,
/// thread geometry padded to the widest fragment.
#[derive(Clone, Debug)]
pub struct HKernel {
    /// e.g. `h2_cu_waxpby_0+cu_vadd2_0`.
    pub name: String,
    /// Padded block shape: the shape of the fragment with the most
    /// threads per block (every block launches this many threads).
    pub block: (u32, u32),
    /// Shared memory per block in words — the max across fragments,
    /// since the static allocation covers whichever fragment a block
    /// dispatches to.
    pub smem_words: u32,
    /// Register budget per thread — the max across fragments (the
    /// combined kernel is compiled once, so the fattest fragment sets
    /// the per-thread footprint for occupancy purposes).
    pub regs_per_thread: u32,
    pub fragments: Vec<HFragment>,
}

impl HKernel {
    /// Total blocks in the combined grid.
    pub fn total_blocks(&self) -> u64 {
        self.fragments.last().map(|f| f.blocks.end).unwrap_or(0)
    }

    pub fn threads_per_block(&self) -> u32 {
        self.block.0 * self.block.1
    }

    /// The combined launch's resource footprint as a [`KernelPlan`],
    /// for occupancy pricing: padded block shape, max shared memory,
    /// max registers. Other fields are carried from the widest fragment
    /// and are not meaningful for the combined launch.
    pub fn footprint(&self) -> KernelPlan {
        let widest = self
            .fragments
            .iter()
            .max_by_key(|f| f.plan.grid.threads_per_block())
            .expect("HKernel has at least one fragment");
        let mut k = widest.plan.clone();
        k.name = self.name.clone();
        k.grid.block = self.block;
        k.smem_words = self.smem_words;
        k.regs_per_thread = self.regs_per_thread;
        k
    }
}

/// A combined launch sequence over several source [`SeqPlan`]s.
#[derive(Clone, Debug)]
pub struct HFusedPlan {
    /// e.g. `hfuse(waxpby.m32n65536, vadd.m32n4096)`.
    pub name: String,
    /// Combined launches, one per stage of the longest member.
    pub kernels: Vec<HKernel>,
    /// Number of source members zipped together.
    pub members: usize,
    /// Kernel launches saved vs running the members back-to-back:
    /// `Σ member stage counts − max member stage count`.
    pub launches_saved: u64,
}

/// Blocks a kernel launches at a problem size, as a whole number.
fn block_count(plan: &KernelPlan, p: ProblemSize) -> u64 {
    plan.blocks(p).ceil().max(1.0) as u64
}

/// Combine the `k`-th kernels of several members into one launch.
/// `parts` pairs each contributing member's index with its kernel and
/// problem size, in member order (which fixes the block-range layout).
pub fn fuse_kernels(name: String, parts: &[(usize, &KernelPlan, ProblemSize)]) -> HKernel {
    assert!(!parts.is_empty(), "fuse_kernels needs at least one part");
    let mut fragments = Vec::with_capacity(parts.len());
    let mut next_block = 0u64;
    let mut block = (1u32, 1u32);
    let mut smem_words = 0u32;
    let mut regs = 0u32;
    for &(member, plan, p) in parts {
        let n = block_count(plan, p);
        fragments.push(HFragment {
            member,
            plan: plan.clone(),
            p,
            blocks: next_block..next_block + n,
            active_threads: plan.grid.threads_per_block(),
        });
        next_block += n;
        if plan.grid.threads_per_block() > block.0 * block.1 {
            block = plan.grid.block;
        }
        smem_words = smem_words.max(plan.smem_words);
        regs = regs.max(plan.regs_per_thread);
    }
    HKernel {
        name,
        block,
        smem_words,
        regs_per_thread: regs,
        fragments,
    }
}

/// Zip several source sequences into one combined launch sequence.
/// Combined stage `k` fuses the `k`-th kernel of every member that has
/// one; members shorter than the longest simply stop contributing.
/// A single member passes through unchanged (zero launches saved).
pub fn fuse_seqs(members: &[(&SeqPlan, ProblemSize)]) -> Result<HFusedPlan> {
    if members.is_empty() {
        bail!("horizontal fusion needs at least one member");
    }
    for (sp, _) in members {
        if sp.kernels.is_empty() {
            bail!("member '{}' has no kernels", sp.seq);
        }
    }
    let stages = members.iter().map(|(sp, _)| sp.kernels.len()).max().unwrap();
    let total: usize = members.iter().map(|(sp, _)| sp.kernels.len()).sum();
    let mut kernels = Vec::with_capacity(stages);
    for k in 0..stages {
        let parts: Vec<(usize, &KernelPlan, ProblemSize)> = members
            .iter()
            .enumerate()
            .filter_map(|(i, (sp, p))| sp.kernels.get(k).map(|kp| (i, kp, *p)))
            .collect();
        let name = format!(
            "h{}_{}",
            parts.len(),
            parts
                .iter()
                .map(|(_, kp, _)| kp.name.as_str())
                .collect::<Vec<_>>()
                .join("+")
        );
        kernels.push(fuse_kernels(name, &parts));
    }
    let name = format!(
        "hfuse({})",
        members
            .iter()
            .map(|(sp, p)| format!("{}.m{}n{}", sp.seq, p.m, p.n))
            .collect::<Vec<_>>()
            .join(", ")
    );
    Ok(HFusedPlan {
        name,
        kernels,
        members: members.len(),
        launches_saved: (total - stages) as u64,
    })
}

/// Render one combined kernel as pseudo-CUDA with block-range dispatch,
/// in the style of [`super::emit_cuda`]. Documentation output; the
/// executable form on the stub is the interpreter path.
pub fn emit_hkernel(h: &HKernel) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "// horizontal fusion: {} source kernel(s) | {} blocks | block ({}, {}) padded\n",
        h.fragments.len(),
        h.total_blocks(),
        h.block.0,
        h.block.1
    ));
    out.push_str(&format!(
        "// regs/thread ≈ {} (max) | smem {} words (max)\n",
        h.regs_per_thread, h.smem_words
    ));
    out.push_str(&format!("__global__ void {}(...)\n{{\n", h.name));
    out.push_str("    int cb = blockIdx.x; // combined block id\n");
    out.push_str("    int lt = threadIdx.x + threadIdx.y * blockDim.x;\n");
    if h.smem_words > 0 {
        out.push_str(&format!(
            "    __shared__ float s_fusion[{}]; // max over fragments\n",
            h.smem_words
        ));
    }
    for (i, f) in h.fragments.iter().enumerate() {
        let cond = format!("cb < {}", f.blocks.end);
        let kw = if i == 0 {
            format!("if ({cond})")
        } else {
            format!("else if ({cond})")
        };
        out.push_str(&format!(
            "    {kw} {{ // {}: blocks [{}, {}), {}/{} threads active\n",
            f.plan.name,
            f.blocks.start,
            f.blocks.end,
            f.active_threads,
            h.threads_per_block()
        ));
        out.push_str(&format!(
            "        int bx = cb - {}; // fragment-local block id\n",
            f.blocks.start
        ));
        if f.active_threads < h.threads_per_block() {
            out.push_str(&format!(
                "        if (lt < {}) {{ // mask padded lanes\n            {}_body(bx, lt, ...);\n        }}\n",
                f.active_threads, f.plan.name
            ));
        } else {
            out.push_str(&format!("        {}_body(bx, lt, ...);\n", f.plan.name));
        }
        out.push_str("    }\n");
    }
    out.push_str("}\n");
    out
}

/// Render a whole combined launch sequence.
pub fn emit_hfused(plan: &HFusedPlan) -> String {
    let mut out = format!(
        "// {}: {} member(s), {} combined launch(es), {} launch(es) saved\n\n",
        plan.name,
        plan.members,
        plan.kernels.len(),
        plan.launches_saved
    );
    for k in &plan.kernels {
        out.push_str(&emit_hkernel(k));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::{enumerate_fusions, gen_impls, ImplAxes};
    use crate::graph::DepGraph;
    use crate::library::Library;
    use crate::script::compile_script;

    fn plan_for(name: &str, src: &str) -> SeqPlan {
        let lib = Library::standard();
        let prog = compile_script(name, src, &lib).unwrap();
        let g = DepGraph::build(&prog, &lib);
        let f = enumerate_fusions(&prog, &lib, &g).remove(0);
        let fi = gen_impls(&prog, &lib, &g, &f, &ImplAxes::minimal())
            .into_iter()
            .next()
            .unwrap();
        crate::codegen::compile_seq(&prog, &lib, &[fi], "fused")
    }

    fn waxpby() -> SeqPlan {
        plan_for(
            "waxpby",
            "vector<N> x, y, w; input x, y;
             w = waxpby(x, y, alpha=2.0, beta=3.0); return w;",
        )
    }

    fn vadd() -> SeqPlan {
        plan_for(
            "vadd",
            "vector<N> x, y, w; input x, y; w = vadd2(x, y); return w;",
        )
    }

    #[test]
    fn fused_ranges_are_contiguous_and_cover_the_grid() {
        let a = waxpby();
        let b = vadd();
        let h = fuse_seqs(&[
            (&a, ProblemSize::new(1, 65536)),
            (&b, ProblemSize::new(1, 4096)),
        ])
        .unwrap();
        assert_eq!(h.members, 2);
        for hk in &h.kernels {
            let mut next = 0u64;
            for f in &hk.fragments {
                assert_eq!(f.blocks.start, next, "ranges must be contiguous");
                assert!(f.blocks.end > f.blocks.start, "every fragment owns blocks");
                next = f.blocks.end;
            }
            assert_eq!(hk.total_blocks(), next);
        }
    }

    #[test]
    fn geometry_pads_to_the_widest_fragment() {
        let a = waxpby();
        let b = vadd();
        let h = fuse_seqs(&[
            (&a, ProblemSize::new(1, 65536)),
            (&b, ProblemSize::new(1, 65536)),
        ])
        .unwrap();
        for hk in &h.kernels {
            let max_threads = hk
                .fragments
                .iter()
                .map(|f| f.plan.grid.threads_per_block())
                .max()
                .unwrap();
            assert_eq!(hk.threads_per_block(), max_threads);
            let max_smem = hk.fragments.iter().map(|f| f.plan.smem_words).max().unwrap();
            assert_eq!(hk.smem_words, max_smem);
            let max_regs = hk
                .fragments
                .iter()
                .map(|f| f.plan.regs_per_thread)
                .max()
                .unwrap();
            assert_eq!(hk.regs_per_thread, max_regs);
            let fp = hk.footprint();
            assert_eq!(fp.grid.threads_per_block(), max_threads);
            assert_eq!(fp.smem_words, max_smem);
        }
    }

    #[test]
    fn stage_zip_saves_the_right_launch_count() {
        let a = waxpby();
        let b = vadd();
        let (ka, kb) = (a.kernels.len(), b.kernels.len());
        let h = fuse_seqs(&[
            (&a, ProblemSize::new(1, 1024)),
            (&b, ProblemSize::new(1, 1024)),
        ])
        .unwrap();
        assert_eq!(h.kernels.len(), ka.max(kb));
        assert_eq!(h.launches_saved, (ka + kb - ka.max(kb)) as u64);
    }

    #[test]
    fn singleton_passes_through_with_zero_savings() {
        let a = waxpby();
        let h = fuse_seqs(&[(&a, ProblemSize::new(1, 1024))]).unwrap();
        assert_eq!(h.members, 1);
        assert_eq!(h.launches_saved, 0);
        assert_eq!(h.kernels.len(), a.kernels.len());
        for hk in &h.kernels {
            assert_eq!(hk.fragments.len(), 1);
        }
    }

    #[test]
    fn empty_member_list_is_an_error() {
        assert!(fuse_seqs(&[]).is_err());
    }

    #[test]
    fn emission_shows_block_range_dispatch() {
        let a = waxpby();
        let b = vadd();
        let h = fuse_seqs(&[
            (&a, ProblemSize::new(1, 65536)),
            (&b, ProblemSize::new(1, 4096)),
        ])
        .unwrap();
        let text = emit_hfused(&h);
        assert!(text.contains("__global__ void h2_"), "{text}");
        assert!(text.contains("blocks ["), "{text}");
        assert!(text.contains("else if (cb <"), "{text}");
        assert!(text.contains("fragment-local block id"), "{text}");
        assert!(text.contains("launch(es) saved"), "{text}");
    }
}
