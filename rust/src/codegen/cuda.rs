//! Pseudo-CUDA rendering of a [`KernelPlan`] — mirrors the paper's
//! Appendix A so a generated plan can be inspected the way the authors
//! present their generated BiCGK kernel. This is documentation output;
//! the executable form of a plan is the AOT HLO artifact.

use crate::ir::func::RoutineKind;
use crate::ir::plan::{Hoist, KernelPlan, SeqPlan};

/// Render one kernel as pseudo-CUDA.
pub fn emit_cuda(plan: &KernelPlan) -> String {
    let mut out = String::new();
    let g = &plan.grid;
    out.push_str(&format!(
        "// grid: depth-{} | block ({}, {}) | {} instance(s)/block | {} serial iteration(s) over {}\n",
        g.depth, g.block.0, g.block.1, g.instances_per_block, g.iters, g.iter_dim
    ));
    out.push_str(&format!(
        "// regs/thread ≈ {} | smem {} words ({} B)\n",
        plan.regs_per_thread,
        plan.smem_words,
        plan.smem_bytes()
    ));
    out.push_str(&format!("__global__ void {}(...)\n{{\n", plan.name));
    out.push_str("    int tx = threadIdx.x;\n    int ty = threadIdx.y;\n");
    out.push_str("    int bx = blockIdx.x;\n    int by = blockIdx.y;\n");
    if plan.smem_words > 0 {
        out.push_str(&format!(
            "    __shared__ float s_fusion[{}];\n",
            plan.smem_words
        ));
        for s in &plan.smem_slots {
            out.push_str(&format!(
                "    float* s_{} = s_fusion + {}; // {} words, live steps {}..{}\n",
                s.var, s.offset, s.words, s.live.0, s.live.1
            ));
        }
    }
    let emit_step = |s: &crate::ir::plan::Step, indent: &str, out: &mut String| {
        if s.barrier_before {
            out.push_str(&format!("{indent}__syncthreads();\n"));
        }
        if s.clear_before {
            let v = s.op.var.as_deref().unwrap_or("out");
            out.push_str(&format!(
                "{indent}// clear output of reduction\n{indent}s_{v}[tx] = 0.0f;\n"
            ));
        }
        let what = match s.op.kind {
            RoutineKind::Load { .. } => "data loading",
            RoutineKind::Compute => "computation",
            RoutineKind::Store { .. } => "data storing",
        };
        let atomic = if s.op.uses_atomic { " [atomicAdd]" } else { "" };
        out.push_str(&format!(
            "{indent}// {what}{atomic}\n{indent}{}(...);\n",
            s.op.routine_name
        ));
    };
    for s in plan.steps.iter().filter(|s| s.hoist == Hoist::BeforeLoop) {
        emit_step(s, "    ", &mut out);
    }
    let has_loop = plan.steps.iter().any(|s| s.hoist == Hoist::InLoop);
    if has_loop {
        if g.iters > 1 {
            out.push_str(&format!(
                "    {0} = {0} * {1};\n    int stop = min({0} + {1}, grid_{2});\n    for (; {0} < stop; {0}++) {{\n",
                if g.depth == 2 { "by" } else { "bx" },
                g.iters,
                g.iter_dim
            ));
        } else {
            out.push_str("    { // single iteration\n");
        }
        for s in plan.steps.iter().filter(|s| s.hoist == Hoist::InLoop) {
            emit_step(s, "        ", &mut out);
        }
        out.push_str("    }\n");
    }
    for s in plan.steps.iter().filter(|s| s.hoist == Hoist::AfterLoop) {
        emit_step(s, "    ", &mut out);
    }
    out.push_str("}\n");
    out
}

/// Render the whole sequence (one pseudo-kernel per fusion).
pub fn emit_seq(plan: &SeqPlan) -> String {
    let mut out = format!(
        "// sequence '{}', variant '{}': {} kernel(s)\n\n",
        plan.seq,
        plan.variant,
        plan.kernels.len()
    );
    for k in &plan.kernels {
        out.push_str(&emit_cuda(k));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::{enumerate_fusions, gen_impls, ImplAxes};
    use crate::graph::DepGraph;
    use crate::library::Library;
    use crate::script::compile_script;

    #[test]
    fn bicgk_rendering_mentions_sync_and_smem() {
        let lib = Library::standard();
        let prog = compile_script(
            "bicgk",
            "matrix<MxN> A; vector<N> p, s; vector<M> q, r;
             input A, p, r;
             q = sgemv(A, p);
             s = sgemtv(A, r);
             return q, s;",
            &lib,
        )
        .unwrap();
        let g = DepGraph::build(&prog, &lib);
        let f = enumerate_fusions(&prog, &lib, &g).remove(0);
        let fi = gen_impls(&prog, &lib, &g, &f, &ImplAxes::minimal())
            .into_iter()
            .find(|i| i.iters > 1)
            .unwrap();
        let plan = crate::codegen::generate(&prog, &lib, &fi);
        let cuda = emit_cuda(&plan);
        assert!(cuda.contains("__global__ void"), "{cuda}");
        assert!(cuda.contains("__shared__ float s_fusion["), "{cuda}");
        assert!(cuda.contains("__syncthreads()"), "{cuda}");
        assert!(cuda.contains("for ("), "{cuda}");
        assert!(cuda.contains("d_sgemv_compute"), "{cuda}");
        assert!(cuda.contains("d_sgemtv_compute"), "{cuda}");
    }

    #[test]
    fn seq_rendering_counts_kernels() {
        let lib = Library::standard();
        let prog = compile_script(
            "t",
            "vector<N> x, y; input x; y = sscal(x, alpha=2.0); return y;",
            &lib,
        )
        .unwrap();
        let g = DepGraph::build(&prog, &lib);
        let f = crate::fusion::Fusion::singleton(crate::ir::program::CallId(0), &prog, &lib);
        let fi = gen_impls(&prog, &lib, &g, &f, &ImplAxes::minimal())
            .into_iter()
            .next()
            .unwrap();
        let sp = crate::codegen::compile_seq(&prog, &lib, &[fi], "unfused");
        let text = emit_seq(&sp);
        assert!(text.contains("1 kernel(s)"));
    }
}
