//! Code generation (paper §4.3): turn a [`FusionImpl`] into a
//! [`KernelPlan`] following Algorithm 1 (kernel schema) and Algorithm 2
//! (routine-call schema):
//!
//! 1. decide the block shape from the member variants;
//! 2. walk the members in calling order emitting load / compute / store
//!    steps, skipping loads of data already on-chip and stores of data
//!    that dies inside the fusion;
//! 3. classify each step against the serial loop (invariant loads and
//!    accumulable reduction outputs are hoisted — Algorithm 1 lines 4–5
//!    and 10);
//! 4. place exchanged elements in registers or shared memory
//!    (§3.2.3), allocate shared memory with live-range overlap;
//! 5. insert local barriers per the two §4.3.3 conditions (including the
//!    loop back-edge);
//! 6. account global traffic and flops symbolically over (M, N).
//!
//! `emit_cuda` renders the plan as readable pseudo-CUDA mirroring the
//! paper's Appendix A.

pub mod cuda;
pub mod horizontal;
pub mod smem;

pub use cuda::emit_cuda;

use crate::fusion::FusionImpl;
use crate::ir::elem::{ElemType, VarType};
use crate::ir::func::{ElemFunc, FuncVariant, Ix, RoutineKind, ThreadMap};
use crate::ir::plan::{
    GridPlan, Hoist, IterDim, KernelPlan, Poly2, SeqPlan, Step, StepOp, Traffic,
};
use crate::ir::program::{CallId, Program, VarId};
use crate::library::Library;
use std::collections::{BTreeMap, BTreeSet};

/// Where a variable's element lives inside the kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Home {
    Registers,
    Smem,
}

struct Member<'a> {
    call: CallId,
    func: &'a ElemFunc,
    variant: &'a FuncVariant,
}

/// Generate the kernel plan for one fusion implementation.
pub fn generate(prog: &Program, lib: &Library, fi: &FusionImpl) -> KernelPlan {
    let depth = fi.fusion.depth;
    let members: Vec<Member> = fi
        .order
        .iter()
        .zip(fi.variant.iter())
        .map(|(&c, &v)| {
            let f = lib.get(prog.call(c).func);
            Member {
                call: c,
                func: f,
                variant: &f.variants[v],
            }
        })
        .collect();

    // ---- 1. block shape -------------------------------------------------
    let inst_tx = members.iter().map(|m| m.variant.threads.0).max().unwrap();
    let inst_ty = members.iter().map(|m| m.variant.threads.1).max().unwrap();
    let block = if depth == 1 {
        (inst_tx, fi.ipb) // instances packed along y
    } else {
        (inst_tx, inst_ty)
    };
    let iter_over_rows = fi.iter_dim == IterDim::Row;

    // ---- 2/3. emit steps with hoist classes ------------------------------
    // Which vars the kernel keeps on-chip already (loaded or produced).
    let mut on_chip: BTreeSet<VarId> = BTreeSet::new();
    // Accessor bookkeeping for register/smem decisions:
    // var -> (mappings, instance thread-counts) of all accessing steps.
    let mut accessors: BTreeMap<VarId, Vec<(ThreadMap, u32)>> = BTreeMap::new();
    let mut steps: Vec<Step> = Vec::new();

    let escapes = |v: VarId| {
        prog.is_output(v)
            || prog
                .consumers(v)
                .iter()
                .any(|c| !fi.fusion.calls.contains(c))
    };

    for m in &members {
        let call = prog.call(m.call);
        let inst_threads = m.variant.threads.0 * m.variant.threads.1;
        // loads
        for (j, param) in m.func.inputs.iter().enumerate() {
            let var = call.args[j];
            let r = m.func.load_routine(j);
            accessors
                .entry(var)
                .or_default()
                .push((r.mapping, inst_threads));
            // compute also touches it
            accessors
                .entry(var)
                .or_default()
                .push((m.func.compute_routine().mapping, inst_threads));
            if on_chip.contains(&var) {
                continue; // shared load / produced in-fusion — spared
            }
            on_chip.insert(var);
            let hoist = if fi.iters > 1 && !param.ix.varies_along(iter_over_rows) {
                Hoist::BeforeLoop
            } else if param.ix == Ix::None {
                Hoist::BeforeLoop // scalars: once per block
            } else if !param.ix.varies_along(iter_over_rows) {
                Hoist::BeforeLoop
            } else {
                Hoist::InLoop
            };
            steps.push(Step {
                call: m.call,
                op: StepOp {
                    kind: r.kind,
                    routine_name: r.name.clone(),
                    var: Some(prog.var(var).name.clone()),
                    mapping: r.mapping,
                    threads: r.threads_total().min(inst_threads),
                    global_words: r.global_words,
                    flops: 0,
                    uses_atomic: r.uses_atomic,
                },
                barrier_before: false,
                clear_before: false,
                hoist,
            });
        }
        // compute
        let cr = m.func.compute_routine();
        let out_var = call.outs[0];
        let out_param = &m.func.outputs[0];
        accessors
            .entry(out_var)
            .or_default()
            .push((cr.mapping, inst_threads));
        on_chip.insert(out_var);
        let out_accumulable = m.func.hof.output_needs_global_barrier()
            && !out_param.ix.varies_along(iter_over_rows);
        steps.push(Step {
            call: m.call,
            op: StepOp {
                kind: RoutineKind::Compute,
                routine_name: cr.name.clone(),
                var: None,
                mapping: cr.mapping,
                threads: inst_threads,
                global_words: 0,
                flops: cr.flops,
                uses_atomic: false,
            },
            barrier_before: false,
            // non-accumulated reduction outputs are cleared right before
            // the compute that produces them (Algorithm 2 line 2)
            clear_before: m.func.hof.output_needs_global_barrier() && !out_accumulable,
            hoist: Hoist::InLoop,
        });
        // store
        if escapes(out_var) {
            let sr = m.func.store_routine(0);
            accessors
                .entry(out_var)
                .or_default()
                .push((sr.mapping, inst_threads));
            steps.push(Step {
                call: m.call,
                op: StepOp {
                    kind: sr.kind,
                    routine_name: sr.name.clone(),
                    var: Some(prog.var(out_var).name.clone()),
                    mapping: sr.mapping,
                    threads: sr.threads_total().min(inst_threads),
                    global_words: sr.global_words,
                    flops: 0,
                    uses_atomic: sr.uses_atomic,
                },
                barrier_before: false,
                clear_before: false,
                hoist: if out_accumulable {
                    Hoist::AfterLoop
                } else {
                    Hoist::InLoop
                },
            });
        }
    }

    // Reorder: BeforeLoop steps first, then InLoop (original order), then
    // AfterLoop — the Algorithm-1 layout.
    let hoist_rank = |h: Hoist| match h {
        Hoist::BeforeLoop => 0u8,
        Hoist::InLoop => 1,
        Hoist::AfterLoop => 2,
    };
    let mut idx: Vec<usize> = (0..steps.len()).collect();
    idx.sort_by_key(|&i| (hoist_rank(steps[i].hoist), i));
    let mut steps: Vec<Step> = idx.into_iter().map(|i| steps[i].clone()).collect();

    // ---- 4. register / shared-memory placement --------------------------
    let mut home: BTreeMap<VarId, Home> = BTreeMap::new();
    for (&var, acc) in &accessors {
        let elem = prog.var(var).ty.elem();
        let h = if depth == 2 {
            // Tile kernels keep every exchanged element in shared memory:
            // tiles because of transposed access, sub-vectors because
            // they are broadcast to all tile rows/columns.
            Home::Smem
        } else {
            // Depth-1: registers iff all accessors agree on the
            // per-instance thread count and use a per-thread-slice
            // mapping (Vec32, or BlockReduce's element-wise phase).
            let t0 = acc[0].1;
            let uniform = acc.iter().all(|&(m, t)| {
                t == t0
                    && matches!(
                        m,
                        ThreadMap::Vec32 | ThreadMap::BlockReduce | ThreadMap::Single
                    )
            });
            if uniform && elem != ElemType::Tile {
                Home::Registers
            } else {
                Home::Smem
            }
        };
        home.insert(var, h);
    }

    // ---- 5. shared-memory allocation ------------------------------------
    let loop_span = {
        let first = steps.iter().position(|s| s.hoist == Hoist::InLoop);
        let last = steps.iter().rposition(|s| s.hoist == Hoist::InLoop);
        first.zip(last)
    };
    let mut reqs: Vec<smem::SmemReq> = Vec::new();
    let per_instance_copies = if depth == 1 { fi.ipb } else { 1 };
    // Hot path: precompute which vars each step touches (the per-var ×
    // per-step × per-member string scan dominated codegen — see
    // EXPERIMENTS.md §Perf).
    let step_vars: Vec<Vec<VarId>> = steps
        .iter()
        .map(|s| match s.op.kind {
            RoutineKind::Compute => {
                let call = prog.call(s.call);
                call.args.iter().chain(call.outs.iter()).copied().collect()
            }
            _ => s
                .op
                .var
                .as_deref()
                .and_then(|n| prog.var_id(n))
                .into_iter()
                .collect(),
        })
        .collect();
    for (&var, &h) in &home {
        if h != Home::Smem {
            continue;
        }
        let name = prog.var(var).name.clone();
        let touches: Vec<usize> = step_vars
            .iter()
            .enumerate()
            .filter(|(_, vs)| vs.contains(&var))
            .map(|(i, _)| i)
            .collect();
        if touches.is_empty() {
            continue;
        }
        let (mut lo, mut hi) = (
            *touches.iter().min().unwrap(),
            *touches.iter().max().unwrap(),
        );
        // Anything touched inside the loop is live across the whole loop
        // body (back-edge reuse) — unless produced & consumed between
        // two in-loop points with no carry, which we conservatively
        // ignore for invariant/accumulated data only.
        if let Some((lf, ll)) = loop_span {
            let in_loop = touches
                .iter()
                .any(|&i| steps[i].hoist == Hoist::InLoop);
            let hoisted = touches
                .iter()
                .any(|&i| steps[i].hoist != Hoist::InLoop);
            if in_loop && hoisted {
                // invariant load or accumulated output: live everywhere
                lo = lo.min(lf);
                hi = hi.max(ll);
            }
        }
        let words = prog.var(var).ty.elem().smem_words_padded() as u32 * per_instance_copies;
        reqs.push(smem::SmemReq {
            var: name,
            words,
            live: (lo, hi),
        });
    }
    // per-member scratch (reduction staging etc.) — live during compute
    for m in &members {
        if m.variant.scratch_smem_words > 0 {
            let ci = steps
                .iter()
                .position(|s| {
                    s.call == m.call && s.op.kind == RoutineKind::Compute
                })
                .unwrap();
            reqs.push(smem::SmemReq {
                var: format!("scratch_{}", m.func.name),
                words: m.variant.scratch_smem_words * per_instance_copies,
                live: (ci, ci),
            });
        }
    }
    let (smem_slots, smem_words) = smem::allocate(&reqs);

    // ---- 6. barrier insertion (§4.3.3) -----------------------------------
    insert_barriers(&mut steps, &smem_slots, &home, prog);

    // ---- 7. traffic & flops accounting -----------------------------------
    let elem_dim_is_m = first_vector_dim_is_m(prog, &members);
    let mut traffic = Traffic::default();
    let mut flops = Poly2::ZERO;
    for s in &steps {
        let var = s
            .op
            .var
            .as_ref()
            .and_then(|n| prog.var_id(n));
        let poly = step_traffic(prog, depth, fi, s, var, elem_dim_is_m);
        match s.op.kind {
            RoutineKind::Load { .. } => traffic.loads += poly,
            RoutineKind::Store { .. } => {
                traffic.stores += poly;
                if s.op.uses_atomic {
                    traffic.atomic_words += poly;
                    // zero-init of the accumulation target (runtime
                    // memset before launch)
                    if let Some(v) = var {
                        traffic.stores += crate::fusion::var_words(prog, v);
                    }
                }
            }
            RoutineKind::Compute => {
                flops += instances_poly(depth, fi, elem_dim_is_m).scale(s.op.flops as f64);
            }
        }
    }

    // ---- 8. summary fields ------------------------------------------------
    let total_flop_weight: f64 = members
        .iter()
        .map(|m| m.func.flops_per_instance as f64)
        .sum();
    let compute_efficiency = if total_flop_weight > 0.0 {
        members
            .iter()
            .map(|m| m.variant.compute_efficiency * m.func.flops_per_instance as f64)
            .sum::<f64>()
            / total_flop_weight
    } else {
        1.0
    };
    let reg_words_per_thread: u32 = home
        .iter()
        .filter(|(_, &h)| h == Home::Registers)
        .map(|(&v, _)| {
            let words = prog.var(v).ty.elem().words() as u32;
            words.div_ceil(inst_tx * inst_ty)
        })
        .sum();
    let regs_per_thread = members
        .iter()
        .map(|m| m.variant.regs_per_thread)
        .max()
        .unwrap()
        + reg_words_per_thread;
    let barriers_per_iter = steps
        .iter()
        .filter(|s| s.hoist == Hoist::InLoop && s.barrier_before)
        .count() as u32;

    let name = format!(
        "cu_{}_{}",
        fi.fusion.label(prog, lib).replace('+', "_"),
        fi.label()
    );
    KernelPlan {
        name,
        members: fi.order.clone(),
        grid: GridPlan {
            depth,
            block,
            instances_per_block: fi.ipb,
            iters: fi.iters,
            iter_dim: fi.iter_dim,
        },
        smem_words,
        regs_per_thread,
        smem_slots,
        steps,
        instances: instances_poly(depth, fi, elem_dim_is_m),
        traffic,
        flops,
        compute_efficiency,
        barriers_per_iter,
    }
}

fn first_vector_dim_is_m(prog: &Program, members: &[Member]) -> bool {
    for m in members {
        let call = prog.call(m.call);
        for &v in call.args.iter().chain(call.outs.iter()) {
            let d = prog.var(v);
            if d.ty == VarType::Vector {
                return d.dims[0].0 == "M";
            }
        }
    }
    false
}

/// Instance count of the kernel (how many element-slots the grid covers).
fn instances_poly(depth: u8, _fi: &FusionImpl, elem_dim_is_m: bool) -> Poly2 {
    if depth == 2 {
        Poly2::mn(1.0 / 1024.0)
    } else if elem_dim_is_m {
        Poly2::m(1.0 / 32.0)
    } else {
        Poly2::n(1.0 / 32.0)
    }
}

/// Total global words a load/store step moves at problem scale.
fn step_traffic(
    prog: &Program,
    depth: u8,
    fi: &FusionImpl,
    s: &Step,
    var: Option<VarId>,
    elem_dim_is_m: bool,
) -> Poly2 {
    let elem = var
        .map(|v| prog.var(v).ty.elem())
        .unwrap_or(ElemType::Scalar);
    let per_block_factor = 1.0 / (fi.ipb as f64 * fi.iters as f64);
    match (depth, elem) {
        // Full matrix pass: every tile exactly once.
        (2, ElemType::Tile) => Poly2::mn(1.0),
        (2, ElemType::SubVector) => {
            match s.hoist {
                // once per tile-instance: 32 words × mn/1024 instances
                Hoist::InLoop => Poly2::mn(32.0 / 1024.0),
                // once per block: instances / iters blocks
                _ => Poly2::mn(32.0 / 1024.0 / fi.iters as f64),
            }
        }
        (2, ElemType::Scalar) => Poly2::mn(1.0 / 1024.0 / fi.iters as f64),
        (1, ElemType::SubVector) => {
            let full = if var
                .map(|v| prog.var(v).dims[0].0 == "M")
                .unwrap_or(elem_dim_is_m)
            {
                Poly2::m(1.0)
            } else {
                Poly2::n(1.0)
            };
            match s.hoist {
                Hoist::InLoop => full,
                _ => full.scale(per_block_factor),
            }
        }
        (1, ElemType::Scalar) => {
            // one word per block (dot partials)
            let d = if elem_dim_is_m {
                Poly2::m(1.0 / 32.0)
            } else {
                Poly2::n(1.0 / 32.0)
            };
            d.scale(per_block_factor)
        }
        _ => Poly2::ZERO,
    }
}

/// Barrier insertion, §4.3.3: a local barrier precedes routine `r` when
/// (a) `r` accesses an element written by an earlier routine with a
/// different thread-to-data mapping and no barrier intervenes, or
/// (b) `r` writes a shared-memory element overlapping another element
/// accessed since the last barrier. The serial loop's back-edge is
/// handled by a wrap-around pass.
fn insert_barriers(
    steps: &mut [Step],
    slots: &[crate::ir::plan::SmemSlot],
    home: &BTreeMap<VarId, Home>,
    prog: &Program,
) {
    let slot_of = |name: &str| slots.iter().find(|s| s.var == name);
    let smem_names: BTreeSet<&str> = home
        .iter()
        .filter(|(_, &h)| h == Home::Smem)
        .map(|(&v, _)| prog.var(v).name.as_str())
        .collect();
    let in_smem = |name: &str| smem_names.contains(name);

    // Precompute each step's smem reads/writes once — this pass runs
    // 2n times and per-iteration string allocation dominated it
    // (EXPERIMENTS.md §Perf).
    let step_access: Vec<(Vec<String>, Vec<String>)> = (0..steps.len())
        .map(|si| match steps[si].op.kind {
            RoutineKind::Load { .. } => (
                vec![],
                steps[si].op.var.iter().cloned().filter(|v| in_smem(v)).collect(),
            ),
            RoutineKind::Store { .. } => (
                steps[si].op.var.iter().cloned().filter(|v| in_smem(v)).collect(),
                vec![],
            ),
            RoutineKind::Compute => {
                let call_vars = compute_vars(prog, steps, si);
                (
                    call_vars.0.into_iter().filter(|v| in_smem(v)).collect(),
                    call_vars.1.into_iter().filter(|v| in_smem(v)).collect(),
                )
            }
        })
        .collect();

    // Simpler, faithful tracking: last writer mapping per smem var since
    // the last barrier, plus set of smem vars accessed since last barrier.
    let n = steps.len();
    let two_pass = 2 * n; // second pass models the loop back-edge
    let mut last_write: BTreeMap<&str, ThreadMap> = BTreeMap::new();
    let mut accessed: BTreeSet<&str> = BTreeSet::new();
    for i in 0..two_pass {
        let si = i % n;
        // Pass 2 only matters for in-loop steps (back-edge).
        if i >= n && steps[si].hoist != Hoist::InLoop {
            continue;
        }
        let (reads, writes) = &step_access[si];
        let mapping = steps[si].op.mapping;
        let mut need_barrier = false;
        // (a) read-after-write with different mapping
        for r in reads {
            if let Some(&wm) = last_write.get(r.as_str()) {
                if wm != mapping {
                    need_barrier = true;
                }
            }
        }
        // (b) write overlapping another accessed element
        for w in writes {
            if let Some(slot_w) = slot_of(w) {
                for other in accessed.iter() {
                    if *other == w.as_str() {
                        // rewriting an element read since last barrier
                        // also requires a sync (WAR within the block)
                        if !last_write.contains_key(w.as_str()) {
                            need_barrier = true;
                        }
                        continue;
                    }
                    if let Some(slot_o) = slot_of(other) {
                        let addr_overlap = slot_w.offset < slot_o.offset + slot_o.words
                            && slot_o.offset < slot_w.offset + slot_w.words;
                        if addr_overlap {
                            need_barrier = true;
                        }
                    }
                }
            }
        }
        if need_barrier {
            if i < n {
                steps[si].barrier_before = true;
            } else if !steps[si].barrier_before {
                // back-edge conflict: sync at the loop top
                steps[si].barrier_before = true;
            }
            last_write.clear();
            accessed.clear();
        }
        for w in writes {
            last_write.insert(w.as_str(), mapping);
            accessed.insert(w.as_str());
        }
        for r in reads {
            accessed.insert(r.as_str());
        }
    }
}

/// (reads, writes) of the compute step at index `si`, by variable name.
fn compute_vars(prog: &Program, steps: &[Step], si: usize) -> (Vec<String>, Vec<String>) {
    let call = prog.call(steps[si].call);
    let reads = call
        .args
        .iter()
        .map(|&v| prog.var(v).name.clone())
        .collect();
    let writes = call
        .outs
        .iter()
        .map(|&v| prog.var(v).name.clone())
        .collect();
    (reads, writes)
}

/// Compile a full script with chosen per-part implementations into an
/// ordered [`SeqPlan`] (kernel order = script order of each part's first
/// member; parts are convex so this respects dependencies).
pub fn compile_seq(
    prog: &Program,
    lib: &Library,
    impls: &[FusionImpl],
    variant_label: &str,
) -> SeqPlan {
    // coverage check
    let mut covered = BTreeSet::new();
    for fi in impls {
        for &c in &fi.fusion.calls {
            assert!(covered.insert(c), "call {c:?} covered twice");
        }
    }
    assert_eq!(
        covered.len(),
        prog.calls.len(),
        "implementation selection must cover every call"
    );
    let mut sorted: Vec<&FusionImpl> = impls.iter().collect();
    sorted.sort_by_key(|fi| fi.fusion.calls.iter().next().unwrap().0);
    SeqPlan {
        seq: prog.name.clone(),
        variant: variant_label.to_string(),
        kernels: sorted.iter().map(|fi| generate(prog, lib, fi)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::{enumerate_fusions, gen_impls, Fusion, ImplAxes};
    use crate::graph::DepGraph;
    use crate::ir::elem::ProblemSize;
    use crate::script::compile_script;

    fn setup(src: &str) -> (Program, Library, DepGraph) {
        let lib = Library::standard();
        let prog = compile_script("t", src, &lib).unwrap();
        let g = DepGraph::build(&prog, &lib);
        (prog, lib, g)
    }

    const BICGK: &str = "
        matrix<MxN> A; vector<N> p, s; vector<M> q, r;
        input A, p, r;
        q = sgemv(A, p);
        s = sgemtv(A, r);
        return q, s;
    ";

    fn bicgk_fused_impl(
        prog: &Program,
        lib: &Library,
        g: &DepGraph,
        iters: u32,
        iter_dim: IterDim,
    ) -> FusionImpl {
        let f = enumerate_fusions(prog, lib, g).remove(0);
        let axes = ImplAxes {
            iters: vec![iters],
            ipb: vec![1],
            max_orders: 6,
            both_iter_dims: true,
        };
        gen_impls(prog, lib, g, &f, &axes)
            .into_iter()
            .find(|i| {
                i.iter_dim == iter_dim
                    && i.variant == vec![0, 0]
                    && i.order == vec![CallId(1), CallId(0)] // gemtv first, like Listing 3
            })
            .unwrap()
    }

    #[test]
    fn bicgk_fused_traffic_shares_a() {
        let (prog, lib, g) = setup(BICGK);
        let fi = bicgk_fused_impl(&prog, &lib, &g, 8, IterDim::Row);
        let plan = generate(&prog, &lib, &fi);
        // A loaded once: loads.mn == 1.0 plus vector terms
        assert!((plan.traffic.loads.mn - (1.0 + 32.0 / 1024.0 + 32.0 / 1024.0 / 8.0)).abs() < 1e-9,
            "loads {:?}", plan.traffic.loads);
        let p = ProblemSize::square(8192);
        // fused moves ~1.07·mn words; two unfused gemv+gemtv would move ~2.07·mn
        let words = plan.traffic.total_words().eval(p);
        assert!(words < 1.1 * 8192.0 * 8192.0, "traffic too high: {words}");
        // 4·mn flops total
        assert!((plan.flops.eval(p) - 4.0 * 8192.0 * 8192.0).abs() < 1e-3);
    }

    #[test]
    fn bicgk_smem_matches_paper_1152() {
        // The paper's generated BiCGK kernel allocates
        // `__shared__ float s_fusion[1152]` — A (33·32) + p + s + one
        // overlapped slot for {r, q}. Our allocator must reproduce it
        // (+ reduction scratch which the paper folds into outputs).
        let (prog, lib, g) = setup(BICGK);
        let fi = bicgk_fused_impl(&prog, &lib, &g, 8, IterDim::Row);
        let plan = generate(&prog, &lib, &fi);
        assert!(
            plan.smem_words >= 1152 && plan.smem_words <= 1152 + 2 * 32,
            "smem {} outside expected window",
            plan.smem_words
        );
        crate::codegen::smem::verify(&plan.smem_slots).unwrap();
    }

    #[test]
    fn bicgk_hoisting_matches_algorithm3() {
        // iter over rows: p (Col-indexed) is invariant → BeforeLoop;
        // s (Col output) accumulates → store AfterLoop;
        // r, A load + q store stay in the loop.
        let (prog, lib, g) = setup(BICGK);
        let fi = bicgk_fused_impl(&prog, &lib, &g, 8, IterDim::Row);
        let plan = generate(&prog, &lib, &fi);
        let find = |var: &str, kind_load: bool| {
            plan.steps
                .iter()
                .find(|s| {
                    s.op.var.as_deref() == Some(var)
                        && (kind_load == s.op.kind.is_load())
                })
                .unwrap_or_else(|| panic!("no step for {var}"))
        };
        assert_eq!(find("p", true).hoist, Hoist::BeforeLoop);
        assert_eq!(find("A", true).hoist, Hoist::InLoop);
        assert_eq!(find("r", true).hoist, Hoist::InLoop);
        assert_eq!(find("q", false).hoist, Hoist::InLoop);
        assert_eq!(find("s", false).hoist, Hoist::AfterLoop);
    }

    #[test]
    fn bicgk_has_local_barriers() {
        // gemv reads the tile transposed after a row-major load → at
        // least one barrier inside the loop (Listing 3 has several).
        let (prog, lib, g) = setup(BICGK);
        let fi = bicgk_fused_impl(&prog, &lib, &g, 8, IterDim::Row);
        let plan = generate(&prog, &lib, &fi);
        assert!(plan.barriers_per_iter >= 1, "expected in-loop barriers");
    }

    #[test]
    fn iter_dim_swaps_hoisting() {
        let (prog, lib, g) = setup(BICGK);
        let fi = bicgk_fused_impl(&prog, &lib, &g, 8, IterDim::Col);
        let plan = generate(&prog, &lib, &fi);
        let find = |var: &str, load: bool| {
            plan.steps
                .iter()
                .find(|s| s.op.var.as_deref() == Some(var) && (load == s.op.kind.is_load()))
                .unwrap()
        };
        // now r (Row-indexed) is invariant and q accumulates
        assert_eq!(find("r", true).hoist, Hoist::BeforeLoop);
        assert_eq!(find("q", false).hoist, Hoist::AfterLoop);
        assert_eq!(find("p", true).hoist, Hoist::InLoop);
        assert_eq!(find("s", false).hoist, Hoist::InLoop);
    }

    const AXPYDOT: &str = "
        vector<N> w, v, u, z; scalar r;
        input w, v, u;
        z = waxpby(w, v, alpha=1.0, beta=-2.0);
        r = sdot(z, u);
        return z, r;
    ";

    #[test]
    fn axpydot_fused_keeps_z_in_registers() {
        let (prog, lib, g) = setup(AXPYDOT);
        let f = enumerate_fusions(&prog, &lib, &g).remove(0);
        let axes = ImplAxes {
            iters: vec![1],
            ipb: vec![4],
            max_orders: 2,
            both_iter_dims: false,
        };
        let fi = gen_impls(&prog, &lib, &g, &f, &axes)
            .into_iter()
            .find(|i| i.variant == vec![0, 0])
            .unwrap();
        let plan = generate(&prog, &lib, &fi);
        // z passes via registers: smem holds only the dot scratch.
        assert!(
            plan.smem_words <= 32 * 4,
            "z should not occupy smem: {} words",
            plan.smem_words
        );
        // traffic: loads w, v, u (3n), stores z (n) + dot partials
        let p = ProblemSize::new(32, 1 << 20);
        let words = plan.traffic.total_words().eval(p);
        let n = (1 << 20) as f64;
        assert!((words - 4.0 * n).abs() < 0.01 * n, "words {words} vs 4n {n}");
        assert!((plan.flops.eval(p) - 5.0 * n).abs() < 1e-6); // 3n waxpby + 2n dot
    }

    #[test]
    fn unfused_singleton_plan() {
        let (prog, lib, _g) = setup(AXPYDOT);
        let fi = FusionImpl {
            fusion: Fusion::singleton(CallId(0), &prog, &lib),
            order: vec![CallId(0)],
            variant: vec![0],
            ipb: 4,
            iters: 1,
            iter_dim: IterDim::Elem,
        };
        let plan = generate(&prog, &lib, &fi);
        let p = ProblemSize::new(32, 1 << 20);
        let n = (1 << 20) as f64;
        // waxpby: load w, v (2n), store z (n)
        assert!((plan.traffic.total_words().eval(p) - 3.0 * n).abs() < 1.0);
        assert_eq!(plan.grid.threads_per_block(), 128);
    }

    #[test]
    fn compile_seq_covers_all_calls() {
        let (prog, lib, g) = setup(AXPYDOT);
        let f = enumerate_fusions(&prog, &lib, &g).remove(0);
        let fi = gen_impls(&prog, &lib, &g, &f, &ImplAxes::minimal())
            .into_iter()
            .next()
            .unwrap();
        let sp = compile_seq(&prog, &lib, &[fi], "fused");
        assert_eq!(sp.kernels.len(), 1);
        assert_eq!(sp.kernels[0].members.len(), 2);
    }

    #[test]
    #[should_panic(expected = "cover every call")]
    fn compile_seq_rejects_partial_coverage() {
        let (prog, lib, _) = setup(AXPYDOT);
        let fi = FusionImpl {
            fusion: Fusion::singleton(CallId(0), &prog, &lib),
            order: vec![CallId(0)],
            variant: vec![0],
            ipb: 1,
            iters: 1,
            iter_dim: IterDim::Elem,
        };
        compile_seq(&prog, &lib, &[fi], "bad");
    }

    #[test]
    fn gemver_fused_plan_shape() {
        let src = "
            matrix<MxN> A, B;
            vector<M> u1, u2, y, w;
            vector<N> v1, v2, z, x;
            input A, u1, v1, u2, v2, y, z;
            B = sger2(A, u1, v1, u2, v2);
            x = sgemtvpz(B, y, z);
            w = sgemv(B, x);
            return B, x, w;
        ";
        let (prog, lib, g) = setup(src);
        let f = enumerate_fusions(&prog, &lib, &g).remove(0);
        let fi = gen_impls(&prog, &lib, &g, &f, &ImplAxes::minimal())
            .into_iter()
            .next()
            .unwrap();
        let plan = generate(&prog, &lib, &fi);
        // fused k1 loads A once, stores B once (it escapes), no reload of
        // B for gemtvpz; subvector terms stay small (< 0.25·mn)
        assert!((plan.traffic.loads.mn - 1.0).abs() < 0.25, "{:?}", plan.traffic.loads);
        assert!((plan.traffic.stores.mn - 1.0).abs() < 0.25, "{:?}", plan.traffic.stores);
    }
}
