//! # fusebla — kernel-fusion compiler for BLAS sequences
//!
//! Reproduction of *“Optimizing CUDA Code By Kernel Fusion — Application
//! on BLAS”* (Filipovič et al., 2013) as a three-layer Rust + JAX/Pallas
//! stack:
//!
//! * **L3 (this crate)** — the paper's contribution: a source-to-source
//!   fusion compiler over a library of elementary map/reduce functions,
//!   an optimization-space search with empirical performance prediction
//!   (the [`planner`] runs it memoized, pruned and in parallel on the
//!   hot path), a calibrated GTX 480 timing model standing in for the
//!   paper's testbed, and a PJRT runtime served through the batching
//!   [`Engine`]/[`Client`] facade behind an LRU plan cache, executing
//!   resolve-once plans (indexed manifest + slot-interned environments
//!   + pinned executables — see [`runtime`]). The engine serves a
//!   heterogeneous *fleet*: one worker (plan cache, calibration) per
//!   registered device, with predictor-guided routing in front — see
//!   [`fleet`].
//! * **L2 (python/compile)** — JAX definitions of each BLAS sequence.
//! * **L1 (python/compile/kernels)** — Pallas kernels (fused and
//!   elementary) mirroring the paper's 32×32-tile scheme.
//!
//! See `DESIGN.md` for the architecture and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod autotune;
pub mod bench_support;
pub mod codegen;
pub mod coordinator;
pub mod fleet;
pub mod fusion;
pub mod graph;
pub mod ir;
pub mod library;
pub mod pipelines;
pub mod planner;
pub mod predict;
pub mod runtime;
pub mod script;
pub mod sequences;
pub mod sim;
pub mod split;
pub mod util;

pub use coordinator::{
    Client, Engine, EngineConfig, Fault, FaultPlan, FleetMetrics, ServeError, SubmitRequest, Ticket,
};
pub use fleet::{DeviceId, DeviceRegistry};
