//! Fusion-implementation generation (paper §4.2, step "generation of
//! fusion implementations"): each fusion can be implemented many ways,
//! differing in (i) calling order, (ii) chosen implementations of the
//! elementary functions, (iii) block size and (iv) number of serial
//! iterations. Depth-2 kernels additionally choose which matrix axis the
//! serial loop walks (the BiCGK kernel of Algorithm 3 iterates rows).

use super::Fusion;
use crate::graph::DepGraph;
use crate::ir::plan::IterDim;
use crate::ir::program::{CallId, Program};
use crate::library::Library;

/// Enumeration knobs. Defaults mirror the paper's search ranges; benches
/// shrink or widen them for the ablation study.
#[derive(Clone, Debug)]
pub struct ImplAxes {
    /// Serial iteration counts to try (paper: "certain ranges of …
    /// sequential iterations").
    pub iters: Vec<u32>,
    /// Instances per block for depth-1 kernels (block = 32·ipb threads
    /// for the tuned variants).
    pub ipb: Vec<u32>,
    /// Cap on calling orders enumerated per fusion.
    pub max_orders: usize,
    /// Explore both serial-loop axes for depth-2 kernels.
    pub both_iter_dims: bool,
}

impl Default for ImplAxes {
    fn default() -> Self {
        ImplAxes {
            iters: vec![1, 2, 4, 8, 16],
            ipb: vec![1, 2, 4, 8],
            max_orders: 6,
            both_iter_dims: true,
        }
    }
}

impl ImplAxes {
    /// A minimal axis set (fast compiles; used by `--first` mode).
    pub fn minimal() -> Self {
        ImplAxes {
            iters: vec![1, 8],
            ipb: vec![4],
            max_orders: 2,
            both_iter_dims: true,
        }
    }
}

/// One concrete implementation choice for a fusion.
#[derive(Clone, Debug)]
pub struct FusionImpl {
    pub fusion: Fusion,
    /// Member calls in chosen execution order.
    pub order: Vec<CallId>,
    /// Variant index per member (parallel to `order`).
    pub variant: Vec<usize>,
    /// Instances per block (depth-1 packing; 1 for tile kernels).
    pub ipb: u32,
    /// Serial iterations (grid shrink factor).
    pub iters: u32,
    pub iter_dim: IterDim,
}

impl FusionImpl {
    /// Stable label used in plan names and artifact keys, e.g.
    /// `o0.v1_0.b4.i8.row`.
    pub fn label(&self) -> String {
        let v: Vec<String> = self.variant.iter().map(|x| x.to_string()).collect();
        format!(
            "v{}.b{}.i{}.{}",
            v.join("_"),
            self.ipb,
            self.iters,
            self.iter_dim
        )
    }

    pub fn variant_of(&self, c: CallId) -> usize {
        let i = self
            .order
            .iter()
            .position(|&x| x == c)
            .expect("call not in fusion");
        self.variant[i]
    }
}

fn cartesian_variants(lib: &Library, prog: &Program, order: &[CallId]) -> Vec<Vec<usize>> {
    let counts: Vec<usize> = order
        .iter()
        .map(|c| lib.get(prog.call(*c).func).variants.len())
        .collect();
    let total: usize = counts.iter().product();
    let mut out = Vec::with_capacity(total);
    for mut idx in 0..total {
        let mut choice = Vec::with_capacity(counts.len());
        for &c in &counts {
            choice.push(idx % c);
            idx /= c;
        }
        out.push(choice);
    }
    out
}

/// Generate all implementations of a fusion under the given axes.
pub fn gen_impls(
    prog: &Program,
    lib: &Library,
    graph: &DepGraph,
    fusion: &Fusion,
    axes: &ImplAxes,
) -> Vec<FusionImpl> {
    let orders = graph.topo_orders_of(&fusion.calls, axes.max_orders);
    let iter_dims: Vec<IterDim> = if fusion.depth == 1 {
        vec![IterDim::Elem]
    } else if axes.both_iter_dims {
        vec![IterDim::Row, IterDim::Col]
    } else {
        vec![IterDim::Row]
    };
    let ipbs: Vec<u32> = if fusion.depth == 1 {
        axes.ipb.clone()
    } else {
        vec![1] // one tile instance per block (§4.4)
    };

    let mut out = Vec::new();
    for order in &orders {
        for variant in cartesian_variants(lib, prog, order) {
            for &ipb in &ipbs {
                for &iters in &axes.iters {
                    for &iter_dim in &iter_dims {
                        out.push(FusionImpl {
                            fusion: fusion.clone(),
                            order: order.clone(),
                            variant: variant.clone(),
                            ipb,
                            iters,
                            iter_dim,
                        });
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::enumerate_fusions;
    use crate::script::compile_script;

    fn setup(src: &str) -> (Program, Library, DepGraph) {
        let lib = Library::standard();
        let prog = compile_script("t", src, &lib).unwrap();
        let g = DepGraph::build(&prog, &lib);
        (prog, lib, g)
    }

    const BICGK: &str = "
        matrix<MxN> A; vector<N> p, s; vector<M> q, r;
        input A, p, r;
        q = sgemv(A, p);
        s = sgemtv(A, r);
        return q, s;
    ";

    #[test]
    fn bicgk_impl_count() {
        let (prog, lib, g) = setup(BICGK);
        let f = &enumerate_fusions(&prog, &lib, &g)[0];
        let axes = ImplAxes::default();
        let impls = gen_impls(&prog, &lib, &g, f, &axes);
        // orders(2) × variants(3·3) × ipb(1) × iters(5) × dims(2) = 180
        assert_eq!(impls.len(), 180);
        // depth-2 fusions never pack instances
        assert!(impls.iter().all(|i| i.ipb == 1));
    }

    #[test]
    fn singleton_depth1_impls() {
        let src = "
            vector<N> x, y; input x;
            y = sscal(x, alpha=2.0); return y;
        ";
        let (prog, lib, g) = setup(src);
        let f = Fusion::singleton(CallId(0), &prog, &lib);
        let impls = gen_impls(&prog, &lib, &g, &f, &ImplAxes::default());
        // variants(3) × ipb(4) × iters(5) × dims(1) = 60
        assert_eq!(impls.len(), 60);
        assert!(impls.iter().all(|i| i.iter_dim == IterDim::Elem));
    }

    #[test]
    fn minimal_axes_shrink_space() {
        let (prog, lib, g) = setup(BICGK);
        let f = &enumerate_fusions(&prog, &lib, &g)[0];
        let impls = gen_impls(&prog, &lib, &g, f, &ImplAxes::minimal());
        // orders(2) × variants(9) × iters(2) × dims(2) = 72
        assert_eq!(impls.len(), 72);
    }

    #[test]
    fn labels_unique_within_order() {
        let (prog, lib, g) = setup(BICGK);
        let f = &enumerate_fusions(&prog, &lib, &g)[0];
        let impls = gen_impls(&prog, &lib, &g, f, &ImplAxes::minimal());
        let mut labels: Vec<String> = impls
            .iter()
            .map(|i| format!("{:?}{}", i.order, i.label()))
            .collect();
        let before = labels.len();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), before);
    }

    #[test]
    fn variant_of_maps_by_call() {
        let (prog, lib, g) = setup(BICGK);
        let f = &enumerate_fusions(&prog, &lib, &g)[0];
        let impls = gen_impls(&prog, &lib, &g, f, &ImplAxes::minimal());
        let i = &impls[0];
        for &c in &i.order {
            let _ = i.variant_of(c); // must not panic
        }
    }
}
