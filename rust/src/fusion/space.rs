//! Optimization-space construction (paper §4.2, step "generation of
//! combinations of fusion implementations").
//!
//! A *partition* selects a set of fusions plus singletons covering every
//! call in the script. A *combination* further chooses one concrete
//! implementation per part. The space is pruned exactly as the paper
//! describes: fusions that spare no transfers never enter (handled at
//! enumeration), and fusion implementations dominated by another
//! implementation of the same fusion — no better in on-chip memory,
//! traffic, or synchronization — are dropped.

use super::implgen::{gen_impls, FusionImpl, ImplAxes};
use super::Fusion;
use crate::codegen;
use crate::graph::DepGraph;
use crate::ir::plan::KernelPlan;
use crate::ir::program::{CallId, Program};
use crate::library::Library;
use std::collections::{BTreeMap, BTreeSet};

/// One way of covering all calls with fusions + singletons.
#[derive(Clone, Debug)]
pub struct Partition {
    pub parts: Vec<Fusion>,
}

impl Partition {
    pub fn label(&self, prog: &Program, lib: &Library) -> String {
        self.parts
            .iter()
            .map(|p| p.label(prog, lib))
            .collect::<Vec<_>>()
            .join(" | ")
    }

    /// Number of multi-call parts (0 = fully unfused).
    pub fn n_fused(&self) -> usize {
        self.parts.iter().filter(|p| !p.is_singleton()).count()
    }
}

/// Enumerate every partition of the calls into non-overlapping parts
/// drawn from `fusions` ∪ singletons.
pub fn enumerate_partitions(
    prog: &Program,
    lib: &Library,
    fusions: &[Fusion],
) -> Vec<Partition> {
    let n = prog.calls.len();
    let mut out = Vec::new();
    let mut parts: Vec<Fusion> = Vec::new();
    fn rec(
        next: usize,
        n: usize,
        covered: &mut BTreeSet<CallId>,
        parts: &mut Vec<Fusion>,
        fusions: &[Fusion],
        prog: &Program,
        lib: &Library,
        out: &mut Vec<Partition>,
    ) {
        if covered.len() == n {
            out.push(Partition {
                parts: parts.clone(),
            });
            return;
        }
        // first uncovered call
        let c = (next..n)
            .map(CallId)
            .find(|c| !covered.contains(c))
            .unwrap();
        // option 1: c stays a singleton
        let s = Fusion::singleton(c, prog, lib);
        covered.insert(c);
        parts.push(s);
        rec(next + 1, n, covered, parts, fusions, prog, lib, out);
        parts.pop();
        covered.remove(&c);
        // option 2: any fusion containing c and disjoint from covered
        for f in fusions {
            if !f.contains(c) || f.calls.iter().any(|x| covered.contains(x)) {
                continue;
            }
            for &x in &f.calls {
                covered.insert(x);
            }
            parts.push(f.clone());
            rec(next + 1, n, covered, parts, fusions, prog, lib, out);
            parts.pop();
            for &x in &f.calls {
                covered.remove(&x);
            }
        }
    }
    let mut covered = BTreeSet::new();
    rec(0, n, &mut covered, &mut parts, fusions, prog, lib, &mut out);
    out
}

/// An implementation with its generated plan (the unit the predictor
/// ranks and the autotuner runs).
#[derive(Clone, Debug)]
pub struct PlannedImpl {
    pub fi: FusionImpl,
    pub plan: KernelPlan,
}

/// Generate + prune the implementations of one part.
///
/// Pruning follows the paper's on-chip rule: an implementation is
/// dropped when another implementation of the same fusion **with the
/// same calling order, block packing, iteration count and loop axis**
/// (i.e. differing only in the chosen elementary-function variants) uses
/// no less on-chip memory and registers while offering no better
/// instruction efficiency — it is dominated in resources with nothing in
/// return. Configuration axes (iterations, packing, loop axis) are left
/// to the performance predictor, which is what ranks them in the paper.
pub fn planned_impls(
    prog: &Program,
    lib: &Library,
    graph: &DepGraph,
    part: &Fusion,
    axes: &ImplAxes,
) -> Vec<PlannedImpl> {
    let all: Vec<PlannedImpl> = gen_impls(prog, lib, graph, part, axes)
        .into_iter()
        .map(|fi| {
            let plan = codegen::generate(prog, lib, &fi);
            PlannedImpl { fi, plan }
        })
        .collect();
    // Precompute group/resource keys once — the pairwise domination scan
    // is O(n²) and cloning per pair dominated space construction
    // (EXPERIMENTS.md §Perf).
    let groups: Vec<(&[CallId], u32, u32, crate::ir::plan::IterDim)> = all
        .iter()
        .map(|p| (p.fi.order.as_slice(), p.fi.ipb, p.fi.iters, p.fi.iter_dim))
        .collect();
    let keys: Vec<(u32, u32, i64)> = all
        .iter()
        .map(|p| {
            (
                p.plan.smem_words,
                p.plan.regs_per_thread,
                // negate efficiency so "smaller is better" uniformly
                -(p.plan.compute_efficiency * 1e6) as i64,
            )
        })
        .collect();
    let mut keep = Vec::with_capacity(all.len());
    'outer: for i in 0..all.len() {
        let (ga, ka) = (&groups[i], keys[i]);
        for j in 0..all.len() {
            if i == j || &groups[j] != ga {
                continue;
            }
            let kb = keys[j];
            let no_worse = kb.0 <= ka.0 && kb.1 <= ka.1 && kb.2 <= ka.2;
            let strictly = kb != ka;
            if (no_worse && strictly) || (kb == ka && j < i) {
                continue 'outer;
            }
        }
        keep.push(all[i].clone());
    }
    keep
}

/// The pruned optimization space of a whole script.
pub struct Space {
    pub partitions: Vec<Partition>,
    /// Pruned implementations per partition part:
    /// `impls[pi][part_idx]` = candidates for that part.
    pub impls: Vec<Vec<Vec<PlannedImpl>>>,
}

impl Space {
    pub fn build(
        prog: &Program,
        lib: &Library,
        graph: &DepGraph,
        fusions: &[Fusion],
        axes: &ImplAxes,
    ) -> Space {
        let partitions = enumerate_partitions(prog, lib, fusions);
        // One pruned impl list per distinct fusion (parts repeat across
        // partitions), keyed by call set. This reuse is a compiler-side
        // dedup AND a contract: `planner::CostCache` keys kernel costs
        // by (call set, impl index), which is only sound because every
        // occurrence of a part resolves to the same list built here.
        let mut cache: BTreeMap<Vec<usize>, Vec<PlannedImpl>> = BTreeMap::new();
        let mut impls = Vec::with_capacity(partitions.len());
        for part_list in &partitions {
            let mut per_part = Vec::with_capacity(part_list.parts.len());
            for part in &part_list.parts {
                let key: Vec<usize> = part.calls.iter().map(|c| c.0).collect();
                if let Some(v) = cache.get(&key) {
                    per_part.push(v.clone());
                } else {
                    let v = planned_impls(prog, lib, graph, part, axes);
                    cache.insert(key, v.clone());
                    per_part.push(v);
                }
            }
            impls.push(per_part);
        }
        Space { partitions, impls }
    }

    /// Total number of combinations of fusion implementations
    /// (Table 4's "Impl. count").
    pub fn combination_count(&self) -> usize {
        self.impls
            .iter()
            .map(|per_part| {
                per_part
                    .iter()
                    .map(|v| v.len())
                    .product::<usize>()
            })
            .sum()
    }

    /// Iterate all combinations as (partition index, per-part impl
    /// indices). Callers materialize plans on demand.
    pub fn combinations(&self) -> impl Iterator<Item = (usize, Vec<usize>)> + '_ {
        self.impls.iter().enumerate().flat_map(|(pi, per_part)| {
            let counts: Vec<usize> = per_part.iter().map(|v| v.len()).collect();
            let total: usize = counts.iter().product();
            (0..total).map(move |mut ix| {
                let mut choice = Vec::with_capacity(counts.len());
                for &c in &counts {
                    choice.push(ix % c);
                    ix /= c;
                }
                (pi, choice)
            })
        })
    }

    /// Materialize one combination as the per-part implementations.
    pub fn combination(&self, pi: usize, choice: &[usize]) -> Vec<&PlannedImpl> {
        self.impls[pi]
            .iter()
            .zip(choice.iter())
            .map(|(v, &i)| &v[i])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::enumerate_fusions;
    use crate::script::compile_script;

    fn setup(src: &str) -> (Program, Library, DepGraph) {
        let lib = Library::standard();
        let prog = compile_script("t", src, &lib).unwrap();
        let g = DepGraph::build(&prog, &lib);
        (prog, lib, g)
    }

    const BICGK: &str = "
        matrix<MxN> A; vector<N> p, s; vector<M> q, r;
        input A, p, r;
        q = sgemv(A, p);
        s = sgemtv(A, r);
        return q, s;
    ";

    #[test]
    fn bicgk_partitions() {
        let (prog, lib, g) = setup(BICGK);
        let fusions = enumerate_fusions(&prog, &lib, &g);
        let parts = enumerate_partitions(&prog, &lib, &fusions);
        // {singleton, singleton} and {fused pair}
        assert_eq!(parts.len(), 2);
        assert_eq!(parts.iter().filter(|p| p.n_fused() == 1).count(), 1);
    }

    #[test]
    fn space_counts_and_prunes() {
        let (prog, lib, g) = setup(BICGK);
        let fusions = enumerate_fusions(&prog, &lib, &g);
        let axes = ImplAxes::default();
        let space = Space::build(&prog, &lib, &g, &fusions, &axes);
        let count = space.combination_count();
        assert!(count > 2, "space too small: {count}");
        // pruning must keep at least one impl per part
        for per_part in &space.impls {
            for v in per_part {
                assert!(!v.is_empty());
            }
        }
        // iterating combinations yields exactly `count`
        assert_eq!(space.combinations().count(), count);
    }

    #[test]
    fn pruning_reduces_space() {
        let (prog, lib, g) = setup(BICGK);
        let fusions = enumerate_fusions(&prog, &lib, &g);
        let axes = ImplAxes::default();
        let raw: usize = gen_impls(&prog, &lib, &g, &fusions[0], &axes).len();
        let pruned = planned_impls(&prog, &lib, &g, &fusions[0], &axes).len();
        assert!(pruned < raw, "pruning had no effect ({pruned} of {raw})");
        assert!(pruned >= 1);
    }

    #[test]
    fn atax_single_partition() {
        let src = "
            matrix<MxN> A; subvector32 x, t, y;
            input A, x;
            t = sgemv(A, x);
            y = sgemtv(A, t);
            return y;
        ";
        let (prog, lib, g) = setup(src);
        let fusions = enumerate_fusions(&prog, &lib, &g);
        assert!(fusions.is_empty());
        let parts = enumerate_partitions(&prog, &lib, &fusions);
        assert_eq!(parts.len(), 1); // all singletons, only option
        assert_eq!(parts[0].parts.len(), 2);
    }

    #[test]
    fn combination_materializes() {
        let (prog, lib, g) = setup(BICGK);
        let fusions = enumerate_fusions(&prog, &lib, &g);
        let space = Space::build(&prog, &lib, &g, &fusions, &ImplAxes::minimal());
        let (pi, choice) = space.combinations().next().unwrap();
        let combo = space.combination(pi, &choice);
        let total_calls: usize = combo.iter().map(|p| p.fi.fusion.len()).sum();
        assert_eq!(total_calls, prog.calls.len());
    }
}
