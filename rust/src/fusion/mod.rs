//! Fusion enumeration (paper §4.2, step "generation of fusions").
//!
//! A *fusion* is a fusible subgraph of the dependency graph: a set of
//! elementary calls that can be glued into one kernel without changing
//! program semantics. Fusibility rules (§3.2):
//!
//! * all members share one nesting depth (mixing depths repeats the
//!   shallower function's work — the compiler refuses, §4.3.2);
//! * no *internal* reduction edge: a reduce / mapped-reduce result needs
//!   a global barrier, so its consumer cannot sit in the same kernel;
//! * the set is weakly connected (otherwise nothing is shared) and
//!   convex (no dependency path leaves and re-enters — such a set cannot
//!   be scheduled as a single kernel);
//! * the fusion spares global-memory transfers (step "pruning": fusions
//!   which do not spare memory transfers are dropped) — either an
//!   intermediate stays on-chip or a shared input is read once.

pub mod implgen;
pub mod space;

pub use implgen::{gen_impls, FusionImpl, ImplAxes};
pub use space::{enumerate_partitions, Partition};

use crate::graph::DepGraph;
use crate::ir::elem::VarType;
use crate::ir::plan::Poly2;
use crate::ir::program::{CallId, Program, VarId};
use crate::library::Library;
use std::collections::BTreeSet;

/// A candidate fusion: a fusible set of calls (singletons are the
/// degenerate case — one call, no sparing requirement).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fusion {
    pub calls: BTreeSet<CallId>,
    pub depth: u8,
}

impl Fusion {
    pub fn singleton(c: CallId, prog: &Program, lib: &Library) -> Fusion {
        let depth = lib.get(prog.call(c).func).depth();
        Fusion {
            calls: [c].into(),
            depth,
        }
    }

    pub fn len(&self) -> usize {
        self.calls.len()
    }

    pub fn is_empty(&self) -> bool {
        self.calls.is_empty()
    }

    pub fn is_singleton(&self) -> bool {
        self.calls.len() == 1
    }

    pub fn contains(&self, c: CallId) -> bool {
        self.calls.contains(&c)
    }

    /// Human-readable id, e.g. `sgemv_0+sgemtv_1`.
    pub fn label(&self, prog: &Program, lib: &Library) -> String {
        self.calls
            .iter()
            .map(|c| format!("{}_{}", lib.get(prog.call(*c).func).name, c.0))
            .collect::<Vec<_>>()
            .join("+")
    }
}

/// Total words of one variable at problem scale (matrix → m·n, vector →
/// its dim, scalar → 1).
pub fn var_words(prog: &Program, v: VarId) -> Poly2 {
    let decl = prog.var(v);
    match decl.ty {
        VarType::Scalar => Poly2::constant(1.0),
        VarType::Vector => match decl.dims[0].0.as_str() {
            "M" => Poly2::m(1.0),
            _ => Poly2::n(1.0),
        },
        VarType::Matrix => Poly2::mn(1.0),
    }
}

/// Words of global traffic a fusion spares relative to running its
/// members as separate kernels (coarse, iteration-independent bound used
/// for enumeration-stage pruning; exact per-plan traffic comes from
/// codegen).
pub fn spared_words(prog: &Program, graph: &DepGraph, set: &BTreeSet<CallId>) -> Poly2 {
    let mut spared = Poly2::ZERO;
    // (a) intermediates passed on-chip: each internal edge spares the
    // consumer's load; if the variable dies inside the fusion it also
    // spares the producer's store.
    let mut counted_store: BTreeSet<VarId> = BTreeSet::new();
    for e in graph.internal_edges(set) {
        spared += var_words(prog, e.var);
        let escapes = prog.is_output(e.var)
            || prog.consumers(e.var).iter().any(|c| !set.contains(c));
        if !escapes && counted_store.insert(e.var) {
            spared += var_words(prog, e.var);
        }
    }
    // (b) shared inputs: a variable read by k>1 members from global is
    // loaded once instead of k times (BiCGK's matrix A).
    let mut seen: BTreeSet<VarId> = BTreeSet::new();
    for &c in set {
        for &arg in &prog.call(c).args {
            if prog.producer(arg).map(|p| set.contains(&p)) == Some(true) {
                continue; // already counted as internal edge
            }
            if !seen.insert(arg) {
                spared += var_words(prog, arg);
            }
        }
    }
    spared
}

/// Is `set` fusible under the §3.2 rules (ignoring the sparing test)?
pub fn is_fusible(
    prog: &Program,
    lib: &Library,
    graph: &DepGraph,
    set: &BTreeSet<CallId>,
) -> bool {
    if set.is_empty() {
        return false;
    }
    // uniform nesting depth
    let mut depths = set.iter().map(|c| lib.get(prog.call(*c).func).depth());
    let d0 = depths.next().unwrap();
    if !depths.all(|d| d == d0) {
        return false;
    }
    // no internal reduction edge
    if graph.internal_edges(set).any(|e| e.reduction) {
        return false;
    }
    // connected (dependency edges OR shared inputs — BiCGK's two calls
    // are linked only through the shared matrix A) + convex
    is_connected_with_shared_inputs(prog, graph, set) && graph.is_convex(set)
}

/// Weak connectivity over dependency edges ∪ shared-input links.
fn is_connected_with_shared_inputs(
    prog: &Program,
    graph: &DepGraph,
    set: &BTreeSet<CallId>,
) -> bool {
    if set.is_empty() {
        return false;
    }
    let nodes: Vec<CallId> = set.iter().copied().collect();
    let linked = |a: CallId, b: CallId| {
        graph.successors(a).any(|s| s == b)
            || graph.predecessors(a).any(|p| p == b)
            || prog
                .call(a)
                .args
                .iter()
                .any(|v| prog.call(b).args.contains(v))
    };
    let mut seen: BTreeSet<CallId> = [nodes[0]].into();
    let mut stack = vec![nodes[0]];
    while let Some(c) = stack.pop() {
        for &nb in &nodes {
            if !seen.contains(&nb) && linked(c, nb) {
                seen.insert(nb);
                stack.push(nb);
            }
        }
    }
    seen.len() == set.len()
}

/// Enumerate all reasonable fusions of size ≥ 2: fusible sets that spare
/// at least one word of transfer. Exhaustive over connected subgraphs —
/// scripts are short (the paper's longest has 3 calls; ours ≤ 6).
pub fn enumerate_fusions(prog: &Program, lib: &Library, graph: &DepGraph) -> Vec<Fusion> {
    let n = prog.calls.len();
    let mut out = Vec::new();
    // Enumerate subsets via bitmask — n ≤ 16 by construction.
    assert!(n <= 16, "script too long for exhaustive fusion enumeration");
    for mask in 1u32..(1 << n) {
        if mask.count_ones() < 2 {
            continue;
        }
        let set: BTreeSet<CallId> = (0..n)
            .filter(|i| mask & (1 << i) != 0)
            .map(|i| CallId(i))
            .collect();
        if !is_fusible(prog, lib, graph, &set) {
            continue;
        }
        if spared_words(prog, graph, &set).is_zero() {
            continue; // prunes fusions that spare no transfers
        }
        let depth = lib
            .get(prog.call(*set.iter().next().unwrap()).func)
            .depth();
        out.push(Fusion { calls: set, depth });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::script::compile_script;

    fn setup(src: &str) -> (Program, Library, DepGraph) {
        let lib = Library::standard();
        let prog = compile_script("t", src, &lib).unwrap();
        let g = DepGraph::build(&prog, &lib);
        (prog, lib, g)
    }

    const BICGK: &str = "
        matrix<MxN> A; vector<N> p, s; vector<M> q, r;
        input A, p, r;
        q = sgemv(A, p);
        s = sgemtv(A, r);
        return q, s;
    ";

    #[test]
    fn bicgk_fuses_on_shared_input() {
        let (prog, lib, g) = setup(BICGK);
        let fusions = enumerate_fusions(&prog, &lib, &g);
        assert_eq!(fusions.len(), 1);
        assert_eq!(fusions[0].len(), 2);
        assert_eq!(fusions[0].depth, 2);
        // sparing = one read of A = m·n words
        let sp = spared_words(&prog, &g, &fusions[0].calls);
        assert_eq!(sp.mn, 1.0);
    }

    const ATAX: &str = "
        matrix<MxN> A; subvector32 x, t, y;
        input A, x;
        t = sgemv(A, x);
        y = sgemtv(A, t);
        return y;
    ";

    #[test]
    fn atax_cannot_fuse() {
        // t is a reduction output consumed by the second call → global
        // barrier → no fusion (paper §5.1: "ATAX … cannot be improved
        // by fusion").
        let (prog, lib, g) = setup(ATAX);
        assert!(enumerate_fusions(&prog, &lib, &g).is_empty());
    }

    const AXPYDOT: &str = "
        vector<N> w, v, u, z; scalar r;
        input w, v, u;
        z = waxpby(w, v, alpha=1.0, beta=-2.0);
        r = sdot(z, u);
        return z, r;
    ";

    #[test]
    fn axpydot_fuses_map_into_reduce() {
        let (prog, lib, g) = setup(AXPYDOT);
        let fusions = enumerate_fusions(&prog, &lib, &g);
        assert_eq!(fusions.len(), 1);
        // z escapes (program output) → only the consumer load is spared.
        let sp = spared_words(&prog, &g, &fusions[0].calls);
        assert_eq!(sp.n, 1.0);
    }

    #[test]
    fn dying_intermediate_spares_store_too() {
        let src = "
            vector<N> a, b, c;
            input a;
            b = sscal(a, alpha=2.0);
            c = sscal(b, alpha=3.0);
            return c;
        ";
        let (prog, _, g) = setup(src);
        let set: BTreeSet<CallId> = [CallId(0), CallId(1)].into();
        // b dies inside → spare its store and its load: 2n words
        assert_eq!(spared_words(&prog, &g, &set).n, 2.0);
    }

    #[test]
    fn mixed_depth_not_fusible() {
        let src = "
            matrix<MxN> A; subvector32 x, t, y;
            input A, x;
            t = sgemv(A, x);
            y = sscal(t, alpha=2.0);
            return y;
        ";
        let (prog, lib, g) = setup(src);
        let set: BTreeSet<CallId> = [CallId(0), CallId(1)].into();
        assert!(!is_fusible(&prog, &lib, &g, &set));
    }

    const GEMVER: &str = "
        matrix<MxN> A, B;
        vector<M> u1, u2, y, w;
        vector<N> v1, v2, z, x;
        input A, u1, v1, u2, v2, y, z;
        B = sger2(A, u1, v1, u2, v2);
        x = sgemtvpz(B, y, z);
        w = sgemv(B, x);
        return B, x, w;
    ";

    #[test]
    fn gemver_fusion_structure() {
        let (prog, lib, g) = setup(GEMVER);
        let fusions = enumerate_fusions(&prog, &lib, &g);
        // {ger2, gemtvpz} is the only legal multi-call fusion:
        // the x edge (reduction) blocks {gemtvpz, gemv} and the triple;
        // {ger2, gemv} is non-convex (path ger2→gemtvpz→gemv re-enters).
        assert_eq!(fusions.len(), 1);
        let f = &fusions[0];
        assert!(f.contains(CallId(0)) && f.contains(CallId(1)));
        // B escapes (program output) → sparing is B's consumer load (mn).
        let sp = spared_words(&prog, &g, &f.calls);
        assert!(sp.mn >= 1.0);
    }

    #[test]
    fn label_is_stable() {
        let (prog, lib, g) = setup(BICGK);
        let f = &enumerate_fusions(&prog, &lib, &g)[0];
        assert_eq!(f.label(&prog, &lib), "sgemv_0+sgemtv_1");
    }

    #[test]
    fn singleton_helper() {
        let (prog, lib, _) = setup(BICGK);
        let s = Fusion::singleton(CallId(0), &prog, &lib);
        assert!(s.is_singleton());
        assert_eq!(s.depth, 2);
    }
}
