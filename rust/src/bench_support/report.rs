//! Machine-readable bench output.
//!
//! Each bench target merges its own section into one JSON report file
//! (`BENCH_hotpath.json` at the crate root), so re-running a single
//! bench refreshes its numbers without clobbering the others and the
//! perf trajectory stays diffable across PRs:
//!
//! ```json
//! {
//!   "hotpath": { "dispatch_speedup": 9.3, ... },
//!   "serve_throughput": { "req_per_sec_batched": 41000.0, ... }
//! }
//! ```

use crate::util::Json;
use std::path::Path;

/// Default report file, relative to the bench working directory (the
/// crate root under `cargo bench`).
pub const BENCH_JSON: &str = "BENCH_hotpath.json";

/// Merge `section` into the JSON report at `path`: existing sections
/// are preserved, the named one is replaced. A missing or unparseable
/// file starts a fresh report.
pub fn update_bench_json(path: &Path, section: &str, value: Json) -> std::io::Result<()> {
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .unwrap_or_else(|| Json::Obj(Vec::new()));
    if !matches!(root, Json::Obj(_)) {
        root = Json::Obj(Vec::new());
    }
    root.set(section, value);
    std::fs::write(path, root.to_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("fusebla_report_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        dir.join(name)
    }

    #[test]
    fn sections_merge_without_clobbering() {
        let path = scratch("merge.json");
        let _ = std::fs::remove_file(&path);
        update_bench_json(&path, "a", Json::Obj(vec![("x".into(), Json::num(1.0))])).unwrap();
        update_bench_json(&path, "b", Json::Obj(vec![("y".into(), Json::num(2.0))])).unwrap();
        update_bench_json(&path, "a", Json::Obj(vec![("x".into(), Json::num(3.0))])).unwrap();
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(root.get("a").unwrap().get("x").and_then(Json::as_f64), Some(3.0));
        assert_eq!(root.get("b").unwrap().get("y").and_then(Json::as_f64), Some(2.0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_report_starts_fresh() {
        let path = scratch("corrupt.json");
        std::fs::write(&path, "not json {{{").unwrap();
        update_bench_json(&path, "a", Json::num(1.0)).unwrap();
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(root.get("a").and_then(Json::as_f64), Some(1.0));
        let _ = std::fs::remove_file(&path);
    }
}
