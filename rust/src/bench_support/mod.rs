//! Shared harness regenerating every table and figure of the paper's
//! evaluation section. Used by `cargo bench` targets and the CLI.
//! [`report`] adds the machine-readable side: benches merge their
//! results into `BENCH_hotpath.json` via [`report::update_bench_json`].

pub mod report;

use crate::autotune::{self, SearchReport};
use crate::coordinator::Context;
use crate::fusion::ImplAxes;
use crate::ir::elem::ProblemSize;
use crate::ir::plan::SeqPlan;
use crate::sequences::{self, Sequence};
use crate::sim::{simulate_seq, SeqTiming};
use crate::util::{fmt_duration, fmt_gflops, Table};
use std::collections::BTreeMap;

/// Write a minimal parseable artifact catalog (one fused stage-0
/// stanza per sequence at m=32, n=65536, with a stub HLO text) into a
/// fresh scratch directory, and return that directory. Enough to start
/// an engine without built artifacts: planning and the control plane
/// work end-to-end; only execution fails, at the offline stub backend.
/// One definition shared by the shard bench and the integration tests,
/// so the manifest wire format lives in one place.
pub fn stub_catalog(tag: &str, seqs: &[&str]) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("fusebla_stub_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut manifest = String::new();
    for seq in seqs {
        manifest.push_str(&format!(
            "artifact {seq}.fused.m32n65536.s0\n file {seq}.hlo.txt\n seq {seq}\n variant fused\n \
             stage 0\n in x:f32[65536]\n in y:f32[65536]\n out w:f32[65536]\n m 32\n n 65536\nend\n"
        ));
        std::fs::write(dir.join(format!("{seq}.hlo.txt")), format!("HloModule {seq}\n")).unwrap();
    }
    std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
    dir
}

/// Evaluation sizes (paper: "sized to GPU memory"; our model is
/// analytic, so the paper-scale sizes are free).
pub fn eval_size(seq: &Sequence) -> ProblemSize {
    if seq.is_blas2() {
        ProblemSize::square(8192)
    } else {
        ProblemSize::new(32, 1 << 24)
    }
}

/// Full per-sequence evaluation: compiler search + baseline simulation.
pub struct SeqEval {
    pub seq: Sequence,
    pub report: SearchReport,
    pub ours: SeqTiming,
    pub cublas: SeqTiming,
}

/// Lazy cache of per-sequence evaluations (search is the expensive part;
/// tables 2–5 share it).
#[derive(Default)]
pub struct Evaluator {
    cache: BTreeMap<String, SeqEval>,
}

/// Implementation axes per sequence: GEMVER's space explodes
/// combinatorially (the paper's 1271-implementation case takes 42 s
/// to generate there too) — trim the iteration axis to keep the
/// all-implementations path responsive while preserving the ordering
/// GEMVER ≫ GESUMMV ≫ rest. Shared by the evaluator, the planner bench
/// and the autotune-report example.
pub fn eval_axes(seq: &Sequence) -> ImplAxes {
    if seq.program_calls() >= 3 {
        ImplAxes {
            iters: vec![1, 4, 16],
            ipb: vec![2, 8],
            max_orders: 4,
            both_iter_dims: true,
        }
    } else {
        ImplAxes::default()
    }
}

impl Evaluator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn eval(&mut self, ctx: &Context, name: &str) -> &SeqEval {
        if !self.cache.contains_key(name) {
            let seq = sequences::by_name(name).unwrap_or_else(|| panic!("no sequence {name}"));
            let p = eval_size(&seq);
            let flops = seq.flops.eval(p);
            let (prog, graph) = seq.graph(&ctx.lib);
            let axes = eval_axes(&seq);
            let report =
                autotune::search(&prog, &ctx.lib, &graph, &ctx.dev, &ctx.db, &axes, p);
            let ours = simulate_seq(&ctx.dev, &report.best, p, flops);
            let cublas_prog = seq.cublas_program(&ctx.lib);
            let cublas_plan = autotune::baseline_plan(&cublas_prog, &ctx.lib);
            let cublas = simulate_seq(&ctx.dev, &cublas_plan, p, flops);
            self.cache.insert(
                name.to_string(),
                SeqEval {
                    seq,
                    report,
                    ours,
                    cublas,
                },
            );
        }
        &self.cache[name]
    }
}

impl Sequence {
    fn program_calls(&self) -> usize {
        self.script.matches('=').count() - self.script.matches("alpha=").count()
            - self.script.matches("beta=").count()
    }
}

/// Table 1: the studied sequences and their tags.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1 — sequences used in the performance study",
        &["Sequence", "Operation", "Tag"],
    );
    let ops: &[(&str, &str)] = &[
        ("axpydot", "z = w - a*v ; r = z'u"),
        ("atax", "y = A'Ax"),
        ("bicgk", "q = Ap ; s = A'r"),
        ("sgemv", "z = a*Ax + b*y"),
        ("sgemvt", "x = b*A'y + z ; w = a*Ax"),
        ("sscal", "x = a*x"),
        ("gemver", "B = A + u1v1' + u2v2' ; x = b*B'y + z ; w = a*Bx"),
        ("gesummv", "y = a*Ax + b*Bx"),
        ("madd", "C = A + B"),
        ("vadd", "x = w + y + z"),
        ("waxpby", "w = a*x + b*y"),
    ];
    for (name, op) in ops {
        let seq = sequences::by_name(name).unwrap();
        t.row(&[name.to_uppercase(), op.to_string(), seq.tag.to_string()]);
    }
    t
}

/// Table 2: generated vs CUBLAS GFlops (model) with the paper's numbers.
pub fn table2(ctx: &Context, ev: &mut Evaluator) -> Table {
    let mut t = Table::new(
        "Table 2 — performance vs CUBLAS (GTX480 model; paper values for reference)",
        &[
            "Sequence", "Ours", "CUBLAS", "Speedup", "Tag",
            "Paper ours", "Paper CUBLAS", "Paper speedup",
        ],
    );
    for seq in sequences::all() {
        let e = ev.eval(ctx, seq.name);
        let speedup = e.ours.gflops / e.cublas.gflops;
        t.row(&[
            seq.name.to_uppercase(),
            fmt_gflops(e.ours.gflops),
            fmt_gflops(e.cublas.gflops),
            format!("{speedup:.2}x"),
            seq.tag.to_string(),
            fmt_gflops(seq.paper.ours_gflops),
            fmt_gflops(seq.paper.cublas_gflops),
            format!("{:.2}x", seq.paper.speedup),
        ]);
    }
    t
}

/// Table 3: speedup comparison with BTO BLAS + our kernel bandwidth.
pub fn table3(ctx: &Context, ev: &mut Evaluator) -> Table {
    let mut t = Table::new(
        "Table 3 — speedup vs BTO BLAS (CPU, quoted from paper) and kernel bandwidth",
        &[
            "Sequence", "Our speedup", "Paper speedup", "BTO speedup",
            "Our bandwidth", "Paper bandwidth",
        ],
    );
    for seq in sequences::all() {
        let e = ev.eval(ctx, seq.name);
        let speedup = e.ours.gflops / e.cublas.gflops;
        t.row(&[
            seq.name.to_uppercase(),
            format!("{speedup:.2}x"),
            format!("{:.2}x", seq.paper.speedup),
            seq.paper
                .bto_speedup
                .map(|s| format!("{s:.2}x"))
                .unwrap_or_else(|| "n/a".into()),
            format!("{:.1} GB/s", e.ours.bandwidth_gbs),
            format!("{:.1} GB/s", seq.paper.bandwidth_gbs),
        ]);
    }
    t
}

/// Table 4: optimization-space size and prediction accuracy.
pub fn table4(ctx: &Context, ev: &mut Evaluator) -> Table {
    let mut t = Table::new(
        "Table 4 — implementation count, rank of best, first/worst relative perf",
        &[
            "Sequence", "Impl count", "Best found", "First impl", "Worst impl",
            "Paper count", "Paper best",
        ],
    );
    for seq in sequences::all() {
        let e = ev.eval(ctx, seq.name);
        let r = &e.report;
        t.row(&[
            seq.name.to_uppercase(),
            r.impl_count.to_string(),
            format!("{}{}", r.best_rank, ordinal(r.best_rank)),
            format!("{:.1}%", r.first_pct),
            r.worst_pct
                .map(|w| format!("{w:.1}%"))
                .unwrap_or_else(|| "n/a".into()),
            seq.paper.impl_count.to_string(),
            format!("{}{}", seq.paper.best_rank, ordinal(seq.paper.best_rank)),
        ]);
    }
    t
}

/// Table 5: compile and search wallclock.
pub fn table5(ctx: &Context, ev: &mut Evaluator) -> Table {
    let mut t = Table::new(
        "Table 5 — compilation and empirical-search time (this machine vs paper's)",
        &[
            "Sequence", "First impl", "All impls", "Empirical search",
            "Paper first", "Paper all", "Paper search",
        ],
    );
    for seq in sequences::all() {
        let e = ev.eval(ctx, seq.name);
        let r = &e.report;
        t.row(&[
            seq.name.to_uppercase(),
            fmt_duration(r.t_first),
            fmt_duration(r.t_all),
            fmt_duration(r.t_search),
            fmt_duration(seq.paper.t_first_s),
            fmt_duration(seq.paper.t_all_s),
            fmt_duration(seq.paper.t_search_s),
        ]);
    }
    t
}

/// Scaling figure (5: BiCGK, 6: GEMVER): GFlops vs matrix size for the
/// fused/compiled plan and the CUBLAS baseline.
pub fn figure(ctx: &Context, seq_name: &str) -> Table {
    let seq = sequences::by_name(seq_name).unwrap();
    let fig = if seq_name == "bicgk" { 5 } else { 6 };
    let mut t = Table::new(
        &format!(
            "Figure {fig} — {} scaling (GFlops vs n; GTX480 model)",
            seq_name.to_uppercase()
        ),
        &["n", "Ours", "CUBLAS", "Speedup"],
    );
    let (prog, graph) = seq.graph(&ctx.lib);
    let cublas_prog = seq.cublas_program(&ctx.lib);
    let cublas_plan = autotune::baseline_plan(&cublas_prog, &ctx.lib);
    for k in 1..=16 {
        let n = k * 1024;
        let p = ProblemSize::square(n);
        let flops = seq.flops.eval(p);
        let best = autotune::compile_first(
            &prog,
            &ctx.lib,
            &graph,
            &ctx.db,
            &ImplAxes::default(),
            p,
        );
        let ours = simulate_seq(&ctx.dev, &best.plan, p, flops);
        let base = simulate_seq(&ctx.dev, &cublas_plan, p, flops);
        t.row(&[
            n.to_string(),
            fmt_gflops(ours.gflops),
            fmt_gflops(base.gflops),
            format!("{:.2}x", ours.gflops / base.gflops),
        ]);
    }
    t
}

/// Simulated plan pair for one sequence (used by ablation benches).
pub fn plans_for(ctx: &Context, name: &str) -> (SeqPlan, SeqPlan, ProblemSize, f64) {
    let seq = sequences::by_name(name).unwrap();
    let p = eval_size(&seq);
    let flops = seq.flops.eval(p);
    let (prog, graph) = seq.graph(&ctx.lib);
    let best = autotune::compile_first(&prog, &ctx.lib, &graph, &ctx.db, &ImplAxes::default(), p);
    let cublas_prog = seq.cublas_program(&ctx.lib);
    let baseline = autotune::baseline_plan(&cublas_prog, &ctx.lib);
    (best.plan, baseline, p, flops)
}

fn ordinal(n: usize) -> &'static str {
    match (n % 10, n % 100) {
        (1, 11) | (2, 12) | (3, 13) => "th",
        (1, _) => "st",
        (2, _) => "nd",
        (3, _) => "rd",
        _ => "th",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_eleven_rows() {
        assert_eq!(table1().n_rows(), 11);
    }

    #[test]
    fn ordinals() {
        assert_eq!(ordinal(1), "st");
        assert_eq!(ordinal(2), "nd");
        assert_eq!(ordinal(3), "rd");
        assert_eq!(ordinal(4), "th");
        assert_eq!(ordinal(11), "th");
        assert_eq!(ordinal(21), "st");
        assert_eq!(ordinal(54), "th");
    }

    #[test]
    fn evaluator_caches() {
        let ctx = Context::new();
        let mut ev = Evaluator::new();
        let g1 = ev.eval(&ctx, "sscal").ours.gflops;
        let g2 = ev.eval(&ctx, "sscal").ours.gflops;
        assert_eq!(g1, g2);
    }

    #[test]
    fn table2_speedups_have_paper_shape() {
        // The core reproduction claim: F/S sequences speed up strongly,
        // B/untagged sequences stay near 1x. Tolerances are generous —
        // the model reproduces shape, not authors' exact numbers.
        let ctx = Context::new();
        let mut ev = Evaluator::new();
        let mut check = |name: &str, lo: f64, hi: f64| {
            let e = ev.eval(&ctx, name);
            let s = e.ours.gflops / e.cublas.gflops;
            assert!(
                (lo..=hi).contains(&s),
                "{name}: speedup {s:.2} outside [{lo}, {hi}] (paper {:.2})",
                e.seq.paper.speedup
            );
        };
        check("vadd", 1.8, 2.9); // paper 2.26
        check("waxpby", 1.6, 2.9); // paper 1.93
        check("axpydot", 1.5, 2.5); // paper 1.94
        check("bicgk", 1.25, 2.1); // paper 1.61
        check("gemver", 2.0, 3.3); // paper 2.61
        check("madd", 1.3, 1.8); // paper 1.47
        check("atax", 0.95, 1.15); // paper 1.03
        check("sgemv", 0.95, 1.25); // paper 1.05
        check("gesummv", 0.9, 1.15); // paper 1.00
        check("sscal", 0.95, 1.25); // paper 1.05
        check("sgemvt", 0.95, 1.25); // paper 1.03
    }
}
