//! Command-line interface (hand-rolled; clap is unreachable offline).
//!
//! ```text
//! fusebla tables [1|2|3|4|5|all]          regenerate the paper's tables
//! fusebla figures [5|6|all]               regenerate the scaling figures
//! fusebla compile <script> [--all] [--emit-cuda]
//! fusebla run <seq> [--variant fused|cublas] [--m M] [--n N] [--no-check]
//! fusebla autotune <seq>                  search + prediction-accuracy report
//! fusebla serve-demo [--requests N] [--batch-window MS] [--devices N]
//!                    [--scenario poisson|bursty|diurnal|hotkey] [--seed N]
//!                    [--rate R] [--duration-ms MS] [--deadline-ms MS]
//!                    [--priority P] [--queue-cap N] [--script FILE]
//!                    [--chaos-seed N] [--chaos-faults N]
//!                    [--retry-budget N] [--wedge-timeout-ms MS]
//!                    [--split G] [--link pcie|nvlink]
//!                                         batched (fleet) serve demo; with
//!                                         --scenario, a seeded open-loop
//!                                         traffic run with SLO reporting;
//!                                         --script registers the file as a
//!                                         user pipeline and mixes it into
//!                                         the served traffic; --chaos-seed
//!                                         injects a seeded fault plan
//!                                         (worker kills, reply chaos) the
//!                                         supervisor must absorb; --split
//!                                         lets the router scatter one
//!                                         request across up to G lanes,
//!                                         with --link picking the priced
//!                                         interconnect profile
//! fusebla list                            sequences + artifact catalog
//! ```

use crate::autotune;
use crate::bench_support as bench;
use crate::codegen;
use crate::coordinator::{
    synth_inputs, traffic, Context, Coordinator, Engine, EngineConfig, FaultPlan, Metrics,
    PlanChoice, SubmitRequest, Ticket,
};
use crate::fleet::{DeviceRegistry, SplitPolicy};
use crate::fusion::ImplAxes;
use crate::ir::elem::ProblemSize;
use crate::sim::multi::Interconnect;
use crate::script::compile_script;
use crate::sequences;
use crate::util::fmt_duration;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn artifacts_dir() -> PathBuf {
    std::env::var("FUSEBLA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn usage() -> i32 {
    eprintln!(
        "fusebla — kernel-fusion compiler for BLAS sequences
usage:
  fusebla tables [1|2|3|4|5|all]
  fusebla figures [5|6|all]
  fusebla compile <script-file> [--all] [--emit-cuda]
  fusebla run <seq> [--variant fused|cublas] [--m M] [--n N] [--no-check]
  fusebla autotune <seq>
  fusebla serve-demo [--requests N] [--batch-window MS] [--devices N]
                     [--scenario poisson|bursty|diurnal|hotkey] [--seed N]
                     [--rate R] [--duration-ms MS] [--deadline-ms MS]
                     [--priority P] [--queue-cap N] [--script FILE]
                     [--chaos-seed N] [--chaos-faults N]
                     [--retry-budget N] [--wedge-timeout-ms MS]
                     [--split G] [--link pcie|nvlink]
  fusebla list"
    );
    2
}

/// Value of `--name` if the flag is present; an error when the flag is
/// given without a trailing value (never a silent fallback).
fn flag_value(args: &[String], name: &str) -> Result<Option<String>, String> {
    match args.iter().position(|a| a == name) {
        None => Ok(None),
        Some(i) => match args.get(i + 1) {
            Some(v) => Ok(Some(v.clone())),
            None => Err(format!("{name} requires a value")),
        },
    }
}

/// Parse a typed flag strictly: absent → `Ok(None)`, present but
/// missing or unparseable → an error message (commands exit 2 instead
/// of silently falling back to a default).
fn parse_flag<T: std::str::FromStr>(args: &[String], name: &str) -> Result<Option<T>, String> {
    match flag_value(args, name)? {
        None => Ok(None),
        Some(v) => v
            .parse()
            .map(Some)
            .map_err(|_| format!("invalid value '{v}' for {name}")),
    }
}

pub fn run() -> i32 {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        return usage();
    };
    match cmd.as_str() {
        "tables" => cmd_tables(args.get(1).map(|s| s.as_str()).unwrap_or("all")),
        "figures" => cmd_figures(args.get(1).map(|s| s.as_str()).unwrap_or("all")),
        "compile" => cmd_compile(&args[1..]),
        "run" => cmd_run(&args[1..]),
        "autotune" => cmd_autotune(&args[1..]),
        "serve-demo" => cmd_serve(&args[1..]),
        "list" => cmd_list(),
        _ => usage(),
    }
}

fn cmd_tables(which: &str) -> i32 {
    let ctx = Context::new();
    let mut ev = bench::Evaluator::new();
    let all = which == "all";
    if all || which == "1" {
        bench::table1().print();
    }
    if all || which == "2" {
        bench::table2(&ctx, &mut ev).print();
    }
    if all || which == "3" {
        bench::table3(&ctx, &mut ev).print();
    }
    if all || which == "4" {
        bench::table4(&ctx, &mut ev).print();
    }
    if all || which == "5" {
        bench::table5(&ctx, &mut ev).print();
    }
    0
}

fn cmd_figures(which: &str) -> i32 {
    let ctx = Context::new();
    if which == "all" || which == "5" {
        bench::figure(&ctx, "bicgk").print();
    }
    if which == "all" || which == "6" {
        bench::figure(&ctx, "gemver").print();
    }
    0
}

fn cmd_compile(args: &[String]) -> i32 {
    let Some(path) = args.first().filter(|a| !a.starts_with("--")) else {
        eprintln!("compile: need a script file");
        return 2;
    };
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("compile: {path}: {e}");
            return 1;
        }
    };
    let name = PathBuf::from(path)
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "script".into());
    let ctx = Context::new();
    let prog = match compile_script(&name, &src, &ctx.lib) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("compile: {e}");
            return 1;
        }
    };
    let graph = crate::graph::DepGraph::build(&prog, &ctx.lib);
    let p = ProblemSize::square(4096);
    let want_all = args.iter().any(|a| a == "--all");
    let t0 = std::time::Instant::now();
    let cands = autotune::rank_all(&prog, &ctx.lib, &graph, &ctx.db, &ImplAxes::default(), p);
    println!(
        "compiled '{}' — {} implementation(s) in {}",
        name,
        cands.len(),
        fmt_duration(t0.elapsed().as_secs_f64())
    );
    let show = if want_all { cands.len() } else { 1 };
    for (i, c) in cands.iter().take(show).enumerate() {
        println!(
            "#{}: {} kernel(s), predicted {:.3} ms — {}",
            i + 1,
            c.plan.kernels.len(),
            c.predicted * 1e3,
            c.plan
                .kernels
                .iter()
                .map(|k| k.name.clone())
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    if args.iter().any(|a| a == "--emit-cuda") {
        println!("\n{}", codegen::cuda::emit_seq(&cands[0].plan));
    }
    0
}

fn cmd_run(args: &[String]) -> i32 {
    let Some(seq) = args.first().filter(|a| !a.starts_with("--")) else {
        eprintln!("run: need a sequence name");
        return 2;
    };
    let variant = match flag_value(args, "--variant") {
        Ok(v) => match v.as_deref() {
            Some("cublas") => PlanChoice::Cublas,
            Some("fused") | None => PlanChoice::Fused,
            Some(other) => {
                eprintln!("run: unknown variant '{other}' (expected 'fused' or 'cublas')");
                return 2;
            }
        },
        Err(e) => {
            eprintln!("run: {e}");
            return 2;
        }
    };
    let ctx = Arc::new(Context::new());
    let mut coord = match Coordinator::new(ctx, &artifacts_dir()) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("run: {e:#}");
            return 1;
        }
    };
    let sizes = coord.runtime().sizes_of(seq, variant.as_str());
    if sizes.is_empty() {
        eprintln!("run: no artifacts for '{seq}'");
        return 1;
    }
    let (dm, dn) = sizes[sizes.len() / 2];
    let (m, n) = match (parse_flag::<usize>(args, "--m"), parse_flag::<usize>(args, "--n")) {
        (Ok(m), Ok(n)) => (m.unwrap_or(dm), n.unwrap_or(dn)),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("run: {e}");
            return 2;
        }
    };
    let inputs = synth_inputs(coord.runtime(), seq, variant.as_str(), m, n, 42);
    let check = !args.iter().any(|a| a == "--no-check");
    println!(
        "running {seq}.{} at m={m} n={n} on {}",
        variant.as_str(),
        coord.runtime().platform()
    );
    if check {
        match coord.run_checked(seq, variant, m, n, &inputs) {
            Ok((res, err)) => {
                for s in &res.stages {
                    println!("  stage {:40} {}", s.key, fmt_duration(s.seconds));
                }
                println!(
                    "total {} | max abs error vs reference: {:.2e} {}",
                    fmt_duration(res.seconds),
                    err,
                    if err < 1e-2 { "OK" } else { "FAIL" }
                );
                i32::from(err >= 1e-2)
            }
            Err(e) => {
                eprintln!("run: {e:#}");
                1
            }
        }
    } else {
        match coord.runtime().run_seq(seq, variant.as_str(), m, n, &inputs) {
            Ok(res) => {
                println!("total {}", fmt_duration(res.seconds));
                0
            }
            Err(e) => {
                eprintln!("run: {e:#}");
                1
            }
        }
    }
}

fn cmd_autotune(args: &[String]) -> i32 {
    let Some(name) = args.first() else {
        eprintln!("autotune: need a sequence name");
        return 2;
    };
    let Some(seq) = sequences::by_name(name) else {
        eprintln!("autotune: unknown sequence '{name}'");
        return 1;
    };
    let ctx = Context::new();
    let (prog, graph) = seq.graph(&ctx.lib);
    let p = bench::eval_size(&seq);
    let report = autotune::search(
        &prog,
        &ctx.lib,
        &graph,
        &ctx.dev,
        &ctx.db,
        &ImplAxes::default(),
        p,
    );
    println!("sequence {}:", name.to_uppercase());
    println!("  implementations     : {}", report.impl_count);
    println!(
        "  pruned planner      : best of {} combination(s) found by predicting {} — one per unpruned partition of {} ({} pruned) — with {} kernel cost(s) memoized over {} reference(s)",
        report.planner.space_combinations,
        report.planner.combos_evaluated,
        report.planner.combos_evaluated + report.planner.partitions_pruned,
        report.planner.partitions_pruned,
        report.planner.kernel_evals,
        report.planner.kernel_refs
    );
    println!("  best found at rank  : {}", report.best_rank);
    println!("  first impl perf     : {:.1}%", report.first_pct);
    if let Some(w) = report.worst_pct {
        println!("  worst impl perf     : {w:.1}%");
    }
    println!("  compile first       : {}", fmt_duration(report.t_first));
    println!("  compile all         : {}", fmt_duration(report.t_all));
    println!("  empirical search    : {}", fmt_duration(report.t_search));
    println!(
        "  best plan           : {} kernel(s): {}",
        report.best.kernels.len(),
        report
            .best
            .kernels
            .iter()
            .map(|k| k.name.clone())
            .collect::<Vec<_>>()
            .join(", ")
    );
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    let n_requests: usize = match parse_flag(args, "--requests") {
        Ok(v) => v.unwrap_or(32),
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    let window_ms: u64 = match parse_flag(args, "--batch-window") {
        Ok(v) => v.unwrap_or(10),
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    let n_devices: usize = match parse_flag(args, "--devices") {
        Ok(v) => v.unwrap_or(1),
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    if n_devices == 0 {
        eprintln!("serve-demo: --devices must be at least 1");
        return 2;
    }
    let scenario = match flag_value(args, "--scenario") {
        Ok(None) => None,
        Ok(Some(s)) => match traffic::Scenario::parse(&s) {
            Some(sc) => Some(sc),
            None => {
                eprintln!(
                    "serve-demo: unknown scenario '{s}' (expected poisson|bursty|diurnal|hotkey)"
                );
                return 2;
            }
        },
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    let seed: u64 = match parse_flag(args, "--seed") {
        Ok(v) => v.unwrap_or(42),
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    let rate: f64 = match parse_flag(args, "--rate") {
        Ok(v) => v.unwrap_or(200.0),
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    if rate <= 0.0 {
        eprintln!("serve-demo: --rate must be positive");
        return 2;
    }
    let duration_ms: u64 = match parse_flag(args, "--duration-ms") {
        Ok(v) => v.unwrap_or(1000),
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    let deadline_ms: Option<u64> = match parse_flag(args, "--deadline-ms") {
        Ok(v) => v,
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    let priority: u8 = match parse_flag(args, "--priority") {
        Ok(v) => v.unwrap_or(0),
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    let queue_cap: Option<usize> = match parse_flag(args, "--queue-cap") {
        Ok(v) => v,
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    let chaos_seed: Option<u64> = match parse_flag(args, "--chaos-seed") {
        Ok(v) => v,
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    let chaos_faults: usize = match parse_flag(args, "--chaos-faults") {
        Ok(v) => v.unwrap_or(4),
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    let retry_budget: Option<u32> = match parse_flag(args, "--retry-budget") {
        Ok(v) => v,
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    let wedge_timeout_ms: Option<u64> = match parse_flag(args, "--wedge-timeout-ms") {
        Ok(v) => v,
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    let split_g: Option<usize> = match parse_flag(args, "--split") {
        Ok(v) => v,
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    if split_g.is_some_and(|g| g < 2) {
        eprintln!("serve-demo: --split must be at least 2");
        return 2;
    }
    let link: Interconnect = match flag_value(args, "--link") {
        Ok(None) => Interconnect::pcie2_x16(),
        Ok(Some(name)) => match Interconnect::by_name(&name) {
            Some(l) => l,
            None => {
                eprintln!("serve-demo: unknown link profile '{name}' (expected pcie|nvlink)");
                return 2;
            }
        },
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    // --script FILE: register the file's pipeline under its stem name
    // and mix it into the served traffic alongside the built-ins.
    let script: Option<(String, String)> = match flag_value(args, "--script") {
        Ok(None) => None,
        Ok(Some(path)) => {
            let src = match std::fs::read_to_string(&path) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("serve-demo: --script {path}: {e}");
                    return 1;
                }
            };
            let name = PathBuf::from(&path)
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "pipeline".into());
            Some((name, src))
        }
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 2;
        }
    };
    // Size discovery from the manifest alone (no PJRT on this thread —
    // the client is !Send and lives on the engine's worker).
    let manifest = match crate::util::manifest::Manifest::load(&artifacts_dir().join("manifest.txt")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("serve-demo: {e}");
            return 1;
        }
    };
    let mix = ["waxpby", "vadd", "sscal", "axpydot"];
    let mut prepared: Vec<(String, usize, usize)> = Vec::new();
    for seq in mix {
        let Some(&(m, n)) = manifest.sizes(seq, "fused").first() else {
            eprintln!("serve-demo: missing artifacts for {seq}");
            return 1;
        };
        prepared.push((seq.to_string(), m, n));
    }
    // A seeded fault plan turns the demo into a chaos run: the plan is
    // a pure function of the seed, so the same flags replay the same
    // kills against the same (seeded) arrival schedule.
    let fault_plan = chaos_seed
        .map(|s| FaultPlan::seeded(s, n_devices, chaos_faults))
        .unwrap_or_default();
    if let Some(s) = chaos_seed {
        println!(
            "chaos: {} fault(s) from seed {s} (plan {:016x})",
            fault_plan.faults.len(),
            fault_plan.digest()
        );
    }
    let defaults = EngineConfig::default();
    let cfg = EngineConfig {
        batch_window: Duration::from_millis(window_ms),
        max_batch: 256,
        queue_cap: queue_cap.unwrap_or(usize::MAX),
        fault_plan,
        retry_budget: retry_budget.unwrap_or(defaults.retry_budget),
        wedge_timeout: wedge_timeout_ms.map(Duration::from_millis),
        split: split_g.map(|g| SplitPolicy {
            max_g: g,
            ..SplitPolicy::default()
        }),
        ..defaults
    };
    // One device serves the classic single-device path (no router in
    // the way); more cycle the heterogeneous simulated profiles, each
    // with its own calibration file next to the catalog.
    let engine = if n_devices == 1 {
        Engine::with_config(Arc::new(Context::new()), &artifacts_dir(), cfg)
    } else {
        let registry =
            Arc::new(DeviceRegistry::simulated(n_devices, artifacts_dir()).with_link(link));
        Engine::start_fleet(registry, &artifacts_dir(), cfg)
    };
    let engine = match engine {
        Ok(e) => e,
        Err(e) => {
            eprintln!("serve-demo: {e:#}");
            return 1;
        }
    };
    let client = engine.client();
    if let Some((name, src)) = script {
        match client.register_pipeline(&name, &src) {
            Ok(fp) => {
                println!("registered pipeline '{name}' ({fp:#018x}) on every device");
                // the fleet agreed on the name: serve it like a built-in
                prepared.push((name, 32, 65536));
            }
            Err(e) => {
                eprintln!("serve-demo: --script: {e:#}");
                let _ = engine.shutdown();
                return 1;
            }
        }
    }
    // Open-loop SLO mode: replayable seeded arrivals instead of the
    // closed-loop burst, with shed/SLO accounting printed at the end.
    if let Some(scenario) = scenario {
        let spec = traffic::TrafficSpec {
            scenario,
            seed,
            rate,
            horizon: Duration::from_millis(duration_ms),
            keys: prepared.clone(),
        };
        let opts = traffic::OpenLoopOptions {
            deadline: deadline_ms.map(Duration::from_millis),
            priority,
        };
        // schedule() is pure, so recomputing it for the digest is free
        // of replay risk
        let digest = traffic::digest(&traffic::schedule(&spec));
        let t0 = std::time::Instant::now();
        let report = traffic::run_open_loop(&client, &spec, &opts);
        let dt = t0.elapsed().as_secs_f64();
        let fleet = engine.shutdown_fleet();
        let metrics = fleet.aggregate();
        println!(
            "open-loop {} (seed {seed}, schedule {digest:016x}): {} submitted in {} — \
             {} completed, {} failed, {} queue shed(s), {} deadline shed(s), \
             {} worker-lost shed(s), {} other error(s)",
            scenario.as_str(),
            report.submitted,
            fmt_duration(dt),
            report.completed,
            report.failed,
            report.queue_sheds,
            report.deadline_sheds,
            report.worker_lost,
            report.other_errors
        );
        if fleet.devices.len() > 1 {
            for (id, m) in &fleet.devices {
                println!(
                    "device {id}: {} request(s), {} batch(es), {}",
                    m.requests,
                    m.batches,
                    queued_line(m)
                );
            }
        }
        println!("{}", slo_line(&metrics));
        println!("{}", queued_line(&metrics));
        if let Some(line) = split_line(&metrics, &client, split_g.is_some()) {
            println!("{line}");
        }
        if let Some(line) = fault_line(&metrics) {
            println!("{line}");
        }
        return i32::from(report.other_errors != 0);
    }
    let t0 = std::time::Instant::now();
    // a burst of repeated keys — exactly the traffic batching groups
    let mut tickets = Vec::new();
    for i in 0..n_requests {
        let (seq, m, n) = &prepared[i % prepared.len()];
        match client.submit(SubmitRequest::new(seq.clone(), *m, *n).synth(i as u64)) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                eprintln!("serve-demo: {e:#}");
                return 1;
            }
        }
    }
    let ok = tickets.into_iter().map(Ticket::wait).filter(Result::is_ok).count();
    let fleet = engine.shutdown_fleet();
    let metrics = fleet.aggregate();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {ok}/{n_requests} requests in {} ({:.1} req/s, batch window {window_ms} ms, {} device(s))",
        fmt_duration(dt),
        n_requests as f64 / dt,
        fleet.devices.len()
    );
    if fleet.devices.len() > 1 {
        for (id, m) in &fleet.devices {
            println!(
                "device {id}: {} request(s), {} batch(es), {}",
                m.requests,
                m.batches,
                queued_line(m)
            );
        }
    }
    for (seq, (count, secs)) in &metrics.per_seq {
        println!("  {seq:10} {count:4} requests, mean {}", fmt_duration(secs / *count as f64));
    }
    println!(
        "batches: {} for {} request(s) — mean size {:.1}, max {}, {} request(s) shared a batch",
        metrics.batches,
        metrics.requests,
        metrics.mean_batch_size(),
        metrics.max_batch_size,
        metrics.batched_requests
    );
    println!(
        "plan cache: {} hit(s) / {} miss(es) / {} eviction(s)",
        metrics.plan_cache_hits, metrics.plan_cache_misses, metrics.plan_cache_evictions
    );
    println!(
        "resolve cache: {} hit(s) / {} miss(es); executables: {} compile(s) / {} cache hit(s)",
        metrics.resolve_hits,
        metrics.resolve_misses,
        metrics.executable_compiles,
        metrics.executable_cache_hits
    );
    let routing = client.routing_stats();
    println!(
        "shard plane: {} planner run(s) on workers, {} shard chunk(s) served of {} requested; \
         cold keys {} ({} worker / {} local forecast(s))",
        metrics.planner_on_worker,
        metrics.shard_served,
        metrics.shard_requests,
        routing.cold_keys,
        routing.worker_forecasts,
        routing.local_forecasts
    );
    println!("{}", slo_line(&metrics));
    println!("{}", queued_line(&metrics));
    if let Some(line) = split_line(&metrics, &client, split_g.is_some()) {
        println!("{line}");
    }
    if let Some(line) = fault_line(&metrics) {
        println!("{line}");
    }
    i32::from(ok != n_requests)
}

/// One-line split-plane summary: how many requests the router scattered
/// across lanes, the row blocks executed fleet-wide, and the attempts
/// that degraded back to whole single-device execution. Printed always
/// under `--split`, and whenever a split actually happened otherwise.
fn split_line(m: &Metrics, client: &crate::coordinator::Client, forced: bool) -> Option<String> {
    let decisions = client.routing_stats().split_decisions;
    if !forced && m.splits == 0 && m.split_fallbacks == 0 && decisions == 0 {
        return None;
    }
    Some(format!(
        "split plane: {} split decision(s) routed — {} served split ({} row block(s)), \
         {} fallback(s) to whole single-device",
        decisions, m.splits, m.split_blocks, m.split_fallbacks
    ))
}

/// One-line fault-tolerance summary, printed only when supervision saw
/// action (chaos runs, real crashes) — healthy demos stay unchanged.
fn fault_line(m: &Metrics) -> Option<String> {
    if m.worker_restarts == 0
        && m.failovers == 0
        && m.worker_lost_sheds == 0
        && m.breaker_transitions == 0
    {
        return None;
    }
    Some(format!(
        "supervision: {} restart(s), {} failover(s) ({} retried execution(s)), \
         {} worker-lost shed(s), {} breaker transition(s)",
        m.worker_restarts, m.failovers, m.retries, m.worker_lost_sheds, m.breaker_transitions
    ))
}

/// One-line queued-duration summary (submission → batch dispatch) from
/// a worker's histogram — the routing-vs-queueing signal per device.
fn queued_line(m: &Metrics) -> String {
    if m.queued.is_empty() {
        return "queued: (no dispatched requests)".to_string();
    }
    // the is_empty guard above makes the unwraps unreachable
    format!(
        "queued: mean {} p50 {} p90 {} max {} over {} request(s)",
        fmt_duration(m.queued.mean().unwrap_or(0.0)),
        fmt_duration(m.queued.quantile(0.5).unwrap_or(0.0)),
        fmt_duration(m.queued.quantile(0.9).unwrap_or(0.0)),
        fmt_duration(m.queued.max()),
        m.queued.count()
    )
}

/// One-line submit→reply latency and SLO summary from the merged
/// metrics: the distribution every request lands in, plus the
/// deadline-scoped miss and shed counters.
fn slo_line(m: &Metrics) -> String {
    let q = |q: f64| {
        m.latency
            .quantile(q)
            .map(fmt_duration)
            .unwrap_or_else(|| "-".into())
    };
    format!(
        "latency: p50 {} p99 {} max {} over {} request(s); SLO misses {}/{} deadline request(s); \
         sheds: {} queue, {} deadline",
        q(0.5),
        q(0.99),
        fmt_duration(m.latency.max()),
        m.latency.count(),
        m.slo_misses,
        m.deadline_requests,
        m.queue_sheds,
        m.deadline_sheds
    )
}

fn cmd_list() -> i32 {
    println!("sequences:");
    for s in sequences::all() {
        println!("  {:8} [{}]", s.name, s.tag);
    }
    match crate::runtime::Runtime::load(&artifacts_dir()) {
        Ok(rt) => {
            println!("artifacts: {} entries", rt.manifest.entries.len());
            for s in sequences::all() {
                let sizes = rt.sizes_of(s.name, "fused");
                println!("  {:8} sizes {:?}", s.name, sizes);
            }
        }
        Err(e) => println!("artifacts: not loaded ({e})"),
    }
    0
}
