//! L3 coordinator: the process that owns the compiled plans and serves
//! execution requests.
//!
//! For this paper the system contribution lives in the compiler, so the
//! coordinator is a thin driver (per DESIGN.md): it holds the compiler
//! context (library, device model, routine DB), a plan cache keyed by
//! sequence, and a request loop executing AOT artifacts through the PJRT
//! runtime with per-sequence metrics. std::thread + channels — tokio is
//! unreachable in this offline environment.

pub mod cli;

use crate::autotune;
use crate::fusion::ImplAxes;
use crate::ir::elem::ProblemSize;
use crate::library::Library;
use crate::predict::RoutineDb;
use crate::runtime::{refcheck, RunResult, Runtime, Tensor};
use crate::sequences::{self, Sequence};
use crate::sim::DeviceModel;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Shared compiler context (built once per process).
pub struct Context {
    pub lib: Library,
    pub dev: DeviceModel,
    pub db: RoutineDb,
}

impl Context {
    pub fn new() -> Context {
        let lib = Library::standard();
        let dev = DeviceModel::gtx480();
        let db = RoutineDb::calibrate(&dev, &lib);
        Context { lib, dev, db }
    }
}

impl Default for Context {
    fn default() -> Self {
        Self::new()
    }
}

/// Which plan variant to execute for a sequence (the coordinator decides
/// once via the compiler, then caches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanChoice {
    Fused,
    Cublas,
}

impl PlanChoice {
    pub fn as_str(&self) -> &'static str {
        match self {
            PlanChoice::Fused => "fused",
            PlanChoice::Cublas => "cublas",
        }
    }
}

/// Input payload of a request. `Synth` lets producers on other threads
/// enqueue work without touching the (thread-bound) PJRT runtime: the
/// coordinator materializes deterministic random inputs itself.
pub enum RequestInputs {
    Explicit(BTreeMap<String, Tensor>),
    Synth { seed: u64 },
}

/// One execution request.
pub struct Request {
    pub seq: String,
    pub m: usize,
    pub n: usize,
    pub inputs: RequestInputs,
    /// Force a variant; None = let the coordinator's plan cache decide.
    pub variant: Option<PlanChoice>,
    pub reply: mpsc::Sender<Result<RunResult>>,
}

/// Aggregated metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub failures: u64,
    pub seconds_total: f64,
    pub per_seq: BTreeMap<String, (u64, f64)>,
}

/// The coordinator: plan cache + runtime + metrics behind a request
/// channel.
pub struct Coordinator {
    ctx: Arc<Context>,
    runtime: Runtime,
    /// seq name → chosen variant (decided by the fusion compiler).
    plan_cache: BTreeMap<String, PlanChoice>,
    pub metrics: Metrics,
}

impl Coordinator {
    pub fn new(ctx: Arc<Context>, artifacts_dir: &Path) -> Result<Coordinator> {
        Ok(Coordinator {
            ctx,
            runtime: Runtime::load(artifacts_dir)?,
            plan_cache: BTreeMap::new(),
            metrics: Metrics::default(),
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Decide (and cache) the plan for a sequence: run the fusion
    /// compiler's search on the device model; if the best plan fuses
    /// anything (fewer kernels than calls), execute the fused artifact
    /// variant, else the baseline decomposition.
    pub fn choose_plan(&mut self, seq_name: &str) -> Result<PlanChoice> {
        if let Some(&c) = self.plan_cache.get(seq_name) {
            return Ok(c);
        }
        let seq: Sequence = sequences::by_name(seq_name)
            .ok_or_else(|| anyhow!("unknown sequence '{seq_name}'"))?;
        let (prog, graph) = seq.graph(&self.ctx.lib);
        let p = if seq.is_blas2() {
            ProblemSize::square(4096)
        } else {
            ProblemSize::new(32, 1 << 22)
        };
        let first = autotune::compile_first(
            &prog,
            &self.ctx.lib,
            &graph,
            &self.ctx.db,
            &ImplAxes::minimal(),
            p,
        );
        let choice = if first.plan.kernels.len() < prog.calls.len() {
            PlanChoice::Fused
        } else {
            // no fusion found: the "fused" artifacts equal the natural
            // decomposition — still prefer them (no CUBLAS copy kernels)
            PlanChoice::Fused
        };
        self.plan_cache.insert(seq_name.to_string(), choice);
        Ok(choice)
    }

    /// Handle one request synchronously.
    pub fn handle(&mut self, req: &Request) -> Result<RunResult> {
        let variant = match req.variant {
            Some(v) => v,
            None => self.choose_plan(&req.seq)?,
        };
        let inputs = match &req.inputs {
            RequestInputs::Explicit(m) => m.clone(),
            RequestInputs::Synth { seed } => {
                synth_inputs(&self.runtime, &req.seq, variant.as_str(), req.m, req.n, *seed)
            }
        };
        let t0 = Instant::now();
        let result = self
            .runtime
            .run_seq(&req.seq, variant.as_str(), req.m, req.n, &inputs);
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.requests += 1;
        self.metrics.seconds_total += dt;
        let e = self.metrics.per_seq.entry(req.seq.clone()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += dt;
        if result.is_err() {
            self.metrics.failures += 1;
        }
        result
    }

    /// Run a request loop until the channel closes. Returns metrics.
    pub fn serve(mut self, rx: mpsc::Receiver<Request>) -> Metrics {
        while let Ok(req) = rx.recv() {
            let res = self.handle(&req);
            let _ = req.reply.send(res);
        }
        self.metrics
    }

    /// Execute + verify one sequence against the Rust reference oracle;
    /// returns (result, max abs error).
    pub fn run_checked(
        &mut self,
        seq: &str,
        variant: PlanChoice,
        m: usize,
        n: usize,
        inputs: &BTreeMap<String, Tensor>,
    ) -> Result<(RunResult, f32)> {
        let result = self
            .runtime
            .run_seq(seq, variant.as_str(), m, n, inputs)?;
        let err = refcheck::max_abs_error(seq, inputs, &result.env);
        Ok((result, err))
    }
}

/// Generate deterministic random inputs for a sequence at a size
/// (matching the free inputs its artifacts declare).
pub fn synth_inputs(
    runtime: &Runtime,
    seq: &str,
    variant: &str,
    m: usize,
    n: usize,
    seed: u64,
) -> BTreeMap<String, Tensor> {
    use crate::util::Prng;
    let mut produced: Vec<String> = vec![];
    let mut inputs = BTreeMap::new();
    let mut rng = Prng::new(seed);
    let mut entries: Vec<_> = runtime
        .manifest
        .entries
        .values()
        .filter(|e| {
            e.seq == seq
                && e.variant == variant
                && e.attrs.get("m").map(|s| s.as_str()) == Some(m.to_string().as_str())
                && e.attrs.get("n").map(|s| s.as_str()) == Some(n.to_string().as_str())
        })
        .collect();
    entries.sort_by_key(|e| e.stage);
    for e in entries {
        for spec in &e.inputs {
            if !produced.contains(&spec.name) && !inputs.contains_key(&spec.name) {
                let len: usize = spec.dims.iter().product::<usize>().max(1);
                inputs.insert(
                    spec.name.clone(),
                    Tensor::new(spec.dims.clone(), rng.f32_vec(len)),
                );
            }
        }
        for spec in &e.outputs {
            produced.push(spec.name.clone());
        }
    }
    inputs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.txt").exists().then_some(dir)
    }

    #[test]
    fn coordinator_runs_checked_bicgk() {
        let Some(dir) = artifacts_dir() else { return };
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let inputs = synth_inputs(coord.runtime(), "bicgk", "fused", 256, 256, 7);
        let (res, err) = coord
            .run_checked("bicgk", PlanChoice::Fused, 256, 256, &inputs)
            .unwrap();
        assert_eq!(res.stages.len(), 1);
        assert!(err < 1e-3, "max abs error {err}");
    }

    #[test]
    fn plan_cache_decides_once() {
        let Some(dir) = artifacts_dir() else { return };
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let a = coord.choose_plan("bicgk").unwrap();
        let b = coord.choose_plan("bicgk").unwrap();
        assert_eq!(a, b);
        assert_eq!(a, PlanChoice::Fused);
    }

    #[test]
    fn serve_loop_processes_requests() {
        let Some(dir) = artifacts_dir() else { return };
        let (tx, rx) = mpsc::channel();
        // The PJRT client is !Send: the coordinator lives entirely on the
        // worker thread; producers send Synth inputs.
        let handle = std::thread::spawn(move || {
            let ctx = Arc::new(Context::new());
            let coord = Coordinator::new(ctx, &dir).unwrap();
            coord.serve(rx)
        });
        let mut replies = vec![];
        for i in 0..3 {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Request {
                seq: "waxpby".into(),
                m: 32,
                n: 65536,
                inputs: RequestInputs::Synth { seed: i },
                variant: Some(PlanChoice::Fused),
                reply: rtx,
            })
            .unwrap();
            replies.push(rrx);
        }
        drop(tx);
        for r in replies {
            assert!(r.recv().unwrap().is_ok());
        }
        let metrics = handle.join().unwrap();
        assert_eq!(metrics.requests, 3);
        assert_eq!(metrics.failures, 0);
    }

    #[test]
    fn metrics_track_failures() {
        let Some(dir) = artifacts_dir() else { return };
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let (rtx, _rrx) = mpsc::channel();
        let req = Request {
            seq: "bicgk".into(),
            m: 7, // no such size
            n: 7,
            inputs: RequestInputs::Explicit(BTreeMap::new()),
            variant: Some(PlanChoice::Fused),
            reply: rtx,
        };
        assert!(coord.handle(&req).is_err());
        assert_eq!(coord.metrics.failures, 1);
    }
}
