//! L3 coordinator: the process that owns the compiled plans and serves
//! execution requests.
//!
//! For this paper the system contribution lives in the compiler, so the
//! coordinator is a thin driver (per DESIGN.md): it holds the compiler
//! context (library, device model, routine DB), an LRU plan cache keyed
//! by `(sequence, problem size, device)`, and a request loop executing
//! AOT artifacts through the PJRT runtime with per-sequence metrics.
//! std::thread + channels — tokio is unreachable in this offline
//! environment.
//!
//! The plan cache is what keeps the serve path off the compiler: a cold
//! `(seq, m, n)` runs the pruned planner once (`crate::planner`); every
//! repeat of the same key skips planning entirely, and hit/miss/eviction
//! counts surface through [`Metrics`]. A plan decided for one
//! `ProblemSize` or device is never served for another — size and
//! device are part of the key.

pub mod cli;

use crate::autotune;
use crate::fusion::ImplAxes;
use crate::ir::elem::ProblemSize;
use crate::library::Library;
use crate::planner::{self, PlannerConfig};
use crate::predict::{predict_seq, RoutineDb};
use crate::runtime::{refcheck, RunResult, Runtime, Tensor};
use crate::sequences::{self, Sequence};
use crate::sim::DeviceModel;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Shared compiler context (built once per process).
pub struct Context {
    pub lib: Library,
    pub dev: DeviceModel,
    pub db: RoutineDb,
}

impl Context {
    pub fn new() -> Context {
        let lib = Library::standard();
        let dev = DeviceModel::gtx480();
        let db = RoutineDb::calibrate(&dev, &lib);
        Context { lib, dev, db }
    }
}

impl Default for Context {
    fn default() -> Self {
        Self::new()
    }
}

/// Which plan variant to execute for a sequence (the coordinator decides
/// once via the compiler, then caches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanChoice {
    Fused,
    Cublas,
}

impl PlanChoice {
    pub fn as_str(&self) -> &'static str {
        match self {
            PlanChoice::Fused => "fused",
            PlanChoice::Cublas => "cublas",
        }
    }
}

/// Input payload of a request. `Synth` lets producers on other threads
/// enqueue work without touching the (thread-bound) PJRT runtime: the
/// coordinator materializes deterministic random inputs itself.
pub enum RequestInputs {
    Explicit(BTreeMap<String, Tensor>),
    Synth { seed: u64 },
}

/// One execution request.
pub struct Request {
    pub seq: String,
    pub m: usize,
    pub n: usize,
    pub inputs: RequestInputs,
    /// Force a variant; None = let the coordinator's plan cache decide.
    pub variant: Option<PlanChoice>,
    pub reply: mpsc::Sender<Result<RunResult>>,
}

/// Aggregated metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub failures: u64,
    pub seconds_total: f64,
    /// Plan decisions served from the LRU cache vs computed fresh, plus
    /// entries evicted by capacity. Mirrored from [`PlanCache`] (the
    /// single source of truth) on every `choose_plan`.
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    pub plan_cache_evictions: u64,
    pub per_seq: BTreeMap<String, (u64, f64)>,
}

/// Cache key of one plan decision: a sequence at a problem size on a
/// device. Size and device are part of the key so a plan tuned for one
/// `ProblemSize` (or GPU model) is never served for another. Sizes are
/// stored tile-padded (the granularity the planner actually plans at),
/// so raw sizes that pad to the same shape share one entry instead of
/// re-planning per raw pair.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct PlanKey {
    pub seq: String,
    pub m: usize,
    pub n: usize,
    pub device: String,
}

impl PlanKey {
    /// Key for a sequence at a (tile-padded) problem size on a device.
    pub fn new(seq: &str, p: ProblemSize, device: &str) -> PlanKey {
        let p = p.padded();
        PlanKey {
            seq: seq.to_string(),
            m: p.m,
            n: p.n,
            device: device.to_string(),
        }
    }
}

/// Small LRU cache of plan decisions with hit/miss/eviction counters.
/// The coordinator's working set is tiny (sequences × hot sizes), so a
/// vector in recency order is simpler and faster than a linked map.
#[derive(Debug)]
pub struct PlanCache {
    cap: usize,
    /// Recency order: front = least recently used, back = most recent.
    entries: Vec<(PlanKey, PlanChoice)>,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl PlanCache {
    pub const DEFAULT_CAP: usize = 64;

    pub fn new(cap: usize) -> PlanCache {
        assert!(cap >= 1, "plan cache needs capacity >= 1");
        PlanCache {
            cap,
            entries: Vec::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, key: &PlanKey) -> bool {
        self.entries.iter().any(|(k, _)| k == key)
    }

    /// Look up a plan, refreshing its recency. Counts a hit or a miss.
    pub fn get(&mut self, key: &PlanKey) -> Option<PlanChoice> {
        if let Some(i) = self.entries.iter().position(|(k, _)| k == key) {
            let entry = self.entries.remove(i);
            let choice = entry.1;
            self.entries.push(entry);
            self.hits += 1;
            Some(choice)
        } else {
            self.misses += 1;
            None
        }
    }

    /// Insert as most-recent, evicting the least-recent entry beyond
    /// capacity.
    pub fn insert(&mut self, key: PlanKey, choice: PlanChoice) {
        if let Some(i) = self.entries.iter().position(|(k, _)| k == &key) {
            self.entries.remove(i);
        }
        self.entries.push((key, choice));
        if self.entries.len() > self.cap {
            self.entries.remove(0);
            self.evictions += 1;
        }
    }

    /// Keys in recency order (least recent first).
    pub fn keys(&self) -> impl Iterator<Item = &PlanKey> {
        self.entries.iter().map(|(k, _)| k)
    }
}

/// The coordinator: plan cache + runtime + metrics behind a request
/// channel.
pub struct Coordinator {
    ctx: Arc<Context>,
    runtime: Runtime,
    /// (seq, size, device) → chosen variant (decided by the planner).
    plan_cache: PlanCache,
    pub metrics: Metrics,
}

impl Coordinator {
    pub fn new(ctx: Arc<Context>, artifacts_dir: &Path) -> Result<Coordinator> {
        Ok(Coordinator {
            ctx,
            runtime: Runtime::load(artifacts_dir)?,
            plan_cache: PlanCache::new(PlanCache::DEFAULT_CAP),
            metrics: Metrics::default(),
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Decide (and cache) the plan for a sequence at a problem size: run
    /// the pruned planner on the device model; if the best plan fuses
    /// anything (fewer kernels than calls), execute the fused artifact
    /// variant, else the baseline decomposition. Repeat requests for the
    /// same `(seq, m, n)` on the same device skip planning entirely.
    pub fn choose_plan(&mut self, seq_name: &str, m: usize, n: usize) -> Result<PlanChoice> {
        let p = ProblemSize::new(m, n).padded();
        let key = PlanKey::new(seq_name, p, self.ctx.dev.name);
        let cached = self.plan_cache.get(&key);
        self.sync_plan_cache_metrics();
        if let Some(choice) = cached {
            return Ok(choice);
        }
        let seq: Sequence = sequences::by_name(seq_name)
            .ok_or_else(|| anyhow!("unknown sequence '{seq_name}'"))?;
        let (prog, graph) = seq.graph(&self.ctx.lib);
        let planned = planner::plan(
            &prog,
            &self.ctx.lib,
            &graph,
            &self.ctx.db,
            &ImplAxes::minimal(),
            p,
            &PlannerConfig::default(),
        );
        // Execute the CUBLAS decomposition only if it actually predicts
        // faster than the searched plan. Ties go to the fused artifacts:
        // even a no-fusion plan is retuned per size, while the baseline
        // is fixed-config and pays copy kernels for the S-tagged
        // sequences. (Predictions favor fused on all 11 sequences; the
        // comparison is what makes this a per-size decision.)
        let cublas_prog = seq.cublas_program(&self.ctx.lib);
        let baseline = autotune::baseline_plan(&cublas_prog, &self.ctx.lib);
        let choice = if predict_seq(&self.ctx.db, &baseline, p) < planned.predicted {
            PlanChoice::Cublas
        } else {
            PlanChoice::Fused
        };
        self.plan_cache.insert(key, choice);
        self.sync_plan_cache_metrics();
        Ok(choice)
    }

    /// Mirror the plan cache's counters into the metrics snapshot.
    fn sync_plan_cache_metrics(&mut self) {
        self.metrics.plan_cache_hits = self.plan_cache.hits;
        self.metrics.plan_cache_misses = self.plan_cache.misses;
        self.metrics.plan_cache_evictions = self.plan_cache.evictions;
    }

    /// Handle one request synchronously.
    pub fn handle(&mut self, req: &Request) -> Result<RunResult> {
        let variant = match req.variant {
            Some(v) => v,
            None => self.choose_plan(&req.seq, req.m, req.n)?,
        };
        let inputs = match &req.inputs {
            RequestInputs::Explicit(m) => m.clone(),
            RequestInputs::Synth { seed } => {
                synth_inputs(&self.runtime, &req.seq, variant.as_str(), req.m, req.n, *seed)
            }
        };
        let t0 = Instant::now();
        let result = self
            .runtime
            .run_seq(&req.seq, variant.as_str(), req.m, req.n, &inputs);
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.requests += 1;
        self.metrics.seconds_total += dt;
        let e = self.metrics.per_seq.entry(req.seq.clone()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += dt;
        if result.is_err() {
            self.metrics.failures += 1;
        }
        result
    }

    /// Run a request loop until the channel closes. Returns metrics.
    pub fn serve(mut self, rx: mpsc::Receiver<Request>) -> Metrics {
        while let Ok(req) = rx.recv() {
            let res = self.handle(&req);
            let _ = req.reply.send(res);
        }
        self.metrics
    }

    /// Execute + verify one sequence against the Rust reference oracle;
    /// returns (result, max abs error).
    pub fn run_checked(
        &mut self,
        seq: &str,
        variant: PlanChoice,
        m: usize,
        n: usize,
        inputs: &BTreeMap<String, Tensor>,
    ) -> Result<(RunResult, f32)> {
        let result = self
            .runtime
            .run_seq(seq, variant.as_str(), m, n, inputs)?;
        let err = refcheck::max_abs_error(seq, inputs, &result.env);
        Ok((result, err))
    }
}

/// Generate deterministic random inputs for a sequence at a size
/// (matching the free inputs its artifacts declare).
pub fn synth_inputs(
    runtime: &Runtime,
    seq: &str,
    variant: &str,
    m: usize,
    n: usize,
    seed: u64,
) -> BTreeMap<String, Tensor> {
    use crate::util::Prng;
    let mut produced: Vec<String> = vec![];
    let mut inputs = BTreeMap::new();
    let mut rng = Prng::new(seed);
    let mut entries: Vec<_> = runtime
        .manifest
        .entries
        .values()
        .filter(|e| {
            e.seq == seq
                && e.variant == variant
                && e.attrs.get("m").map(|s| s.as_str()) == Some(m.to_string().as_str())
                && e.attrs.get("n").map(|s| s.as_str()) == Some(n.to_string().as_str())
        })
        .collect();
    entries.sort_by_key(|e| e.stage);
    for e in entries {
        for spec in &e.inputs {
            if !produced.contains(&spec.name) && !inputs.contains_key(&spec.name) {
                let len: usize = spec.dims.iter().product::<usize>().max(1);
                inputs.insert(
                    spec.name.clone(),
                    Tensor::new(spec.dims.clone(), rng.f32_vec(len)),
                );
            }
        }
        for spec in &e.outputs {
            produced.push(spec.name.clone());
        }
    }
    inputs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.txt").exists().then_some(dir)
    }

    #[test]
    fn coordinator_runs_checked_bicgk() {
        let Some(dir) = artifacts_dir() else { return };
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let inputs = synth_inputs(coord.runtime(), "bicgk", "fused", 256, 256, 7);
        let (res, err) = coord
            .run_checked("bicgk", PlanChoice::Fused, 256, 256, &inputs)
            .unwrap();
        assert_eq!(res.stages.len(), 1);
        assert!(err < 1e-3, "max abs error {err}");
    }

    #[test]
    fn plan_cache_decides_once() {
        let Some(dir) = artifacts_dir() else { return };
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let a = coord.choose_plan("bicgk", 256, 256).unwrap();
        let b = coord.choose_plan("bicgk", 256, 256).unwrap();
        assert_eq!(a, b);
        assert_eq!(a, PlanChoice::Fused);
        assert_eq!(coord.metrics.plan_cache_misses, 1);
        assert_eq!(coord.metrics.plan_cache_hits, 1);
    }

    fn key(seq: &str, m: usize, n: usize) -> PlanKey {
        PlanKey {
            seq: seq.to_string(),
            m,
            n,
            device: "GeForce GTX 480 (model)".to_string(),
        }
    }

    #[test]
    fn plan_cache_counts_hits_and_misses() {
        let mut cache = PlanCache::new(4);
        let k = key("bicgk", 256, 256);
        assert_eq!(cache.get(&k), None);
        cache.insert(k.clone(), PlanChoice::Fused);
        assert_eq!(cache.get(&k), Some(PlanChoice::Fused));
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn plan_cache_isolates_problem_sizes_and_devices() {
        let mut cache = PlanCache::new(4);
        cache.insert(key("bicgk", 256, 256), PlanChoice::Fused);
        // same sequence, other size → miss
        assert_eq!(cache.get(&key("bicgk", 512, 512)), None);
        // same sequence and size, other device → miss
        let mut other_dev = key("bicgk", 256, 256);
        other_dev.device = "some other GPU".to_string();
        assert_eq!(cache.get(&other_dev), None);
        // exact key → hit
        assert_eq!(cache.get(&key("bicgk", 256, 256)), Some(PlanChoice::Fused));
        assert_eq!(cache.misses, 2);
        assert_eq!(cache.hits, 1);
    }

    #[test]
    fn plan_cache_evicts_least_recently_used() {
        let mut cache = PlanCache::new(2);
        let (a, b, c) = (key("a", 32, 32), key("b", 32, 32), key("c", 32, 32));
        cache.insert(a.clone(), PlanChoice::Fused);
        cache.insert(b.clone(), PlanChoice::Cublas);
        // touch `a` so `b` becomes least-recent
        assert_eq!(cache.get(&a), Some(PlanChoice::Fused));
        cache.insert(c.clone(), PlanChoice::Fused);
        assert_eq!(cache.evictions, 1);
        assert!(cache.contains(&a), "recently-used entry must survive");
        assert!(!cache.contains(&b), "least-recent entry must be evicted");
        assert!(cache.contains(&c));
        // eviction order is observable: least recent first
        let order: Vec<&PlanKey> = cache.keys().collect();
        assert_eq!(order, vec![&a, &c]);
    }

    #[test]
    fn plan_cache_reinsert_refreshes_instead_of_duplicating() {
        let mut cache = PlanCache::new(2);
        let k = key("a", 32, 32);
        cache.insert(k.clone(), PlanChoice::Fused);
        cache.insert(k.clone(), PlanChoice::Cublas);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.get(&k), Some(PlanChoice::Cublas));
        assert_eq!(cache.evictions, 0);
    }

    /// The serve-path acceptance check: a repeated `handle` for the same
    /// `(seq, m, n)` must hit the plan cache. Uses a stub manifest (no
    /// real artifacts needed — planning happens before execution, and
    /// the failed execution is itself tracked by the failure counter).
    #[test]
    fn handle_hits_plan_cache_on_repeat() {
        let dir = std::env::temp_dir().join(format!("fusebla_plancache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "artifact waxpby.fused.m32n65536.s0\n file waxpby.hlo.txt\n seq waxpby\n variant fused\n stage 0\n in x:f32[65536]\n in y:f32[65536]\n out w:f32[65536]\n m 32\n n 65536\nend\n",
        )
        .unwrap();
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let request = |m: usize, n: usize| {
            let (rtx, _rrx) = mpsc::channel();
            Request {
                seq: "waxpby".into(),
                m,
                n,
                inputs: RequestInputs::Synth { seed: 7 },
                variant: None, // let the plan cache decide
                reply: rtx,
            }
        };
        let _ = coord.handle(&request(32, 65536)); // cold: plans
        let _ = coord.handle(&request(32, 65536)); // warm: cache hit
        assert_eq!(coord.metrics.plan_cache_misses, 1);
        assert_eq!(coord.metrics.plan_cache_hits, 1);
        assert_eq!(coord.metrics.requests, 2);
        // a different problem size must re-plan, never reuse the entry
        let _ = coord.handle(&request(32, 1024));
        assert_eq!(coord.metrics.plan_cache_misses, 2);
        assert_eq!(coord.metrics.plan_cache_hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_loop_processes_requests() {
        let Some(dir) = artifacts_dir() else { return };
        let (tx, rx) = mpsc::channel();
        // The PJRT client is !Send: the coordinator lives entirely on the
        // worker thread; producers send Synth inputs.
        let handle = std::thread::spawn(move || {
            let ctx = Arc::new(Context::new());
            let coord = Coordinator::new(ctx, &dir).unwrap();
            coord.serve(rx)
        });
        let mut replies = vec![];
        for i in 0..3 {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Request {
                seq: "waxpby".into(),
                m: 32,
                n: 65536,
                inputs: RequestInputs::Synth { seed: i },
                variant: Some(PlanChoice::Fused),
                reply: rtx,
            })
            .unwrap();
            replies.push(rrx);
        }
        drop(tx);
        for r in replies {
            assert!(r.recv().unwrap().is_ok());
        }
        let metrics = handle.join().unwrap();
        assert_eq!(metrics.requests, 3);
        assert_eq!(metrics.failures, 0);
    }

    #[test]
    fn metrics_track_failures() {
        let Some(dir) = artifacts_dir() else { return };
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let (rtx, _rrx) = mpsc::channel();
        let req = Request {
            seq: "bicgk".into(),
            m: 7, // no such size
            n: 7,
            inputs: RequestInputs::Explicit(BTreeMap::new()),
            variant: Some(PlanChoice::Fused),
            reply: rtx,
        };
        assert!(coord.handle(&req).is_err());
        assert_eq!(coord.metrics.failures, 1);
    }
}
