//! L3 coordinator: the process that owns the compiled plans and serves
//! execution requests.
//!
//! The public serving surface is the [`Engine`]/[`Client`] pair in
//! [`engine`]: an [`Engine`] owns the worker thread (the PJRT client is
//! `!Send`, so the runtime lives there), a cloneable [`Client`] submits
//! typed [`SubmitRequest`]s and gets a [`Ticket`] back, and the raw
//! request/reply wire types stay private to this module. std::thread +
//! channels — tokio is unreachable in this offline environment.
//!
//! Inside the engine the scheduler is *batched* (the paper's premise,
//! applied to serving): each turn drains every queued request and groups
//! them by `(seq, tile-padded size, device, resolved plan)` — see
//! [`batch`]. That key is deliberately the same shape as [`PlanKey`], so
//! one `choose_plan` serves a whole group, and the group executes as one
//! multi-input dispatch over a `Runtime::resolve`d plan: the runtime's
//! resolve cache maps the batch key to a pinned `ResolvedSeq` (indexed
//! stage list, slot-interned environments, pinned executables), so a
//! repeat key costs one read-locked probe and the dispatch itself
//! touches no manifest scan, no string-keyed env map and no lock.
//! Per-batch counters — including the resolve/compile hit-miss counts
//! mirrored from the runtime — surface through [`Metrics`].
//!
//! The plan cache is what keeps the serve path off the compiler: a cold
//! `(seq, m, n)` runs the pruned planner once (`crate::planner`); every
//! repeat of the same key skips planning entirely, and hit/miss/eviction
//! counts surface through [`Metrics`]. A plan decided for one
//! `ProblemSize` or device is never served for another — size and
//! device are part of the key.
//!
//! [`Context::new`] also reloads the routine calibration database from
//! the per-device cache next to the artifact catalog (keyed by device
//! name + library fingerprint) instead of recalibrating every process
//! start; see [`crate::predict::RoutineDb::load_or_calibrate`]. A
//! [`crate::fleet::DeviceRegistry`] holds one such context per
//! registered device, and the engine then runs one worker (one
//! coordinator, one plan cache) per device with a predictor-guided
//! router in front — see [`crate::fleet`].

pub(crate) mod batch;
pub mod cli;
pub mod engine;
pub mod traffic;

pub use engine::{
    Client, Engine, EngineConfig, Fault, FaultPlan, FleetMetrics, SubmitRequest, Ticket,
};

use crate::autotune;
use crate::fusion::space::Space;
use crate::fusion::ImplAxes;
use crate::ir::elem::ProblemSize;
use crate::ir::plan::SeqPlan;
use crate::library::Library;
use crate::pipelines;
use crate::planner::{self, PlannerConfig, VariantForecast};
use crate::predict::{predict_seq, RoutineDb};
use crate::runtime::{refcheck, RunResult, Runtime, Tensor};
use crate::sequences::{self, Sequence};
use crate::sim::DeviceModel;
use crate::split;
use crate::util::manifest::Manifest;
use crate::util::Histogram;
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, VecDeque};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-device compiler context: the shared function library plus one
/// device's model and calibration. A single-device process builds one
/// ([`Context::new`]); a fleet holds one per registered device, sharing
/// the library `Arc` (see [`crate::fleet::DeviceRegistry`]).
pub struct Context {
    pub lib: Arc<Library>,
    pub dev: DeviceModel,
    pub db: Arc<RoutineDb>,
    /// The device name interned once; cloning it into a [`PlanKey`] or
    /// batch key is a refcount bump, not a `String` allocation.
    pub device: Arc<str>,
}

impl Context {
    /// Build the default single-device context (the paper's GTX 480),
    /// reloading the routine calibration from the per-device cache next
    /// to the artifact catalog when one is present (see
    /// [`Context::with_calibration_cache`]). The catalog directory is
    /// `$FUSEBLA_ARTIFACTS` or `./artifacts`, matching the CLI.
    pub fn new() -> Context {
        let dir = std::env::var("FUSEBLA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"));
        Self::with_calibration_cache(&dir)
    }

    /// Build the default-device context with `dir` as the persistent
    /// calibration cache directory (one `calibration.<device>.txt` per
    /// device; the legacy shared `calibration.txt` is still read as a
    /// migration path). The cache is keyed by device name + library
    /// fingerprint; a stale or mismatched file is ignored and
    /// rewritten. Nothing is written when `dir` does not exist (no
    /// catalog, no side effects).
    pub fn with_calibration_cache(dir: &Path) -> Context {
        Self::for_device(Arc::new(Library::standard()), DeviceModel::gtx480(), dir)
    }

    /// Build the context of one fleet device, loading (or running and
    /// persisting) its own calibration under `cal_dir`.
    pub fn for_device(lib: Arc<Library>, dev: DeviceModel, cal_dir: &Path) -> Context {
        let device: Arc<str> = Arc::from(dev.name.as_str());
        Self::for_device_interned(lib, dev, device, cal_dir)
    }

    /// [`Context::for_device`] with the interned name supplied by the
    /// registry, so plan keys share the registry's `Arc`.
    pub(crate) fn for_device_interned(
        lib: Arc<Library>,
        dev: DeviceModel,
        device: Arc<str>,
        cal_dir: &Path,
    ) -> Context {
        debug_assert_eq!(&*device, dev.name.as_str());
        let db = Arc::new(RoutineDb::load_or_calibrate(cal_dir, &dev, &lib));
        Context {
            lib,
            dev,
            db,
            device,
        }
    }
}

impl Default for Context {
    fn default() -> Self {
        Self::new()
    }
}

/// Which plan variant to execute for a sequence (the coordinator decides
/// once via the compiler, then caches).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum PlanChoice {
    Fused,
    Cublas,
}

impl PlanChoice {
    pub fn as_str(&self) -> &'static str {
        match self {
            PlanChoice::Fused => "fused",
            PlanChoice::Cublas => "cublas",
        }
    }

    /// The variant the serve path executes for a forecast: the CUBLAS
    /// baseline only when it *strictly* beats the searched plan (ties
    /// go to the planned variant, which is retuned per size). The one
    /// decision rule — `choose_plan` and the worker-side forecast
    /// seeding both derive from here, so a seeded cache entry can never
    /// disagree with an unseeded decision for the same forecast.
    pub fn from_forecast(f: &VariantForecast) -> PlanChoice {
        if f.baseline_wins() {
            PlanChoice::Cublas
        } else {
            PlanChoice::Fused
        }
    }
}

/// Input payload of a request. `Synth` lets producers on other threads
/// enqueue work without touching the (thread-bound) PJRT runtime: the
/// coordinator materializes deterministic random inputs itself.
/// Private wire type — callers go through [`SubmitRequest`].
pub(crate) enum RequestInputs {
    Explicit(BTreeMap<String, Tensor>),
    Synth { seed: u64 },
}

/// Wire messages between the engine handle and the worker.
pub(crate) enum Msg {
    Run(Request),
    /// Answered inline by the worker, never batched.
    Control(Control),
}

/// Control-plane messages: observability and lifecycle.
pub(crate) enum Control {
    /// Snapshot the worker's metrics as of the moment it processes the
    /// message.
    Metrics(mpsc::Sender<Metrics>),
    /// Resolve (and cache) the plan for a key without executing
    /// anything.
    Plan {
        seq: String,
        m: usize,
        n: usize,
        reply: mpsc::Sender<Result<PlanChoice>>,
    },
    /// Run the planner for one key on this worker, against this
    /// worker's *own* calibration, and reply with the per-variant
    /// forecast. Seeds the worker's plan cache as a side effect, so the
    /// first routed execution of the key is a plan-cache hit. This is
    /// the fleet's cold-key path: the router scatters one `Forecast`
    /// per device instead of running N planner searches on the
    /// submitting thread (see `fleet::router`).
    Forecast {
        seq: String,
        m: usize,
        n: usize,
        reply: mpsc::Sender<Result<VariantForecast>>,
    },
    /// Evaluate one chunk of a plan-space partition range against the
    /// supplied calibration (the *target* device's — not necessarily
    /// this worker's). The space is rebuilt from the sequence name on
    /// the worker (deterministic, cached per sequence), so the wire
    /// carries only the key and the range. See [`crate::planner::shard`]
    /// for why the merged chunks are bit-identical to unsharded search.
    PlanShard {
        seq: String,
        m: usize,
        n: usize,
        range: Range<usize>,
        db: Arc<RoutineDb>,
        reply: mpsc::Sender<Result<planner::ShardEval>>,
    },
    /// Compile a client-submitted script on this worker and register
    /// the result into the dynamic catalog. Replies with the content
    /// fingerprint; rejections are typed [`ServeError`]s. The engine
    /// fans one of these out per device and only declares the name
    /// routable when every worker acked the same fingerprint.
    RegisterPipeline {
        name: String,
        src: String,
        reply: mpsc::Sender<Result<u64>>,
    },
    /// Remove a registered pipeline (the rollback half of a partial
    /// fleet registration); replies whether the name was registered on
    /// this worker.
    UnregisterPipeline {
        name: String,
        reply: mpsc::Sender<bool>,
    },
    /// Stop serving even while client handles keep the channel open
    /// (an engine shutdown must not wait for every `Client` clone to
    /// drop).
    Shutdown,
}

/// Typed serve-path rejections: outcomes the *serving layer* decided
/// (admission control, deadline shedding), as opposed to runtime
/// failures. Carried as the retained root cause of the `anyhow::Error`
/// a [`Ticket`] resolves to, so callers distinguish a shed from an
/// execution failure with `err.downcast_ref::<ServeError>()`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Admission control refused the request at submit: the target
    /// device's in-flight queue was at capacity.
    QueueFull { depth: u64, cap: u64 },
    /// The request's deadline had already passed when the scheduler
    /// picked it up; it was shed instead of executed late.
    DeadlineExpired { late_by: Duration },
    /// A pipeline registration's script failed to compile
    /// (lex/parse/typecheck); carries the script line the frontend
    /// reported.
    InvalidScript { line: usize, msg: String },
    /// A pipeline registration was refused because the dynamic catalog
    /// is at its registration quota.
    PipelineQuota { count: usize, quota: usize },
    /// The submitted pipeline name is already taken — by a built-in
    /// sequence, or by a registered pipeline with *different* source
    /// (re-submitting identical source is an idempotent dedup, not an
    /// error).
    DuplicatePipeline { name: String },
    /// The worker serving the request died and the request could not be
    /// re-executed elsewhere: it was pinned to the dead device, its
    /// inputs were consumed mid-execute and are not reconstructible, the
    /// retry budget was exhausted, or no healthy lane survived.
    /// `attempts` counts re-executions already spent on the request.
    WorkerLost { device: String, attempts: u32 },
    /// The request was displaced from the queue by cost-aware admission
    /// control: the queue filled and this request was the most
    /// expensive entry of the lowest priority class, so refusing it
    /// (instead of the cheaper newcomer) freed the most device time.
    /// Counted into the same shed metrics as a submit-time refusal.
    Displaced,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueFull { depth, cap } => {
                write!(f, "shed: device queue full ({depth} in flight, cap {cap})")
            }
            ServeError::DeadlineExpired { late_by } => write!(
                f,
                "shed: deadline expired {:.3} ms before dispatch",
                late_by.as_secs_f64() * 1e3
            ),
            ServeError::InvalidScript { line, msg } => {
                write!(f, "rejected: invalid pipeline script (line {line}): {msg}")
            }
            ServeError::PipelineQuota { count, quota } => write!(
                f,
                "rejected: pipeline quota reached ({count} registered, quota {quota})"
            ),
            ServeError::DuplicatePipeline { name } => {
                write!(f, "rejected: pipeline name '{name}' is already taken")
            }
            ServeError::WorkerLost { device, attempts } => write!(
                f,
                "shed: worker for device '{device}' lost (after {attempts} re-execution attempts)"
            ),
            ServeError::Displaced => write!(
                f,
                "shed: displaced from the queue by cost-aware admission control"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// Reply half of one request: the ticket channel plus the router's
/// queue-depth counter for the device the request was dispatched to.
/// The depth slot is released on every terminal outcome — reply sent
/// *or* request dropped unanswered (engine shutdown, worker death) —
/// so the router's view of a device's backlog can never leak upward.
pub(crate) struct Reply {
    tx: mpsc::Sender<Result<RunResult>>,
    depth: Option<Arc<AtomicU64>>,
}

impl Reply {
    pub(crate) fn new(tx: mpsc::Sender<Result<RunResult>>, depth: Option<Arc<AtomicU64>>) -> Reply {
        Reply { tx, depth }
    }

    /// Give the device's queue-depth slot back. Idempotent via
    /// `Option::take`, so `send` followed by the `Drop` releases once.
    fn release(&mut self) {
        if let Some(d) = self.depth.take() {
            d.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Deliver the request's one reply (a dropped ticket is fine).
    pub(crate) fn send(mut self, res: Result<RunResult>) {
        self.release();
        let _ = self.tx.send(res);
    }

    /// Split off a parked copy for the supervisor's reclamation lot: the
    /// parked half takes the depth slot (so a worker panic can't leak
    /// the router's backlog view) and a clone of the ticket sender (so
    /// the ticket stays pending — not disconnected — while the in-flight
    /// half unwinds). `self` keeps delivering the normal reply.
    pub(crate) fn tether(&mut self) -> Reply {
        Reply {
            tx: self.tx.clone(),
            depth: self.depth.take(),
        }
    }

    /// Move the request's queue-depth slot to another device (failover):
    /// release the dead lane's slot and take one on the target.
    pub(crate) fn retarget(&mut self, depth: Arc<AtomicU64>) {
        self.release();
        depth.fetch_add(1, Ordering::Relaxed);
        self.depth = Some(depth);
    }
}

impl Drop for Reply {
    fn drop(&mut self) {
        self.release();
    }
}

/// One execution request on the wire between [`Client`] and the worker.
/// Private — [`Client::submit`] is the only producer, so no hand-wired
/// reply channels exist outside the engine.
pub(crate) struct Request {
    pub seq: String,
    pub m: usize,
    pub n: usize,
    pub inputs: RequestInputs,
    /// Force a variant; None = let the coordinator's plan cache decide.
    pub variant: Option<PlanChoice>,
    /// Submission time, for the queued-duration and latency histograms.
    pub enqueued: Instant,
    /// Absolute completion deadline (submission time + the client's
    /// relative deadline); `None` = no SLO. Batch formation ships when
    /// the most urgent in-hand deadline arrives instead of waiting out
    /// the window, and an already-expired request is shed, not run.
    pub deadline: Option<Instant>,
    /// Scheduling priority: higher executes earlier among a turn's
    /// batches (after deadline order) and gets admission-control
    /// headroom. 0 = best effort.
    pub priority: u8,
    /// Re-executions already spent on this request (failover hops). The
    /// supervisor fails the request fast once this reaches the engine's
    /// retry budget.
    pub attempts: u32,
    /// Pinned to a specific device by the client: never failed over —
    /// the pin is a correctness contract (bit-identity tests depend on
    /// which calibration executes), so lane death turns into a typed
    /// [`ServeError::WorkerLost`] instead.
    pub pinned: bool,
    /// Index of this request's entry in the supervising lane's parking
    /// lot, set when a turn begins on a supervised worker. `None` until
    /// then (and always on unsupervised coordinators).
    pub lot: Option<usize>,
    /// A routed split decision: the lanes of the G-way row-block
    /// partition, in block order, with `lanes[0]` this (owning) lane.
    /// The owner executes block 0 inline, scatters the rest as pinned
    /// sub-executions, and gathers/combines — one ticket throughout.
    /// `None` = serve whole (the only shape on single-device engines).
    pub split: Option<Vec<usize>>,
    /// An owner-scattered row block of some split request: executes and
    /// replies like any request but is excluded from request-level
    /// accounting (requests/failures/latency/SLO), counting into
    /// [`Metrics::split_blocks`] instead — the owning lane accounts the
    /// split as one request.
    pub split_block: bool,
    /// Admission-ledger handle for cost-aware shedding (`None` with
    /// unbounded caps). Checked when the request is drained: a set shed
    /// flag means admission control displaced it while it queued.
    pub admission: Option<engine::Admission>,
    pub reply: Reply,
}

/// What the supervisor needs to re-execute (or fail fast) a request
/// stranded by a worker panic: everything except the input tensors,
/// which are reconstructible only for `Synth` payloads.
pub(crate) struct RetrySpec {
    pub seq: String,
    pub m: usize,
    pub n: usize,
    pub variant: Option<PlanChoice>,
    pub enqueued: Instant,
    pub deadline: Option<Instant>,
    pub priority: u8,
    pub attempts: u32,
    pub pinned: bool,
    /// The input payload to re-submit with. `None` when the original
    /// inputs were explicit tensors already consumed by the dead
    /// worker's execute path — such requests fail fast with
    /// [`ServeError::WorkerLost`].
    pub inputs: Option<RequestInputs>,
}

/// One parked request in a supervised lane's reclamation lot: the retry
/// spec plus the tethered reply half holding the ticket sender and the
/// queue-depth slot. Dropped (released) on normal completion; drained
/// and failed over when the lane dies.
pub(crate) struct Parked {
    pub spec: RetrySpec,
    pub reply: Reply,
}

impl Parked {
    /// Park a request that never reached a worker turn (reclaimed
    /// straight off a dead lane's channel): the reply moves whole —
    /// depth slot included — and explicit inputs survive, since nothing
    /// consumed them yet.
    pub(crate) fn from_request(r: Request) -> Parked {
        Parked {
            spec: RetrySpec {
                seq: r.seq,
                m: r.m,
                n: r.n,
                variant: r.variant,
                enqueued: r.enqueued,
                deadline: r.deadline,
                priority: r.priority,
                attempts: r.attempts,
                pinned: r.pinned,
                inputs: Some(r.inputs),
            },
            reply: r.reply,
        }
    }
}

/// Aggregated metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub failures: u64,
    pub seconds_total: f64,
    /// Plan decisions served from the LRU cache vs computed fresh, plus
    /// entries evicted by capacity. Mirrored from [`PlanCache`] (the
    /// single source of truth) on every `choose_plan`.
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    pub plan_cache_evictions: u64,
    /// Multi-input dispatches executed by the batched scheduler.
    pub batches: u64,
    /// Requests that shared their batch with at least one other request
    /// (the grouping win; 0 means every batch was a singleton).
    pub batched_requests: u64,
    /// Largest batch executed so far.
    pub max_batch_size: u64,
    /// Sum of executed batch sizes (numerator of the mean).
    pub batch_size_sum: u64,
    /// Runtime resolve-cache hits: dispatches that reused a pinned
    /// `ResolvedSeq` (no manifest lookup, no executable-cache probe).
    /// Mirrored from [`crate::runtime::RuntimeCounters`] on every batch
    /// and metrics snapshot.
    pub resolve_hits: u64,
    /// Runtime resolve-cache misses (plans built, or failed attempts —
    /// failures are not cached).
    pub resolve_misses: u64,
    /// Executables compiled fresh by the runtime.
    pub executable_compiles: u64,
    /// Executable-cache hits inside the runtime.
    pub executable_cache_hits: u64,
    /// `PlanShard` chunk requests received by this worker over the
    /// control plane.
    pub shard_requests: u64,
    /// `PlanShard` chunks successfully evaluated and replied (a failed
    /// chunk — unknown sequence, out-of-range — counts a request only;
    /// the submitter re-plans it locally).
    pub shard_served: u64,
    /// Planner searches this worker ran on behalf of control-plane
    /// `Forecast` queries — cold-key planning moved off the submitting
    /// thread. At most one per (key, device): repeats hit the worker's
    /// forecast memo.
    pub planner_on_worker: u64,
    /// Requests refused at submit by admission control (bounded
    /// in-flight queue). Counted engine-side — a shed request never
    /// reaches a worker — and overlaid onto this device's snapshot by
    /// the engine when metrics are collected.
    pub queue_sheds: u64,
    /// Admission-control sheds split by request priority. Engine-side
    /// overlay like `queue_sheds` (whose total it decomposes).
    pub queue_sheds_by_priority: BTreeMap<u8, u64>,
    /// User pipelines accepted into this worker's dynamic catalog
    /// (control-plane `RegisterPipeline`, including idempotent
    /// re-registrations of identical source).
    pub pipeline_registrations: u64,
    /// Pipeline registrations rejected with a typed error (invalid
    /// script, quota, duplicate name).
    pub pipeline_rejections: u64,
    /// Wall time this worker spent handling registrations (script →
    /// IR → fusion space → codegen, plus validation).
    pub pipeline_compile_seconds: f64,
    /// Requests shed by the scheduler because their deadline had
    /// already expired when picked up (typed
    /// [`ServeError::DeadlineExpired`] instead of a late execution).
    pub deadline_sheds: u64,
    /// Deadline-carrying requests that reached a terminal outcome on
    /// this worker (the SLO-miss denominator).
    pub deadline_requests: u64,
    /// Deadline-carrying requests whose terminal outcome — reply or
    /// shed — came after the deadline. Sheds count: the client did not
    /// get its result in time either way.
    pub slo_misses: u64,
    /// Times this worker's lane was respawned by the supervisor after a
    /// panic (fresh `Context`, reloaded calibration, replayed pipeline
    /// catalog). Engine-side overlay like `queue_sheds`.
    pub worker_restarts: u64,
    /// Requests reclaimed from this lane on death and re-routed to a
    /// surviving device. Engine-side overlay.
    pub failovers: u64,
    /// Re-execution attempts spent on requests reclaimed from this lane
    /// (executions are pure, so re-running is safe). Engine-side
    /// overlay.
    pub retries: u64,
    /// Requests that died with this lane and could not be re-executed
    /// (pinned, retry budget exhausted, inputs unreconstructible, or no
    /// surviving lane): typed [`ServeError::WorkerLost`] sheds.
    /// Engine-side overlay.
    pub worker_lost_sheds: u64,
    /// Circuit-breaker state changes on this lane (closed → open on
    /// failure or wedge, open → half-open on respawn, half-open →
    /// closed on a served probe). Engine-side overlay.
    pub breaker_transitions: u64,
    /// Requests this worker served as G-way splits (scatter /
    /// partial-reduce / gather across the fleet, one ticket each). The
    /// owning lane counts the split; the blocks land in `split_blocks`.
    pub splits: u64,
    /// Row blocks executed on this lane on behalf of split requests:
    /// sub-executions scattered here by some owner, plus the owner's
    /// own inline block and any gather-timeout local retries.
    pub split_blocks: u64,
    /// Split attempts that fell back to whole single-device execution
    /// (no legal row-blocking, a failed block past the retry budget, or
    /// a scatter that could not reach its peers).
    pub split_fallbacks: u64,
    /// Source batches that executed as part of a horizontally fused
    /// combined dispatch ([`crate::codegen::horizontal`]): the
    /// scheduler priced adjacent EDF-ordered groups with
    /// [`crate::planner::forecast_hfuse`] and the combined launch won.
    /// Each fused turn adds the number of *member* batches, so
    /// `hfused_batches / batches` is the share of dispatches that
    /// shared a grid.
    pub hfused_batches: u64,
    /// Kernel launches elided by horizontal fusion: for each fused
    /// dispatch, (sum of member launch counts) − (combined launch
    /// count). The forecast's launch-overhead savings term, realized.
    pub hfuse_launch_savings: u64,
    /// Time executed requests spent queued before their batch was
    /// dispatched (submission → batch start). Per device this is the
    /// routing-vs-queueing signal: a device whose queue wait dwarfs its
    /// execution time is over-subscribed.
    pub queued: Histogram,
    /// End-to-end latency (submission → terminal outcome, sheds
    /// included) of every request this worker answered. p50/p99 SLO
    /// reporting reads this.
    pub latency: Histogram,
    /// Per-sequence (executed-request count, batch-attributed seconds).
    /// Requests rejected before dispatch (e.g. plan-resolution errors)
    /// appear only in `requests`/`failures`.
    pub per_seq: BTreeMap<String, (u64, f64)>,
}

impl Metrics {
    /// Mean requests per executed batch (0 before the first batch).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }

    /// Fold another worker's metrics into this one (the fleet
    /// aggregate): counters add, batch maxima take the max, per-seq and
    /// queued-duration distributions merge.
    pub fn merge(&mut self, other: &Metrics) {
        self.requests += other.requests;
        self.failures += other.failures;
        self.seconds_total += other.seconds_total;
        self.plan_cache_hits += other.plan_cache_hits;
        self.plan_cache_misses += other.plan_cache_misses;
        self.plan_cache_evictions += other.plan_cache_evictions;
        self.batches += other.batches;
        self.batched_requests += other.batched_requests;
        self.max_batch_size = self.max_batch_size.max(other.max_batch_size);
        self.batch_size_sum += other.batch_size_sum;
        self.resolve_hits += other.resolve_hits;
        self.resolve_misses += other.resolve_misses;
        self.executable_compiles += other.executable_compiles;
        self.executable_cache_hits += other.executable_cache_hits;
        self.shard_requests += other.shard_requests;
        self.shard_served += other.shard_served;
        self.planner_on_worker += other.planner_on_worker;
        self.queue_sheds += other.queue_sheds;
        for (prio, n) in &other.queue_sheds_by_priority {
            *self.queue_sheds_by_priority.entry(*prio).or_insert(0) += n;
        }
        self.pipeline_registrations += other.pipeline_registrations;
        self.pipeline_rejections += other.pipeline_rejections;
        self.pipeline_compile_seconds += other.pipeline_compile_seconds;
        self.deadline_sheds += other.deadline_sheds;
        self.deadline_requests += other.deadline_requests;
        self.slo_misses += other.slo_misses;
        self.worker_restarts += other.worker_restarts;
        self.failovers += other.failovers;
        self.retries += other.retries;
        self.worker_lost_sheds += other.worker_lost_sheds;
        self.breaker_transitions += other.breaker_transitions;
        self.splits += other.splits;
        self.split_blocks += other.split_blocks;
        self.split_fallbacks += other.split_fallbacks;
        self.hfused_batches += other.hfused_batches;
        self.hfuse_launch_savings += other.hfuse_launch_savings;
        self.queued.merge(&other.queued);
        self.latency.merge(&other.latency);
        for (seq, (count, secs)) in &other.per_seq {
            let e = self.per_seq.entry(seq.clone()).or_insert((0, 0.0));
            e.0 += count;
            e.1 += secs;
        }
    }
}

/// Cache key of one plan decision: a sequence at a problem size on a
/// device. Size and device are part of the key so a plan tuned for one
/// `ProblemSize` (or GPU model) is never served for another. Sizes are
/// stored tile-padded (the granularity the planner actually plans at),
/// so raw sizes that pad to the same shape share one entry instead of
/// re-planning per raw pair. The device name is interned (`Arc<str>`,
/// issued by the context/registry): per-request key construction bumps
/// a refcount instead of allocating a fresh `String`, and equality,
/// ordering and hashing still compare the name's *contents*.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct PlanKey {
    pub seq: String,
    pub m: usize,
    pub n: usize,
    pub device: Arc<str>,
}

impl PlanKey {
    /// Key for a sequence at a problem size on a device. Callers pass
    /// the tile-padded size (pad once at the boundary — `choose_plan`
    /// does); an unpadded size here is a bug, not a request to pad. On
    /// the serve path `device` is the context's interned name
    /// (`ctx.device.clone()`); `&str`/`String` also convert, for tests
    /// and ad-hoc keys.
    pub fn new(seq: &str, p: ProblemSize, device: impl Into<Arc<str>>) -> PlanKey {
        debug_assert!(
            p == p.padded(),
            "PlanKey sizes must be tile-padded (got {}x{})",
            p.m,
            p.n
        );
        PlanKey {
            seq: seq.to_string(),
            m: p.m,
            n: p.n,
            device: device.into(),
        }
    }
}

/// Small LRU cache of plan decisions with hit/miss/eviction counters.
/// The coordinator's working set is tiny (sequences × hot sizes), so a
/// vector in recency order is simpler and faster than a linked map.
#[derive(Debug)]
pub struct PlanCache {
    cap: usize,
    /// Recency order: front = least recently used, back = most recent.
    entries: Vec<(PlanKey, PlanChoice)>,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl PlanCache {
    pub const DEFAULT_CAP: usize = 64;

    pub fn new(cap: usize) -> PlanCache {
        assert!(cap >= 1, "plan cache needs capacity >= 1");
        PlanCache {
            cap,
            entries: Vec::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, key: &PlanKey) -> bool {
        self.entries.iter().any(|(k, _)| k == key)
    }

    /// Look up a plan, refreshing its recency. Counts a hit or a miss.
    pub fn get(&mut self, key: &PlanKey) -> Option<PlanChoice> {
        if let Some(i) = self.entries.iter().position(|(k, _)| k == key) {
            let entry = self.entries.remove(i);
            let choice = entry.1;
            self.entries.push(entry);
            self.hits += 1;
            Some(choice)
        } else {
            self.misses += 1;
            None
        }
    }

    /// Insert as most-recent, evicting the least-recent entry beyond
    /// capacity.
    pub fn insert(&mut self, key: PlanKey, choice: PlanChoice) {
        if let Some(i) = self.entries.iter().position(|(k, _)| k == &key) {
            self.entries.remove(i);
        }
        self.entries.push((key, choice));
        if self.entries.len() > self.cap {
            self.entries.remove(0);
            self.evictions += 1;
        }
    }

    /// Keys in recency order (least recent first).
    pub fn keys(&self) -> impl Iterator<Item = &PlanKey> {
        self.entries.iter().map(|(k, _)| k)
    }
}

/// The coordinator: plan cache + runtime + metrics. The engine drives it
/// through the batched scheduler; it can also be embedded directly for
/// synchronous, checked runs (see the examples).
pub struct Coordinator {
    ctx: Arc<Context>,
    runtime: Runtime,
    /// (seq, size, device) → chosen variant (decided by the planner).
    plan_cache: PlanCache,
    /// Padded `(seq, m, n)` → the planner's per-variant forecast on
    /// this device, memoized so a control-plane `Forecast` repeat (or a
    /// `choose_plan` following a `Forecast`) never re-runs the search.
    /// FIFO-bounded like the router's forecast cache: clients control
    /// the keys.
    forecast_cache: BTreeMap<(String, usize, usize), VariantForecast>,
    /// Insertion order of `forecast_cache` keys, for FIFO eviction.
    forecast_order: VecDeque<(String, usize, usize)>,
    /// Sequence name → its planning inputs (program, built space,
    /// baseline plan), reused across `PlanShard` chunks *and* fresh
    /// per-size forecasts — the space is size-independent, so a new
    /// problem size never re-runs fusion enumeration or space
    /// construction. Deterministic per sequence; built-ins are a closed
    /// set and registered pipelines are bounded by `pipeline_quota`, so
    /// no eviction is needed.
    space_cache: BTreeMap<String, PlanningEntry>,
    /// Cap on concurrently registered user pipelines (the dynamic half
    /// of the catalog). Set from [`EngineConfig::pipeline_quota`] when
    /// serving.
    pipeline_quota: usize,
    /// Supervision context of the fleet lane this coordinator serves
    /// (`None` for unsupervised/embedded use): parking lot, heartbeat,
    /// fault plan, breaker. Set by the engine's worker loop before
    /// serving.
    lane: Option<Arc<engine::LaneCtx>>,
    /// Fault-injection actions scheduled for the turn in flight
    /// (deterministic chaos from [`EngineConfig::fault_plan`]); cleared
    /// when the turn ends.
    chaos: Option<engine::TurnChaos>,
    /// Per-block gather bound for split requests this lane owns
    /// ([`EngineConfig::split_gather`], set when serving).
    split_gather: Duration,
    /// Horizontal fusion on the serve path
    /// ([`EngineConfig::hfuse`], set when serving): when a drained
    /// turn yields several batches, price adjacent EDF-ordered groups
    /// with [`planner::plan_hfuse`] and execute winning segments as
    /// one combined dispatch ([`crate::codegen::horizontal`]).
    hfuse: bool,
    /// Widest fused segment the turn segmentation prices —
    /// [`PlannerConfig::beam`] handed to [`planner::plan_hfuse`];
    /// `None` = exact segmentation ([`EngineConfig::hfuse_beam`]).
    hfuse_beam: Option<usize>,
    /// Padded `(seq, m, n, choice)` → the paper-level plan (kernels and
    /// geometry) the hfuse forecast prices that batch key with; `None`
    /// caches a planning failure so the key stays unfused without
    /// retrying every turn. FIFO-bounded like `forecast_cache`:
    /// clients control the keys.
    hfuse_plans: BTreeMap<(String, usize, usize, PlanChoice), Option<Arc<SeqPlan>>>,
    /// Insertion order of `hfuse_plans` keys, for FIFO eviction.
    hfuse_order: VecDeque<(String, usize, usize, PlanChoice)>,
    /// Metrics carried over from this lane's previous incarnations
    /// (before supervisor respawns). Snapshots and the final return
    /// value fold this in; the live `metrics` field only covers the
    /// current incarnation, because cache counters are mirrored by
    /// assignment.
    metrics_base: Metrics,
    pub metrics: Metrics,
}

/// One sequence's cached planning inputs (see `Coordinator::space_cache`).
struct PlanningEntry {
    prog: crate::ir::program::Program,
    space: Space,
    baseline: SeqPlan,
}

/// Per-member reply bookkeeping of a prepared batch:
/// `(enqueued, deadline, lot, split_block, reply)`.
type ReplySlot = (Instant, Option<Instant>, Option<usize>, bool, Reply);

/// A batch whose requests have been consumed into runnable inputs and
/// reply handles — what `Coordinator::prepare_batch` hands the plain
/// and horizontally fused dispatch paths (the inputs travel beside it
/// so they can move into the runtime without a copy).
struct PreparedBatch {
    key: batch::BatchKey,
    /// Raw (artifact-granularity) rows, for `Runtime::resolve`.
    m: usize,
    /// Raw (artifact-granularity) columns.
    n: usize,
    size: u64,
    /// Members that are scattered split blocks (accounted into the
    /// split plane, not the request plane).
    block_members: u64,
    replies: Vec<ReplySlot>,
}

impl Coordinator {
    /// Cap on memoized per-key forecasts (matches the spirit of
    /// [`crate::fleet::CostModel::CACHE_CAP`]: generous, but bounded
    /// against size-scanning clients).
    const FORECAST_CAP: usize = 4096;

    /// Default registration quota for user pipelines (see
    /// [`EngineConfig::pipeline_quota`]).
    pub const DEFAULT_PIPELINE_QUOTA: usize = 32;

    pub fn new(ctx: Arc<Context>, artifacts_dir: &Path) -> Result<Coordinator> {
        Self::with_manifest(ctx, Runtime::load_manifest(artifacts_dir)?)
    }

    /// Build over an already-parsed manifest — fleet workers share one
    /// parse across their per-device runtimes.
    pub fn with_manifest(ctx: Arc<Context>, manifest: Arc<Manifest>) -> Result<Coordinator> {
        Ok(Coordinator {
            ctx,
            runtime: Runtime::with_manifest(manifest)?,
            plan_cache: PlanCache::new(PlanCache::DEFAULT_CAP),
            forecast_cache: BTreeMap::new(),
            forecast_order: VecDeque::new(),
            space_cache: BTreeMap::new(),
            pipeline_quota: Self::DEFAULT_PIPELINE_QUOTA,
            lane: None,
            chaos: None,
            split_gather: Duration::from_secs(5),
            hfuse: true,
            hfuse_beam: None,
            hfuse_plans: BTreeMap::new(),
            hfuse_order: VecDeque::new(),
            metrics_base: Metrics::default(),
            metrics: Metrics::default(),
        })
    }

    /// Attach the engine's per-lane supervision context (and the metrics
    /// carried over from the lane's previous incarnation, on respawn).
    pub(crate) fn attach_lane(&mut self, lane: Arc<engine::LaneCtx>, base: Metrics) {
        self.lane = Some(lane);
        self.metrics_base = base;
    }

    /// This incarnation's metrics folded over the carried-over base —
    /// what snapshots and the worker's final return value report.
    pub(crate) fn full_metrics(&mut self) -> Metrics {
        self.sync_runtime_metrics();
        let mut m = self.metrics_base.clone();
        m.merge(&self.metrics);
        m
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Decide (and cache) the plan for a sequence at a problem size: run
    /// the pruned planner on the device model; if the best plan fuses
    /// anything (fewer kernels than calls), execute the fused artifact
    /// variant, else the baseline decomposition. Repeat requests for the
    /// same `(seq, m, n)` on the same device skip planning entirely.
    pub fn choose_plan(&mut self, seq_name: &str, m: usize, n: usize) -> Result<PlanChoice> {
        // Validate the name (built-in or registered pipeline) before
        // touching the cache so unknown sequences never pollute the
        // hit/miss counters.
        self.ensure_planning_entry(seq_name)?;
        // Pad exactly once: the padded size is both the plan-cache key
        // and the size the planner plans at (PlanKey::new asserts it).
        let p = ProblemSize::new(m, n).padded();
        let key = PlanKey::new(seq_name, p, self.ctx.device.clone());
        let cached = self.plan_cache.get(&key);
        self.sync_plan_cache_metrics();
        if let Some(choice) = cached {
            return Ok(choice);
        }
        // Execute the CUBLAS decomposition only if it actually predicts
        // faster than the searched plan. Ties go to the fused artifacts:
        // even a no-fusion plan is retuned per size, while the baseline
        // is fixed-config and pays copy kernels for the S-tagged
        // sequences. (Predictions favor fused on all 11 sequences; the
        // comparison is what makes this a per-size decision.) The same
        // forecast, on each device's own calibration, is what the fleet
        // router ranks devices by — one definition of "fast" everywhere.
        let (forecast, _) = self.forecast_memo(seq_name, p)?;
        let choice = PlanChoice::from_forecast(&forecast);
        self.plan_cache.insert(key, choice);
        self.sync_plan_cache_metrics();
        Ok(choice)
    }

    /// Build (once) the planning inputs of a name: a built-in
    /// sequence's space and baseline, or — for registered pipelines —
    /// the entry that registration inserted. One build serves every
    /// `PlanShard` chunk and every problem size's forecast. Errors on
    /// names that are neither built-in nor registered.
    fn ensure_planning_entry(&mut self, seq_name: &str) -> Result<()> {
        if self.space_cache.contains_key(seq_name) {
            return Ok(());
        }
        let seq: Sequence = sequences::by_name(seq_name)
            .ok_or_else(|| anyhow!("unknown sequence '{seq_name}'"))?;
        let (prog, _graph, space) = seq.space(&self.ctx.lib, &ImplAxes::minimal());
        let baseline = autotune::baseline_plan(&seq.cublas_program(&self.ctx.lib), &self.ctx.lib);
        self.space_cache.insert(
            seq_name.to_string(),
            PlanningEntry {
                prog,
                space,
                baseline,
            },
        );
        Ok(())
    }

    /// The planner's per-variant forecast for a sequence at a padded
    /// size on this device's calibration, memoized. Returns
    /// `(forecast, fresh)` where `fresh` marks an actual planner run
    /// (vs a memo hit). Plans over the per-sequence cached space —
    /// bit-identical to [`planner::forecast_variants`], which builds an
    /// identical space fresh (both are pure functions of the same
    /// inputs), so worker-side and submitter-fallback forecasts always
    /// agree.
    fn forecast_memo(&mut self, seq_name: &str, p: ProblemSize) -> Result<(VariantForecast, bool)> {
        debug_assert_eq!(p, p.padded(), "forecasts are memoized per padded size");
        let memo_key = (seq_name.to_string(), p.m, p.n);
        if let Some(&f) = self.forecast_cache.get(&memo_key) {
            return Ok((f, false));
        }
        self.ensure_planning_entry(seq_name)?;
        let forecast = {
            let entry = &self.space_cache[seq_name];
            let planned = planner::plan_space(
                &entry.prog,
                &entry.space,
                &self.ctx.db,
                p,
                &PlannerConfig::default(),
            );
            VariantForecast {
                planned: planned.predicted,
                baseline: predict_seq(&self.ctx.db, &entry.baseline, p),
            }
        };
        while self.forecast_order.len() >= Self::FORECAST_CAP {
            if let Some(old) = self.forecast_order.pop_front() {
                self.forecast_cache.remove(&old);
            }
        }
        self.forecast_order.push_back(memo_key.clone());
        self.forecast_cache.insert(memo_key, forecast);
        Ok((forecast, true))
    }

    /// Answer a control-plane `Forecast`: plan the key on this device's
    /// own calibration (memoized; fresh runs count into
    /// `planner_on_worker`) and seed the plan cache so the first routed
    /// execution of the key hits instead of re-planning.
    fn forecast_for(&mut self, seq_name: &str, m: usize, n: usize) -> Result<VariantForecast> {
        let p = ProblemSize::new(m, n).padded();
        let (forecast, fresh) = self.forecast_memo(seq_name, p)?;
        if fresh {
            self.metrics.planner_on_worker += 1;
        }
        let key = PlanKey::new(seq_name, p, self.ctx.device.clone());
        if self.plan_cache.get(&key).is_none() {
            self.plan_cache.insert(key, PlanChoice::from_forecast(&forecast));
        }
        self.sync_plan_cache_metrics();
        Ok(forecast)
    }

    /// Answer a control-plane `PlanShard`: evaluate one chunk of the
    /// key's partition range against the supplied calibration. The
    /// optimization space is rebuilt from the sequence name (pure —
    /// identical on every worker) and cached per sequence.
    fn eval_shard(
        &mut self,
        seq_name: &str,
        m: usize,
        n: usize,
        range: Range<usize>,
        db: &RoutineDb,
    ) -> Result<planner::ShardEval> {
        let p = ProblemSize::new(m, n).padded();
        self.ensure_planning_entry(seq_name)?;
        let space = &self.space_cache[seq_name].space;
        if range.end > space.partitions.len() {
            return Err(anyhow!(
                "shard range {}..{} exceeds the {} partitions of '{seq_name}'",
                range.start,
                range.end,
                space.partitions.len()
            ));
        }
        Ok(planner::shard::eval_chunk(
            space,
            db,
            p,
            &PlannerConfig::default(),
            range,
        ))
    }

    /// Answer a control-plane `RegisterPipeline`: compile the script
    /// end to end and insert the result into this worker's dynamic
    /// catalog and planning caches. Returns the content fingerprint on
    /// success. Rejections are typed [`ServeError`]s; every outcome is
    /// counted, and all time spent (compile + validation) accrues to
    /// `pipeline_compile_seconds`.
    pub(crate) fn register_pipeline(&mut self, name: &str, src: &str) -> Result<u64> {
        let t0 = Instant::now();
        let res = self.register_pipeline_inner(name, src);
        self.metrics.pipeline_compile_seconds += t0.elapsed().as_secs_f64();
        match &res {
            Ok(_) => self.metrics.pipeline_registrations += 1,
            Err(_) => self.metrics.pipeline_rejections += 1,
        }
        res
    }

    fn register_pipeline_inner(&mut self, name: &str, src: &str) -> Result<u64> {
        // Built-in names are never shadowable: a pipeline must not
        // change what "bicgk" means mid-serve.
        if sequences::by_name(name).is_some() {
            return Err(anyhow::Error::new(ServeError::DuplicatePipeline {
                name: name.to_string(),
            }));
        }
        let fp = pipelines::fingerprint(src, &self.ctx.lib);
        if let Some(existing) = self.runtime.pipeline(name) {
            if existing.fingerprint == fp {
                // Identical content: an idempotent dedup hit, so a
                // rollback retry or a re-sync never errors.
                return Ok(fp);
            }
            return Err(anyhow::Error::new(ServeError::DuplicatePipeline {
                name: name.to_string(),
            }));
        }
        let count = self.runtime.pipeline_names().len();
        if count >= self.pipeline_quota {
            return Err(anyhow::Error::new(ServeError::PipelineQuota {
                count,
                quota: self.pipeline_quota,
            }));
        }
        let compiled = pipelines::compile(name, src, &self.ctx.lib).map_err(|e| {
            anyhow::Error::new(ServeError::InvalidScript {
                line: e.line,
                msg: e.msg,
            })
        })?;
        debug_assert_eq!(compiled.pipeline.fingerprint, fp);
        // The compiled planning inputs slot straight into the same
        // space cache built-ins use, so choose_plan/forecast/shard
        // treat the pipeline exactly like a built-in from here on.
        self.space_cache.insert(
            name.to_string(),
            PlanningEntry {
                prog: compiled.pipeline.program.clone(),
                space: compiled.space,
                baseline: compiled.baseline,
            },
        );
        self.runtime.register_pipeline(compiled.pipeline);
        Ok(fp)
    }

    /// Remove a registered pipeline and every cache entry derived from
    /// it (planning inputs, forecasts, plan decisions, resolved plans).
    /// Returns whether the name was registered. Built-ins are
    /// unaffected: their names never enter the runtime's registry.
    pub(crate) fn unregister_pipeline(&mut self, name: &str) -> bool {
        let was = self.runtime.unregister_pipeline(name);
        if was {
            self.space_cache.remove(name);
            self.forecast_cache.retain(|k, _| k.0 != name);
            self.forecast_order.retain(|k| k.0 != name);
            self.plan_cache.entries.retain(|(k, _)| k.seq != name);
        }
        was
    }

    /// Mirror the plan cache's counters into the metrics snapshot.
    fn sync_plan_cache_metrics(&mut self) {
        self.metrics.plan_cache_hits = self.plan_cache.hits;
        self.metrics.plan_cache_misses = self.plan_cache.misses;
        self.metrics.plan_cache_evictions = self.plan_cache.evictions;
    }

    /// Mirror the runtime's resolve/compile counters into the metrics
    /// snapshot (the runtime's atomics are the single source of truth).
    fn sync_runtime_metrics(&mut self) {
        let c = self.runtime.counters();
        self.metrics.resolve_hits = c.resolve_hits;
        self.metrics.resolve_misses = c.resolve_misses;
        self.metrics.executable_compiles = c.executable_compiles;
        self.metrics.executable_cache_hits = c.executable_cache_hits;
    }

    /// Turn a batch's requests into runnable inputs and reply handles,
    /// recording the per-member queued durations — the shared front
    /// half of the plain and horizontally fused dispatch paths.
    /// Consumes the batch: explicit input tensors move out without a
    /// copy.
    fn prepare_batch(&mut self, b: batch::Batch) -> (PreparedBatch, Vec<BTreeMap<String, Tensor>>) {
        debug_assert_eq!(
            b.key.device, self.ctx.device,
            "batch grouped for another device"
        );
        let batch::Batch { key, m, n, reqs } = b;
        let variant = key.choice.as_str();
        let size = reqs.len() as u64;
        let dispatched = Instant::now();
        let mut inputs = Vec::with_capacity(reqs.len());
        let mut replies = Vec::with_capacity(reqs.len());
        let mut block_members = 0u64;
        for r in reqs {
            if r.split_block {
                // Scattered row block of a split request another lane
                // owns: the owner recorded the ticket's queue time and
                // carries its accounting, so blocks only count into the
                // split plane below.
                block_members += 1;
            } else {
                // queued = submission → batch dispatch, per member
                self.metrics
                    .queued
                    .record(dispatched.duration_since(r.enqueued).as_secs_f64());
            }
            inputs.push(match r.inputs {
                RequestInputs::Explicit(map) => map,
                RequestInputs::Synth { seed } => {
                    synth_inputs(&self.runtime, &key.seq, variant, m, n, seed)
                }
            });
            replies.push((r.enqueued, r.deadline, r.lot, r.split_block, r.reply));
        }
        (
            PreparedBatch {
                key,
                m,
                n,
                size,
                block_members,
                replies,
            },
            inputs,
        )
    }

    /// Record a dispatched batch's metrics and reply to every member —
    /// the shared back half of the plain and horizontally fused
    /// dispatch paths. `dt` is the execution time attributed to this
    /// batch: wall time for a plain dispatch, the members' own stage
    /// seconds for a fused one.
    fn complete_batch(&mut self, prep: PreparedBatch, results: Vec<Result<RunResult>>, dt: f64) {
        let PreparedBatch {
            key,
            size,
            block_members,
            replies,
            ..
        } = prep;
        self.metrics.batches += 1;
        self.metrics.batch_size_sum += size;
        self.metrics.max_batch_size = self.metrics.max_batch_size.max(size);
        if size > 1 {
            self.metrics.batched_requests += size;
        }
        // Scattered split blocks are sub-executions of a ticket the
        // owning lane accounts for — they count into split_blocks and
        // batch occupancy, never into request/latency/SLO planes.
        self.metrics.requests += size - block_members;
        self.metrics.split_blocks += block_members;
        self.metrics.seconds_total += dt;
        let e = self.metrics.per_seq.entry(key.seq.clone()).or_insert((0, 0.0));
        e.0 += size - block_members;
        e.1 += dt;
        self.sync_runtime_metrics();
        // Injected reply delay: ship the batch's replies late (heartbeat
        // stays live — this models a slow lane, not a wedged one).
        if let Some(d) = self.chaos.as_ref().and_then(|c| c.delay) {
            std::thread::sleep(d);
        }
        for ((enqueued, deadline, lot, split_block, reply), res) in
            replies.into_iter().zip(results)
        {
            if split_block {
                // Reply straight to the owner's gather channel: the
                // owner does the ticket-level latency/SLO bookkeeping.
                if let (Some(lane), Some(idx)) = (&self.lane, lot) {
                    lane.unpark(idx);
                }
                if self.chaos.as_ref().is_some_and(|c| c.drop_replies) {
                    drop(reply);
                } else {
                    reply.send(res);
                }
            } else {
                if res.is_err() {
                    self.metrics.failures += 1;
                }
                self.finish(enqueued, deadline, lot, reply, res);
            }
        }
    }

    /// Execute one grouped batch as a multi-input dispatch, record the
    /// per-batch metrics, and reply to every member. Consumes the
    /// batch: explicit input tensors move into the runtime without a
    /// copy.
    pub(crate) fn execute_batch(&mut self, b: batch::Batch) {
        let (prep, inputs) = self.prepare_batch(b);
        // Injected mid-execute panic: fires after the batch consumed its
        // requests (explicit inputs are gone — the worst case the
        // supervisor must handle), before any result exists.
        if self.chaos.as_ref().is_some_and(|c| c.panic_in_execute) {
            std::panic::panic_any(engine::chaos::EXEC_PANIC_MARKER);
        }
        let t0 = Instant::now();
        // Resolve once per batch key: the runtime's resolve cache makes
        // a repeat key one read-locked probe, and the batch then runs
        // entirely on pinned executables and slot-indexed environments.
        let results = match self
            .runtime
            .resolve(&prep.key.seq, prep.key.choice.as_str(), prep.m, prep.n)
        {
            Ok(plan) => self.runtime.run_resolved_batch(&plan, inputs),
            Err(e) => {
                // A missing size or corrupt artifact fails the whole
                // batch — every request would have hit the same artifact.
                let msg = format!("{e:#}");
                inputs.iter().map(|_| Err(anyhow!("{msg}"))).collect()
            }
        };
        let dt = t0.elapsed().as_secs_f64();
        self.complete_batch(prep, results, dt);
    }

    /// Execute a contiguous run of a turn's EDF-ordered batches as ONE
    /// horizontally fused dispatch ([`crate::codegen::horizontal`]):
    /// the segmentation planner decided the combined launch beats
    /// back-to-back execution ([`planner::plan_hfuse`] emits a
    /// multi-member segment only when its forecast wins). Per-member
    /// accounting — queued/latency/SLO, per-seq seconds, replies, and
    /// chaos hooks — matches [`Coordinator::execute_batch`], and
    /// results are bit-identical by [`Runtime::run_hfused`]'s
    /// contract; members complete in drained (EDF) order.
    fn execute_hfused(&mut self, members: Vec<batch::Batch>, forecast: planner::HfuseForecast) {
        debug_assert!(members.len() > 1, "singleton segments dispatch plainly");
        // Prepare every member first: all requests' inputs are consumed
        // before anything runs, matching execute_batch's panic window.
        let mut prepared = Vec::with_capacity(members.len());
        for b in members {
            prepared.push(self.prepare_batch(b));
        }
        if self.chaos.as_ref().is_some_and(|c| c.panic_in_execute) {
            std::panic::panic_any(engine::chaos::EXEC_PANIC_MARKER);
        }
        self.metrics.hfused_batches += prepared.len() as u64;
        self.metrics.hfuse_launch_savings += forecast.launches_saved;
        // Resolve each member once. A member whose artifact is missing
        // fails all its own slots — exactly as it would unfused — while
        // the remaining members still run fused.
        let resolved: Vec<_> = prepared
            .iter()
            .map(|(p, _)| {
                self.runtime
                    .resolve(&p.key.seq, p.key.choice.as_str(), p.m, p.n)
                    .map_err(|e| format!("{e:#}"))
            })
            .collect();
        let mut metas = Vec::with_capacity(prepared.len());
        let mut per_member: Vec<Option<Vec<Result<RunResult>>>> =
            Vec::with_capacity(prepared.len());
        let mut fused = Vec::new();
        let mut fused_at = Vec::new();
        for (mi, ((prep, inputs), res)) in prepared.into_iter().zip(resolved).enumerate() {
            match res {
                Ok(plan) => {
                    per_member.push(None);
                    fused_at.push(mi);
                    fused.push((plan, inputs));
                }
                Err(msg) => {
                    per_member.push(Some(inputs.iter().map(|_| Err(anyhow!("{msg}"))).collect()));
                }
            }
            metas.push(prep);
        }
        let outcomes = self.runtime.run_hfused(fused);
        for (mi, results) in fused_at.into_iter().zip(outcomes) {
            per_member[mi] = Some(results);
        }
        for (prep, results) in metas.into_iter().zip(per_member) {
            let results = results.expect("every member has results");
            // The combined dispatch interleaves members on this thread,
            // so each member is billed its own stage seconds — wall
            // time would charge every member the whole turn.
            let dt: f64 = results
                .iter()
                .filter_map(|r| r.as_ref().ok().map(|r| r.seconds))
                .sum();
            self.complete_batch(prep, results, dt);
        }
    }

    /// The paper-level plan — kernels, geometry, traffic — that the
    /// horizontal-fusion forecast prices a batch key with: the cached
    /// baseline decomposition for `Cublas` keys, the planner's best
    /// searched plan at the padded size for `Fused` keys. Memoized per
    /// key (FIFO-bounded); a key that cannot be planned memoizes `None`
    /// and its batches simply stay unfused.
    fn hfuse_seq_plan(&mut self, key: &batch::BatchKey) -> Option<Arc<SeqPlan>> {
        let memo = (key.seq.clone(), key.m, key.n, key.choice);
        if let Some(plan) = self.hfuse_plans.get(&memo) {
            return plan.clone();
        }
        let built = self.build_hfuse_plan(&key.seq, key.m, key.n, key.choice);
        while self.hfuse_order.len() >= Self::FORECAST_CAP {
            if let Some(old) = self.hfuse_order.pop_front() {
                self.hfuse_plans.remove(&old);
            }
        }
        self.hfuse_order.push_back(memo.clone());
        self.hfuse_plans.insert(memo, built.clone());
        built
    }

    fn build_hfuse_plan(
        &mut self,
        seq: &str,
        m: usize,
        n: usize,
        choice: PlanChoice,
    ) -> Option<Arc<SeqPlan>> {
        self.ensure_planning_entry(seq).ok()?;
        let entry = &self.space_cache[seq];
        match choice {
            PlanChoice::Cublas => Some(Arc::new(entry.baseline.clone())),
            PlanChoice::Fused => {
                let planned = planner::plan_space(
                    &entry.prog,
                    &entry.space,
                    &self.ctx.db,
                    ProblemSize::new(m, n),
                    &PlannerConfig::default(),
                );
                Some(Arc::new(planned.best))
            }
        }
    }

    /// Execute one routed split request as the owning lane: resolve the
    /// plan choice once, row-block the problem per the router's lane
    /// set, scatter the non-owner blocks as pinned sub-executions,
    /// execute block 0 inline, then gather and combine — accounting the
    /// whole exchange as ONE request (one latency sample, one SLO
    /// outcome) on this lane. A structural refusal (the sequence does
    /// not row-block) or a mid-split failure degrades to whole
    /// single-device execution; the ticket is never lost.
    fn execute_split(&mut self, req: Request) {
        let Request {
            seq,
            m,
            n,
            inputs,
            variant,
            enqueued,
            deadline,
            priority,
            lot,
            split,
            reply,
            ..
        } = req;
        let lanes = split.expect("run_turn peels only split requests");
        self.metrics
            .queued
            .record(Instant::now().duration_since(enqueued).as_secs_f64());
        // The planning entry backs both the plan decision and the
        // split analysis of the sequence's dataflow.
        if let Err(e) = self.ensure_planning_entry(&seq) {
            self.metrics.requests += 1;
            self.metrics.failures += 1;
            self.finish(enqueued, deadline, lot, reply, Err(e));
            return;
        }
        let choice = match variant.map(Ok).unwrap_or_else(|| self.choose_plan(&seq, m, n)) {
            Ok(c) => c,
            Err(e) => {
                self.metrics.requests += 1;
                self.metrics.failures += 1;
                self.finish(enqueued, deadline, lot, reply, Err(e));
                return;
            }
        };
        let full = match inputs {
            RequestInputs::Explicit(map) => map,
            RequestInputs::Synth { seed } => {
                synth_inputs(&self.runtime, &seq, choice.as_str(), m, n, seed)
            }
        };
        let spec = split::analyze(&self.space_cache[seq.as_str()].prog);
        let blocks = split::block_rows(m, lanes.len());
        let t0 = Instant::now();
        let res = match spec {
            // TILE-alignment can merge blocks below the decided G; a
            // shrunken partition no longer matches the lane set the
            // router priced, so serve whole instead of improvising.
            Some(spec) if blocks.len() == lanes.len() && lanes.len() >= 2 => {
                match self.run_split(&seq, choice, n, &spec, &blocks, &lanes, &full, priority) {
                    Ok(r) => {
                        self.metrics.splits += 1;
                        Ok(r)
                    }
                    Err(err) => {
                        self.metrics.split_fallbacks += 1;
                        self.runtime
                            .run_seq(&seq, choice.as_str(), m, n, &full)
                            .map_err(|e| e.context(format!("whole fallback after: {err:#}")))
                    }
                }
            }
            _ => {
                self.metrics.split_fallbacks += 1;
                self.runtime.run_seq(&seq, choice.as_str(), m, n, &full)
            }
        };
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.requests += 1;
        self.metrics.seconds_total += dt;
        let e = self.metrics.per_seq.entry(seq.clone()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += dt;
        if res.is_err() {
            self.metrics.failures += 1;
        }
        self.sync_runtime_metrics();
        self.finish(enqueued, deadline, lot, reply, res);
    }

    /// The scatter → partial-execute → gather → combine exchange of a
    /// split request. Blocks 1..G go to the decided peer lanes as
    /// pinned sub-requests (pinned so a peer death surfaces as a typed
    /// reply on the gather channel instead of migrating to a lane the
    /// cost model never priced); block 0 runs inline. A lost, failed or
    /// late block is re-executed locally under the engine's retry
    /// budget; an error return here means the caller falls back to
    /// whole single-device execution.
    #[allow(clippy::too_many_arguments)]
    fn run_split(
        &mut self,
        seq: &str,
        choice: PlanChoice,
        n: usize,
        spec: &split::SplitSpec,
        blocks: &[(usize, usize)],
        lanes: &[usize],
        inputs: &BTreeMap<String, Tensor>,
        priority: u8,
    ) -> Result<RunResult> {
        let lane = self
            .lane
            .clone()
            .ok_or_else(|| anyhow!("split execution needs a supervised fleet lane"))?;
        debug_assert_eq!(lanes[0], lane.index, "block 0 belongs to the owning lane");
        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(lanes.len() - 1);
        for (k, (&peer, &(start, rows))) in lanes.iter().zip(blocks).enumerate().skip(1) {
            let sliced = split::slice_inputs(spec, inputs, start, rows)?;
            let (stx, srx) = mpsc::channel();
            // The sub-request takes its own depth slot on the peer (the
            // router already counted the scatter when it decided), and
            // its Reply gives the slot back on every terminal outcome —
            // including a failed send, whose dropped message drops the
            // Reply and leaves `srx` disconnected for the gather loop.
            lane.depths[peer].fetch_add(1, Ordering::Relaxed);
            let _ = lane.txs[peer].send(Msg::Run(Request {
                seq: seq.to_string(),
                m: rows,
                n,
                inputs: RequestInputs::Explicit(sliced),
                variant: Some(choice),
                enqueued: Instant::now(),
                deadline: None,
                priority,
                attempts: 0,
                pinned: true,
                lot: None,
                split: None,
                split_block: true,
                admission: None,
                reply: Reply::new(stx, Some(lane.depths[peer].clone())),
            }));
            pending.push((k, srx));
        }
        let (start0, rows0) = blocks[0];
        let own_inputs = split::slice_inputs(spec, inputs, start0, rows0)?;
        let own = self
            .runtime
            .run_seq(seq, choice.as_str(), rows0, n, &own_inputs)?;
        self.metrics.split_blocks += 1;
        let RunResult {
            env: own_env,
            stages,
            variant,
            ..
        } = own;
        let mut envs = Vec::with_capacity(blocks.len());
        envs.push(own_env);
        // Gather with a shared bound: every peer gets the remainder of
        // one gather window, not a fresh one each.
        let by = Instant::now() + self.split_gather;
        for (k, srx) in pending {
            let got = match srx.recv_timeout(by.saturating_duration_since(Instant::now())) {
                Ok(Ok(r)) => Some(r.env),
                Ok(Err(_)) | Err(_) => None,
            };
            let env = match got {
                Some(env) => env,
                None => {
                    if lane.retry_budget == 0 {
                        return Err(anyhow!(
                            "split block {k} of '{seq}' lost and the retry budget is 0"
                        ));
                    }
                    lane.fleet.retries[lane.index].fetch_add(1, Ordering::Relaxed);
                    let (start, rows) = blocks[k];
                    let retry = split::slice_inputs(spec, inputs, start, rows)?;
                    let r = self.runtime.run_seq(seq, choice.as_str(), rows, n, &retry)?;
                    self.metrics.split_blocks += 1;
                    r.env
                }
            };
            envs.push(env);
        }
        let mut env = inputs.clone();
        env.extend(split::combine_outputs(spec, &envs)?);
        Ok(RunResult {
            env,
            stages,
            seconds: t0.elapsed().as_secs_f64(),
            variant,
        })
    }

    /// Deliver one request's terminal outcome, recording end-to-end
    /// latency and SLO accounting. A shed or failure still counts into
    /// the latency histogram and (if past its deadline) the SLO misses:
    /// the client did not get a result in time either way.
    fn finish(
        &mut self,
        enqueued: Instant,
        deadline: Option<Instant>,
        lot: Option<usize>,
        reply: Reply,
        res: Result<RunResult>,
    ) {
        let done = Instant::now();
        self.metrics
            .latency
            .record(done.duration_since(enqueued).as_secs_f64());
        if let Some(d) = deadline {
            self.metrics.deadline_requests += 1;
            if done > d {
                self.metrics.slo_misses += 1;
            }
        }
        // Unpark before replying: the parked half holds the queue-depth
        // slot, so releasing it first preserves the invariant that a
        // client observing its reply also observes the depth released.
        if let (Some(lane), Some(idx)) = (&self.lane, lot) {
            lane.unpark(idx);
        }
        // Injected reply drop: the ticket resolves to a disconnect error
        // once both reply halves are gone — never a hang.
        if self.chaos.as_ref().is_some_and(|c| c.drop_replies) {
            drop(reply);
        } else {
            reply.send(res);
        }
    }

    /// One scheduling turn: shed already-expired requests, group the
    /// rest by batch key (one `choose_plan` per key), then execute the
    /// groups earliest-deadline-first as one dispatch each, replying
    /// per request.
    fn run_turn(&mut self, queue: Vec<Request>) {
        // Deadline shedding happens at the turn boundary: a request
        // whose deadline passed while it waited is rejected with a
        // typed error instead of executed late — late work wastes
        // device time that on-time requests need.
        let now = Instant::now();
        let mut live = Vec::with_capacity(queue.len());
        for mut req in queue {
            // Cost-aware admission control marked this queued request as
            // displaced in favor of a cheaper newcomer: reply with the
            // typed shed without executing. The engine counted the shed
            // when it picked the victim, so no request/failure counts
            // here — this mirrors the engine-side refusal path.
            let displaced = req
                .admission
                .take()
                .is_some_and(|a| a.shed.load(Ordering::Relaxed));
            if displaced {
                if let (Some(lane), Some(idx)) = (&self.lane, req.lot) {
                    lane.unpark(idx);
                }
                req.reply
                    .send(Err(anyhow::Error::new(ServeError::Displaced)));
                continue;
            }
            match req.deadline {
                Some(d) if now > d => {
                    self.metrics.requests += 1;
                    self.metrics.failures += 1;
                    self.metrics.deadline_sheds += 1;
                    let late_by = now.duration_since(d);
                    self.finish(
                        req.enqueued,
                        req.deadline,
                        req.lot,
                        req.reply,
                        Err(anyhow::Error::new(ServeError::DeadlineExpired { late_by })),
                    );
                }
                _ => live.push(req),
            }
        }
        // Split requests execute alone: the owning lane scatters row
        // blocks to its peers and gathers/combines, so they never join
        // a same-key batch (their member shapes differ per block).
        let (split, live): (Vec<_>, Vec<_>) = live.into_iter().partition(|r| r.split.is_some());
        for req in split {
            self.execute_split(req);
        }
        let device = self.ctx.device.clone();
        let (mut batches, failed) =
            batch::group(live, &device, |seq, m, n| self.choose_plan(seq, m, n));
        // Requests rejected before dispatch count toward requests and
        // failures but not per_seq, which tracks *executed* traffic —
        // a never-executed request must not dilute a sequence's mean
        // latency.
        for (req, err) in failed {
            self.metrics.requests += 1;
            self.metrics.failures += 1;
            self.finish(req.enqueued, req.deadline, req.lot, req.reply, Err(err));
        }
        batch::order_edf(&mut batches);
        self.dispatch_turn(batches);
    }

    /// Dispatch a turn's EDF-ordered batches: when horizontal fusion is
    /// on and the turn drained several groups, segment the order with
    /// [`planner::plan_hfuse`] — contiguous segments only, so EDF
    /// order (and therefore SLO behavior and reply order) is exactly
    /// what back-to-back dispatch produces — and execute each winning
    /// segment as one combined launch. Everything else dispatches as
    /// before, one batch at a time.
    fn dispatch_turn(&mut self, batches: Vec<batch::Batch>) {
        if !self.hfuse || batches.len() < 2 {
            for b in batches {
                self.execute_batch(b);
            }
            return;
        }
        // Price each batch's plan first (memoized per padded key). A
        // batch whose plan is unavailable — unknown sequence, planning
        // failure — is never fused but still executes normally.
        let plans: Vec<Option<Arc<SeqPlan>>> = batches
            .iter()
            .map(|b| self.hfuse_seq_plan(&b.key))
            .collect();
        let cfg = PlannerConfig {
            beam: self.hfuse_beam,
            ..PlannerConfig::default()
        };
        // Segment maximal runs of priceable batches. plan_hfuse emits a
        // multi-member segment only when its combined forecast beats
        // back-to-back launches, so every fusion is forecast-justified.
        let mut segments: Vec<(usize, Option<planner::HfuseForecast>)> = Vec::new();
        let mut i = 0;
        while i < batches.len() {
            if plans[i].is_none() {
                segments.push((1, None));
                i += 1;
                continue;
            }
            let mut j = i;
            while j < batches.len() && plans[j].is_some() {
                j += 1;
            }
            let members: Vec<(&SeqPlan, ProblemSize)> = (i..j)
                .map(|k| {
                    let plan = plans[k].as_deref().expect("run covers Some plans only");
                    (plan, ProblemSize::new(batches[k].key.m, batches[k].key.n))
                })
                .collect();
            for g in planner::plan_hfuse(&members, &self.ctx.db, &self.ctx.dev, &cfg) {
                segments.push((g.range.len(), Some(g.forecast)));
            }
            i = j;
        }
        let mut rest = batches.into_iter();
        for (len, forecast) in segments {
            let members: Vec<batch::Batch> = rest.by_ref().take(len).collect();
            if members.len() == 1 {
                self.execute_batch(members.into_iter().next().expect("len == 1"));
            } else {
                let f = forecast.expect("multi-member segments carry a forecast");
                self.execute_hfused(members, f);
            }
        }
    }

    /// Answer a control message inline; returns true on shutdown.
    fn answer_control(&mut self, c: Control) -> bool {
        match c {
            Control::Shutdown => true,
            Control::Metrics(reply) => {
                let _ = reply.send(self.full_metrics());
                false
            }
            Control::Plan { seq, m, n, reply } => {
                let _ = reply.send(self.choose_plan(&seq, m, n));
                false
            }
            Control::Forecast { seq, m, n, reply } => {
                let _ = reply.send(self.forecast_for(&seq, m, n));
                false
            }
            Control::PlanShard {
                seq,
                m,
                n,
                range,
                db,
                reply,
            } => {
                self.metrics.shard_requests += 1;
                let res = self.eval_shard(&seq, m, n, range, &db);
                if res.is_ok() {
                    self.metrics.shard_served += 1;
                }
                let _ = reply.send(res);
                false
            }
            Control::RegisterPipeline { name, src, reply } => {
                let _ = reply.send(self.register_pipeline(&name, &src));
                false
            }
            Control::UnregisterPipeline { name, reply } => {
                let _ = reply.send(self.unregister_pipeline(&name));
                false
            }
        }
    }

    /// Drain-and-group request loop (the engine's worker body): block
    /// for the first request of a turn, keep draining until the queue is
    /// empty and the drain deadline has arrived (or the turn cap is
    /// hit), then run the turn. Returns metrics when the channel closes
    /// or a [`Msg::Shutdown`] sentinel arrives.
    ///
    /// Batch formation is EDF-ish: the drain deadline is the *earlier*
    /// of the batch window's end and the most urgent in-hand request's
    /// deadline minus [`EngineConfig::deadline_slack`] (the budget
    /// reserved for dispatch + execution), so a request inside its
    /// slack ships now instead of waiting out `batch_window`. With
    /// `batch_window == 0` (pure drain) the loop never sleeps once a
    /// request is in hand — the `now >= by` check precedes every
    /// blocking receive.
    /// One serving session over a borrowed receiver, so the engine's
    /// supervisor can wrap it in `catch_unwind` and re-enter with a
    /// rebuilt coordinator on the *same* channel after a lane panic
    /// (client handles stay valid across respawns). Returns when the
    /// channel closes or a shutdown sentinel arrives.
    pub(crate) fn serve_session(&mut self, rx: &mpsc::Receiver<Msg>, cfg: &EngineConfig) {
        self.pipeline_quota = cfg.pipeline_quota;
        self.split_gather = cfg.split_gather;
        self.hfuse = cfg.hfuse;
        self.hfuse_beam = cfg.hfuse_beam;
        let mut closing = false;
        while !closing {
            let first = match rx.recv() {
                Ok(Msg::Run(r)) => r,
                Ok(Msg::Control(c)) => {
                    if self.answer_control(c) {
                        break;
                    }
                    continue;
                }
                Err(_) => break,
            };
            let mut queue = vec![first];
            let window_end = Instant::now() + cfg.batch_window;
            while queue.len() < cfg.max_batch {
                // Earliest in-hand deadline (less the execution slack)
                // caps the wait; recomputed each iteration because
                // every drained request can tighten it.
                let by = queue
                    .iter()
                    .filter_map(|r| r.deadline)
                    .min()
                    .map_or(window_end, |d| {
                        let urgent = d.checked_sub(cfg.deadline_slack).unwrap_or(d);
                        urgent.min(window_end)
                    });
                match rx.try_recv() {
                    Ok(Msg::Run(r)) => queue.push(r),
                    Ok(Msg::Control(c)) => {
                        if self.answer_control(c) {
                            closing = true;
                            break;
                        }
                    }
                    Err(mpsc::TryRecvError::Disconnected) => break,
                    Err(mpsc::TryRecvError::Empty) => {
                        let now = Instant::now();
                        if now >= by {
                            break;
                        }
                        match rx.recv_timeout(by - now) {
                            Ok(Msg::Run(r)) => queue.push(r),
                            Ok(Msg::Control(c)) => {
                                if self.answer_control(c) {
                                    closing = true;
                                    break;
                                }
                            }
                            Err(_) => break,
                        }
                    }
                }
            }
            self.begin_turn(&mut queue);
            self.run_turn(queue);
            self.end_turn();
        }
        self.sync_runtime_metrics();
    }

    /// Supervision hooks at a turn boundary (no-ops without a lane):
    /// advance the heartbeat, park every request of the turn in the
    /// lane's reclamation lot, and trigger any fault-plan actions
    /// scheduled for this turn number — injected panics fire *after*
    /// parking, so the supervisor always finds the turn's requests.
    fn begin_turn(&mut self, queue: &mut [Request]) {
        let Some(lane) = self.lane.clone() else {
            return;
        };
        let turn = lane.turns.fetch_add(1, Ordering::Relaxed) + 1;
        lane.beat();
        for req in queue.iter_mut() {
            let spec = RetrySpec {
                seq: req.seq.clone(),
                m: req.m,
                n: req.n,
                variant: req.variant,
                enqueued: req.enqueued,
                deadline: req.deadline,
                priority: req.priority,
                attempts: req.attempts,
                pinned: req.pinned,
                // Explicit tensors are about to be consumed by the
                // execute path; only synthetic payloads replay.
                inputs: match req.inputs {
                    RequestInputs::Synth { seed } => Some(RequestInputs::Synth { seed }),
                    RequestInputs::Explicit(_) => None,
                },
            };
            let reply = req.reply.tether();
            req.lot = Some(lane.park(Parked { spec, reply }));
        }
        let actions = lane.chaos_for(turn);
        if let Some(hold) = actions.wedge {
            // Wedge: go dark mid-turn. The heartbeat was stamped at turn
            // start and now goes stale; with a wedge timeout configured
            // the detector opens the breaker, then closes it when the
            // beat advances again below.
            std::thread::sleep(hold);
            lane.beat();
        }
        self.chaos = actions.chaos;
        if actions.hard_kill {
            std::panic::panic_any(engine::chaos::HARD_KILL_MARKER);
        }
        if actions.kill {
            std::panic::panic_any(engine::chaos::KILL_MARKER);
        }
    }

    /// Close out a turn's supervision state: clear one-turn chaos,
    /// advance the heartbeat, and — if the lane was half-open — count
    /// the served turn as the breaker's successful probe and close it.
    fn end_turn(&mut self) {
        self.chaos = None;
        let Some(lane) = &self.lane else {
            return;
        };
        lane.beat();
        lane.fleet.close_if_half_open(lane.index);
    }

    /// Execute + verify one sequence against the Rust reference oracle;
    /// returns (result, max abs error).
    pub fn run_checked(
        &mut self,
        seq: &str,
        variant: PlanChoice,
        m: usize,
        n: usize,
        inputs: &BTreeMap<String, Tensor>,
    ) -> Result<(RunResult, f32)> {
        let result = self
            .runtime
            .run_seq(seq, variant.as_str(), m, n, inputs)?;
        let err = refcheck::max_abs_error(seq, inputs, &result.env);
        Ok((result, err))
    }
}

/// Generate deterministic random inputs for a sequence at a size
/// (matching the free inputs its artifacts declare).
pub fn synth_inputs(
    runtime: &Runtime,
    seq: &str,
    variant: &str,
    m: usize,
    n: usize,
    seed: u64,
) -> BTreeMap<String, Tensor> {
    use crate::util::Prng;
    let stages = runtime.manifest.stages(seq, variant, m, n);
    if stages.is_empty() {
        // Dynamically registered pipelines have no manifest entries;
        // their free inputs come from the compiled program instead.
        if let Some(p) = runtime.pipeline(seq) {
            return p.synth_inputs(m, n, seed).unwrap_or_default();
        }
    }
    let mut produced: Vec<String> = vec![];
    let mut inputs = BTreeMap::new();
    let mut rng = Prng::new(seed);
    for e in stages {
        for spec in &e.inputs {
            if !produced.contains(&spec.name) && !inputs.contains_key(&spec.name) {
                let len: usize = spec.dims.iter().product::<usize>().max(1);
                inputs.insert(
                    spec.name.clone(),
                    Tensor::new(spec.dims.clone(), rng.f32_vec(len)),
                );
            }
        }
        for spec in &e.outputs {
            produced.push(spec.name.clone());
        }
    }
    inputs
}

/// Shared fixture for the in-crate serve-path tests: a temp catalog
/// whose manifest parses and (optionally) whose HLO files exist, so
/// planning and scheduling run end-to-end and only the offline stub
/// backend stops execution.
#[cfg(test)]
pub(crate) mod testutil {
    use std::path::PathBuf;

    /// Write a stub catalog with one fused stage-0 artifact per `seq`
    /// at m=32, n=65536. With `hlo_files`, each entry gets a minimal
    /// parseable HLO text so execution reaches the stub `compile` (and
    /// fails there) instead of failing at file load.
    pub(crate) fn stub_catalog(tag: &str, seqs: &[&str], hlo_files: bool) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fusebla_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut manifest = String::new();
        for seq in seqs {
            manifest.push_str(&format!(
                "artifact {seq}.fused.m32n65536.s0\n file {seq}.hlo.txt\n seq {seq}\n variant fused\n stage 0\n in x:f32[65536]\n in y:f32[65536]\n out w:f32[65536]\n m 32\n n 65536\nend\n"
            ));
            if hlo_files {
                std::fs::write(dir.join(format!("{seq}.hlo.txt")), format!("HloModule {seq}\n"))
                    .unwrap();
            }
        }
        std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
        dir
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::stub_catalog;
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.txt").exists().then_some(dir)
    }

    #[test]
    fn coordinator_runs_checked_bicgk() {
        let Some(dir) = artifacts_dir() else { return };
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let inputs = synth_inputs(coord.runtime(), "bicgk", "fused", 256, 256, 7);
        let (res, err) = coord
            .run_checked("bicgk", PlanChoice::Fused, 256, 256, &inputs)
            .unwrap();
        assert_eq!(res.stages.len(), 1);
        assert!(err < 1e-3, "max abs error {err}");
    }

    #[test]
    fn plan_cache_decides_once() {
        let Some(dir) = artifacts_dir() else { return };
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let a = coord.choose_plan("bicgk", 256, 256).unwrap();
        let b = coord.choose_plan("bicgk", 256, 256).unwrap();
        assert_eq!(a, b);
        assert_eq!(a, PlanChoice::Fused);
        assert_eq!(coord.metrics.plan_cache_misses, 1);
        assert_eq!(coord.metrics.plan_cache_hits, 1);
    }

    fn key(seq: &str, m: usize, n: usize) -> PlanKey {
        PlanKey {
            seq: seq.to_string(),
            m,
            n,
            device: "GeForce GTX 480 (model)".into(),
        }
    }

    #[test]
    fn plan_cache_counts_hits_and_misses() {
        let mut cache = PlanCache::new(4);
        let k = key("bicgk", 256, 256);
        assert_eq!(cache.get(&k), None);
        cache.insert(k.clone(), PlanChoice::Fused);
        assert_eq!(cache.get(&k), Some(PlanChoice::Fused));
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn plan_cache_isolates_problem_sizes_and_devices() {
        let mut cache = PlanCache::new(4);
        cache.insert(key("bicgk", 256, 256), PlanChoice::Fused);
        // same sequence, other size → miss
        assert_eq!(cache.get(&key("bicgk", 512, 512)), None);
        // same sequence and size, other device → miss
        let mut other_dev = key("bicgk", 256, 256);
        other_dev.device = "some other GPU".into();
        assert_eq!(cache.get(&other_dev), None);
        // exact key → hit
        assert_eq!(cache.get(&key("bicgk", 256, 256)), Some(PlanChoice::Fused));
        assert_eq!(cache.misses, 2);
        assert_eq!(cache.hits, 1);
    }

    #[test]
    fn plan_cache_evicts_least_recently_used() {
        let mut cache = PlanCache::new(2);
        let (a, b, c) = (key("a", 32, 32), key("b", 32, 32), key("c", 32, 32));
        cache.insert(a.clone(), PlanChoice::Fused);
        cache.insert(b.clone(), PlanChoice::Cublas);
        // touch `a` so `b` becomes least-recent
        assert_eq!(cache.get(&a), Some(PlanChoice::Fused));
        cache.insert(c.clone(), PlanChoice::Fused);
        assert_eq!(cache.evictions, 1);
        assert!(cache.contains(&a), "recently-used entry must survive");
        assert!(!cache.contains(&b), "least-recent entry must be evicted");
        assert!(cache.contains(&c));
        // eviction order is observable: least recent first
        let order: Vec<&PlanKey> = cache.keys().collect();
        assert_eq!(order, vec![&a, &c]);
    }

    #[test]
    fn plan_cache_reinsert_refreshes_instead_of_duplicating() {
        let mut cache = PlanCache::new(2);
        let k = key("a", 32, 32);
        cache.insert(k.clone(), PlanChoice::Fused);
        cache.insert(k.clone(), PlanChoice::Cublas);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.get(&k), Some(PlanChoice::Cublas));
        assert_eq!(cache.evictions, 0);
    }

    /// The serve-path acceptance check: a repeated request for the same
    /// `(seq, m, n)` must hit the plan cache across scheduling turns.
    /// Uses a stub manifest (no real artifacts needed — planning happens
    /// before execution, and the failed execution is itself tracked by
    /// the failure counter).
    #[test]
    fn turns_hit_plan_cache_on_repeat() {
        let dir = stub_catalog("plancache", &["waxpby"], false);
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let request = |m: usize, n: usize| {
            let (rtx, _rrx) = mpsc::channel();
            Request {
                seq: "waxpby".into(),
                m,
                n,
                inputs: RequestInputs::Synth { seed: 7 },
                variant: None, // let the plan cache decide
                enqueued: Instant::now(),
                deadline: None,
                priority: 0,
                attempts: 0,
                pinned: false,
                lot: None,
                split: None,
                split_block: false,
                admission: None,
                reply: Reply::new(rtx, None),
            }
        };
        coord.run_turn(vec![request(32, 65536)]); // cold: plans
        coord.run_turn(vec![request(32, 65536)]); // warm: cache hit
        assert_eq!(coord.metrics.plan_cache_misses, 1);
        assert_eq!(coord.metrics.plan_cache_hits, 1);
        assert_eq!(coord.metrics.requests, 2);
        assert_eq!(coord.metrics.batches, 2);
        // a different problem size must re-plan, never reuse the entry
        coord.run_turn(vec![request(32, 1024)]);
        assert_eq!(coord.metrics.plan_cache_misses, 2);
        assert_eq!(coord.metrics.plan_cache_hits, 1);
        // every dispatched request leaves one queued-duration sample
        assert_eq!(coord.metrics.queued.count(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The control-plane forecast runs the planner once, memoizes per
    /// padded key, and seeds the plan cache — so the first execute-path
    /// decision for the key is a cache hit, not a re-plan.
    #[test]
    fn forecast_seeds_the_plan_cache_and_memoizes() {
        let dir = stub_catalog("forecastseed", &["waxpby"], false);
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let f1 = coord.forecast_for("waxpby", 32, 65536).unwrap();
        assert_eq!(coord.metrics.planner_on_worker, 1);
        assert_eq!(coord.metrics.plan_cache_misses, 1, "seeding records the one miss");
        // a padded-identical repeat is a memo hit: no second planner run
        let f2 = coord.forecast_for("waxpby", 32, 65530).unwrap();
        assert_eq!(coord.metrics.planner_on_worker, 1);
        assert_eq!(f1.planned.to_bits(), f2.planned.to_bits());
        assert_eq!(f1.baseline.to_bits(), f2.baseline.to_bits());
        // the execute-path decision now hits the seeded entry
        let choice = coord.choose_plan("waxpby", 32, 65536).unwrap();
        let expect = if f1.baseline < f1.planned {
            PlanChoice::Cublas
        } else {
            PlanChoice::Fused
        };
        assert_eq!(choice, expect);
        assert_eq!(coord.metrics.plan_cache_misses, 1, "no re-plan after seeding");
        assert!(coord.metrics.plan_cache_hits >= 1);
        assert!(coord.forecast_for("ghost", 32, 32).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Raw sizes that tile-pad to the same shape share one plan entry:
    /// the pad-once fix means the second request is a cache hit, not a
    /// re-plan of an unpadded key.
    #[test]
    fn choose_plan_pads_key_once() {
        let dir = stub_catalog("padonce", &["waxpby"], false);
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let a = coord.choose_plan("waxpby", 32, 65530).unwrap();
        let b = coord.choose_plan("waxpby", 32, 65536).unwrap();
        assert_eq!(a, b);
        assert_eq!(coord.metrics.plan_cache_misses, 1);
        assert_eq!(coord.metrics.plan_cache_hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_loop_processes_requests() {
        let Some(dir) = artifacts_dir() else { return };
        let (tx, rx) = mpsc::channel();
        // The PJRT client is !Send: the coordinator lives entirely on the
        // worker thread; producers send Synth inputs.
        let handle = std::thread::spawn(move || {
            let ctx = Arc::new(Context::new());
            let mut coord = Coordinator::new(ctx, &dir).unwrap();
            coord.serve_session(&rx, &EngineConfig::default());
            coord.full_metrics()
        });
        let mut replies = vec![];
        for i in 0..3 {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Msg::Run(Request {
                seq: "waxpby".into(),
                m: 32,
                n: 65536,
                inputs: RequestInputs::Synth { seed: i },
                variant: Some(PlanChoice::Fused),
                enqueued: Instant::now(),
                deadline: None,
                priority: 0,
                attempts: 0,
                pinned: false,
                lot: None,
                split: None,
                split_block: false,
                admission: None,
                reply: Reply::new(rtx, None),
            }))
            .unwrap();
            replies.push(rrx);
        }
        drop(tx);
        for r in replies {
            assert!(r.recv().unwrap().is_ok());
        }
        let metrics = handle.join().unwrap();
        assert_eq!(metrics.requests, 3);
        assert_eq!(metrics.failures, 0);
        // all three share one key — the scheduler must have grouped at
        // least some of them (the queue was full before serving began)
        assert!(metrics.batches <= 3);
        assert_eq!(metrics.batch_size_sum, 3);
    }

    #[test]
    fn metrics_track_failures() {
        let dir = stub_catalog("failures", &["waxpby"], false);
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            seq: "waxpby".into(),
            m: 7, // no such size in the catalog
            n: 7,
            inputs: RequestInputs::Explicit(BTreeMap::new()),
            variant: Some(PlanChoice::Fused),
            enqueued: Instant::now(),
            deadline: None,
            priority: 0,
            attempts: 0,
            pinned: false,
            lot: None,
            split: None,
            split_block: false,
            admission: None,
            reply: Reply::new(rtx, None),
        };
        coord.run_turn(vec![req]);
        let reply = rrx.recv().unwrap();
        let err = reply.err().expect("must fail").to_string();
        assert!(err.contains("no artifacts"), "{err}");
        assert_eq!(coord.metrics.failures, 1);
        assert_eq!(coord.metrics.requests, 1);
        // an execution failure is not a shed and not typed
        assert_eq!(coord.metrics.deadline_sheds, 0);
        // every terminal outcome leaves one latency sample
        assert_eq!(coord.metrics.latency.count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// An over-deadline request is shed with a typed error before any
    /// plan resolution or execution — no batch runs, the shed counter
    /// moves, and the client can downcast the reason.
    #[test]
    fn expired_deadline_sheds_instead_of_executing() {
        let dir = stub_catalog("dlshed", &["waxpby"], false);
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let (rtx, rrx) = mpsc::channel();
        let enqueued = Instant::now() - Duration::from_millis(50);
        let req = Request {
            seq: "waxpby".into(),
            m: 32,
            n: 65536,
            inputs: RequestInputs::Synth { seed: 7 },
            variant: Some(PlanChoice::Fused),
            enqueued,
            deadline: Some(enqueued + Duration::from_millis(1)), // long past
            priority: 0,
            attempts: 0,
            pinned: false,
            lot: None,
            split: None,
            split_block: false,
            admission: None,
            reply: Reply::new(rtx, None),
        };
        coord.run_turn(vec![req]);
        let err = rrx.recv().unwrap().err().expect("shed request must error");
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::DeadlineExpired { late_by }) => {
                assert!(*late_by >= Duration::from_millis(40), "late_by {late_by:?}");
            }
            other => panic!("expected DeadlineExpired, got {other:?} ({err:#})"),
        }
        assert_eq!(coord.metrics.deadline_sheds, 1);
        assert_eq!(coord.metrics.failures, 1);
        assert_eq!(coord.metrics.requests, 1);
        assert_eq!(coord.metrics.batches, 0, "shed requests never execute");
        assert_eq!(coord.metrics.slo_misses, 1, "a shed is an SLO miss");
        assert_eq!(coord.metrics.deadline_requests, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Registration end to end on one worker: typed rejections
    /// (invalid script, duplicate name, built-in collision, quota),
    /// idempotent dedup of identical source, and metrics accounting.
    #[test]
    fn register_pipeline_typed_rejections_and_dedup() {
        let dir = stub_catalog("pipereg", &["waxpby"], false);
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        coord.pipeline_quota = 1;
        // invalid script → typed InvalidScript carrying the frontend's line
        let err = coord
            .register_pipeline("bad", "vector<N> x;\ninput x;\ny = nosuch(x);\nreturn y;")
            .unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::InvalidScript { line: 3, msg }) => {
                assert!(msg.contains("unknown library function"), "{msg}");
            }
            other => panic!("expected InvalidScript at line 3, got {other:?} ({err:#})"),
        }
        let fp = coord
            .register_pipeline("amx", pipelines::examples::ADD_MUL_EXP)
            .unwrap();
        // identical source re-registration: dedup hit, same fingerprint
        assert_eq!(
            coord
                .register_pipeline("amx", pipelines::examples::ADD_MUL_EXP)
                .unwrap(),
            fp
        );
        // same name, different source → typed duplicate
        let err = coord
            .register_pipeline("amx", pipelines::examples::QUANTIZE_INT8)
            .unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::DuplicatePipeline { .. })
        ));
        // a built-in name is never shadowable
        let err = coord
            .register_pipeline("waxpby", pipelines::examples::ADD_MUL_EXP)
            .unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::DuplicatePipeline { .. })
        ));
        // quota counts registered pipelines, not attempts
        let err = coord
            .register_pipeline("q8", pipelines::examples::QUANTIZE_INT8)
            .unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::PipelineQuota { count: 1, quota: 1 })
        ));
        assert_eq!(coord.metrics.pipeline_registrations, 2);
        assert_eq!(coord.metrics.pipeline_rejections, 4);
        assert!(coord.metrics.pipeline_compile_seconds > 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A registered pipeline serves scheduling turns exactly like a
    /// built-in: the plan cache decides once, repeats hit both the plan
    /// cache and the runtime's resolve cache, and — because pipeline
    /// stages run on the interpreter — execution succeeds even on the
    /// offline stub backend. Unregistration purges every derived cache
    /// entry, so the name stops resolving.
    #[test]
    fn registered_pipeline_serves_turns_like_a_builtin() {
        let dir = stub_catalog("pipeserve", &["waxpby"], false);
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        coord
            .register_pipeline("amx", pipelines::examples::ADD_MUL_EXP)
            .unwrap();
        let request = |seed: u64| {
            let (rtx, rrx) = mpsc::channel();
            let r = Request {
                seq: "amx".into(),
                m: 32,
                n: 256,
                inputs: RequestInputs::Synth { seed },
                variant: None, // let the plan cache decide
                enqueued: Instant::now(),
                deadline: None,
                priority: 0,
                attempts: 0,
                pinned: false,
                lot: None,
                split: None,
                split_block: false,
                admission: None,
                reply: Reply::new(rtx, None),
            };
            (r, rrx)
        };
        let (r1, rx1) = request(7);
        coord.run_turn(vec![r1]); // cold: plans + resolves
        let (r2, rx2) = request(8);
        coord.run_turn(vec![r2]); // warm: plan-cache + resolve-cache hit
        assert!(rx1.recv().unwrap().is_ok(), "interp execution must succeed");
        let res = rx2.recv().unwrap().unwrap();
        assert!(res.env.contains_key("z"), "pipeline output must be returned");
        assert_eq!(coord.metrics.failures, 0);
        assert_eq!(coord.metrics.plan_cache_misses, 1);
        assert_eq!(coord.metrics.plan_cache_hits, 1);
        assert_eq!(coord.metrics.resolve_misses, 1);
        assert!(coord.metrics.resolve_hits >= 1, "warm turn must reuse the resolved plan");
        // forecasting works off the registered planning entry
        assert!(coord.forecast_for("amx", 32, 256).is_ok());
        // unregister purges plan + forecast + planning caches
        assert!(coord.unregister_pipeline("amx"));
        assert!(!coord.unregister_pipeline("amx"), "second unregister is a no-op");
        assert!(coord.choose_plan("amx", 32, 256).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A mixed turn (deadline + no-deadline requests) executes both
    /// batches and accounts SLO metrics only for the deadline-carrying
    /// request. (Batch *ordering* itself is unit-tested in `batch`.)
    #[test]
    fn turn_accounts_slo_only_for_deadline_requests() {
        let dir = stub_catalog("sloacct", &["waxpby", "vadd"], false);
        let ctx = Arc::new(Context::new());
        let mut coord = Coordinator::new(ctx, &dir).unwrap();
        let now = Instant::now();
        let req = |seq: &str, deadline: Option<Duration>| {
            let (rtx, rrx) = mpsc::channel();
            let r = Request {
                seq: seq.into(),
                m: 32,
                n: 65536,
                inputs: RequestInputs::Synth { seed: 7 },
                variant: Some(PlanChoice::Fused),
                enqueued: now,
                deadline: deadline.map(|d| now + d),
                priority: 0,
                attempts: 0,
                pinned: false,
                lot: None,
                split: None,
                split_block: false,
                admission: None,
                reply: Reply::new(rtx, None),
            };
            (r, rrx)
        };
        let (r1, rx1) = req("waxpby", None);
        let (r2, rx2) = req("vadd", Some(Duration::from_secs(60)));
        coord.run_turn(vec![r1, r2]);
        let e1 = rx1.recv().unwrap();
        let e2 = rx2.recv().unwrap();
        assert!(e1.is_err() && e2.is_err(), "stub backend cannot execute");
        assert_eq!(coord.metrics.batches, 2);
        assert_eq!(coord.metrics.latency.count(), 2);
        // only the deadline-carrying request is SLO-accounted, and a
        // generous deadline is not a miss
        assert_eq!(coord.metrics.deadline_requests, 1);
        assert_eq!(coord.metrics.slo_misses, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Explicit-input request for a registered pipeline at m=32,
    /// returning the ticket receiver alongside.
    fn pipeline_request(
        seq: &str,
        n: usize,
        inputs: BTreeMap<String, Tensor>,
        deadline: Option<Duration>,
    ) -> (Request, mpsc::Receiver<Result<RunResult>>) {
        let (rtx, rrx) = mpsc::channel();
        let now = Instant::now();
        let r = Request {
            seq: seq.into(),
            m: 32,
            n,
            inputs: RequestInputs::Explicit(inputs),
            variant: None,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            priority: 0,
            attempts: 0,
            pinned: false,
            lot: None,
            split: None,
            split_block: false,
            admission: None,
            reply: Reply::new(rtx, None),
        };
        (r, rrx)
    }

    /// Coordinator over a stub catalog with both exemplar pipelines
    /// registered: the interpreter-backed resolved plans execute for
    /// real, so fused and plain dispatch paths produce actual bits.
    fn pipeline_coordinator(dir: &Path) -> Coordinator {
        let mut c = Coordinator::new(Arc::new(Context::new()), dir).unwrap();
        c.register_pipeline("amx", pipelines::examples::ADD_MUL_EXP).unwrap();
        c.register_pipeline("q8", pipelines::examples::QUANTIZE_INT8).unwrap();
        c
    }

    /// The tentpole acceptance property: a drained turn executed with
    /// horizontal fusion on is bit-identical — per request, per output
    /// tensor — to the same turn executed batch-by-batch with fusion
    /// off, and to the offline reference interpretation. Turn members
    /// are randomized over sequences, sizes and therefore batch keys
    /// and plans; a final deterministic launch-bound pair checks that
    /// fusion actually fires and reports its launch savings.
    #[test]
    fn hfused_turns_are_bit_identical_to_back_to_back() {
        let dir = stub_catalog("hfuseprop", &["waxpby"], false);
        let mut fused = pipeline_coordinator(&dir);
        let mut plain = pipeline_coordinator(&dir);
        plain.hfuse = false;
        // independent offline compile — shares nothing with the coordinators
        let ctx = Context::new();
        let amx = pipelines::compile("amx", pipelines::examples::ADD_MUL_EXP, &ctx.lib).unwrap();
        let q8 = pipelines::compile("q8", pipelines::examples::QUANTIZE_INT8, &ctx.lib).unwrap();
        crate::util::proptest::check("hfused turn matches back-to-back bitwise", 8, |g| {
            let mut turn_fused = Vec::new();
            let mut turn_plain = Vec::new();
            let mut expected = Vec::new();
            for _ in 0..g.usize(2, 5) {
                let (name, c) = if g.bool() { ("amx", &amx) } else { ("q8", &q8) };
                let n = *g.choose(&[256usize, 1024, 65536]);
                let seed = g.usize(0, 1 << 16) as u64;
                let inputs = c.pipeline.synth_inputs(32, n, seed).unwrap();
                let (rf, rxf) = pipeline_request(name, n, inputs.clone(), None);
                let (rp, rxp) = pipeline_request(name, n, inputs.clone(), None);
                turn_fused.push(rf);
                turn_plain.push(rp);
                expected.push((name, c, n, seed, inputs, rxf, rxp));
            }
            fused.run_turn(turn_fused);
            plain.run_turn(turn_plain);
            for (name, c, n, seed, inputs, rxf, rxp) in expected {
                let f = rxf.try_recv().expect("fused turn replied").expect("executes");
                let p = rxp.try_recv().expect("plain turn replied").expect("executes");
                assert_eq!(f.variant, p.variant, "{name} n={n}: same plan either way");
                let offline = c.pipeline.run_offline(&f.variant, 32, n, &inputs).unwrap();
                for &v in &c.pipeline.program.outputs {
                    let out = &c.pipeline.program.var(v).name;
                    assert_eq!(
                        f.env.get(out),
                        p.env.get(out),
                        "{name} n={n} seed={seed}: fused '{out}' must match back-to-back bits"
                    );
                    assert_eq!(
                        f.env.get(out),
                        offline.get(out),
                        "{name} n={n} seed={seed}: fused '{out}' must match offline bits"
                    );
                }
            }
        });
        assert_eq!(fused.metrics.failures, 0);
        assert_eq!(plain.metrics.failures, 0);
        assert_eq!(fused.metrics.requests, plain.metrics.requests);
        assert_eq!(plain.metrics.hfused_batches, 0, "knob off must never fuse");
        // Deterministic crossover: two launch-bound batches of the same
        // pipeline at different sizes (distinct batch keys, matching
        // kernel geometry → interference floor) must share one combined
        // dispatch and bank the elided launches.
        let before = fused.metrics.hfused_batches;
        let a = amx.pipeline.synth_inputs(32, 256, 1).unwrap();
        let b = amx.pipeline.synth_inputs(32, 1024, 2).unwrap();
        let (ra, rxa) = pipeline_request("amx", 256, a, None);
        let (rb, rxb) = pipeline_request("amx", 1024, b, None);
        fused.run_turn(vec![ra, rb]);
        assert!(rxa.try_recv().unwrap().is_ok());
        assert!(rxb.try_recv().unwrap().is_ok());
        assert_eq!(
            fused.metrics.hfused_batches,
            before + 2,
            "launch-bound pair must fuse into one combined dispatch"
        );
        assert!(
            fused.metrics.hfuse_launch_savings > 0,
            "a fused dispatch elides at least one launch"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Fusing never reorders an urgent batch behind loose ones: the
    /// segmentation is contiguous over the EDF order (flattened
    /// segments ARE the EDF order — unit-asserted in the planner), so
    /// the urgent request's SLO accounting is identical with fusion on
    /// and off, and every reply still arrives.
    #[test]
    fn hfuse_keeps_edf_order_and_slo_accounting() {
        let dir = stub_catalog("hfuseslo", &["waxpby"], false);
        let ctx = Context::new();
        let amx = pipelines::compile("amx", pipelines::examples::ADD_MUL_EXP, &ctx.lib).unwrap();
        let q8 = pipelines::compile("q8", pipelines::examples::QUANTIZE_INT8, &ctx.lib).unwrap();
        let turn = |coord: &mut Coordinator| {
            // Submitted loose-first: EDF ordering must hoist the urgent
            // batch to the front, fused or not.
            let (r1, rx1) = pipeline_request(
                "q8",
                1024,
                q8.pipeline.synth_inputs(32, 1024, 3).unwrap(),
                Some(Duration::from_secs(3600)),
            );
            let (r2, rx2) = pipeline_request(
                "amx",
                65536,
                amx.pipeline.synth_inputs(32, 65536, 4).unwrap(),
                None,
            );
            let (r3, rx3) = pipeline_request(
                "amx",
                256,
                amx.pipeline.synth_inputs(32, 256, 5).unwrap(),
                Some(Duration::from_secs(30)),
            );
            coord.run_turn(vec![r1, r2, r3]);
            for rx in [rx1, rx2, rx3] {
                assert!(rx.try_recv().expect("turn replied").is_ok());
            }
        };
        let mut fused = pipeline_coordinator(&dir);
        let mut plain = pipeline_coordinator(&dir);
        plain.hfuse = false;
        turn(&mut fused);
        turn(&mut plain);
        for m in [&fused.metrics, &plain.metrics] {
            assert_eq!(m.requests, 3);
            assert_eq!(m.failures, 0);
            assert_eq!(m.batches, 3, "every source batch is accounted");
            assert_eq!(m.deadline_requests, 2, "both deadline carriers accounted");
            assert_eq!(m.slo_misses, 0, "generous deadlines met fused or not");
            assert_eq!(m.latency.count(), 3);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
