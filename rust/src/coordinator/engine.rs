//! The serving facade: [`Engine`] owns the worker thread and shutdown,
//! [`Client`] is the cloneable submission handle, [`SubmitRequest`] is
//! the typed request builder, and [`Ticket`] is the reply future.
//!
//! ```text
//! let engine = Engine::start(Arc::new(Context::new()), Path::new("artifacts"))?;
//! let client = engine.client();                 // Clone + Send
//! let ticket = client.submit(
//!     SubmitRequest::new("bicgk", 256, 256).synth(42),
//! )?;
//! let result = ticket.wait()?;                  // RunResult
//! let metrics = engine.shutdown();              // drain + join
//! ```
//!
//! The PJRT runtime is `!Send`, so the engine builds the
//! [`Coordinator`] *on* the worker thread and reports readiness (or the
//! load error) back before `start` returns. Requests flow over a
//! private channel; the worker runs the drain-and-group scheduler
//! (`Coordinator::serve_batched`) so concurrent submissions sharing a
//! `(seq, padded size, device, plan)` key execute as one batch.

use super::{Context, Control, Coordinator, Metrics, Msg, PlanChoice, Request, RequestInputs};
use crate::runtime::{RunResult, Tensor};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Scheduler knobs of one engine.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// How long a scheduling turn keeps collecting requests after the
    /// first one arrives. Zero means pure drain: whatever is already
    /// queued groups, nothing waits.
    pub batch_window: Duration,
    /// Cap on requests drained per scheduling turn.
    pub max_batch: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            batch_window: Duration::ZERO,
            max_batch: 256,
        }
    }
}

/// Builder for one execution request. Defaults: deterministic synthetic
/// inputs (seed 0) and the coordinator's plan cache deciding the
/// variant.
pub struct SubmitRequest {
    seq: String,
    m: usize,
    n: usize,
    inputs: RequestInputs,
    variant: Option<PlanChoice>,
}

impl SubmitRequest {
    pub fn new(seq: impl Into<String>, m: usize, n: usize) -> SubmitRequest {
        SubmitRequest {
            seq: seq.into(),
            m,
            n,
            inputs: RequestInputs::Synth { seed: 0 },
            variant: None,
        }
    }

    /// Use deterministic synthetic inputs from `seed` (generated on the
    /// worker — producers never touch the thread-bound runtime).
    pub fn synth(mut self, seed: u64) -> SubmitRequest {
        self.inputs = RequestInputs::Synth { seed };
        self
    }

    /// Use explicit named input tensors.
    pub fn inputs(mut self, inputs: BTreeMap<String, Tensor>) -> SubmitRequest {
        self.inputs = RequestInputs::Explicit(inputs);
        self
    }

    /// Force a plan variant instead of letting the plan cache decide.
    pub fn variant(mut self, v: PlanChoice) -> SubmitRequest {
        self.variant = Some(v);
        self
    }
}

/// Reply handle for one submitted request.
pub struct Ticket<T> {
    rx: mpsc::Receiver<Result<T>>,
}

impl<T> Ticket<T> {
    /// Block until the result arrives. If the engine shuts down with the
    /// request still in flight, this returns an error instead of
    /// hanging.
    pub fn wait(self) -> Result<T> {
        match self.rx.recv() {
            Ok(res) => res,
            Err(_) => Err(anyhow!("engine dropped the request (shut down mid-flight)")),
        }
    }

    /// Non-blocking poll: `None` while the request is still pending.
    pub fn try_wait(&self) -> Option<Result<T>> {
        match self.rx.try_recv() {
            Ok(res) => Some(res),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow!("engine dropped the request (shut down mid-flight)")))
            }
        }
    }
}

/// Cloneable, `Send` submission handle to a running [`Engine`].
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Msg>,
}

impl Client {
    /// Enqueue a request; the returned [`Ticket`] resolves to the run
    /// result. Fails only when the engine is already shut down.
    pub fn submit(&self, req: SubmitRequest) -> Result<Ticket<RunResult>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Run(Request {
                seq: req.seq,
                m: req.m,
                n: req.n,
                inputs: req.inputs,
                variant: req.variant,
                reply,
            }))
            .map_err(|_| anyhow!("engine is shut down"))?;
        Ok(Ticket { rx })
    }

    /// Resolve (and cache) the plan for a `(seq, m, n)` key without
    /// executing anything — the planner runs on the worker exactly as
    /// it would for an unforced submission. Blocks until the worker
    /// picks the query up.
    pub fn plan(&self, seq: &str, m: usize, n: usize) -> Result<PlanChoice> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Control(Control::Plan {
                seq: seq.to_string(),
                m,
                n,
                reply,
            }))
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv()
            .unwrap_or_else(|_| Err(anyhow!("engine dropped the request (shut down mid-flight)")))
    }
}

/// Owns the serving worker: coordinator construction, the request
/// channel, and shutdown. Dropping the engine without calling
/// [`Engine::shutdown`] still stops and joins the worker.
pub struct Engine {
    tx: Option<mpsc::Sender<Msg>>,
    worker: Option<JoinHandle<Metrics>>,
}

impl Engine {
    /// Start an engine with the default scheduler configuration.
    ///
    /// The context decides its own calibration-cache location; when
    /// serving a non-default catalog directory, build it with
    /// `Context::with_calibration_cache(artifacts_dir)` so the cache
    /// lives next to the artifacts it belongs to.
    pub fn start(ctx: Arc<Context>, artifacts_dir: &Path) -> Result<Engine> {
        Self::with_config(ctx, artifacts_dir, EngineConfig::default())
    }

    /// Start an engine: spawn the worker, build the coordinator there
    /// (the PJRT client is `!Send`), and wait for it to come up so load
    /// errors surface here instead of on the first submit.
    pub fn with_config(
        ctx: Arc<Context>,
        artifacts_dir: &Path,
        cfg: EngineConfig,
    ) -> Result<Engine> {
        let (tx, rx) = mpsc::channel();
        let (ready_tx, ready_rx) = mpsc::channel();
        let dir = artifacts_dir.to_path_buf();
        let worker = std::thread::spawn(move || {
            let coord = match Coordinator::new(ctx, &dir) {
                Ok(c) => {
                    let _ = ready_tx.send(Ok(()));
                    c
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return Metrics::default();
                }
            };
            coord.serve_batched(rx, &cfg)
        });
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(Engine {
                tx: Some(tx),
                worker: Some(worker),
            }),
            Ok(Err(e)) => {
                let _ = worker.join();
                Err(e)
            }
            Err(_) => {
                let _ = worker.join();
                Err(anyhow!("engine worker died during startup"))
            }
        }
    }

    /// A new submission handle (cheap; clone freely across threads).
    pub fn client(&self) -> Client {
        Client {
            tx: self.tx.as_ref().expect("engine is running").clone(),
        }
    }

    /// Point-in-time metrics snapshot without shutting down. Blocks
    /// until the worker reaches the query in its queue (it answers
    /// between scheduling turns).
    pub fn metrics(&self) -> Metrics {
        let (reply, rx) = mpsc::channel();
        let sent = self
            .tx
            .as_ref()
            .is_some_and(|tx| tx.send(Msg::Control(Control::Metrics(reply))).is_ok());
        if !sent {
            return Metrics::default();
        }
        rx.recv().unwrap_or_default()
    }

    /// Stop the worker after it finishes everything submitted before
    /// this call, and return the final metrics. A shutdown sentinel (not
    /// channel disconnection) stops the loop, so outstanding [`Client`]
    /// clones cannot keep the engine alive; their later submissions
    /// fail, and tickets for requests enqueued after the sentinel
    /// resolve to an error instead of hanging.
    pub fn shutdown(mut self) -> Metrics {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Control(Control::Shutdown));
        }
        match self.worker.take() {
            Some(w) => w.join().expect("engine worker panicked"),
            None => Metrics::default(),
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Control(Control::Shutdown));
        }
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::stub_catalog;
    use super::*;

    /// Stub catalog with parseable HLO stubs: planning and scheduling
    /// work end-to-end; only the final PJRT `compile` fails on the
    /// offline stub backend — which is exactly what lets these tests
    /// run without built artifacts.
    fn stub_dir(tag: &str) -> std::path::PathBuf {
        stub_catalog(&format!("engine_{tag}"), &["waxpby", "vadd"], true)
    }

    #[test]
    fn engine_start_fails_cleanly_without_manifest() {
        let dir = std::env::temp_dir().join(format!("fusebla_engine_none_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let err = Engine::start(Arc::new(Context::new()), &dir).err().expect("must fail");
        assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let dir = stub_dir("shutdown");
        let engine = Engine::start(Arc::new(Context::new()), &dir).unwrap();
        let client = engine.client();
        let _ = engine.shutdown();
        assert!(client.submit(SubmitRequest::new("waxpby", 32, 65536)).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn engine_groups_a_burst_and_plans_once_per_key() {
        let dir = stub_dir("burst");
        let cfg = EngineConfig {
            batch_window: Duration::from_millis(300),
            max_batch: 64,
        };
        let engine = Engine::with_config(Arc::new(Context::new()), &dir, cfg).unwrap();
        let client = engine.client();
        // 6 waxpby + 3 vadd, interleaved, all planner-resolved
        let mut tickets = Vec::new();
        for i in 0..9u64 {
            let seq = if i % 3 == 2 { "vadd" } else { "waxpby" };
            tickets.push(client.submit(SubmitRequest::new(seq, 32, 65536).synth(i)).unwrap());
        }
        // results are stub-backend errors; delivery is what matters here
        for t in tickets {
            assert!(t.wait().is_err());
        }
        // live snapshot before shutdown sees the same totals
        let live = engine.metrics();
        assert_eq!(live.requests, 9);
        let m = engine.shutdown();
        assert_eq!(m.requests, 9);
        assert_eq!(m.batch_size_sum, 9);
        assert_eq!(m.failures, 9, "stub backend fails every execution");
        // two distinct batch keys → exactly two plan-cache misses, ever
        assert_eq!(m.plan_cache_misses, 2);
        // stub backend: every batch's resolve fails at compile; failed
        // resolves are never cached and never pin an executable
        assert_eq!(m.resolve_misses, m.batches);
        assert_eq!(m.resolve_hits, 0);
        assert_eq!(m.executable_compiles, 0);
        assert!(m.batches >= 2, "at least one batch per distinct key");
        assert!(
            m.batches < 9,
            "a same-key burst must group: {} batches for 9 requests",
            m.batches
        );
        assert!(m.max_batch_size >= 2);
        assert!(m.batched_requests >= 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plan_query_resolves_without_executing() {
        let dir = stub_dir("plan");
        let engine = Engine::start(Arc::new(Context::new()), &dir).unwrap();
        let client = engine.client();
        let choice = client.plan("waxpby", 32, 65536).expect("plan");
        let again = client.plan("waxpby", 32, 65536).expect("plan");
        assert_eq!(choice, again);
        let err = client.plan("ghost", 32, 32).err().expect("unknown seq");
        assert!(format!("{err:#}").contains("unknown sequence"), "{err:#}");
        let m = engine.shutdown();
        // plan queries execute nothing and count no requests
        assert_eq!(m.requests, 0);
        assert_eq!(m.batches, 0);
        assert_eq!(m.plan_cache_misses, 1);
        assert_eq!(m.plan_cache_hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_sequence_fails_that_request_only() {
        let dir = stub_dir("unknown");
        let cfg = EngineConfig {
            batch_window: Duration::from_millis(100),
            max_batch: 64,
        };
        let engine = Engine::with_config(Arc::new(Context::new()), &dir, cfg).unwrap();
        let client = engine.client();
        let bad = client.submit(SubmitRequest::new("ghost", 32, 32)).unwrap();
        let good = client
            .submit(SubmitRequest::new("waxpby", 32, 65536).variant(PlanChoice::Fused))
            .unwrap();
        let bad_err = bad.wait().err().expect("ghost must fail");
        assert!(format!("{bad_err:#}").contains("unknown sequence"), "{bad_err:#}");
        // the good request still got scheduled (stub backend error, not
        // a scheduling error)
        let good_err = good.wait().err().expect("stub backend");
        assert!(format!("{good_err:#}").contains("unavailable"), "{good_err:#}");
        let m = engine.shutdown();
        assert_eq!(m.requests, 2);
        assert_eq!(m.failures, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
