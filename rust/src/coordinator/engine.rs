//! The serving facade: [`Engine`] owns one worker per fleet device,
//! [`Client`] is the cloneable submission handle with the
//! predictor-guided router in front, [`SubmitRequest`] is the typed
//! request builder (now with an optional device pin), and [`Ticket`] is
//! the reply future.
//!
//! ```text
//! let engine = Engine::start(Arc::new(Context::new()), Path::new("artifacts"))?;
//! let client = engine.client();                 // Clone + Send
//! let ticket = client.submit(
//!     SubmitRequest::new("bicgk", 256, 256).synth(42),
//! )?;
//! let result = ticket.wait()?;                  // RunResult
//! let metrics = engine.shutdown();              // drain + join (aggregated)
//! ```
//!
//! A heterogeneous fleet starts from a registry instead of a context;
//! the single-device constructors above wrap the context in a one-slot
//! registry, so existing callers are source-compatible:
//!
//! ```text
//! let reg = Arc::new(DeviceRegistry::simulated(4, "artifacts"));
//! let engine = Engine::start_fleet(reg, Path::new("artifacts"), cfg)?;
//! client.submit(SubmitRequest::new("waxpby", 32, 65536))?;          // routed
//! client.submit(SubmitRequest::new("waxpby", 32, 65536)
//!     .pin("GeForce GTX 480 (model)"))?;                            // pinned
//! let fleet = engine.shutdown_fleet();          // per-device Metrics
//! ```
//!
//! The PJRT runtime is `!Send`, so the engine builds each device's
//! [`Coordinator`] *on* that device's worker thread (N devices
//! calibrate and come up in parallel) and reports readiness (or the
//! load error) back before `start_fleet` returns. The catalog manifest
//! is parsed once and shared across the per-device runtimes. Each
//! worker runs the drain-and-group scheduler
//! (`Coordinator::serve_session`) over its own plan cache, so
//! concurrent submissions sharing a `(seq, padded size, device, plan)`
//! key execute as one batch on one device.
//!
//! Unpinned submissions go through [`CostModel::route`]: predicted
//! seconds of the executed variant on each device's own calibration,
//! scaled by the device's live queue depth — the argmin wins. Pinned
//! submissions bypass the router entirely, which is what makes them
//! bit-identical to a single-device engine (`tests/fleet_serving.rs`).

use super::{
    Context, Control, Coordinator, Metrics, Msg, Parked, PlanChoice, Reply, Request,
    RequestInputs, ServeError,
};
use crate::fleet::{CostModel, DeviceId, DeviceRegistry, RouteDecision, RoutingStats, SplitPolicy};
use crate::fusion::space::Space;
use crate::fusion::ImplAxes;
use crate::ir::elem::ProblemSize;
use crate::ir::program::Program;
use crate::pipelines;
use crate::pipelines::store::CatalogStore;
use crate::planner::{self, PlannerConfig};
use crate::runtime::{RunResult, Runtime, Tensor};
use crate::sequences;
use crate::util::manifest::Manifest;
use crate::util::Prng;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Scheduler knobs of one engine (shared by every fleet worker).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// How long a scheduling turn keeps collecting requests after the
    /// first one arrives. Zero means pure drain: whatever is already
    /// queued groups, nothing waits.
    pub batch_window: Duration,
    /// Cap on requests drained per scheduling turn.
    pub max_batch: usize,
    /// How long the submitting side waits for a worker's `PlanShard`
    /// chunk reply in a sharded search before it re-plans that chunk
    /// locally. The fallback is bit-identical — planning is a pure
    /// function of (key, calibration) — so a busy, wedged or dead
    /// worker costs latency, never a different answer. `ZERO` forces
    /// every chunk local (useful in tests).
    pub shard_deadline: Duration,
    /// How long a cold-key submit waits for the workers' `Forecast`
    /// replies before scoring that device with a locally-computed
    /// (bit-identical) forecast. Deliberately much shorter than
    /// [`EngineConfig::shard_deadline`], because the local fallback
    /// costs only milliseconds: this value *bounds* the cold-key stall
    /// a fully busy fleet can add to a submit (idle workers answer far
    /// sooner). Set it near zero to always plan cold keys locally —
    /// the scattered `Forecast` still seeds each worker's plan cache
    /// whenever the worker drains it, waited-for or not.
    pub forecast_deadline: Duration,
    /// Admission-control bound on a device's in-flight requests
    /// (submitted, not yet answered). A best-effort submit beyond the
    /// cap is refused with [`ServeError::QueueFull`] instead of
    /// queueing unboundedly; with the default empty
    /// [`EngineConfig::priority_caps`], nonzero-priority submits get 2×
    /// headroom, so load shedding hits best-effort traffic first.
    /// `usize::MAX` (the default) disables shedding.
    pub queue_cap: usize,
    /// Explicit per-priority admission caps, replacing the blanket 2×
    /// headroom rule: entry `i` is the in-flight cap applied to
    /// priority-`i` submissions (the last entry covers every higher
    /// priority). Empty (the default) keeps the legacy derivation from
    /// [`EngineConfig::queue_cap`]: best-effort gets `queue_cap`, any
    /// nonzero priority 2×. Sheds are counted per priority either way
    /// ([`Metrics::queue_sheds_by_priority`]).
    pub priority_caps: Vec<usize>,
    /// Cap on user pipelines concurrently registered per worker
    /// ([`Client::register_pipeline`]); a registration beyond it is
    /// refused with [`ServeError::PipelineQuota`].
    pub pipeline_quota: usize,
    /// EDF slack: the per-request deadline budget reserved for dispatch
    /// and execution. Batch formation stops collecting once the most
    /// urgent in-hand request is within this slack of its deadline —
    /// shipping *at* the deadline would already be too late.
    pub deadline_slack: Duration,
    /// Deterministic fault-injection plan for chaos runs: each entry
    /// fires on a specific lane's Nth scheduling turn (logical time, so
    /// a seeded plan composes with the seeded
    /// [`super::traffic`] schedules and replays byte-identically).
    /// Empty (the default) injects nothing; supervision itself is
    /// always on for fleet workers.
    pub fault_plan: FaultPlan,
    /// How many times a request reclaimed from a dead lane may be
    /// re-executed on surviving devices before it fails fast with
    /// [`ServeError::WorkerLost`]. Executions are pure, so re-running
    /// is safe; the budget bounds ping-pong under cascading failures.
    pub retry_budget: u32,
    /// Heartbeat staleness bound for the wedge detector: a lane with
    /// queued work whose heartbeat has not advanced for this long is
    /// quarantined (breaker opens) until it beats again. `None` (the
    /// default) disables the detector thread.
    pub wedge_timeout: Option<Duration>,
    /// Opt-in G-way request splitting: when set, the router scores
    /// "best single device" against "row-block across the G cheapest
    /// eligible lanes" (scatter/partial-reduce/gather priced over the
    /// registry's interconnect) and a winning split executes as one
    /// ticket — block 0 inline on the owning lane, the rest scattered
    /// as pinned sub-executions and gathered/combined there. `None`
    /// (the default) serves every request whole on one device.
    pub split: Option<SplitPolicy>,
    /// How long a split's owning lane waits for each scattered block
    /// before re-executing that block locally (counting into the
    /// retry metrics) — and, with the retry budget exhausted, falling
    /// the whole request back to single-device execution.
    pub split_gather: Duration,
    /// Horizontal fusion across a drained turn: when a turn yields
    /// several batches, the worker prices fusing adjacent EDF-ordered
    /// groups into one combined launch
    /// ([`crate::planner::plan_hfuse`]) and dispatches winning
    /// segments via [`crate::codegen::horizontal`]'s block-range
    /// interpretation. On by default: fusing happens only when the
    /// forecast beats back-to-back launches, and the fused execution
    /// is bit-identical, so the knob exists for A/B measurement and
    /// paranoia, not safety.
    pub hfuse: bool,
    /// Beam width of the turn-segmentation search — the widest fused
    /// segment [`crate::planner::plan_hfuse`] prices. Cross-kernel
    /// cost terms break the planner's additivity, so this is the
    /// exactness-vs-cost knob on the serve path: `None` (the default)
    /// solves the segmentation exactly; `Some(k)` caps segment width
    /// at `k` (`Some(1)` disables fusion without disabling pricing).
    pub hfuse_beam: Option<usize>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            batch_window: Duration::ZERO,
            max_batch: 256,
            shard_deadline: Duration::from_secs(5),
            forecast_deadline: Duration::from_secs(1),
            queue_cap: usize::MAX,
            priority_caps: Vec::new(),
            pipeline_quota: Coordinator::DEFAULT_PIPELINE_QUOTA,
            deadline_slack: Duration::from_millis(5),
            fault_plan: FaultPlan::default(),
            retry_budget: 2,
            wedge_timeout: None,
            split: None,
            split_gather: Duration::from_secs(5),
            hfuse: true,
            hfuse_beam: None,
        }
    }
}

/// One injected fault for chaos runs. Faults trigger on a lane's Nth
/// scheduling turn — logical time, not wall clock — so a plan replays
/// identically against the seeded [`super::traffic`] arrival schedules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Panic the lane's worker at the start of turn `turn`, after its
    /// drained queue is parked: the supervisor fails the turn over to
    /// surviving lanes and respawns the worker.
    Kill { lane: usize, turn: u64 },
    /// Panic mid-`execute_batch`, after the batch's inputs are consumed
    /// — the worst spot: explicit-input requests can no longer be
    /// replayed and shed typed instead.
    PanicInExecute { lane: usize, turn: u64 },
    /// Kill the lane beyond recovery: the supervisor fails over what it
    /// can, quarantines the lane permanently, and lets the thread die
    /// panicked (exercises partial [`FleetMetrics`] at shutdown).
    HardKill { lane: usize, turn: u64 },
    /// Sleep `delay` between executing a turn's batches and sending its
    /// replies — late answers, not lost ones.
    DelayReplies { lane: usize, turn: u64, delay: Duration },
    /// Drop the turn's replies instead of sending them. The parked
    /// reply half keeps each ticket connected: callers get the
    /// request's next outcome (failover or typed shed), never a hang.
    DropReplies { lane: usize, turn: u64 },
    /// Stall the worker for `hold` at the start of the turn without
    /// panicking — what the wedge detector exists to catch.
    Wedge { lane: usize, turn: u64, hold: Duration },
}

/// A replayable set of [`Fault`]s ([`EngineConfig::fault_plan`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// Derive a plan of `count` recoverable faults (kill, mid-execute
    /// panic, delayed replies, dropped replies) spread over `lanes`
    /// lanes and early turns, deterministically from `seed`. Hard kills
    /// and wedges are never generated — opt into those explicitly.
    pub fn seeded(seed: u64, lanes: usize, count: usize) -> FaultPlan {
        let mut rng = Prng::new(seed ^ 0xfa01_7b1a);
        let lanes = lanes.max(1) as u64;
        let faults = (0..count)
            .map(|_| {
                let lane = rng.below(lanes) as usize;
                let turn = 1 + rng.below(8);
                match rng.below(4) {
                    0 => Fault::Kill { lane, turn },
                    1 => Fault::PanicInExecute { lane, turn },
                    2 => Fault::DelayReplies {
                        lane,
                        turn,
                        delay: Duration::from_millis(1 + rng.below(20)),
                    },
                    _ => Fault::DropReplies { lane, turn },
                }
            })
            .collect();
        FaultPlan { faults }
    }

    /// FNV-1a digest of the plan — the replay witness, same scheme as
    /// the traffic schedules' digest.
    pub fn digest(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= u64::from(b);
                h = h.wrapping_mul(0x100_0000_01b3);
            }
        };
        for f in &self.faults {
            let (kind, lane, turn, param) = match *f {
                Fault::Kill { lane, turn } => (0u64, lane as u64, turn, 0u64),
                Fault::PanicInExecute { lane, turn } => (1, lane as u64, turn, 0),
                Fault::HardKill { lane, turn } => (2, lane as u64, turn, 0),
                Fault::DelayReplies { lane, turn, delay } => {
                    (3, lane as u64, turn, delay.as_nanos() as u64)
                }
                Fault::DropReplies { lane, turn } => (4, lane as u64, turn, 0),
                Fault::Wedge { lane, turn, hold } => {
                    (5, lane as u64, turn, hold.as_nanos() as u64)
                }
            };
            eat(kind);
            eat(lane);
            eat(turn);
            eat(param);
        }
        h
    }
}

/// Markers and plumbing for injected panics: every scripted panic
/// carries one of these `&'static str` payloads so the supervisor (and
/// the quiet panic hook) can tell chaos from a genuine bug — genuine
/// panics keep the default noisy report and are salvaged identically.
pub(crate) mod chaos {
    use std::sync::Once;

    /// Payload of a recoverable injected kill ([`super::Fault::Kill`]).
    pub(crate) const KILL_MARKER: &str = "fusebla-chaos-kill";
    /// Payload of an unrecoverable kill ([`super::Fault::HardKill`]).
    pub(crate) const HARD_KILL_MARKER: &str = "fusebla-chaos-hard-kill";
    /// Payload of a mid-execute panic
    /// ([`super::Fault::PanicInExecute`]).
    pub(crate) const EXEC_PANIC_MARKER: &str = "fusebla-chaos-exec-panic";

    fn payload_marker(payload: &(dyn std::any::Any + Send)) -> Option<&'static str> {
        payload
            .downcast_ref::<&'static str>()
            .copied()
            .filter(|s| [KILL_MARKER, HARD_KILL_MARKER, EXEC_PANIC_MARKER].contains(s))
    }

    pub(crate) fn is_hard_kill(payload: &(dyn std::any::Any + Send)) -> bool {
        payload_marker(payload) == Some(HARD_KILL_MARKER)
    }

    /// Keep injected panics off stderr (they are scripted, not bugs)
    /// while leaving every other panic's report intact. Installed once,
    /// process-wide, only when a fault plan is active.
    pub(crate) fn install_quiet_panic_hook() {
        static INSTALL: Once = Once::new();
        INSTALL.call_once(|| {
            let default = std::panic::take_hook();
            std::panic::set_hook(Box::new(move |info| {
                if payload_marker(info.payload()).is_none() {
                    default(info);
                }
            }));
        });
    }
}

/// Reply-path chaos for one scheduling turn, staged by `begin_turn` and
/// consumed inside the turn's execute/finish path.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct TurnChaos {
    pub panic_in_execute: bool,
    pub delay: Option<Duration>,
    pub drop_replies: bool,
}

/// Everything the fault plan injects on one (lane, turn).
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct TurnActions {
    pub kill: bool,
    pub hard_kill: bool,
    pub wedge: Option<Duration>,
    pub chaos: Option<TurnChaos>,
}

/// Circuit-breaker states, one `AtomicU8` per lane.
pub(crate) const BREAKER_CLOSED: u8 = 0;
pub(crate) const BREAKER_OPEN: u8 = 1;
pub(crate) const BREAKER_HALF_OPEN: u8 = 2;

/// Fleet-wide supervision state shared by the engine handle, routing,
/// and every lane's supervisor: per-lane circuit breakers with
/// half-open probe slots, heartbeats for the wedge detector, fault
/// tolerance counters (overlaid onto per-device [`Metrics`]), and the
/// persistent dynamic-pipeline catalog that respawned workers replay.
pub(crate) struct FleetState {
    breakers: Vec<AtomicU8>,
    /// One probe in flight per half-open lane: the request that wins
    /// the CAS routes there; everyone else treats the lane as blocked
    /// until the probe's turn completes (or the lane dies again).
    probes: Vec<AtomicBool>,
    /// Heartbeats, bumped at turn boundaries (and after a scripted
    /// wedge clears) — the wedge detector quarantines a lane whose beat
    /// goes stale while work is queued.
    pub(crate) beats: Vec<AtomicU64>,
    /// Set by the wedge detector when *it* opened the breaker, so it
    /// only closes what it opened — supervisor-opened breakers follow
    /// the respawn protocol instead.
    pub(crate) wedged: Vec<AtomicBool>,
    pub(crate) restarts: Vec<AtomicU64>,
    pub(crate) failovers: Vec<AtomicU64>,
    pub(crate) retries: Vec<AtomicU64>,
    pub(crate) worker_lost: Vec<AtomicU64>,
    pub(crate) transitions: Vec<AtomicU64>,
    pub(crate) catalog: CatalogStore,
}

impl FleetState {
    fn new(lanes: usize, catalog: CatalogStore) -> FleetState {
        fn column<T: Default>(lanes: usize) -> Vec<T> {
            (0..lanes).map(|_| T::default()).collect()
        }
        FleetState {
            breakers: column(lanes),
            probes: column(lanes),
            beats: column(lanes),
            wedged: column(lanes),
            restarts: column(lanes),
            failovers: column(lanes),
            retries: column(lanes),
            worker_lost: column(lanes),
            transitions: column(lanes),
            catalog,
        }
    }

    pub(crate) fn breaker_state(&self, lane: usize) -> u8 {
        self.breakers[lane].load(Ordering::Relaxed)
    }

    /// Move a lane's breaker, counting the transition when the state
    /// actually changes and releasing any stale half-open probe slot.
    pub(crate) fn set_breaker(&self, lane: usize, state: u8) {
        let prev = self.breakers[lane].swap(state, Ordering::Relaxed);
        if prev != state {
            self.transitions[lane].fetch_add(1, Ordering::Relaxed);
        }
        if state != BREAKER_HALF_OPEN {
            self.probes[lane].store(false, Ordering::Relaxed);
        }
    }

    /// Close the breaker if it is half-open — called by the lane itself
    /// at the end of any completed scheduling turn: surviving a whole
    /// turn *is* the probe succeeding.
    pub(crate) fn close_if_half_open(&self, lane: usize) {
        if self.breakers[lane]
            .compare_exchange(
                BREAKER_HALF_OPEN,
                BREAKER_CLOSED,
                Ordering::Relaxed,
                Ordering::Relaxed,
            )
            .is_ok()
        {
            self.transitions[lane].fetch_add(1, Ordering::Relaxed);
            self.probes[lane].store(false, Ordering::Relaxed);
        }
    }

    /// Try to claim a half-open lane's single probe slot.
    fn try_probe(&self, lane: usize) -> bool {
        self.probes[lane]
            .compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
    }

    /// Release a probe slot that was claimed but not used (routing
    /// picked another lane, or admission control shed the request).
    fn release_probe(&self, lane: usize) {
        if self.breaker_state(lane) == BREAKER_HALF_OPEN {
            self.probes[lane].store(false, Ordering::Relaxed);
        }
    }

    /// Which lanes routing should avoid (breaker not closed), or `None`
    /// when no lane is quarantined — the healthy-path fast answer.
    pub(crate) fn blocked(&self) -> Option<Vec<bool>> {
        let mask: Vec<bool> = (0..self.breakers.len())
            .map(|i| self.breaker_state(i) != BREAKER_CLOSED)
            .collect();
        mask.iter().any(|&b| b).then_some(mask)
    }
}

/// Per-lane supervision context, shared between a lane's worker (which
/// parks/unparks requests and reads its chaos script at turn
/// boundaries) and its supervisor wrapper (which reclaims and fails
/// over after a death).
pub(crate) struct LaneCtx {
    pub(crate) index: usize,
    /// Registered device name — the identity reported by
    /// [`ServeError::WorkerLost`].
    device: String,
    /// Scheduling turns taken — the fault plan's logical clock.
    pub(crate) turns: AtomicU64,
    /// The parking lot: a tethered reply (plus enough of the request to
    /// re-submit it) per in-flight request, slot-addressed so `finish`
    /// unparks in O(1). Entries left behind by a death are exactly the
    /// requests that still owe an answer.
    lot: Mutex<Vec<Option<Parked>>>,
    pub(crate) fleet: Arc<FleetState>,
    /// Request lanes of the whole fleet — failover re-sends through
    /// these, and a split's owning coordinator scatters row blocks to
    /// its peers the same way.
    pub(crate) txs: Vec<mpsc::Sender<Msg>>,
    pub(crate) depths: Vec<Arc<AtomicU64>>,
    plan: FaultPlan,
    pub(crate) retry_budget: u32,
}

impl LaneCtx {
    pub(crate) fn beat(&self) {
        self.fleet.beats[self.index].fetch_add(1, Ordering::Relaxed);
    }

    /// Park a request for the duration of its turn; returns the slot
    /// for [`LaneCtx::unpark`].
    pub(crate) fn park(&self, p: Parked) -> usize {
        let mut lot = self.lot.lock().unwrap();
        match lot.iter().position(Option::is_none) {
            Some(i) => {
                lot[i] = Some(p);
                i
            }
            None => {
                lot.push(Some(p));
                lot.len() - 1
            }
        }
    }

    /// Drop a parked entry — its request reached a terminal outcome on
    /// this lane (the tethered reply's drop releases nothing: the
    /// in-flight half owns the channel, the parked half held the depth
    /// slot only until `finish` released it).
    pub(crate) fn unpark(&self, slot: usize) {
        self.lot.lock().unwrap()[slot] = None;
    }

    /// Take every parked entry — the dead session's unanswered
    /// requests.
    fn reclaim(&self) -> Vec<Parked> {
        self.lot.lock().unwrap().iter_mut().filter_map(Option::take).collect()
    }

    /// The fault plan's actions for this lane's turn `turn`.
    pub(crate) fn chaos_for(&self, turn: u64) -> TurnActions {
        let mut a = TurnActions::default();
        let mut chaos = TurnChaos::default();
        let mut any = false;
        for f in &self.plan.faults {
            match *f {
                Fault::Kill { lane, turn: t } if lane == self.index && t == turn => a.kill = true,
                Fault::HardKill { lane, turn: t } if lane == self.index && t == turn => {
                    a.hard_kill = true;
                }
                Fault::Wedge { lane, turn: t, hold } if lane == self.index && t == turn => {
                    a.wedge = Some(hold);
                }
                Fault::PanicInExecute { lane, turn: t } if lane == self.index && t == turn => {
                    chaos.panic_in_execute = true;
                    any = true;
                }
                Fault::DelayReplies { lane, turn: t, delay }
                    if lane == self.index && t == turn =>
                {
                    chaos.delay = Some(delay);
                    any = true;
                }
                Fault::DropReplies { lane, turn: t } if lane == self.index && t == turn => {
                    chaos.drop_replies = true;
                    any = true;
                }
                _ => {}
            }
        }
        if any {
            a.chaos = Some(chaos);
        }
        a
    }

    /// Re-route one reclaimed request: re-execute it on the shallowest
    /// surviving (breaker-closed) lane when the retry budget and the
    /// request's nature allow — executions are pure, so re-running is
    /// safe — else fail fast with [`ServeError::WorkerLost`]. Pinned
    /// requests never migrate, and a request whose explicit inputs were
    /// consumed mid-execute cannot be replayed.
    fn failover(&self, p: Parked) {
        let Parked { spec, mut reply } = p;
        let target = (0..self.txs.len())
            .filter(|&j| j != self.index && self.fleet.breaker_state(j) == BREAKER_CLOSED)
            .min_by_key(|&j| self.depths[j].load(Ordering::Relaxed));
        let give_up = spec.pinned
            || spec.inputs.is_none()
            || spec.attempts >= self.retry_budget
            || target.is_none();
        if give_up {
            self.fleet.worker_lost[self.index].fetch_add(1, Ordering::Relaxed);
            reply.send(Err(anyhow::Error::new(ServeError::WorkerLost {
                device: self.device.clone(),
                attempts: spec.attempts,
            })));
            return;
        }
        let target = target.expect("give_up covers the no-target case");
        reply.retarget(self.depths[target].clone());
        self.fleet.failovers[self.index].fetch_add(1, Ordering::Relaxed);
        self.fleet.retries[self.index].fetch_add(1, Ordering::Relaxed);
        // A failed send hands the request back; its dropped Reply
        // releases the depth slot and disconnects the ticket, which
        // surfaces as a typed shutdown error at the caller.
        let _ = self.txs[target].send(Msg::Run(Request {
            seq: spec.seq,
            m: spec.m,
            n: spec.n,
            inputs: spec.inputs.expect("give_up covers the consumed-inputs case"),
            variant: spec.variant,
            enqueued: spec.enqueued,
            deadline: spec.deadline,
            priority: spec.priority,
            attempts: spec.attempts + 1,
            pinned: false,
            lot: None,
            // A reclaimed split owner retries whole on one device: the
            // surviving fleet's shape no longer matches the decided
            // lane set, and single-device execution is always legal.
            split: None,
            split_block: false,
            admission: None,
            reply,
        }));
    }
}

/// Builder for one execution request. Defaults: deterministic synthetic
/// inputs (seed 0), the coordinator's plan cache deciding the variant,
/// and the fleet router deciding the device.
pub struct SubmitRequest {
    seq: String,
    m: usize,
    n: usize,
    inputs: RequestInputs,
    variant: Option<PlanChoice>,
    device: Option<String>,
    deadline: Option<Duration>,
    priority: u8,
}

impl SubmitRequest {
    pub fn new(seq: impl Into<String>, m: usize, n: usize) -> SubmitRequest {
        SubmitRequest {
            seq: seq.into(),
            m,
            n,
            inputs: RequestInputs::Synth { seed: 0 },
            variant: None,
            device: None,
            deadline: None,
            priority: 0,
        }
    }

    /// Use deterministic synthetic inputs from `seed` (generated on the
    /// worker — producers never touch the thread-bound runtime).
    pub fn synth(mut self, seed: u64) -> SubmitRequest {
        self.inputs = RequestInputs::Synth { seed };
        self
    }

    /// Use explicit named input tensors.
    pub fn inputs(mut self, inputs: BTreeMap<String, Tensor>) -> SubmitRequest {
        self.inputs = RequestInputs::Explicit(inputs);
        self
    }

    /// Force a plan variant instead of letting the plan cache decide.
    pub fn variant(mut self, v: PlanChoice) -> SubmitRequest {
        self.variant = Some(v);
        self
    }

    /// Pin the request to a registered device (by exact name),
    /// bypassing the router. Pinned execution is bit-identical to a
    /// single-device engine; an unknown name fails the submit.
    pub fn pin(mut self, device: impl Into<String>) -> SubmitRequest {
        self.device = Some(device.into());
        self
    }

    /// Attach a completion deadline, relative to submission. The
    /// scheduler ships the request without waiting out the batch window
    /// once the deadline (less [`EngineConfig::deadline_slack`]) nears,
    /// and sheds it with [`ServeError::DeadlineExpired`] if it is still
    /// queued when the deadline passes. The resulting SLO accounting
    /// lands in [`Metrics::slo_misses`]/[`Metrics::deadline_requests`].
    pub fn deadline(mut self, d: Duration) -> SubmitRequest {
        self.deadline = Some(d);
        self
    }

    /// Scheduling priority (default 0 = best effort): higher executes
    /// earlier among a turn's batches after deadline order, and gets
    /// more admission-control headroom (2× by default, or the class's
    /// [`EngineConfig::priority_caps`] entry) so overload sheds
    /// best-effort traffic first.
    pub fn priority(mut self, p: u8) -> SubmitRequest {
        self.priority = p;
        self
    }
}

/// Reply handle for one submitted request.
pub struct Ticket<T> {
    rx: mpsc::Receiver<Result<T>>,
}

impl<T> Ticket<T> {
    /// Block until the result arrives. If the engine shuts down with the
    /// request still in flight, this returns an error instead of
    /// hanging.
    pub fn wait(self) -> Result<T> {
        match self.rx.recv() {
            Ok(res) => res,
            Err(_) => Err(anyhow!("engine dropped the request (shut down mid-flight)")),
        }
    }

    /// Non-blocking poll: `None` while the request is still pending.
    pub fn try_wait(&self) -> Option<Result<T>> {
        match self.rx.try_recv() {
            Ok(res) => Some(res),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow!("engine dropped the request (shut down mid-flight)")))
            }
        }
    }
}

/// Routing state shared by the engine handle and every [`Client`]
/// clone: the cost model (which owns the registry) and the live
/// per-device queue depths (incremented at submit, decremented when a
/// reply leaves its worker). The request senders themselves are *not*
/// shared — each handle owns its own `mpsc::Sender` clones.
struct Shared {
    model: CostModel,
    depths: Vec<Arc<AtomicU64>>,
    /// Per-device admission-control shed counters. Engine-side — a shed
    /// request never reaches a worker — and overlaid onto the device's
    /// [`Metrics`] snapshot when metrics are collected.
    sheds: Vec<AtomicU64>,
    /// Per-device sheds split by request priority (same engine-side
    /// overlay; decomposes `sheds`). A Mutex'd map per device is fine:
    /// sheds are the refusal path, not the hot path.
    priority_sheds: Vec<Mutex<BTreeMap<u8, u64>>>,
    /// Best-effort in-flight cap per device
    /// ([`EngineConfig::queue_cap`]); see [`Shared::cap_for`].
    queue_cap: u64,
    /// Explicit per-priority caps ([`EngineConfig::priority_caps`]);
    /// empty = derive from `queue_cap` (legacy 2× headroom).
    priority_caps: Vec<u64>,
    /// Submitter-side wait bound for `PlanShard` chunk replies
    /// ([`EngineConfig::shard_deadline`]).
    deadline: Duration,
    /// Submitter-side wait bound for cold-key `Forecast` replies
    /// ([`EngineConfig::forecast_deadline`]).
    forecast_deadline: Duration,
    /// Sequence name → its (program, built optimization space), shared
    /// by every client clone. Sharded searches of the same sequence
    /// skip fusion enumeration and space construction on the
    /// submitting thread — the workers keep the equivalent per-worker
    /// cache. Keyed by validated sequence names only (a closed set),
    /// so no eviction is needed.
    spaces: Mutex<BTreeMap<String, Arc<(Program, Space)>>>,
    /// Supervision state: breakers (consulted on every route), probe
    /// slots, heartbeats, fault-tolerance counters, pipeline catalog.
    fleet: Arc<FleetState>,
    /// Opt-in split routing ([`EngineConfig::split`]): `None` keeps
    /// every request whole on one device.
    split: Option<SplitPolicy>,
    /// Per-lane admission ledger for cost-aware shedding: one entry per
    /// queued-but-not-yet-drained request, so an overflowing submit can
    /// displace the most expensive lowest-class entry instead of
    /// refusing the newcomer unconditionally. Maintained only when some
    /// admission cap is finite — the unbounded default pays nothing.
    ledger: Vec<Arc<Mutex<BTreeMap<u64, LedgerEntry>>>>,
    /// Monotonic ledger keys (fleet-wide — uniqueness is all that
    /// matters; larger = admitted later).
    ledger_seq: AtomicU64,
}

/// One queued request's admission record: enough of its key to forecast
/// its cost, plus the shed flag the worker checks when it drains the
/// request ([`ServeError::Displaced`]).
pub(crate) struct LedgerEntry {
    priority: u8,
    seq: String,
    m: usize,
    n: usize,
    shed: Arc<AtomicBool>,
}

/// A queued request's handle on its ledger entry, carried inside the
/// [`Request`]. Dropping it (the request was drained, failed over, or
/// abandoned) retires the entry, so the ledger tracks exactly the
/// displaceable — still-queued — population.
pub(crate) struct Admission {
    pub(crate) shed: Arc<AtomicBool>,
    ledger: Arc<Mutex<BTreeMap<u64, LedgerEntry>>>,
    key: u64,
}

impl Drop for Admission {
    fn drop(&mut self) {
        self.ledger.lock().unwrap().remove(&self.key);
    }
}

impl Shared {
    /// Point-in-time queue depths, parallel to registry indices.
    fn snapshot(&self) -> Vec<u64> {
        self.depths.iter().map(|d| d.load(Ordering::Relaxed)).collect()
    }

    /// The admission cap applied to one priority class: the explicit
    /// per-priority table when configured (its last entry covers every
    /// higher priority), else the legacy derivation — best-effort gets
    /// `queue_cap`, any nonzero priority 2×.
    fn cap_for(&self, priority: u8) -> u64 {
        match self.priority_caps.last() {
            None => {
                if priority > 0 {
                    self.queue_cap.saturating_mul(2)
                } else {
                    self.queue_cap
                }
            }
            Some(&last) => *self
                .priority_caps
                .get(priority as usize)
                .unwrap_or(&last),
        }
    }

    /// Placement for a request: the pin when present (an unknown name
    /// is an error, not a silent reroute), otherwise the router's
    /// decision — a single-lane argmin, or (with [`EngineConfig::split`]
    /// set) a G-way row-block split when the split forecast beats the
    /// best single device. Short-circuited on one-device fleets so the
    /// single-device serve path never pays a forecast. `lanes` are the
    /// caller's request senders: a cold key's forecasts run *on* the
    /// workers behind them (seeding their plan caches), not here on the
    /// submitting thread.
    fn route_for(
        &self,
        pin: Option<&str>,
        seq: &str,
        m: usize,
        n: usize,
        lanes: &[mpsc::Sender<Msg>],
        slack: Option<f64>,
    ) -> Result<RouteDecision> {
        match pin {
            Some(name) => match self.model.registry().find(name) {
                Some(id) => Ok(RouteDecision::Single(id.index())),
                None => Err(anyhow!(
                    "unknown device '{name}' (registered: {})",
                    self.model
                        .registry()
                        .ids()
                        .iter()
                        .map(DeviceId::name)
                        .collect::<Vec<_>>()
                        .join(", ")
                )),
            },
            None if self.depths.len() == 1 => Ok(RouteDecision::Single(0)),
            None => {
                // Quarantined lanes (breaker open) are skipped; a
                // half-open lane admits exactly one probe request — the
                // CAS winner — and blocks everyone else. If that leaves
                // no lane at all, route unmasked: serving on a
                // quarantined lane beats refusing outright.
                let count = self.depths.len();
                let mut blocked = vec![false; count];
                let mut won: Vec<usize> = Vec::new();
                for i in 0..count {
                    match self.fleet.breaker_state(i) {
                        BREAKER_OPEN => blocked[i] = true,
                        BREAKER_HALF_OPEN => {
                            if self.fleet.try_probe(i) {
                                won.push(i);
                            } else {
                                blocked[i] = true;
                            }
                        }
                        _ => {}
                    }
                }
                let mask = (!blocked.iter().all(|&b| b)).then_some(blocked.as_slice());
                let decision = self.model.decide_via(
                    seq,
                    m,
                    n,
                    &self.snapshot(),
                    Some((lanes, self.forecast_deadline)),
                    mask,
                    slack,
                    self.split,
                );
                for w in won {
                    let kept = match &decision {
                        RouteDecision::Single(i) => *i == w,
                        RouteDecision::Split(ls) => ls.contains(&w),
                    };
                    if !kept {
                        self.fleet.release_probe(w);
                    }
                }
                Ok(decision)
            }
        }
    }

    /// Is any admission cap finite? Only then is the ledger maintained.
    fn sheddable(&self) -> bool {
        self.queue_cap != u64::MAX || !self.priority_caps.is_empty()
    }

    /// Record an admitted request in its lane's ledger (no-op with
    /// unbounded caps). The returned handle rides inside the request;
    /// its drop retires the entry.
    fn admit(&self, lane: usize, priority: u8, seq: &str, m: usize, n: usize) -> Option<Admission> {
        if !self.sheddable() {
            return None;
        }
        let key = self.ledger_seq.fetch_add(1, Ordering::Relaxed);
        let shed = Arc::new(AtomicBool::new(false));
        self.ledger[lane].lock().unwrap().insert(
            key,
            LedgerEntry {
                priority,
                seq: seq.to_string(),
                m,
                n,
                shed: shed.clone(),
            },
        );
        Some(Admission {
            shed,
            ledger: self.ledger[lane].clone(),
            key,
        })
    }

    /// Cost-aware shedding: on queue-cap overflow, look for a queued
    /// request that is a better refusal than the newcomer — within the
    /// *lowest* priority class in the lane's ledger, the entry with the
    /// highest forecast cost (refusing it frees the most device time
    /// per refusal). Returns `true` after marking such a victim shed
    /// (counted into the same engine-side shed metrics as a submit-time
    /// refusal) — the newcomer then takes the freed slot. Returns
    /// `false` when the newcomer itself is the cheapest-to-refuse
    /// candidate (ties included, so a uniform workload keeps the legacy
    /// refuse-the-newest behavior) and should be refused as before.
    fn displace_for(
        &self,
        lane: usize,
        seq: &str,
        m: usize,
        n: usize,
        priority: u8,
        lanes: &[mpsc::Sender<Msg>],
    ) -> bool {
        if !self.sheddable() {
            return false;
        }
        let cost_of = |s: &str, m: usize, n: usize| -> f64 {
            self.model
                .costs_via(s, m, n, Some((lanes, self.forecast_deadline)), None)
                .map(|c| c[lane])
                .filter(|c| c.is_finite())
                // An unforecastable key (unknown sequence) will fail
                // anyway: the cheapest possible thing to refuse.
                .unwrap_or(f64::INFINITY)
        };
        let mut ledger = self.ledger[lane].lock().unwrap();
        let Some(class) = ledger.values().map(|e| e.priority).min() else {
            return false;
        };
        if priority < class {
            // The newcomer alone is the lowest class: it is the shed.
            return false;
        }
        // The most expensive queued entry of the lowest class; cost
        // ties go to the newest entry (closest to the legacy order).
        let victim = ledger
            .iter()
            .filter(|(_, e)| e.priority == class)
            .map(|(k, e)| (*k, cost_of(&e.seq, e.m, e.n)))
            .max_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        let Some((key, victim_cost)) = victim else {
            return false;
        };
        if priority == class && cost_of(seq, m, n) >= victim_cost {
            // The newcomer is at least as expensive as anything queued
            // in its class: refusing it is the cheaper refusal.
            return false;
        }
        let entry = ledger.remove(&key).expect("victim key was just scanned");
        entry.shed.store(true, Ordering::Relaxed);
        drop(ledger);
        self.sheds[lane].fetch_add(1, Ordering::Relaxed);
        *self.priority_sheds[lane]
            .lock()
            .unwrap()
            .entry(entry.priority)
            .or_insert(0) += 1;
        true
    }
}

/// Cloneable, `Send` submission handle to a running [`Engine`]. Routing
/// happens here, on the submitting thread: the worker a request lands
/// on is decided before it is enqueued.
#[derive(Clone)]
pub struct Client {
    shared: Arc<Shared>,
    txs: Vec<mpsc::Sender<Msg>>,
}

impl Client {
    /// Enqueue a request; the returned [`Ticket`] resolves to the run
    /// result. Fails when the engine is already shut down, the pin
    /// names an unregistered device, or admission control sheds the
    /// request ([`ServeError::QueueFull`] — the routed device's
    /// in-flight queue is at capacity).
    pub fn submit(&self, req: SubmitRequest) -> Result<Ticket<RunResult>> {
        // Deadline slack for the router's completion-time term: at
        // submit the full relative deadline is still available.
        let slack = req.deadline.map(|d| d.as_secs_f64());
        let decision = self.shared.route_for(
            req.device.as_deref(),
            &req.seq,
            req.m,
            req.n,
            &self.txs,
            slack,
        )?;
        let lane = decision.owner();
        let depth = &self.shared.depths[lane];
        // Priority classes get their own caps (explicit table, or the
        // legacy 2×-headroom derivation), so overload sheds best-effort
        // submissions first.
        let cap = self.shared.cap_for(req.priority);
        let (reply, rx) = mpsc::channel();
        // Count the request before sending so a racing router on
        // another thread sees it; undo on shed. (A concurrent burst can
        // transiently overshoot the cap by the number of racing
        // submitters — admission control bounds the queue, it does not
        // serialize submits.) A split counts one slot on its owning
        // lane only: the scattered blocks take their peers' slots when
        // the owner actually sends them.
        let prev = depth.fetch_add(1, Ordering::Relaxed);
        if prev >= cap
            && !self
                .shared
                .displace_for(lane, &req.seq, req.m, req.n, req.priority, &self.txs)
        {
            depth.fetch_sub(1, Ordering::Relaxed);
            // The request may have won half-open probe slots in
            // routing; shedding it must not leave them claimed.
            self.shared.fleet.release_probe(lane);
            if let RouteDecision::Split(lanes) = &decision {
                for &l in &lanes[1..] {
                    self.shared.fleet.release_probe(l);
                }
            }
            self.shared.sheds[lane].fetch_add(1, Ordering::Relaxed);
            *self.shared.priority_sheds[lane]
                .lock()
                .unwrap()
                .entry(req.priority)
                .or_insert(0) += 1;
            return Err(anyhow::Error::new(ServeError::QueueFull {
                depth: prev,
                cap,
            }));
        }
        let admission = self
            .shared
            .admit(lane, req.priority, &req.seq, req.m, req.n);
        let split = match decision {
            RouteDecision::Single(_) => None,
            RouteDecision::Split(lanes) => Some(lanes),
        };
        let enqueued = Instant::now();
        let sent = self.txs[lane].send(Msg::Run(Request {
            seq: req.seq,
            m: req.m,
            n: req.n,
            inputs: req.inputs,
            variant: req.variant,
            enqueued,
            deadline: req.deadline.map(|d| enqueued + d),
            priority: req.priority,
            attempts: 0,
            pinned: req.device.is_some(),
            lot: None,
            split,
            split_block: false,
            admission,
            reply: Reply::new(reply, Some(depth.clone())),
        }));
        if sent.is_err() {
            // The failed send handed the request back, and its dropped
            // Reply already released the depth slot (decrement-on-drop)
            // — no manual undo here, that would double-count.
            return Err(anyhow!("engine is shut down"));
        }
        Ok(Ticket { rx })
    }

    /// Live per-device in-flight queue depths, in registry order — the
    /// router's backlog view. Every submitted request releases its slot
    /// on *any* terminal outcome (reply, failure, shed, shutdown), so
    /// once all tickets resolve the depths are zero again.
    pub fn queue_depths(&self) -> Vec<u64> {
        self.shared.snapshot()
    }

    /// Resolve (and cache) the plan for a `(seq, m, n)` key without
    /// executing anything — the planner runs on the worker of the
    /// device the router prefers for the key *at steady state* (empty
    /// queues), so the pre-warm lands where unforced submissions of the
    /// same key settle once transient backlogs drain, not wherever a
    /// momentary spike happens to point. Blocks until the worker picks
    /// the query up.
    pub fn plan(&self, seq: &str, m: usize, n: usize) -> Result<PlanChoice> {
        let lane = self.steady_state_lane(seq, m, n);
        let (reply, rx) = mpsc::channel();
        self.txs[lane]
            .send(Msg::Control(Control::Plan {
                seq: seq.to_string(),
                m,
                n,
                reply,
            }))
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv()
            .unwrap_or_else(|_| Err(anyhow!("engine dropped the request (shut down mid-flight)")))
    }

    /// The registered device identities, in routing (registry) order.
    pub fn devices(&self) -> Vec<DeviceId> {
        self.shared.model.registry().ids()
    }

    /// Submitting-side routing counters: cold keys seen, forecasts
    /// served by workers vs computed locally (the fallback). The
    /// cold-key regression test pins `local_forecasts == 0` on the
    /// routed path — planning must stay off the submitting thread.
    pub fn routing_stats(&self) -> RoutingStats {
        self.shared.model.stats()
    }

    /// The device the router would pick for this key at steady state
    /// (empty queues) — where unforced submissions of the key settle
    /// once transient backlogs drain.
    fn steady_state_lane(&self, seq: &str, m: usize, n: usize) -> usize {
        if self.txs.len() == 1 {
            0
        } else {
            self.shared.model.route_via(
                seq,
                m,
                n,
                &vec![0; self.txs.len()],
                Some((&self.txs, self.shared.forecast_deadline)),
                None,
            )
        }
    }

    /// Run the pruned planner for `(seq, m, n)` with its partition
    /// range sharded into `k` chunks scattered across the fleet's
    /// workers — idle lanes first — and merged here. The merged result
    /// is **bit-identical** to unsharded
    /// [`planner::plan_space`] on the same device's calibration (see
    /// [`crate::planner::shard`]); chunks whose worker is busy past
    /// [`EngineConfig::shard_deadline`], gone, or answering with an
    /// error are re-planned locally, so degraded fleets cost latency,
    /// never correctness — and never a partial merge.
    ///
    /// `device` pins whose calibration the search runs against (by
    /// registered name); `None` uses the steady-state routed device for
    /// the key — note that routing a *cold* key scatters the usual
    /// `Forecast` queries, which seed worker plan caches like any
    /// routed submission would. The search itself is pure: nothing
    /// executes, no plan cache is consulted, and its answer is
    /// returned, not retained.
    pub fn search_sharded(
        &self,
        seq: &str,
        m: usize,
        n: usize,
        k: usize,
        device: Option<&str>,
    ) -> Result<planner::Planned> {
        self.search_sharded_inner(seq, m, n, Some(k), device)
    }

    /// [`Client::search_sharded`] with the shard count derived from
    /// live fleet state instead of chosen by the caller: one chunk per
    /// currently-idle lane (at least one), capped by the space's
    /// partition count — an idle fleet fans the search out wide, a
    /// saturated fleet collapses to a single chunk on the shallowest
    /// lane rather than queueing chunk work behind serving traffic.
    pub fn search_sharded_auto(
        &self,
        seq: &str,
        m: usize,
        n: usize,
        device: Option<&str>,
    ) -> Result<planner::Planned> {
        self.search_sharded_inner(seq, m, n, None, device)
    }

    fn search_sharded_inner(
        &self,
        seq: &str,
        m: usize,
        n: usize,
        k: Option<usize>,
        device: Option<&str>,
    ) -> Result<planner::Planned> {
        let registry = self.shared.model.registry().clone();
        let target = match device {
            Some(name) => registry
                .find(name)
                .ok_or_else(|| anyhow!("unknown device '{name}'"))?
                .index(),
            None => self.steady_state_lane(seq, m, n),
        };
        let db = registry.context(target).db.clone();
        // Build (or reuse) the sequence's space: deterministic per
        // name, so every client clone shares one construction. Built
        // outside the lock — a racing duplicate build keeps the first
        // insert and both are identical anyway. Registered pipelines
        // published their space here at registration time, so a cache
        // miss that also fails the built-in lookup is an unknown name.
        let cached = self.shared.spaces.lock().unwrap().get(seq).cloned();
        let entry = match cached {
            Some(e) => e,
            None => {
                let sq = sequences::by_name(seq)
                    .ok_or_else(|| anyhow!("unknown sequence '{seq}'"))?;
                let (prog, _graph, space) = sq.space(registry.library(), &ImplAxes::minimal());
                let built = Arc::new((prog, space));
                self.shared
                    .spaces
                    .lock()
                    .unwrap()
                    .entry(seq.to_string())
                    .or_insert(built)
                    .clone()
            }
        };
        let (prog, space) = (&entry.0, &entry.1);
        let p = ProblemSize::new(m, n).padded();
        let cfg = PlannerConfig::default();

        // Scatter: chunks round-robin over lanes ordered shallowest
        // queue first (stable on ties → deterministic), all sends
        // before any gather so workers overlap.
        let depths = self.shared.snapshot();
        // Quarantined lanes (breaker not closed) are skipped by the
        // scatter — chunk work queued behind a dead or probing lane
        // would just ride out the local-fallback deadline. If every
        // lane is quarantined, scatter anyway: the local fallback still
        // guarantees the merge.
        let blocked = self.shared.fleet.blocked();
        let mut order: Vec<usize> = match &blocked {
            Some(mask) => (0..self.txs.len()).filter(|&i| !mask[i]).collect(),
            None => (0..self.txs.len()).collect(),
        };
        if blocked.is_some() {
            if order.is_empty() {
                order = (0..self.txs.len()).collect();
            } else {
                self.shared
                    .model
                    .note_quarantined((self.txs.len() - order.len()) as u64);
            }
        }
        order.sort_by_key(|&i| depths[i]);
        // Adaptive shard count: one chunk per idle *eligible* lane,
        // bounded by the partition count (an explicit `k` skips the
        // adaptation).
        let k = k.unwrap_or_else(|| {
            let idle = order.iter().filter(|&&i| depths[i] == 0).count().max(1);
            idle.min(space.partitions.len()).max(1)
        });
        let ranges = planner::chunk_ranges(space.partitions.len(), k);
        let pending: Vec<_> = ranges
            .into_iter()
            .enumerate()
            .map(|(j, r)| {
                let lane = order[j % order.len()];
                let (reply, rx) = mpsc::channel();
                let sent = self.txs[lane]
                    .send(Msg::Control(Control::PlanShard {
                        seq: seq.to_string(),
                        m: p.m,
                        n: p.n,
                        range: r.clone(),
                        db: db.clone(),
                        reply,
                    }))
                    .is_ok();
                (r, sent.then_some(rx))
            })
            .collect();

        // Gather under one overall deadline; any lost, late or failed
        // chunk is evaluated locally (pure function — identical bits).
        let by = Instant::now() + self.shared.deadline;
        let chunks = pending
            .into_iter()
            .map(|(r, rx)| {
                let served = rx
                    .and_then(|rx| {
                        rx.recv_timeout(by.saturating_duration_since(Instant::now())).ok()
                    })
                    .and_then(|res| res.ok())
                    .filter(|c: &planner::ShardEval| c.range == r);
                served.unwrap_or_else(|| planner::shard::eval_chunk(space, &db, p, &cfg, r))
            })
            .collect();
        Ok(planner::shard::merge(prog, space, chunks))
    }

    /// Register a user-defined script pipeline fleet-wide and return
    /// its content fingerprint. The source is compiled *on every
    /// worker* (script → typecheck → IR → fusion space → planner inputs
    /// → codegen) and the name only becomes routable once all of them
    /// acked the same fingerprint — a partial registration (a worker
    /// rejecting, dying, or disagreeing) is rolled back from the
    /// workers that accepted, and the first error is returned.
    ///
    /// Typed rejections ([`ServeError`]): `InvalidScript` (the script
    /// fails to compile — checked client-side before any worker sees
    /// it), `DuplicatePipeline` (the name collides with a built-in, or
    /// with a registered pipeline of *different* source; identical
    /// source is an idempotent dedup that returns the existing
    /// fingerprint), `PipelineQuota` (a worker's dynamic catalog is
    /// full). After success the pipeline is a first-class sequence:
    /// submits route to it, plan/resolve caches apply, and
    /// [`Client::search_sharded`] shards its space.
    pub fn register_pipeline(&self, name: &str, src: &str) -> Result<u64> {
        // Client-side prechecks, so the common rejections never cost a
        // control-plane round trip: built-in names are never
        // shadowable, and the routable roster already knows whether
        // this name is taken (and with what content).
        if sequences::by_name(name).is_some() {
            return Err(anyhow::Error::new(ServeError::DuplicatePipeline {
                name: name.to_string(),
            }));
        }
        let lib = self.shared.model.registry().library();
        let fp = pipelines::fingerprint(src, lib);
        if let Some(existing) = self.shared.model.pipeline_fingerprint(name) {
            if existing == fp {
                return Ok(fp);
            }
            return Err(anyhow::Error::new(ServeError::DuplicatePipeline {
                name: name.to_string(),
            }));
        }
        // Compile locally once: an invalid script is rejected typed
        // without perturbing any worker, and the compiled planning
        // inputs feed the router roster after the fleet agrees.
        let compiled = pipelines::compile(name, src, lib).map_err(|e| {
            anyhow::Error::new(ServeError::InvalidScript {
                line: e.line,
                msg: e.msg,
            })
        })?;
        debug_assert_eq!(compiled.pipeline.fingerprint, fp);
        // Scatter to every worker before gathering any reply, so the
        // compiles overlap.
        let pending: Vec<_> = self
            .txs
            .iter()
            .map(|tx| {
                let (reply, rx) = mpsc::channel();
                let sent = tx
                    .send(Msg::Control(Control::RegisterPipeline {
                        name: name.to_string(),
                        src: src.to_string(),
                        reply,
                    }))
                    .is_ok();
                sent.then_some(rx)
            })
            .collect();
        let mut failure: Option<anyhow::Error> = None;
        let mut acked: Vec<usize> = Vec::with_capacity(pending.len());
        for (i, rx) in pending.into_iter().enumerate() {
            let res = match rx {
                Some(rx) => rx
                    .recv()
                    .unwrap_or_else(|_| Err(anyhow!("a worker died during registration"))),
                None => Err(anyhow!("engine is shut down")),
            };
            match res {
                Ok(wfp) if wfp == fp => acked.push(i),
                Ok(wfp) => {
                    if failure.is_none() {
                        failure = Some(anyhow!(
                            "pipeline '{name}': worker {i} compiled fingerprint \
                             {wfp:#018x}, submitter computed {fp:#018x}"
                        ));
                    }
                }
                Err(e) => {
                    if failure.is_none() {
                        failure = Some(e);
                    }
                }
            }
        }
        if let Some(e) = failure {
            // All-or-nothing: roll the acked workers back so a partial
            // registration never leaves the fleet disagreeing on what
            // the name means. Only the lanes that *just* accepted are
            // touched — a pre-existing same-name pipeline on other
            // lanes (the degraded case this guards) stays as it was.
            for i in acked {
                let (reply, rx) = mpsc::channel();
                if self.txs[i]
                    .send(Msg::Control(Control::UnregisterPipeline {
                        name: name.to_string(),
                        reply,
                    }))
                    .is_ok()
                {
                    let _ = rx.recv();
                }
            }
            return Err(e);
        }
        // Every worker agreed: publish the name to the router roster
        // and the shared space cache, making it routable + shardable,
        // and persist it so registrations survive engine restarts and
        // worker respawns replay it with the same fingerprint.
        self.shared.model.register_pipeline(&compiled);
        self.shared.spaces.lock().unwrap().insert(
            name.to_string(),
            Arc::new((compiled.pipeline.program.clone(), compiled.space)),
        );
        self.shared.fleet.catalog.insert(name, src, fp);
        Ok(fp)
    }

    /// Remove a registered pipeline fleet-wide (workers, router roster,
    /// shared space cache). Returns whether any worker had it; removing
    /// an unknown name is a no-op. Built-ins cannot be removed — their
    /// names never enter the dynamic catalog.
    pub fn unregister_pipeline(&self, name: &str) -> bool {
        let pending: Vec<_> = self
            .txs
            .iter()
            .map(|tx| {
                let (reply, rx) = mpsc::channel();
                let sent = tx
                    .send(Msg::Control(Control::UnregisterPipeline {
                        name: name.to_string(),
                        reply,
                    }))
                    .is_ok();
                sent.then_some(rx)
            })
            .collect();
        let mut any = false;
        for rx in pending.into_iter().flatten() {
            any |= rx.recv().unwrap_or(false);
        }
        self.shared.model.unregister_pipeline(name);
        self.shared.spaces.lock().unwrap().remove(name);
        self.shared.fleet.catalog.remove(name);
        any
    }
}

/// Final or point-in-time metrics of a fleet: one [`Metrics`] per
/// device, in registry order, plus the aggregate view.
pub struct FleetMetrics {
    pub devices: Vec<(DeviceId, Metrics)>,
    /// Lanes whose worker could not be joined cleanly at shutdown
    /// (hard-killed, or panicked beyond supervision) — their entry in
    /// `devices` carries only the engine-side counters.
    pub lost: Vec<DeviceId>,
}

impl FleetMetrics {
    /// Fold every device's metrics into one (counters add, batch maxima
    /// take the max, distributions merge).
    pub fn aggregate(&self) -> Metrics {
        let mut total = Metrics::default();
        for (_, m) in &self.devices {
            total.merge(m);
        }
        total
    }
}

/// Owns the serving fleet: per-device coordinator construction, the
/// request lanes, and shutdown. Dropping the engine without calling
/// [`Engine::shutdown`] still stops and joins every worker.
pub struct Engine {
    shared: Arc<Shared>,
    txs: Vec<mpsc::Sender<Msg>>,
    ids: Vec<DeviceId>,
    workers: Vec<Option<JoinHandle<Metrics>>>,
    /// The wedge-detector watchdog ([`EngineConfig::wedge_timeout`])
    /// and its stop flag; joined at shutdown.
    wedge: Option<(Arc<AtomicBool>, JoinHandle<()>)>,
}

impl Engine {
    /// Start a single-device engine with the default scheduler
    /// configuration.
    ///
    /// The context decides its own calibration-cache location; when
    /// serving a non-default catalog directory, build it with
    /// `Context::with_calibration_cache(artifacts_dir)` so the cache
    /// lives next to the artifacts it belongs to.
    pub fn start(ctx: Arc<Context>, artifacts_dir: &Path) -> Result<Engine> {
        Self::with_config(ctx, artifacts_dir, EngineConfig::default())
    }

    /// Start a single-device engine: the context is wrapped in a
    /// one-slot registry (no recalibration), so the serve path is the
    /// fleet path with the router short-circuited.
    pub fn with_config(
        ctx: Arc<Context>,
        artifacts_dir: &Path,
        cfg: EngineConfig,
    ) -> Result<Engine> {
        let registry = Arc::new(DeviceRegistry::from_context(ctx, artifacts_dir));
        Self::start_fleet(registry, artifacts_dir, cfg)
    }

    /// Start one worker per registered device: each spawns, builds its
    /// own coordinator there (the PJRT client is `!Send`; the parsed
    /// manifest is shared), loads or runs its device's calibration, and
    /// reports readiness. All workers must come up — any load error
    /// shuts the rest down and surfaces here instead of on the first
    /// submit.
    pub fn start_fleet(
        registry: Arc<DeviceRegistry>,
        artifacts_dir: &Path,
        cfg: EngineConfig,
    ) -> Result<Engine> {
        let manifest = Runtime::load_manifest(artifacts_dir)?;
        let ids = registry.ids();
        let n = registry.len();
        if !cfg.fault_plan.faults.is_empty() {
            chaos::install_quiet_panic_hook();
        }
        // Supervision state exists before any worker: lanes are born
        // with closed breakers, and the persisted pipeline catalog is
        // loaded once for both the start-time replay below and every
        // later worker respawn.
        let fleet = Arc::new(FleetState::new(n, CatalogStore::load(artifacts_dir)));
        let mut txs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = mpsc::channel();
            txs.push(tx);
            rxs.push(rx);
        }
        let depths: Vec<Arc<AtomicU64>> = (0..n).map(|_| Arc::new(AtomicU64::new(0))).collect();
        let mut workers = Vec::with_capacity(n);
        let mut readies = Vec::with_capacity(n);
        for (i, rx) in rxs.into_iter().enumerate() {
            let (ready_tx, ready_rx) = mpsc::channel();
            let lane = Arc::new(LaneCtx {
                index: i,
                device: ids[i].name().to_string(),
                turns: AtomicU64::new(0),
                lot: Mutex::new(Vec::new()),
                fleet: fleet.clone(),
                txs: txs.clone(),
                depths: depths.clone(),
                plan: cfg.fault_plan.clone(),
                retry_budget: cfg.retry_budget,
            });
            let reg = registry.clone();
            let man = manifest.clone();
            let cfg = cfg.clone();
            let worker = std::thread::Builder::new()
                .name(format!("fusebla-dev{i}"))
                .spawn(move || worker_loop(rx, lane, reg, man, cfg, ready_tx))
                .expect("spawning a fleet worker thread");
            workers.push(Some(worker));
            readies.push(ready_rx);
        }
        let mut failure = None;
        for ready in readies {
            match ready.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => failure = Some(e),
                Err(_) => failure = Some(anyhow!("a fleet worker died during startup")),
            }
        }
        if let Some(e) = failure {
            for tx in &txs {
                let _ = tx.send(Msg::Control(Control::Shutdown));
            }
            for w in workers.into_iter().flatten() {
                let _ = w.join();
            }
            return Err(e);
        }
        let sheds = (0..n).map(|_| AtomicU64::new(0)).collect();
        let priority_sheds = (0..n).map(|_| Mutex::new(BTreeMap::new())).collect();
        let wedge = cfg.wedge_timeout.map(|timeout| {
            let stop = Arc::new(AtomicBool::new(false));
            let handle =
                spawn_wedge_detector(fleet.clone(), depths.clone(), timeout, stop.clone());
            (stop, handle)
        });
        let engine = Engine {
            shared: Arc::new(Shared {
                model: CostModel::new(registry),
                depths,
                sheds,
                priority_sheds,
                queue_cap: cfg.queue_cap as u64,
                priority_caps: cfg.priority_caps.iter().map(|&c| c as u64).collect(),
                deadline: cfg.shard_deadline,
                forecast_deadline: cfg.forecast_deadline,
                spaces: Mutex::new(BTreeMap::new()),
                fleet: fleet.clone(),
                split: cfg.split,
                ledger: (0..n).map(|_| Arc::new(Mutex::new(BTreeMap::new()))).collect(),
                ledger_seq: AtomicU64::new(0),
            }),
            txs,
            ids,
            workers,
            wedge,
        };
        // Replay the persisted dynamic catalog so registrations survive
        // engine restarts. Entries that no longer reproduce their
        // recorded fingerprint (source drift, library change) are
        // evicted rather than served with different semantics.
        let persisted = fleet.catalog.entries();
        if !persisted.is_empty() {
            let client = engine.client();
            for (name, src, fp) in persisted {
                match client.register_pipeline(&name, &src) {
                    Ok(got) if got == fp => {}
                    _ => fleet.catalog.remove(&name),
                }
            }
        }
        Ok(engine)
    }

    /// A new submission handle (cheap; clone freely across threads).
    pub fn client(&self) -> Client {
        Client {
            shared: self.shared.clone(),
            txs: self.txs.clone(),
        }
    }

    /// The registered device identities, in registry order.
    pub fn devices(&self) -> &[DeviceId] {
        &self.ids
    }

    /// Aggregated point-in-time metrics snapshot without shutting down
    /// (the single-device view; see [`Engine::fleet_metrics`] for the
    /// per-device breakdown). Blocks until each worker reaches the
    /// query in its queue (they answer between scheduling turns).
    pub fn metrics(&self) -> Metrics {
        self.fleet_metrics().aggregate()
    }

    /// Per-device point-in-time metrics snapshot, in registry order.
    /// The query fans out to every worker before any reply is awaited,
    /// so the snapshot waits for the slowest single turn, not the sum
    /// of all turns. Admission-control sheds are counted engine-side (a
    /// shed request never reaches a worker) and overlaid here.
    pub fn fleet_metrics(&self) -> FleetMetrics {
        let replies: Vec<Option<mpsc::Receiver<Metrics>>> = self
            .txs
            .iter()
            .map(|tx| {
                let (reply, rx) = mpsc::channel();
                tx.send(Msg::Control(Control::Metrics(reply))).ok().map(|_| rx)
            })
            .collect();
        let devices = self
            .ids
            .iter()
            .cloned()
            .zip(replies.into_iter().enumerate().map(|(i, rx)| {
                let mut m = match rx {
                    Some(rx) => rx.recv().unwrap_or_default(),
                    None => Metrics::default(),
                };
                Self::overlay(&self.shared, i, &mut m);
                m
            }))
            .collect();
        FleetMetrics { devices, lost: Vec::new() }
    }

    /// Engine-side counter overlay for one lane: admission sheds and
    /// the supervision counters, all owned outside the worker — a
    /// restarted (or even lost) worker loses none of them.
    fn overlay(shared: &Shared, i: usize, m: &mut Metrics) {
        m.queue_sheds = shared.sheds[i].load(Ordering::Relaxed);
        m.queue_sheds_by_priority = shared.priority_sheds[i].lock().unwrap().clone();
        let fleet = &shared.fleet;
        m.worker_restarts = fleet.restarts[i].load(Ordering::Relaxed);
        m.failovers = fleet.failovers[i].load(Ordering::Relaxed);
        m.retries = fleet.retries[i].load(Ordering::Relaxed);
        m.worker_lost_sheds = fleet.worker_lost[i].load(Ordering::Relaxed);
        m.breaker_transitions = fleet.transitions[i].load(Ordering::Relaxed);
    }

    /// Stop every worker after it finishes everything submitted before
    /// this call, and return the aggregated final metrics. A shutdown
    /// sentinel (not channel disconnection) stops each loop, so
    /// outstanding [`Client`] clones cannot keep the engine alive;
    /// their later submissions fail, and tickets for requests enqueued
    /// after the sentinel resolve to an error instead of hanging.
    pub fn shutdown(self) -> Metrics {
        self.shutdown_fleet().aggregate()
    }

    /// [`Engine::shutdown`] with the per-device breakdown preserved.
    /// Engine-side shed counters are overlaid like in
    /// [`Engine::fleet_metrics`].
    pub fn shutdown_fleet(mut self) -> FleetMetrics {
        for tx in &self.txs {
            let _ = tx.send(Msg::Control(Control::Shutdown));
        }
        if let Some((stop, handle)) = self.wedge.take() {
            stop.store(true, Ordering::Relaxed);
            let _ = handle.join();
        }
        let shared = self.shared.clone();
        let mut lost = Vec::new();
        let devices = self
            .ids
            .iter()
            .cloned()
            .zip(self.workers.iter_mut().enumerate().map(|(i, w)| {
                let mut m = match w.take().map(JoinHandle::join) {
                    Some(Ok(m)) => m,
                    Some(Err(_)) => {
                        // The worker died beyond supervision (hard
                        // kill, or a panic outside the guarded turn
                        // loop). Report the fleet partially instead of
                        // poisoning shutdown — the engine-side overlay
                        // below is everything that survives for the
                        // lane.
                        lost.push(self.ids[i].clone());
                        Metrics::default()
                    }
                    None => Metrics::default(),
                };
                Self::overlay(&shared, i, &mut m);
                m
            }))
            .collect();
        FleetMetrics { devices, lost }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Msg::Control(Control::Shutdown));
        }
        if let Some((stop, handle)) = self.wedge.take() {
            stop.store(true, Ordering::Relaxed);
            let _ = handle.join();
        }
        for w in self.workers.iter_mut().filter_map(Option::take) {
            let _ = w.join();
        }
    }
}

/// One fleet worker's supervised lifetime: build the coordinator on
/// this thread (the runtime is `!Send`), serve scheduling turns inside
/// `catch_unwind`, and on a panic — injected or genuine — salvage the
/// lane: quarantine it, fail its stranded requests over, respawn the
/// coordinator on a fresh context, replay the dynamic pipeline catalog,
/// and re-enter the *same* receiver, so the lane's channel (and every
/// client clone holding its sender) stays valid across the restart.
fn worker_loop(
    rx: mpsc::Receiver<Msg>,
    lane: Arc<LaneCtx>,
    reg: Arc<DeviceRegistry>,
    man: Arc<Manifest>,
    cfg: EngineConfig,
    ready_tx: mpsc::Sender<Result<()>>,
) -> Metrics {
    let i = lane.index;
    let mut coord = match Coordinator::with_manifest(reg.context(i), man.clone()) {
        Ok(mut c) => {
            c.attach_lane(lane.clone(), Metrics::default());
            let _ = ready_tx.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return Metrics::default();
        }
    };
    let mut rx_slot = Some(rx);
    loop {
        let served = {
            let rx = rx_slot.as_ref().expect("receiver held while serving");
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                coord.serve_session(rx, &cfg)
            }))
        };
        let payload = match served {
            Ok(()) => return coord.full_metrics(),
            Err(payload) => payload,
        };
        // The session died mid-turn. Quarantine the lane first so
        // routing stops feeding it, then salvage: metrics survive in
        // the carried base, parked requests fail over or shed typed,
        // and anything still queued behind the dead session drains the
        // same way.
        lane.fleet.wedged[i].store(false, Ordering::Relaxed);
        lane.fleet.set_breaker(i, BREAKER_OPEN);
        let carried = coord.full_metrics();
        for p in lane.reclaim() {
            lane.failover(p);
        }
        let mut shutdown = false;
        {
            let rx = rx_slot.as_ref().expect("receiver held while draining");
            loop {
                match rx.try_recv() {
                    Ok(Msg::Run(r)) => lane.failover(Parked::from_request(r)),
                    Ok(Msg::Control(Control::Metrics(reply))) => {
                        let _ = reply.send(carried.clone());
                    }
                    Ok(Msg::Control(Control::Shutdown)) => shutdown = true,
                    // Other control queries lose their reply sender;
                    // every such caller has a disconnect fallback
                    // (local planning, typed error, or timeout).
                    Ok(Msg::Control(_)) => {}
                    Err(_) => break,
                }
            }
        }
        if shutdown {
            return carried;
        }
        if chaos::is_hard_kill(&*payload) {
            // Scripted as unrecoverable: drop the receiver so later
            // submissions fail fast at send instead of queueing
            // forever, then die for real — shutdown reports the lane in
            // [`FleetMetrics::lost`].
            drop(rx_slot.take());
            std::panic::resume_unwind(payload);
        }
        // Respawn: fresh context over the same device (persistent
        // calibration makes this a reload, not a re-run), replay the
        // dynamic catalog with fingerprint verification, re-admit
        // through a half-open breaker probe.
        match Coordinator::with_manifest(reg.rebuild_context(i), man.clone()) {
            Ok(mut c) => {
                c.attach_lane(lane.clone(), carried);
                for (name, src, fp) in lane.fleet.catalog.entries() {
                    match c.register_pipeline(&name, &src) {
                        Ok(got) if got == fp => {}
                        // An entry that cannot reproduce its recorded
                        // fingerprint must not serve silently-different
                        // results on this lane.
                        _ => {
                            c.unregister_pipeline(&name);
                        }
                    }
                }
                lane.fleet.restarts[i].fetch_add(1, Ordering::Relaxed);
                lane.fleet.set_breaker(i, BREAKER_HALF_OPEN);
                coord = c;
            }
            Err(_) => {
                // The device cannot come back: stay quarantined and
                // keep answering, so every future request gets a
                // terminal outcome instead of a hang.
                let rx = rx_slot.take().expect("receiver held for the drain");
                return degraded_drain(&rx, &lane, carried);
            }
        }
    }
}

/// Terminal state of a lane whose respawn failed: the breaker stays
/// open and the channel is drained until shutdown, so every request
/// gets a typed answer and every control query a sane fallback.
fn degraded_drain(rx: &mpsc::Receiver<Msg>, lane: &LaneCtx, carried: Metrics) -> Metrics {
    loop {
        match rx.recv() {
            Ok(Msg::Run(r)) => lane.failover(Parked::from_request(r)),
            Ok(Msg::Control(Control::Metrics(reply))) => {
                let _ = reply.send(carried.clone());
            }
            Ok(Msg::Control(Control::Shutdown)) | Err(_) => return carried,
            Ok(Msg::Control(_)) => {}
        }
    }
}

/// Watchdog for wedged (stalled, not panicked) lanes: a lane with
/// queued work whose heartbeat has not advanced within `timeout` gets
/// its breaker opened; when the beat moves again the detector closes
/// what it opened — and only that; supervisor-opened breakers follow
/// the respawn protocol instead.
fn spawn_wedge_detector(
    fleet: Arc<FleetState>,
    depths: Vec<Arc<AtomicU64>>,
    timeout: Duration,
    stop: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("fusebla-wedge".into())
        .spawn(move || {
            let lanes = depths.len();
            let mut last: Vec<(u64, Instant)> = (0..lanes)
                .map(|i| (fleet.beats[i].load(Ordering::Relaxed), Instant::now()))
                .collect();
            let poll = (timeout / 4).max(Duration::from_millis(1));
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(poll);
                let now = Instant::now();
                for i in 0..lanes {
                    let beat = fleet.beats[i].load(Ordering::Relaxed);
                    if beat != last[i].0 {
                        last[i] = (beat, now);
                        if fleet.wedged[i].swap(false, Ordering::Relaxed) {
                            fleet.set_breaker(i, BREAKER_CLOSED);
                        }
                        continue;
                    }
                    let stale = now.duration_since(last[i].1) >= timeout;
                    let busy = depths[i].load(Ordering::Relaxed) > 0;
                    if stale
                        && busy
                        && fleet.breaker_state(i) == BREAKER_CLOSED
                        && !fleet.wedged[i].swap(true, Ordering::Relaxed)
                    {
                        fleet.set_breaker(i, BREAKER_OPEN);
                    }
                }
            }
        })
        .expect("spawning the wedge detector thread")
}

#[cfg(test)]
mod tests {
    use super::super::testutil::stub_catalog;
    use super::*;
    use crate::sim::DeviceModel;

    /// Stub catalog with parseable HLO stubs: planning and scheduling
    /// work end-to-end; only the final PJRT `compile` fails on the
    /// offline stub backend — which is exactly what lets these tests
    /// run without built artifacts.
    fn stub_dir(tag: &str) -> std::path::PathBuf {
        stub_catalog(&format!("engine_{tag}"), &["waxpby", "vadd"], true)
    }

    /// GTX 480 + GT 430 fleet over a stub catalog (the calibration
    /// files land in the stub dir, wiped with it).
    fn stub_fleet(tag: &str, cfg: EngineConfig) -> (std::path::PathBuf, Engine) {
        let dir = stub_dir(tag);
        let reg = Arc::new(
            DeviceRegistry::new(vec![DeviceModel::gtx480(), DeviceModel::gt430()], &dir).unwrap(),
        );
        let engine = Engine::start_fleet(reg, &dir, cfg).unwrap();
        (dir, engine)
    }

    #[test]
    fn engine_start_fails_cleanly_without_manifest() {
        let dir = std::env::temp_dir().join(format!("fusebla_engine_none_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let err = Engine::start(Arc::new(Context::new()), &dir).err().expect("must fail");
        assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let dir = stub_dir("shutdown");
        let engine = Engine::start(Arc::new(Context::new()), &dir).unwrap();
        let client = engine.client();
        let _ = engine.shutdown();
        assert!(client.submit(SubmitRequest::new("waxpby", 32, 65536)).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn engine_groups_a_burst_and_plans_once_per_key() {
        let dir = stub_dir("burst");
        let cfg = EngineConfig {
            batch_window: Duration::from_millis(300),
            max_batch: 64,
            ..EngineConfig::default()
        };
        let engine = Engine::with_config(Arc::new(Context::new()), &dir, cfg).unwrap();
        let client = engine.client();
        // 6 waxpby + 3 vadd, interleaved, all planner-resolved
        let mut tickets = Vec::new();
        for i in 0..9u64 {
            let seq = if i % 3 == 2 { "vadd" } else { "waxpby" };
            tickets.push(client.submit(SubmitRequest::new(seq, 32, 65536).synth(i)).unwrap());
        }
        // results are stub-backend errors; delivery is what matters here
        for t in tickets {
            assert!(t.wait().is_err());
        }
        // live snapshot before shutdown sees the same totals
        let live = engine.metrics();
        assert_eq!(live.requests, 9);
        let m = engine.shutdown();
        assert_eq!(m.requests, 9);
        assert_eq!(m.batch_size_sum, 9);
        assert_eq!(m.failures, 9, "stub backend fails every execution");
        // two distinct batch keys → exactly two plan-cache misses, ever
        assert_eq!(m.plan_cache_misses, 2);
        // stub backend: every batch's resolve fails at compile; failed
        // resolves are never cached and never pin an executable
        assert_eq!(m.resolve_misses, m.batches);
        assert_eq!(m.resolve_hits, 0);
        assert_eq!(m.executable_compiles, 0);
        assert!(m.batches >= 2, "at least one batch per distinct key");
        assert!(
            m.batches < 9,
            "a same-key burst must group: {} batches for 9 requests",
            m.batches
        );
        assert!(m.max_batch_size >= 2);
        assert!(m.batched_requests >= 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plan_query_resolves_without_executing() {
        let dir = stub_dir("plan");
        let engine = Engine::start(Arc::new(Context::new()), &dir).unwrap();
        let client = engine.client();
        let choice = client.plan("waxpby", 32, 65536).expect("plan");
        let again = client.plan("waxpby", 32, 65536).expect("plan");
        assert_eq!(choice, again);
        let err = client.plan("ghost", 32, 32).err().expect("unknown seq");
        assert!(format!("{err:#}").contains("unknown sequence"), "{err:#}");
        let m = engine.shutdown();
        // plan queries execute nothing and count no requests
        assert_eq!(m.requests, 0);
        assert_eq!(m.batches, 0);
        assert_eq!(m.plan_cache_misses, 1);
        assert_eq!(m.plan_cache_hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_sequence_fails_that_request_only() {
        let dir = stub_dir("unknown");
        let cfg = EngineConfig {
            batch_window: Duration::from_millis(100),
            max_batch: 64,
            ..EngineConfig::default()
        };
        let engine = Engine::with_config(Arc::new(Context::new()), &dir, cfg).unwrap();
        let client = engine.client();
        let bad = client.submit(SubmitRequest::new("ghost", 32, 32)).unwrap();
        let good = client
            .submit(SubmitRequest::new("waxpby", 32, 65536).variant(PlanChoice::Fused))
            .unwrap();
        let bad_err = bad.wait().err().expect("ghost must fail");
        assert!(format!("{bad_err:#}").contains("unknown sequence"), "{bad_err:#}");
        // the good request still got scheduled (stub backend error, not
        // a scheduling error)
        let good_err = good.wait().err().expect("stub backend");
        assert!(format!("{good_err:#}").contains("unavailable"), "{good_err:#}");
        let m = engine.shutdown();
        assert_eq!(m.requests, 2);
        assert_eq!(m.failures, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pinned_submissions_land_on_the_pinned_device() {
        let (dir, engine) = stub_fleet("pin", EngineConfig::default());
        let client = engine.client();
        let ids = client.devices();
        assert_eq!(ids.len(), 2);
        // two to the slow device, one to the fast — counts must follow
        // the pins, not the router's preference
        let slow = ids[1].name().to_string();
        let fast = ids[0].name().to_string();
        let tickets = vec![
            client.submit(SubmitRequest::new("waxpby", 32, 65536).pin(&slow)).unwrap(),
            client.submit(SubmitRequest::new("waxpby", 32, 65536).pin(&slow)).unwrap(),
            client.submit(SubmitRequest::new("waxpby", 32, 65536).pin(&fast)).unwrap(),
        ];
        for t in tickets {
            assert!(t.wait().is_err(), "stub backend fails execution");
        }
        let fleet = engine.shutdown_fleet();
        assert_eq!(fleet.devices.len(), 2);
        assert_eq!(fleet.devices[0].1.requests, 1, "fast device got the one pin");
        assert_eq!(fleet.devices[1].1.requests, 2, "slow device got both pins");
        let agg = fleet.aggregate();
        assert_eq!(agg.requests, 3);
        // every dispatched request left one queued-duration sample
        assert_eq!(agg.queued.count(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pinning_an_unknown_device_fails_the_submit() {
        let (dir, engine) = stub_fleet("badpin", EngineConfig::default());
        let client = engine.client();
        let err = client
            .submit(SubmitRequest::new("waxpby", 32, 65536).pin("no such device"))
            .err()
            .expect("unknown pin must fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown device"), "{msg}");
        assert!(msg.contains("GTX 480"), "message lists the roster: {msg}");
        let m = engine.shutdown();
        assert_eq!(m.requests, 0, "nothing was enqueued");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn router_starves_the_slow_device_on_a_small_burst() {
        let (dir, engine) = stub_fleet("route", EngineConfig::default());
        let client = engine.client();
        // GT 430 is ~6× slower on bandwidth-bound keys; a burst smaller
        // than the cost ratio must route entirely to the GTX 480 even
        // with the queue-depth term counting the in-flight requests.
        let tickets: Vec<_> = (0..3u64)
            .map(|i| client.submit(SubmitRequest::new("waxpby", 32, 65536).synth(i)).unwrap())
            .collect();
        for t in tickets {
            assert!(t.wait().is_err(), "stub backend fails execution");
        }
        let fleet = engine.shutdown_fleet();
        assert_eq!(fleet.devices[0].1.requests, 3, "fast device takes the burst");
        assert_eq!(fleet.devices[1].1.requests, 0, "slow device stays idle");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Sharded search through the control plane: different chunkings of
    /// the same key on the same device are bit-identical, the workers
    /// served the chunks, and planning touched no plan cache and
    /// executed nothing.
    #[test]
    fn search_sharded_is_chunking_invariant_and_runs_on_workers() {
        let (dir, engine) = stub_fleet("shard", EngineConfig::default());
        let client = engine.client();
        let device = client.devices()[0].name().to_string();
        let a = client.search_sharded("gemver", 4096, 4096, 1, Some(device.as_str())).unwrap();
        let b = client.search_sharded("gemver", 4096, 4096, 4, Some(device.as_str())).unwrap();
        assert_eq!(a.best.variant, b.best.variant);
        assert_eq!(a.predicted.to_bits(), b.predicted.to_bits());
        assert_eq!(a.stats.combos_evaluated, b.stats.combos_evaluated);
        assert_eq!(a.stats.kernel_evals, b.stats.kernel_evals);
        assert!(client.search_sharded("ghost", 32, 32, 2, None).is_err());
        assert!(client
            .search_sharded("gemver", 4096, 4096, 2, Some("no such device"))
            .is_err());
        let m = engine.shutdown();
        // 1 + 4 chunks scattered; every one was received and served
        assert_eq!(m.shard_requests, 5);
        assert_eq!(m.shard_served, 5);
        assert_eq!(m.requests, 0, "sharded search executes nothing");
        assert_eq!(
            m.plan_cache_misses + m.plan_cache_hits,
            0,
            "sharded search is pure planning — no plan-cache traffic"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Admission control: a best-effort submit beyond `queue_cap` is
    /// refused with a typed `QueueFull`, priority submits get 2×
    /// headroom, and the sheds surface in the per-device snapshot. The
    /// long batch window holds the admitted requests in flight; their
    /// deadlines make the drain loop ship early (EDF), so the test
    /// never waits the window out.
    #[test]
    fn queue_cap_sheds_with_typed_error_and_priority_headroom() {
        let dir = stub_dir("qcap");
        let cfg = EngineConfig {
            batch_window: Duration::from_secs(60),
            queue_cap: 1,
            // ship ~500ms after a deadline-carrying request is in hand,
            // leaving its 60s budget intact — wide enough for the
            // submits below to land while the first is still in flight
            deadline_slack: Duration::from_millis(59_500),
            ..EngineConfig::default()
        };
        let engine = Engine::with_config(Arc::new(Context::new()), &dir, cfg).unwrap();
        let client = engine.client();
        let sub = || SubmitRequest::new("waxpby", 32, 65536).deadline(Duration::from_secs(60));
        let t1 = client.submit(sub()).unwrap();
        // the queue is at cap: best-effort submits shed, typed
        let err = client.submit(sub()).err().expect("must shed");
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::QueueFull { depth, cap }) => assert_eq!((*depth, *cap), (1, 1)),
            other => panic!("expected QueueFull, got {other:?} ({err:#})"),
        }
        // priority headroom: cap doubles, so one more gets in...
        let t2 = client.submit(sub().priority(1)).unwrap();
        // ...and the next priority submit finds 2 >= 2
        let err2 = client.submit(sub().priority(1)).err().expect("priority cap");
        assert!(err2.is::<ServeError>());
        assert!(client.queue_depths().iter().sum::<u64>() <= 2);
        let live = engine.fleet_metrics();
        assert_eq!(live.devices[0].1.queue_sheds, 2);
        // admitted requests complete (stub backend error, not a shed)
        for t in [t1, t2] {
            let res = t.wait();
            let e = res.err().expect("stub backend fails execution");
            assert!(e.downcast_ref::<ServeError>().is_none(), "not shed: {e:#}");
        }
        assert_eq!(client.queue_depths().iter().sum::<u64>(), 0);
        let m = engine.shutdown();
        assert_eq!(m.queue_sheds, 2);
        assert_eq!(m.requests, 2, "shed requests never reach a worker");
        assert_eq!(m.slo_misses, 0, "generous deadlines are met");
        assert_eq!(m.deadline_requests, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Explicit per-priority caps replace the 2×-headroom rule: each
    /// class sheds at its own bound (the table's last entry covering
    /// higher priorities), and sheds are counted per class.
    #[test]
    fn per_priority_queue_caps_shed_by_class() {
        let dir = stub_dir("priocaps");
        let cfg = EngineConfig {
            batch_window: Duration::from_secs(60),
            queue_cap: 1,
            priority_caps: vec![1, 3],
            // hold admitted requests in flight while the rest submit
            deadline_slack: Duration::from_millis(59_500),
            ..EngineConfig::default()
        };
        let engine = Engine::with_config(Arc::new(Context::new()), &dir, cfg).unwrap();
        let client = engine.client();
        let sub = || SubmitRequest::new("waxpby", 32, 65536).deadline(Duration::from_secs(60));
        let t1 = client.submit(sub()).unwrap(); // p0: depth 0 < cap 1
        let e0 = client.submit(sub()).err().expect("p0 must shed at its cap");
        match e0.downcast_ref::<ServeError>() {
            Some(ServeError::QueueFull { depth: 1, cap: 1 }) => {}
            other => panic!("expected QueueFull(1,1), got {other:?} ({e0:#})"),
        }
        let t2 = client.submit(sub().priority(1)).unwrap(); // depth 1 < cap 3
        let t3 = client.submit(sub().priority(1)).unwrap(); // depth 2 < cap 3
        let e1 = client.submit(sub().priority(1)).err().expect("p1 cap");
        assert!(e1.is::<ServeError>());
        // priorities past the table's end use its last entry
        let e5 = client.submit(sub().priority(5)).err().expect("p5 uses last cap");
        assert!(e5.is::<ServeError>());
        let live = engine.fleet_metrics();
        assert_eq!(live.devices[0].1.queue_sheds, 3);
        let by_prio = &live.devices[0].1.queue_sheds_by_priority;
        assert_eq!(by_prio.get(&0), Some(&1));
        assert_eq!(by_prio.get(&1), Some(&1));
        assert_eq!(by_prio.get(&5), Some(&1));
        for t in [t1, t2, t3] {
            let _ = t.wait();
        }
        let m = engine.shutdown();
        assert_eq!(m.queue_sheds, 3);
        assert_eq!(m.queue_sheds_by_priority.values().sum::<u64>(), 3);
        assert_eq!(m.requests, 3, "shed requests never reach a worker");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Fleet-wide pipeline registration: every worker compiles and
    /// acks, the name becomes routable and executable (interpreter
    /// backend succeeds even on the stub), re-registration of identical
    /// source dedups, an invalid script is rejected typed before any
    /// worker sees it, and the registered space shards.
    #[test]
    fn register_pipeline_fans_out_and_serves() {
        let (dir, engine) = stub_fleet("pipereg", EngineConfig::default());
        let client = engine.client();
        let fp = client
            .register_pipeline("amx", pipelines::examples::ADD_MUL_EXP)
            .unwrap();
        assert_ne!(fp, 0);
        // identical source: idempotent dedup, same fingerprint
        assert_eq!(
            client
                .register_pipeline("amx", pipelines::examples::ADD_MUL_EXP)
                .unwrap(),
            fp
        );
        // invalid script: typed, client-side, no worker perturbed
        let err = client.register_pipeline("bad", "return z;").err().expect("invalid");
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::InvalidScript { .. })
        ));
        // built-in collision: typed duplicate
        let err = client
            .register_pipeline("waxpby", pipelines::examples::ADD_MUL_EXP)
            .err()
            .expect("built-in name");
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::DuplicatePipeline { .. })
        ));
        // the registered name executes end to end (routed, interp-backed)
        let t = client.submit(SubmitRequest::new("amx", 32, 256).synth(7)).unwrap();
        let res = t.wait().expect("interp execution succeeds on the stub backend");
        assert!(res.env.contains_key("z"));
        // and its space shards like a built-in's
        let planned = client.search_sharded_auto("amx", 32, 256, None).unwrap();
        assert!(planned.predicted > 0.0);
        assert!(client.search_sharded_auto("ghost", 32, 32, None).is_err());
        let m = engine.shutdown();
        assert_eq!(m.requests, 1);
        assert_eq!(m.failures, 0);
        // one registration per worker; the idempotent re-register and
        // both rejections resolved client-side, before any worker
        assert_eq!(m.pipeline_registrations, 2);
        assert_eq!(m.pipeline_rejections, 0, "rejections were client-side");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fleet_metrics_snapshot_fans_out_per_device() {
        let (dir, engine) = stub_fleet("fanout", EngineConfig::default());
        let client = engine.client();
        let ids = client.devices();
        let t = client
            .submit(SubmitRequest::new("vadd", 32, 65536).pin(ids[1].name()))
            .unwrap();
        let _ = t.wait();
        let live = engine.fleet_metrics();
        assert_eq!(live.devices[0].0.index(), 0);
        assert_eq!(live.devices[1].0.index(), 1);
        assert_eq!(live.devices[0].1.requests, 0);
        assert_eq!(live.devices[1].1.requests, 1);
        assert_eq!(live.aggregate().requests, engine.metrics().requests);
        let _ = engine.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The fault plan is a pure function of its seed — the property the
    /// byte-identical chaos replays rest on — and its digest witnesses
    /// every field of every fault.
    #[test]
    fn fault_plan_seeded_is_deterministic() {
        let a = FaultPlan::seeded(7, 3, 12);
        let b = FaultPlan::seeded(7, 3, 12);
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.faults.len(), 12);
        assert_ne!(a.digest(), FaultPlan::seeded(8, 3, 12).digest());
        assert_eq!(FaultPlan::default().digest(), FaultPlan::seeded(7, 3, 0).digest());
        for f in &a.faults {
            match *f {
                Fault::Kill { lane, turn }
                | Fault::PanicInExecute { lane, turn }
                | Fault::DropReplies { lane, turn }
                | Fault::DelayReplies { lane, turn, .. } => {
                    assert!(lane < 3, "lane {lane} out of range");
                    assert!((1..=8).contains(&turn), "turn {turn} out of range");
                }
                other => panic!("seeded plans never script {other:?}"),
            }
        }
        // the digest covers the delay parameter, not just (kind, lane, turn)
        let base = FaultPlan {
            faults: vec![Fault::DelayReplies {
                lane: 0,
                turn: 1,
                delay: Duration::from_millis(5),
            }],
        };
        let slower = FaultPlan {
            faults: vec![Fault::DelayReplies {
                lane: 0,
                turn: 1,
                delay: Duration::from_millis(6),
            }],
        };
        assert_ne!(base.digest(), slower.digest());
    }

    /// The per-lane circuit breaker: open quarantines, half-open admits
    /// exactly one probe, a survived turn closes, and `blocked()` is
    /// `None` on an all-healthy fleet (the fast path routing takes).
    #[test]
    fn breaker_state_machine_and_probe_slot() {
        let fleet = FleetState::new(2, CatalogStore::in_memory());
        assert_eq!(fleet.blocked(), None);
        fleet.set_breaker(1, BREAKER_OPEN);
        assert_eq!(fleet.blocked(), Some(vec![false, true]));
        // closing from open is the supervisor's job, not the turn's
        fleet.close_if_half_open(1);
        assert_eq!(fleet.breaker_state(1), BREAKER_OPEN);
        fleet.set_breaker(1, BREAKER_HALF_OPEN);
        // one probe slot: first claimant wins, second is turned away
        assert!(fleet.try_probe(1));
        assert!(!fleet.try_probe(1));
        fleet.release_probe(1);
        assert!(fleet.try_probe(1));
        // surviving a turn closes the breaker and frees the slot
        fleet.close_if_half_open(1);
        assert_eq!(fleet.breaker_state(1), BREAKER_CLOSED);
        assert_eq!(fleet.blocked(), None);
        // closed → open → half-open → closed: 3 transitions, all lane 1
        assert_eq!(fleet.transitions[1].load(Ordering::Relaxed), 3);
        assert_eq!(fleet.transitions[0].load(Ordering::Relaxed), 0);
    }

    /// A bicgk-shaped pipeline (interpreter-backed, so it executes end
    /// to end on the stub): `q` row-concatenates across blocks
    /// (order-preserving), `s` is a fixed-order partial sum.
    const ROWBLOCK_PIPELINE: &str = "
        matrix<MxN> A; vector<N> p, s; vector<M> q, r;
        input A, p, r;
        q = sgemv(A, p);
        s = sgemtv(A, r);
        return q, s;
    ";

    /// Hand a split request straight to its owning lane, bypassing the
    /// router — the execution path must serve whatever lane set a
    /// decision names, so these tests do not depend on the forecast
    /// choosing to split.
    fn send_split(
        client: &Client,
        seq: &str,
        m: usize,
        n: usize,
        seed: u64,
        lanes: Vec<usize>,
    ) -> Ticket<RunResult> {
        let owner = lanes[0];
        let depth = client.shared.depths[owner].clone();
        depth.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        client.txs[owner]
            .send(Msg::Run(Request {
                seq: seq.into(),
                m,
                n,
                inputs: RequestInputs::Synth { seed },
                variant: None,
                enqueued: Instant::now(),
                deadline: None,
                priority: 0,
                attempts: 0,
                pinned: false,
                lot: None,
                split: Some(lanes),
                split_block: false,
                admission: None,
                reply: Reply::new(reply, Some(depth)),
            }))
            .expect("engine is serving");
        Ticket { rx }
    }

    /// A 2-way split of a registered pipeline serves as one ticket: the
    /// order-preserving output is bit-identical to the whole
    /// single-device run, the partial-sum output is numerically close
    /// and (fixed combine order) bit-stable across replays, and the
    /// block accounting lands on the decided lanes.
    #[test]
    fn split_execution_matches_whole_and_counts_blocks() {
        let (dir, engine) = stub_fleet("splitexec", EngineConfig::default());
        let client = engine.client();
        client.register_pipeline("rowblock", ROWBLOCK_PIPELINE).unwrap();
        let (m, n, seed) = (96usize, 64usize, 7u64);
        let owner = client.devices()[0].name().to_string();
        let whole = client
            .submit(SubmitRequest::new("rowblock", m, n).synth(seed).pin(&owner))
            .unwrap()
            .wait()
            .expect("interp execution succeeds on the stub backend");
        let split = send_split(&client, "rowblock", m, n, seed, vec![0, 1])
            .wait()
            .expect("split execution succeeds");
        let replay = send_split(&client, "rowblock", m, n, seed, vec![0, 1])
            .wait()
            .expect("split replay succeeds");
        assert_eq!(split.env["q"].dims, whole.env["q"].dims);
        for (a, b) in split.env["q"].data.iter().zip(&whole.env["q"].data) {
            assert_eq!(a.to_bits(), b.to_bits(), "ConcatRows output is bit-identical");
        }
        assert_eq!(split.env["s"].dims, whole.env["s"].dims);
        for (a, b) in split.env["s"].data.iter().zip(&whole.env["s"].data) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "{a} vs {b}");
        }
        for name in ["q", "s"] {
            for (a, b) in split.env[name].data.iter().zip(&replay.env[name].data) {
                assert_eq!(a.to_bits(), b.to_bits(), "fixed-order combine replays bitwise");
            }
        }
        assert_eq!(client.queue_depths(), vec![0, 0]);
        let fleet = engine.shutdown_fleet();
        let (m0, m1) = (&fleet.devices[0].1, &fleet.devices[1].1);
        assert_eq!(m0.splits, 2, "the owner served both split tickets");
        assert_eq!(m0.split_fallbacks, 0);
        assert_eq!(m0.split_blocks, 2, "block 0 of each split ran inline");
        assert_eq!(m1.split_blocks, 2, "block 1 of each split scattered to the peer");
        assert_eq!(m0.requests, 3, "two splits + the pinned whole, one request each");
        assert_eq!(m1.requests, 0, "scattered blocks are sub-executions, not requests");
        assert_eq!(m0.failures + m1.failures, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A peer lane killed mid-split: the scattered block comes back as
    /// a typed WorkerLost reply, the owner re-executes it locally under
    /// the retry budget, and the ticket resolves with the correct
    /// combined result — no lost tickets, no whole-run fallback.
    #[test]
    fn split_survives_peer_kill_by_local_retry() {
        let cfg = EngineConfig {
            fault_plan: FaultPlan {
                faults: vec![Fault::Kill { lane: 1, turn: 1 }],
            },
            ..EngineConfig::default()
        };
        let (dir, engine) = stub_fleet("splitkill", cfg);
        let client = engine.client();
        client.register_pipeline("rowblock", ROWBLOCK_PIPELINE).unwrap();
        let (m, n, seed) = (96usize, 64usize, 3u64);
        let split = send_split(&client, "rowblock", m, n, seed, vec![0, 1])
            .wait()
            .expect("the split ticket must survive the peer kill");
        let owner = client.devices()[0].name().to_string();
        let whole = client
            .submit(SubmitRequest::new("rowblock", m, n).synth(seed).pin(&owner))
            .unwrap()
            .wait()
            .unwrap();
        for (a, b) in split.env["q"].data.iter().zip(&whole.env["q"].data) {
            assert_eq!(a.to_bits(), b.to_bits(), "the retried block keeps bit-identity");
        }
        assert_eq!(client.queue_depths(), vec![0, 0]);
        let fleet = engine.shutdown_fleet();
        let (m0, m1) = (&fleet.devices[0].1, &fleet.devices[1].1);
        assert_eq!(m0.splits, 1);
        assert_eq!(m0.split_fallbacks, 0, "local retry, not whole-run fallback");
        assert_eq!(m0.split_blocks, 2, "own block + the locally retried block");
        assert_eq!(m1.split_blocks, 0, "the peer died before executing its block");
        assert_eq!(m0.retries, 1, "the lost block cost one retry");
        assert_eq!(m1.worker_lost_sheds, 1, "the pinned block shed typed on the dead lane");
        assert_eq!(m1.worker_restarts, 1, "the killed lane respawned");
        assert!(fleet.lost.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Cost-aware shedding: on queue-cap overflow the most expensive
    /// queued request of the lowest priority class is displaced (typed
    /// [`ServeError::Displaced`]) in favor of a cheaper newcomer, while
    /// a newcomer at least as expensive as everything queued still
    /// sheds itself with the legacy [`ServeError::QueueFull`].
    #[test]
    fn queue_overflow_displaces_the_most_expensive_queued_request() {
        let dir = stub_dir("displace");
        let cfg = EngineConfig {
            batch_window: Duration::from_secs(60),
            queue_cap: 1,
            // hold admitted requests in flight while the rest submit
            deadline_slack: Duration::from_millis(59_500),
            ..EngineConfig::default()
        };
        let engine = Engine::with_config(Arc::new(Context::new()), &dir, cfg).unwrap();
        let client = engine.client();
        let sub = |n: usize| SubmitRequest::new("waxpby", 32, n).deadline(Duration::from_secs(60));
        // expensive in, cheap arrives: the expensive one is displaced
        let costly = client.submit(sub(65536)).unwrap();
        let cheap = client.submit(sub(256)).unwrap();
        let err = costly.wait().err().expect("must be displaced");
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::Displaced)),
            "{err:#}"
        );
        let e = cheap.wait().err().expect("stub backend fails execution");
        assert!(e.downcast_ref::<ServeError>().is_none(), "served, not shed: {e:#}");
        // cheap in, expensive arrives: the newcomer is the better refusal
        let cheap2 = client.submit(sub(256)).unwrap();
        let err2 = client.submit(sub(65536)).err().expect("refused at submit");
        assert!(
            matches!(err2.downcast_ref::<ServeError>(), Some(ServeError::QueueFull { .. })),
            "{err2:#}"
        );
        let _ = cheap2.wait();
        let m = engine.shutdown();
        assert_eq!(m.queue_sheds, 2, "one displacement + one refusal");
        assert_eq!(m.requests, 2, "only the two cheap requests executed");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
