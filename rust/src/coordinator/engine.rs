//! The serving facade: [`Engine`] owns one worker per fleet device,
//! [`Client`] is the cloneable submission handle with the
//! predictor-guided router in front, [`SubmitRequest`] is the typed
//! request builder (now with an optional device pin), and [`Ticket`] is
//! the reply future.
//!
//! ```text
//! let engine = Engine::start(Arc::new(Context::new()), Path::new("artifacts"))?;
//! let client = engine.client();                 // Clone + Send
//! let ticket = client.submit(
//!     SubmitRequest::new("bicgk", 256, 256).synth(42),
//! )?;
//! let result = ticket.wait()?;                  // RunResult
//! let metrics = engine.shutdown();              // drain + join (aggregated)
//! ```
//!
//! A heterogeneous fleet starts from a registry instead of a context;
//! the single-device constructors above wrap the context in a one-slot
//! registry, so existing callers are source-compatible:
//!
//! ```text
//! let reg = Arc::new(DeviceRegistry::simulated(4, "artifacts"));
//! let engine = Engine::start_fleet(reg, Path::new("artifacts"), cfg)?;
//! client.submit(SubmitRequest::new("waxpby", 32, 65536))?;          // routed
//! client.submit(SubmitRequest::new("waxpby", 32, 65536)
//!     .pin("GeForce GTX 480 (model)"))?;                            // pinned
//! let fleet = engine.shutdown_fleet();          // per-device Metrics
//! ```
//!
//! The PJRT runtime is `!Send`, so the engine builds each device's
//! [`Coordinator`] *on* that device's worker thread (N devices
//! calibrate and come up in parallel) and reports readiness (or the
//! load error) back before `start_fleet` returns. The catalog manifest
//! is parsed once and shared across the per-device runtimes. Each
//! worker runs the drain-and-group scheduler
//! (`Coordinator::serve_batched`) over its own plan cache, so
//! concurrent submissions sharing a `(seq, padded size, device, plan)`
//! key execute as one batch on one device.
//!
//! Unpinned submissions go through [`CostModel::route`]: predicted
//! seconds of the executed variant on each device's own calibration,
//! scaled by the device's live queue depth — the argmin wins. Pinned
//! submissions bypass the router entirely, which is what makes them
//! bit-identical to a single-device engine (`tests/fleet_serving.rs`).

use super::{
    Context, Control, Coordinator, Metrics, Msg, PlanChoice, Reply, Request, RequestInputs,
    ServeError,
};
use crate::fleet::{CostModel, DeviceId, DeviceRegistry, RoutingStats};
use crate::fusion::space::Space;
use crate::fusion::ImplAxes;
use crate::ir::elem::ProblemSize;
use crate::ir::program::Program;
use crate::pipelines;
use crate::planner::{self, PlannerConfig};
use crate::runtime::{RunResult, Runtime, Tensor};
use crate::sequences;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Scheduler knobs of one engine (shared by every fleet worker).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// How long a scheduling turn keeps collecting requests after the
    /// first one arrives. Zero means pure drain: whatever is already
    /// queued groups, nothing waits.
    pub batch_window: Duration,
    /// Cap on requests drained per scheduling turn.
    pub max_batch: usize,
    /// How long the submitting side waits for a worker's `PlanShard`
    /// chunk reply in a sharded search before it re-plans that chunk
    /// locally. The fallback is bit-identical — planning is a pure
    /// function of (key, calibration) — so a busy, wedged or dead
    /// worker costs latency, never a different answer. `ZERO` forces
    /// every chunk local (useful in tests).
    pub shard_deadline: Duration,
    /// How long a cold-key submit waits for the workers' `Forecast`
    /// replies before scoring that device with a locally-computed
    /// (bit-identical) forecast. Deliberately much shorter than
    /// [`EngineConfig::shard_deadline`], because the local fallback
    /// costs only milliseconds: this value *bounds* the cold-key stall
    /// a fully busy fleet can add to a submit (idle workers answer far
    /// sooner). Set it near zero to always plan cold keys locally —
    /// the scattered `Forecast` still seeds each worker's plan cache
    /// whenever the worker drains it, waited-for or not.
    pub forecast_deadline: Duration,
    /// Admission-control bound on a device's in-flight requests
    /// (submitted, not yet answered). A best-effort submit beyond the
    /// cap is refused with [`ServeError::QueueFull`] instead of
    /// queueing unboundedly; with the default empty
    /// [`EngineConfig::priority_caps`], nonzero-priority submits get 2×
    /// headroom, so load shedding hits best-effort traffic first.
    /// `usize::MAX` (the default) disables shedding.
    pub queue_cap: usize,
    /// Explicit per-priority admission caps, replacing the blanket 2×
    /// headroom rule: entry `i` is the in-flight cap applied to
    /// priority-`i` submissions (the last entry covers every higher
    /// priority). Empty (the default) keeps the legacy derivation from
    /// [`EngineConfig::queue_cap`]: best-effort gets `queue_cap`, any
    /// nonzero priority 2×. Sheds are counted per priority either way
    /// ([`Metrics::queue_sheds_by_priority`]).
    pub priority_caps: Vec<usize>,
    /// Cap on user pipelines concurrently registered per worker
    /// ([`Client::register_pipeline`]); a registration beyond it is
    /// refused with [`ServeError::PipelineQuota`].
    pub pipeline_quota: usize,
    /// EDF slack: the per-request deadline budget reserved for dispatch
    /// and execution. Batch formation stops collecting once the most
    /// urgent in-hand request is within this slack of its deadline —
    /// shipping *at* the deadline would already be too late.
    pub deadline_slack: Duration,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            batch_window: Duration::ZERO,
            max_batch: 256,
            shard_deadline: Duration::from_secs(5),
            forecast_deadline: Duration::from_secs(1),
            queue_cap: usize::MAX,
            priority_caps: Vec::new(),
            pipeline_quota: Coordinator::DEFAULT_PIPELINE_QUOTA,
            deadline_slack: Duration::from_millis(5),
        }
    }
}

/// Builder for one execution request. Defaults: deterministic synthetic
/// inputs (seed 0), the coordinator's plan cache deciding the variant,
/// and the fleet router deciding the device.
pub struct SubmitRequest {
    seq: String,
    m: usize,
    n: usize,
    inputs: RequestInputs,
    variant: Option<PlanChoice>,
    device: Option<String>,
    deadline: Option<Duration>,
    priority: u8,
}

impl SubmitRequest {
    pub fn new(seq: impl Into<String>, m: usize, n: usize) -> SubmitRequest {
        SubmitRequest {
            seq: seq.into(),
            m,
            n,
            inputs: RequestInputs::Synth { seed: 0 },
            variant: None,
            device: None,
            deadline: None,
            priority: 0,
        }
    }

    /// Use deterministic synthetic inputs from `seed` (generated on the
    /// worker — producers never touch the thread-bound runtime).
    pub fn synth(mut self, seed: u64) -> SubmitRequest {
        self.inputs = RequestInputs::Synth { seed };
        self
    }

    /// Use explicit named input tensors.
    pub fn inputs(mut self, inputs: BTreeMap<String, Tensor>) -> SubmitRequest {
        self.inputs = RequestInputs::Explicit(inputs);
        self
    }

    /// Force a plan variant instead of letting the plan cache decide.
    pub fn variant(mut self, v: PlanChoice) -> SubmitRequest {
        self.variant = Some(v);
        self
    }

    /// Pin the request to a registered device (by exact name),
    /// bypassing the router. Pinned execution is bit-identical to a
    /// single-device engine; an unknown name fails the submit.
    pub fn pin(mut self, device: impl Into<String>) -> SubmitRequest {
        self.device = Some(device.into());
        self
    }

    /// Attach a completion deadline, relative to submission. The
    /// scheduler ships the request without waiting out the batch window
    /// once the deadline (less [`EngineConfig::deadline_slack`]) nears,
    /// and sheds it with [`ServeError::DeadlineExpired`] if it is still
    /// queued when the deadline passes. The resulting SLO accounting
    /// lands in [`Metrics::slo_misses`]/[`Metrics::deadline_requests`].
    pub fn deadline(mut self, d: Duration) -> SubmitRequest {
        self.deadline = Some(d);
        self
    }

    /// Scheduling priority (default 0 = best effort): higher executes
    /// earlier among a turn's batches after deadline order, and gets
    /// more admission-control headroom (2× by default, or the class's
    /// [`EngineConfig::priority_caps`] entry) so overload sheds
    /// best-effort traffic first.
    pub fn priority(mut self, p: u8) -> SubmitRequest {
        self.priority = p;
        self
    }
}

/// Reply handle for one submitted request.
pub struct Ticket<T> {
    rx: mpsc::Receiver<Result<T>>,
}

impl<T> Ticket<T> {
    /// Block until the result arrives. If the engine shuts down with the
    /// request still in flight, this returns an error instead of
    /// hanging.
    pub fn wait(self) -> Result<T> {
        match self.rx.recv() {
            Ok(res) => res,
            Err(_) => Err(anyhow!("engine dropped the request (shut down mid-flight)")),
        }
    }

    /// Non-blocking poll: `None` while the request is still pending.
    pub fn try_wait(&self) -> Option<Result<T>> {
        match self.rx.try_recv() {
            Ok(res) => Some(res),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow!("engine dropped the request (shut down mid-flight)")))
            }
        }
    }
}

/// Routing state shared by the engine handle and every [`Client`]
/// clone: the cost model (which owns the registry) and the live
/// per-device queue depths (incremented at submit, decremented when a
/// reply leaves its worker). The request senders themselves are *not*
/// shared — each handle owns its own `mpsc::Sender` clones.
struct Shared {
    model: CostModel,
    depths: Vec<Arc<AtomicU64>>,
    /// Per-device admission-control shed counters. Engine-side — a shed
    /// request never reaches a worker — and overlaid onto the device's
    /// [`Metrics`] snapshot when metrics are collected.
    sheds: Vec<AtomicU64>,
    /// Per-device sheds split by request priority (same engine-side
    /// overlay; decomposes `sheds`). A Mutex'd map per device is fine:
    /// sheds are the refusal path, not the hot path.
    priority_sheds: Vec<Mutex<BTreeMap<u8, u64>>>,
    /// Best-effort in-flight cap per device
    /// ([`EngineConfig::queue_cap`]); see [`Shared::cap_for`].
    queue_cap: u64,
    /// Explicit per-priority caps ([`EngineConfig::priority_caps`]);
    /// empty = derive from `queue_cap` (legacy 2× headroom).
    priority_caps: Vec<u64>,
    /// Submitter-side wait bound for `PlanShard` chunk replies
    /// ([`EngineConfig::shard_deadline`]).
    deadline: Duration,
    /// Submitter-side wait bound for cold-key `Forecast` replies
    /// ([`EngineConfig::forecast_deadline`]).
    forecast_deadline: Duration,
    /// Sequence name → its (program, built optimization space), shared
    /// by every client clone. Sharded searches of the same sequence
    /// skip fusion enumeration and space construction on the
    /// submitting thread — the workers keep the equivalent per-worker
    /// cache. Keyed by validated sequence names only (a closed set),
    /// so no eviction is needed.
    spaces: Mutex<BTreeMap<String, Arc<(Program, Space)>>>,
}

impl Shared {
    /// Point-in-time queue depths, parallel to registry indices.
    fn snapshot(&self) -> Vec<u64> {
        self.depths.iter().map(|d| d.load(Ordering::Relaxed)).collect()
    }

    /// The admission cap applied to one priority class: the explicit
    /// per-priority table when configured (its last entry covers every
    /// higher priority), else the legacy derivation — best-effort gets
    /// `queue_cap`, any nonzero priority 2×.
    fn cap_for(&self, priority: u8) -> u64 {
        match self.priority_caps.last() {
            None => {
                if priority > 0 {
                    self.queue_cap.saturating_mul(2)
                } else {
                    self.queue_cap
                }
            }
            Some(&last) => *self
                .priority_caps
                .get(priority as usize)
                .unwrap_or(&last),
        }
    }

    /// Lane index for a request: the pin when present (an unknown name
    /// is an error, not a silent reroute), otherwise the router's
    /// argmin — short-circuited on one-device fleets so the
    /// single-device serve path never pays a forecast. `lanes` are the
    /// caller's request senders: a cold key's forecasts run *on* the
    /// workers behind them (seeding their plan caches), not here on the
    /// submitting thread.
    fn lane_for(
        &self,
        pin: Option<&str>,
        seq: &str,
        m: usize,
        n: usize,
        lanes: &[mpsc::Sender<Msg>],
    ) -> Result<usize> {
        match pin {
            Some(name) => match self.model.registry().find(name) {
                Some(id) => Ok(id.index()),
                None => Err(anyhow!(
                    "unknown device '{name}' (registered: {})",
                    self.model
                        .registry()
                        .ids()
                        .iter()
                        .map(DeviceId::name)
                        .collect::<Vec<_>>()
                        .join(", ")
                )),
            },
            None if self.depths.len() == 1 => Ok(0),
            None => Ok(self.model.route_via(
                seq,
                m,
                n,
                &self.snapshot(),
                Some((lanes, self.forecast_deadline)),
            )),
        }
    }
}

/// Cloneable, `Send` submission handle to a running [`Engine`]. Routing
/// happens here, on the submitting thread: the worker a request lands
/// on is decided before it is enqueued.
#[derive(Clone)]
pub struct Client {
    shared: Arc<Shared>,
    txs: Vec<mpsc::Sender<Msg>>,
}

impl Client {
    /// Enqueue a request; the returned [`Ticket`] resolves to the run
    /// result. Fails when the engine is already shut down, the pin
    /// names an unregistered device, or admission control sheds the
    /// request ([`ServeError::QueueFull`] — the routed device's
    /// in-flight queue is at capacity).
    pub fn submit(&self, req: SubmitRequest) -> Result<Ticket<RunResult>> {
        let lane = self
            .shared
            .lane_for(req.device.as_deref(), &req.seq, req.m, req.n, &self.txs)?;
        let depth = &self.shared.depths[lane];
        // Priority classes get their own caps (explicit table, or the
        // legacy 2×-headroom derivation), so overload sheds best-effort
        // submissions first.
        let cap = self.shared.cap_for(req.priority);
        let (reply, rx) = mpsc::channel();
        // Count the request before sending so a racing router on
        // another thread sees it; undo on shed. (A concurrent burst can
        // transiently overshoot the cap by the number of racing
        // submitters — admission control bounds the queue, it does not
        // serialize submits.)
        let prev = depth.fetch_add(1, Ordering::Relaxed);
        if prev >= cap {
            depth.fetch_sub(1, Ordering::Relaxed);
            self.shared.sheds[lane].fetch_add(1, Ordering::Relaxed);
            *self.shared.priority_sheds[lane]
                .lock()
                .unwrap()
                .entry(req.priority)
                .or_insert(0) += 1;
            return Err(anyhow::Error::new(ServeError::QueueFull {
                depth: prev,
                cap,
            }));
        }
        let enqueued = Instant::now();
        let sent = self.txs[lane].send(Msg::Run(Request {
            seq: req.seq,
            m: req.m,
            n: req.n,
            inputs: req.inputs,
            variant: req.variant,
            enqueued,
            deadline: req.deadline.map(|d| enqueued + d),
            priority: req.priority,
            reply: Reply::new(reply, Some(depth.clone())),
        }));
        if sent.is_err() {
            // The failed send handed the request back, and its dropped
            // Reply already released the depth slot (decrement-on-drop)
            // — no manual undo here, that would double-count.
            return Err(anyhow!("engine is shut down"));
        }
        Ok(Ticket { rx })
    }

    /// Live per-device in-flight queue depths, in registry order — the
    /// router's backlog view. Every submitted request releases its slot
    /// on *any* terminal outcome (reply, failure, shed, shutdown), so
    /// once all tickets resolve the depths are zero again.
    pub fn queue_depths(&self) -> Vec<u64> {
        self.shared.snapshot()
    }

    /// Resolve (and cache) the plan for a `(seq, m, n)` key without
    /// executing anything — the planner runs on the worker of the
    /// device the router prefers for the key *at steady state* (empty
    /// queues), so the pre-warm lands where unforced submissions of the
    /// same key settle once transient backlogs drain, not wherever a
    /// momentary spike happens to point. Blocks until the worker picks
    /// the query up.
    pub fn plan(&self, seq: &str, m: usize, n: usize) -> Result<PlanChoice> {
        let lane = self.steady_state_lane(seq, m, n);
        let (reply, rx) = mpsc::channel();
        self.txs[lane]
            .send(Msg::Control(Control::Plan {
                seq: seq.to_string(),
                m,
                n,
                reply,
            }))
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv()
            .unwrap_or_else(|_| Err(anyhow!("engine dropped the request (shut down mid-flight)")))
    }

    /// The registered device identities, in routing (registry) order.
    pub fn devices(&self) -> Vec<DeviceId> {
        self.shared.model.registry().ids()
    }

    /// Submitting-side routing counters: cold keys seen, forecasts
    /// served by workers vs computed locally (the fallback). The
    /// cold-key regression test pins `local_forecasts == 0` on the
    /// routed path — planning must stay off the submitting thread.
    pub fn routing_stats(&self) -> RoutingStats {
        self.shared.model.stats()
    }

    /// The device the router would pick for this key at steady state
    /// (empty queues) — where unforced submissions of the key settle
    /// once transient backlogs drain.
    fn steady_state_lane(&self, seq: &str, m: usize, n: usize) -> usize {
        if self.txs.len() == 1 {
            0
        } else {
            self.shared.model.route_via(
                seq,
                m,
                n,
                &vec![0; self.txs.len()],
                Some((&self.txs, self.shared.forecast_deadline)),
            )
        }
    }

    /// Run the pruned planner for `(seq, m, n)` with its partition
    /// range sharded into `k` chunks scattered across the fleet's
    /// workers — idle lanes first — and merged here. The merged result
    /// is **bit-identical** to unsharded
    /// [`planner::plan_space`] on the same device's calibration (see
    /// [`crate::planner::shard`]); chunks whose worker is busy past
    /// [`EngineConfig::shard_deadline`], gone, or answering with an
    /// error are re-planned locally, so degraded fleets cost latency,
    /// never correctness — and never a partial merge.
    ///
    /// `device` pins whose calibration the search runs against (by
    /// registered name); `None` uses the steady-state routed device for
    /// the key — note that routing a *cold* key scatters the usual
    /// `Forecast` queries, which seed worker plan caches like any
    /// routed submission would. The search itself is pure: nothing
    /// executes, no plan cache is consulted, and its answer is
    /// returned, not retained.
    pub fn search_sharded(
        &self,
        seq: &str,
        m: usize,
        n: usize,
        k: usize,
        device: Option<&str>,
    ) -> Result<planner::Planned> {
        self.search_sharded_inner(seq, m, n, Some(k), device)
    }

    /// [`Client::search_sharded`] with the shard count derived from
    /// live fleet state instead of chosen by the caller: one chunk per
    /// currently-idle lane (at least one), capped by the space's
    /// partition count — an idle fleet fans the search out wide, a
    /// saturated fleet collapses to a single chunk on the shallowest
    /// lane rather than queueing chunk work behind serving traffic.
    pub fn search_sharded_auto(
        &self,
        seq: &str,
        m: usize,
        n: usize,
        device: Option<&str>,
    ) -> Result<planner::Planned> {
        self.search_sharded_inner(seq, m, n, None, device)
    }

    fn search_sharded_inner(
        &self,
        seq: &str,
        m: usize,
        n: usize,
        k: Option<usize>,
        device: Option<&str>,
    ) -> Result<planner::Planned> {
        let registry = self.shared.model.registry().clone();
        let target = match device {
            Some(name) => registry
                .find(name)
                .ok_or_else(|| anyhow!("unknown device '{name}'"))?
                .index(),
            None => self.steady_state_lane(seq, m, n),
        };
        let db = registry.context(target).db.clone();
        // Build (or reuse) the sequence's space: deterministic per
        // name, so every client clone shares one construction. Built
        // outside the lock — a racing duplicate build keeps the first
        // insert and both are identical anyway. Registered pipelines
        // published their space here at registration time, so a cache
        // miss that also fails the built-in lookup is an unknown name.
        let cached = self.shared.spaces.lock().unwrap().get(seq).cloned();
        let entry = match cached {
            Some(e) => e,
            None => {
                let sq = sequences::by_name(seq)
                    .ok_or_else(|| anyhow!("unknown sequence '{seq}'"))?;
                let (prog, _graph, space) = sq.space(registry.library(), &ImplAxes::minimal());
                let built = Arc::new((prog, space));
                self.shared
                    .spaces
                    .lock()
                    .unwrap()
                    .entry(seq.to_string())
                    .or_insert(built)
                    .clone()
            }
        };
        let (prog, space) = (&entry.0, &entry.1);
        let p = ProblemSize::new(m, n).padded();
        let cfg = PlannerConfig::default();

        // Scatter: chunks round-robin over lanes ordered shallowest
        // queue first (stable on ties → deterministic), all sends
        // before any gather so workers overlap.
        let depths = self.shared.snapshot();
        let mut order: Vec<usize> = (0..self.txs.len()).collect();
        order.sort_by_key(|&i| depths[i]);
        // Adaptive shard count: one chunk per idle lane, bounded by the
        // partition count (an explicit `k` skips the adaptation).
        let k = k.unwrap_or_else(|| {
            let idle = depths.iter().filter(|&&d| d == 0).count().max(1);
            idle.min(space.partitions.len()).max(1)
        });
        let ranges = planner::chunk_ranges(space.partitions.len(), k);
        let pending: Vec<_> = ranges
            .into_iter()
            .enumerate()
            .map(|(j, r)| {
                let lane = order[j % order.len()];
                let (reply, rx) = mpsc::channel();
                let sent = self.txs[lane]
                    .send(Msg::Control(Control::PlanShard {
                        seq: seq.to_string(),
                        m: p.m,
                        n: p.n,
                        range: r.clone(),
                        db: db.clone(),
                        reply,
                    }))
                    .is_ok();
                (r, sent.then_some(rx))
            })
            .collect();

        // Gather under one overall deadline; any lost, late or failed
        // chunk is evaluated locally (pure function — identical bits).
        let by = Instant::now() + self.shared.deadline;
        let chunks = pending
            .into_iter()
            .map(|(r, rx)| {
                let served = rx
                    .and_then(|rx| {
                        rx.recv_timeout(by.saturating_duration_since(Instant::now())).ok()
                    })
                    .and_then(|res| res.ok())
                    .filter(|c: &planner::ShardEval| c.range == r);
                served.unwrap_or_else(|| planner::shard::eval_chunk(space, &db, p, &cfg, r))
            })
            .collect();
        Ok(planner::shard::merge(prog, space, chunks))
    }

    /// Register a user-defined script pipeline fleet-wide and return
    /// its content fingerprint. The source is compiled *on every
    /// worker* (script → typecheck → IR → fusion space → planner inputs
    /// → codegen) and the name only becomes routable once all of them
    /// acked the same fingerprint — a partial registration (a worker
    /// rejecting, dying, or disagreeing) is rolled back from the
    /// workers that accepted, and the first error is returned.
    ///
    /// Typed rejections ([`ServeError`]): `InvalidScript` (the script
    /// fails to compile — checked client-side before any worker sees
    /// it), `DuplicatePipeline` (the name collides with a built-in, or
    /// with a registered pipeline of *different* source; identical
    /// source is an idempotent dedup that returns the existing
    /// fingerprint), `PipelineQuota` (a worker's dynamic catalog is
    /// full). After success the pipeline is a first-class sequence:
    /// submits route to it, plan/resolve caches apply, and
    /// [`Client::search_sharded`] shards its space.
    pub fn register_pipeline(&self, name: &str, src: &str) -> Result<u64> {
        // Client-side prechecks, so the common rejections never cost a
        // control-plane round trip: built-in names are never
        // shadowable, and the routable roster already knows whether
        // this name is taken (and with what content).
        if sequences::by_name(name).is_some() {
            return Err(anyhow::Error::new(ServeError::DuplicatePipeline {
                name: name.to_string(),
            }));
        }
        let lib = self.shared.model.registry().library();
        let fp = pipelines::fingerprint(src, lib);
        if let Some(existing) = self.shared.model.pipeline_fingerprint(name) {
            if existing == fp {
                return Ok(fp);
            }
            return Err(anyhow::Error::new(ServeError::DuplicatePipeline {
                name: name.to_string(),
            }));
        }
        // Compile locally once: an invalid script is rejected typed
        // without perturbing any worker, and the compiled planning
        // inputs feed the router roster after the fleet agrees.
        let compiled = pipelines::compile(name, src, lib).map_err(|e| {
            anyhow::Error::new(ServeError::InvalidScript {
                line: e.line,
                msg: e.msg,
            })
        })?;
        debug_assert_eq!(compiled.pipeline.fingerprint, fp);
        // Scatter to every worker before gathering any reply, so the
        // compiles overlap.
        let pending: Vec<_> = self
            .txs
            .iter()
            .map(|tx| {
                let (reply, rx) = mpsc::channel();
                let sent = tx
                    .send(Msg::Control(Control::RegisterPipeline {
                        name: name.to_string(),
                        src: src.to_string(),
                        reply,
                    }))
                    .is_ok();
                sent.then_some(rx)
            })
            .collect();
        let mut failure: Option<anyhow::Error> = None;
        let mut acked: Vec<usize> = Vec::with_capacity(pending.len());
        for (i, rx) in pending.into_iter().enumerate() {
            let res = match rx {
                Some(rx) => rx
                    .recv()
                    .unwrap_or_else(|_| Err(anyhow!("a worker died during registration"))),
                None => Err(anyhow!("engine is shut down")),
            };
            match res {
                Ok(wfp) if wfp == fp => acked.push(i),
                Ok(wfp) => {
                    if failure.is_none() {
                        failure = Some(anyhow!(
                            "pipeline '{name}': worker {i} compiled fingerprint \
                             {wfp:#018x}, submitter computed {fp:#018x}"
                        ));
                    }
                }
                Err(e) => {
                    if failure.is_none() {
                        failure = Some(e);
                    }
                }
            }
        }
        if let Some(e) = failure {
            // All-or-nothing: roll the acked workers back so a partial
            // registration never leaves the fleet disagreeing on what
            // the name means. Only the lanes that *just* accepted are
            // touched — a pre-existing same-name pipeline on other
            // lanes (the degraded case this guards) stays as it was.
            for i in acked {
                let (reply, rx) = mpsc::channel();
                if self.txs[i]
                    .send(Msg::Control(Control::UnregisterPipeline {
                        name: name.to_string(),
                        reply,
                    }))
                    .is_ok()
                {
                    let _ = rx.recv();
                }
            }
            return Err(e);
        }
        // Every worker agreed: publish the name to the router roster
        // and the shared space cache, making it routable + shardable.
        self.shared.model.register_pipeline(&compiled);
        self.shared.spaces.lock().unwrap().insert(
            name.to_string(),
            Arc::new((compiled.pipeline.program.clone(), compiled.space)),
        );
        Ok(fp)
    }

    /// Remove a registered pipeline fleet-wide (workers, router roster,
    /// shared space cache). Returns whether any worker had it; removing
    /// an unknown name is a no-op. Built-ins cannot be removed — their
    /// names never enter the dynamic catalog.
    pub fn unregister_pipeline(&self, name: &str) -> bool {
        let pending: Vec<_> = self
            .txs
            .iter()
            .map(|tx| {
                let (reply, rx) = mpsc::channel();
                let sent = tx
                    .send(Msg::Control(Control::UnregisterPipeline {
                        name: name.to_string(),
                        reply,
                    }))
                    .is_ok();
                sent.then_some(rx)
            })
            .collect();
        let mut any = false;
        for rx in pending.into_iter().flatten() {
            any |= rx.recv().unwrap_or(false);
        }
        self.shared.model.unregister_pipeline(name);
        self.shared.spaces.lock().unwrap().remove(name);
        any
    }
}

/// Final or point-in-time metrics of a fleet: one [`Metrics`] per
/// device, in registry order, plus the aggregate view.
pub struct FleetMetrics {
    pub devices: Vec<(DeviceId, Metrics)>,
}

impl FleetMetrics {
    /// Fold every device's metrics into one (counters add, batch maxima
    /// take the max, distributions merge).
    pub fn aggregate(&self) -> Metrics {
        let mut total = Metrics::default();
        for (_, m) in &self.devices {
            total.merge(m);
        }
        total
    }
}

/// Owns the serving fleet: per-device coordinator construction, the
/// request lanes, and shutdown. Dropping the engine without calling
/// [`Engine::shutdown`] still stops and joins every worker.
pub struct Engine {
    shared: Arc<Shared>,
    txs: Vec<mpsc::Sender<Msg>>,
    ids: Vec<DeviceId>,
    workers: Vec<Option<JoinHandle<Metrics>>>,
}

impl Engine {
    /// Start a single-device engine with the default scheduler
    /// configuration.
    ///
    /// The context decides its own calibration-cache location; when
    /// serving a non-default catalog directory, build it with
    /// `Context::with_calibration_cache(artifacts_dir)` so the cache
    /// lives next to the artifacts it belongs to.
    pub fn start(ctx: Arc<Context>, artifacts_dir: &Path) -> Result<Engine> {
        Self::with_config(ctx, artifacts_dir, EngineConfig::default())
    }

    /// Start a single-device engine: the context is wrapped in a
    /// one-slot registry (no recalibration), so the serve path is the
    /// fleet path with the router short-circuited.
    pub fn with_config(
        ctx: Arc<Context>,
        artifacts_dir: &Path,
        cfg: EngineConfig,
    ) -> Result<Engine> {
        let registry = Arc::new(DeviceRegistry::from_context(ctx, artifacts_dir));
        Self::start_fleet(registry, artifacts_dir, cfg)
    }

    /// Start one worker per registered device: each spawns, builds its
    /// own coordinator there (the PJRT client is `!Send`; the parsed
    /// manifest is shared), loads or runs its device's calibration, and
    /// reports readiness. All workers must come up — any load error
    /// shuts the rest down and surfaces here instead of on the first
    /// submit.
    pub fn start_fleet(
        registry: Arc<DeviceRegistry>,
        artifacts_dir: &Path,
        cfg: EngineConfig,
    ) -> Result<Engine> {
        let manifest = Runtime::load_manifest(artifacts_dir)?;
        let ids = registry.ids();
        let mut txs = Vec::with_capacity(registry.len());
        let mut depths = Vec::with_capacity(registry.len());
        let mut workers = Vec::with_capacity(registry.len());
        let mut readies = Vec::with_capacity(registry.len());
        for i in 0..registry.len() {
            let (tx, rx) = mpsc::channel();
            let (ready_tx, ready_rx) = mpsc::channel();
            let reg = registry.clone();
            let man = manifest.clone();
            let cfg = cfg.clone();
            let worker = std::thread::Builder::new()
                .name(format!("fusebla-dev{i}"))
                .spawn(move || {
                    let coord = match Coordinator::with_manifest(reg.context(i), man) {
                        Ok(c) => {
                            let _ = ready_tx.send(Ok(()));
                            c
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return Metrics::default();
                        }
                    };
                    coord.serve_batched(rx, &cfg)
                })
                .expect("spawning a fleet worker thread");
            txs.push(tx);
            depths.push(Arc::new(AtomicU64::new(0)));
            workers.push(Some(worker));
            readies.push(ready_rx);
        }
        let mut failure = None;
        for ready in readies {
            match ready.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => failure = Some(e),
                Err(_) => failure = Some(anyhow!("a fleet worker died during startup")),
            }
        }
        if let Some(e) = failure {
            for tx in &txs {
                let _ = tx.send(Msg::Control(Control::Shutdown));
            }
            for w in workers.into_iter().flatten() {
                let _ = w.join();
            }
            return Err(e);
        }
        let sheds = (0..depths.len()).map(|_| AtomicU64::new(0)).collect();
        let priority_sheds = (0..depths.len())
            .map(|_| Mutex::new(BTreeMap::new()))
            .collect();
        Ok(Engine {
            shared: Arc::new(Shared {
                model: CostModel::new(registry),
                depths,
                sheds,
                priority_sheds,
                queue_cap: cfg.queue_cap as u64,
                priority_caps: cfg.priority_caps.iter().map(|&c| c as u64).collect(),
                deadline: cfg.shard_deadline,
                forecast_deadline: cfg.forecast_deadline,
                spaces: Mutex::new(BTreeMap::new()),
            }),
            txs,
            ids,
            workers,
        })
    }

    /// A new submission handle (cheap; clone freely across threads).
    pub fn client(&self) -> Client {
        Client {
            shared: self.shared.clone(),
            txs: self.txs.clone(),
        }
    }

    /// The registered device identities, in registry order.
    pub fn devices(&self) -> &[DeviceId] {
        &self.ids
    }

    /// Aggregated point-in-time metrics snapshot without shutting down
    /// (the single-device view; see [`Engine::fleet_metrics`] for the
    /// per-device breakdown). Blocks until each worker reaches the
    /// query in its queue (they answer between scheduling turns).
    pub fn metrics(&self) -> Metrics {
        self.fleet_metrics().aggregate()
    }

    /// Per-device point-in-time metrics snapshot, in registry order.
    /// The query fans out to every worker before any reply is awaited,
    /// so the snapshot waits for the slowest single turn, not the sum
    /// of all turns. Admission-control sheds are counted engine-side (a
    /// shed request never reaches a worker) and overlaid here.
    pub fn fleet_metrics(&self) -> FleetMetrics {
        let replies: Vec<Option<mpsc::Receiver<Metrics>>> = self
            .txs
            .iter()
            .map(|tx| {
                let (reply, rx) = mpsc::channel();
                tx.send(Msg::Control(Control::Metrics(reply))).ok().map(|_| rx)
            })
            .collect();
        let devices = self
            .ids
            .iter()
            .cloned()
            .zip(replies.into_iter().enumerate().map(|(i, rx)| {
                let mut m = match rx {
                    Some(rx) => rx.recv().unwrap_or_default(),
                    None => Metrics::default(),
                };
                m.queue_sheds = self.shared.sheds[i].load(Ordering::Relaxed);
                m.queue_sheds_by_priority =
                    self.shared.priority_sheds[i].lock().unwrap().clone();
                m
            }))
            .collect();
        FleetMetrics { devices }
    }

    /// Stop every worker after it finishes everything submitted before
    /// this call, and return the aggregated final metrics. A shutdown
    /// sentinel (not channel disconnection) stops each loop, so
    /// outstanding [`Client`] clones cannot keep the engine alive;
    /// their later submissions fail, and tickets for requests enqueued
    /// after the sentinel resolve to an error instead of hanging.
    pub fn shutdown(self) -> Metrics {
        self.shutdown_fleet().aggregate()
    }

    /// [`Engine::shutdown`] with the per-device breakdown preserved.
    /// Engine-side shed counters are overlaid like in
    /// [`Engine::fleet_metrics`].
    pub fn shutdown_fleet(mut self) -> FleetMetrics {
        for tx in &self.txs {
            let _ = tx.send(Msg::Control(Control::Shutdown));
        }
        let shared = self.shared.clone();
        let devices = self
            .ids
            .iter()
            .cloned()
            .zip(self.workers.iter_mut().enumerate().map(|(i, w)| {
                let mut m = match w.take() {
                    Some(w) => w.join().expect("fleet worker panicked"),
                    None => Metrics::default(),
                };
                m.queue_sheds = shared.sheds[i].load(Ordering::Relaxed);
                m.queue_sheds_by_priority = shared.priority_sheds[i].lock().unwrap().clone();
                m
            }))
            .collect();
        FleetMetrics { devices }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Msg::Control(Control::Shutdown));
        }
        for w in self.workers.iter_mut().filter_map(Option::take) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::stub_catalog;
    use super::*;
    use crate::sim::DeviceModel;

    /// Stub catalog with parseable HLO stubs: planning and scheduling
    /// work end-to-end; only the final PJRT `compile` fails on the
    /// offline stub backend — which is exactly what lets these tests
    /// run without built artifacts.
    fn stub_dir(tag: &str) -> std::path::PathBuf {
        stub_catalog(&format!("engine_{tag}"), &["waxpby", "vadd"], true)
    }

    /// GTX 480 + GT 430 fleet over a stub catalog (the calibration
    /// files land in the stub dir, wiped with it).
    fn stub_fleet(tag: &str, cfg: EngineConfig) -> (std::path::PathBuf, Engine) {
        let dir = stub_dir(tag);
        let reg = Arc::new(
            DeviceRegistry::new(vec![DeviceModel::gtx480(), DeviceModel::gt430()], &dir).unwrap(),
        );
        let engine = Engine::start_fleet(reg, &dir, cfg).unwrap();
        (dir, engine)
    }

    #[test]
    fn engine_start_fails_cleanly_without_manifest() {
        let dir = std::env::temp_dir().join(format!("fusebla_engine_none_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let err = Engine::start(Arc::new(Context::new()), &dir).err().expect("must fail");
        assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let dir = stub_dir("shutdown");
        let engine = Engine::start(Arc::new(Context::new()), &dir).unwrap();
        let client = engine.client();
        let _ = engine.shutdown();
        assert!(client.submit(SubmitRequest::new("waxpby", 32, 65536)).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn engine_groups_a_burst_and_plans_once_per_key() {
        let dir = stub_dir("burst");
        let cfg = EngineConfig {
            batch_window: Duration::from_millis(300),
            max_batch: 64,
            ..EngineConfig::default()
        };
        let engine = Engine::with_config(Arc::new(Context::new()), &dir, cfg).unwrap();
        let client = engine.client();
        // 6 waxpby + 3 vadd, interleaved, all planner-resolved
        let mut tickets = Vec::new();
        for i in 0..9u64 {
            let seq = if i % 3 == 2 { "vadd" } else { "waxpby" };
            tickets.push(client.submit(SubmitRequest::new(seq, 32, 65536).synth(i)).unwrap());
        }
        // results are stub-backend errors; delivery is what matters here
        for t in tickets {
            assert!(t.wait().is_err());
        }
        // live snapshot before shutdown sees the same totals
        let live = engine.metrics();
        assert_eq!(live.requests, 9);
        let m = engine.shutdown();
        assert_eq!(m.requests, 9);
        assert_eq!(m.batch_size_sum, 9);
        assert_eq!(m.failures, 9, "stub backend fails every execution");
        // two distinct batch keys → exactly two plan-cache misses, ever
        assert_eq!(m.plan_cache_misses, 2);
        // stub backend: every batch's resolve fails at compile; failed
        // resolves are never cached and never pin an executable
        assert_eq!(m.resolve_misses, m.batches);
        assert_eq!(m.resolve_hits, 0);
        assert_eq!(m.executable_compiles, 0);
        assert!(m.batches >= 2, "at least one batch per distinct key");
        assert!(
            m.batches < 9,
            "a same-key burst must group: {} batches for 9 requests",
            m.batches
        );
        assert!(m.max_batch_size >= 2);
        assert!(m.batched_requests >= 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plan_query_resolves_without_executing() {
        let dir = stub_dir("plan");
        let engine = Engine::start(Arc::new(Context::new()), &dir).unwrap();
        let client = engine.client();
        let choice = client.plan("waxpby", 32, 65536).expect("plan");
        let again = client.plan("waxpby", 32, 65536).expect("plan");
        assert_eq!(choice, again);
        let err = client.plan("ghost", 32, 32).err().expect("unknown seq");
        assert!(format!("{err:#}").contains("unknown sequence"), "{err:#}");
        let m = engine.shutdown();
        // plan queries execute nothing and count no requests
        assert_eq!(m.requests, 0);
        assert_eq!(m.batches, 0);
        assert_eq!(m.plan_cache_misses, 1);
        assert_eq!(m.plan_cache_hits, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_sequence_fails_that_request_only() {
        let dir = stub_dir("unknown");
        let cfg = EngineConfig {
            batch_window: Duration::from_millis(100),
            max_batch: 64,
            ..EngineConfig::default()
        };
        let engine = Engine::with_config(Arc::new(Context::new()), &dir, cfg).unwrap();
        let client = engine.client();
        let bad = client.submit(SubmitRequest::new("ghost", 32, 32)).unwrap();
        let good = client
            .submit(SubmitRequest::new("waxpby", 32, 65536).variant(PlanChoice::Fused))
            .unwrap();
        let bad_err = bad.wait().err().expect("ghost must fail");
        assert!(format!("{bad_err:#}").contains("unknown sequence"), "{bad_err:#}");
        // the good request still got scheduled (stub backend error, not
        // a scheduling error)
        let good_err = good.wait().err().expect("stub backend");
        assert!(format!("{good_err:#}").contains("unavailable"), "{good_err:#}");
        let m = engine.shutdown();
        assert_eq!(m.requests, 2);
        assert_eq!(m.failures, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pinned_submissions_land_on_the_pinned_device() {
        let (dir, engine) = stub_fleet("pin", EngineConfig::default());
        let client = engine.client();
        let ids = client.devices();
        assert_eq!(ids.len(), 2);
        // two to the slow device, one to the fast — counts must follow
        // the pins, not the router's preference
        let slow = ids[1].name().to_string();
        let fast = ids[0].name().to_string();
        let tickets = vec![
            client.submit(SubmitRequest::new("waxpby", 32, 65536).pin(&slow)).unwrap(),
            client.submit(SubmitRequest::new("waxpby", 32, 65536).pin(&slow)).unwrap(),
            client.submit(SubmitRequest::new("waxpby", 32, 65536).pin(&fast)).unwrap(),
        ];
        for t in tickets {
            assert!(t.wait().is_err(), "stub backend fails execution");
        }
        let fleet = engine.shutdown_fleet();
        assert_eq!(fleet.devices.len(), 2);
        assert_eq!(fleet.devices[0].1.requests, 1, "fast device got the one pin");
        assert_eq!(fleet.devices[1].1.requests, 2, "slow device got both pins");
        let agg = fleet.aggregate();
        assert_eq!(agg.requests, 3);
        // every dispatched request left one queued-duration sample
        assert_eq!(agg.queued.count(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pinning_an_unknown_device_fails_the_submit() {
        let (dir, engine) = stub_fleet("badpin", EngineConfig::default());
        let client = engine.client();
        let err = client
            .submit(SubmitRequest::new("waxpby", 32, 65536).pin("no such device"))
            .err()
            .expect("unknown pin must fail");
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown device"), "{msg}");
        assert!(msg.contains("GTX 480"), "message lists the roster: {msg}");
        let m = engine.shutdown();
        assert_eq!(m.requests, 0, "nothing was enqueued");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn router_starves_the_slow_device_on_a_small_burst() {
        let (dir, engine) = stub_fleet("route", EngineConfig::default());
        let client = engine.client();
        // GT 430 is ~6× slower on bandwidth-bound keys; a burst smaller
        // than the cost ratio must route entirely to the GTX 480 even
        // with the queue-depth term counting the in-flight requests.
        let tickets: Vec<_> = (0..3u64)
            .map(|i| client.submit(SubmitRequest::new("waxpby", 32, 65536).synth(i)).unwrap())
            .collect();
        for t in tickets {
            assert!(t.wait().is_err(), "stub backend fails execution");
        }
        let fleet = engine.shutdown_fleet();
        assert_eq!(fleet.devices[0].1.requests, 3, "fast device takes the burst");
        assert_eq!(fleet.devices[1].1.requests, 0, "slow device stays idle");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Sharded search through the control plane: different chunkings of
    /// the same key on the same device are bit-identical, the workers
    /// served the chunks, and planning touched no plan cache and
    /// executed nothing.
    #[test]
    fn search_sharded_is_chunking_invariant_and_runs_on_workers() {
        let (dir, engine) = stub_fleet("shard", EngineConfig::default());
        let client = engine.client();
        let device = client.devices()[0].name().to_string();
        let a = client.search_sharded("gemver", 4096, 4096, 1, Some(device.as_str())).unwrap();
        let b = client.search_sharded("gemver", 4096, 4096, 4, Some(device.as_str())).unwrap();
        assert_eq!(a.best.variant, b.best.variant);
        assert_eq!(a.predicted.to_bits(), b.predicted.to_bits());
        assert_eq!(a.stats.combos_evaluated, b.stats.combos_evaluated);
        assert_eq!(a.stats.kernel_evals, b.stats.kernel_evals);
        assert!(client.search_sharded("ghost", 32, 32, 2, None).is_err());
        assert!(client
            .search_sharded("gemver", 4096, 4096, 2, Some("no such device"))
            .is_err());
        let m = engine.shutdown();
        // 1 + 4 chunks scattered; every one was received and served
        assert_eq!(m.shard_requests, 5);
        assert_eq!(m.shard_served, 5);
        assert_eq!(m.requests, 0, "sharded search executes nothing");
        assert_eq!(
            m.plan_cache_misses + m.plan_cache_hits,
            0,
            "sharded search is pure planning — no plan-cache traffic"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Admission control: a best-effort submit beyond `queue_cap` is
    /// refused with a typed `QueueFull`, priority submits get 2×
    /// headroom, and the sheds surface in the per-device snapshot. The
    /// long batch window holds the admitted requests in flight; their
    /// deadlines make the drain loop ship early (EDF), so the test
    /// never waits the window out.
    #[test]
    fn queue_cap_sheds_with_typed_error_and_priority_headroom() {
        let dir = stub_dir("qcap");
        let cfg = EngineConfig {
            batch_window: Duration::from_secs(60),
            queue_cap: 1,
            // ship ~500ms after a deadline-carrying request is in hand,
            // leaving its 60s budget intact — wide enough for the
            // submits below to land while the first is still in flight
            deadline_slack: Duration::from_millis(59_500),
            ..EngineConfig::default()
        };
        let engine = Engine::with_config(Arc::new(Context::new()), &dir, cfg).unwrap();
        let client = engine.client();
        let sub = || SubmitRequest::new("waxpby", 32, 65536).deadline(Duration::from_secs(60));
        let t1 = client.submit(sub()).unwrap();
        // the queue is at cap: best-effort submits shed, typed
        let err = client.submit(sub()).err().expect("must shed");
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::QueueFull { depth, cap }) => assert_eq!((*depth, *cap), (1, 1)),
            other => panic!("expected QueueFull, got {other:?} ({err:#})"),
        }
        // priority headroom: cap doubles, so one more gets in...
        let t2 = client.submit(sub().priority(1)).unwrap();
        // ...and the next priority submit finds 2 >= 2
        let err2 = client.submit(sub().priority(1)).err().expect("priority cap");
        assert!(err2.is::<ServeError>());
        assert!(client.queue_depths().iter().sum::<u64>() <= 2);
        let live = engine.fleet_metrics();
        assert_eq!(live.devices[0].1.queue_sheds, 2);
        // admitted requests complete (stub backend error, not a shed)
        for t in [t1, t2] {
            let res = t.wait();
            let e = res.err().expect("stub backend fails execution");
            assert!(e.downcast_ref::<ServeError>().is_none(), "not shed: {e:#}");
        }
        assert_eq!(client.queue_depths().iter().sum::<u64>(), 0);
        let m = engine.shutdown();
        assert_eq!(m.queue_sheds, 2);
        assert_eq!(m.requests, 2, "shed requests never reach a worker");
        assert_eq!(m.slo_misses, 0, "generous deadlines are met");
        assert_eq!(m.deadline_requests, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Explicit per-priority caps replace the 2×-headroom rule: each
    /// class sheds at its own bound (the table's last entry covering
    /// higher priorities), and sheds are counted per class.
    #[test]
    fn per_priority_queue_caps_shed_by_class() {
        let dir = stub_dir("priocaps");
        let cfg = EngineConfig {
            batch_window: Duration::from_secs(60),
            queue_cap: 1,
            priority_caps: vec![1, 3],
            // hold admitted requests in flight while the rest submit
            deadline_slack: Duration::from_millis(59_500),
            ..EngineConfig::default()
        };
        let engine = Engine::with_config(Arc::new(Context::new()), &dir, cfg).unwrap();
        let client = engine.client();
        let sub = || SubmitRequest::new("waxpby", 32, 65536).deadline(Duration::from_secs(60));
        let t1 = client.submit(sub()).unwrap(); // p0: depth 0 < cap 1
        let e0 = client.submit(sub()).err().expect("p0 must shed at its cap");
        match e0.downcast_ref::<ServeError>() {
            Some(ServeError::QueueFull { depth: 1, cap: 1 }) => {}
            other => panic!("expected QueueFull(1,1), got {other:?} ({e0:#})"),
        }
        let t2 = client.submit(sub().priority(1)).unwrap(); // depth 1 < cap 3
        let t3 = client.submit(sub().priority(1)).unwrap(); // depth 2 < cap 3
        let e1 = client.submit(sub().priority(1)).err().expect("p1 cap");
        assert!(e1.is::<ServeError>());
        // priorities past the table's end use its last entry
        let e5 = client.submit(sub().priority(5)).err().expect("p5 uses last cap");
        assert!(e5.is::<ServeError>());
        let live = engine.fleet_metrics();
        assert_eq!(live.devices[0].1.queue_sheds, 3);
        let by_prio = &live.devices[0].1.queue_sheds_by_priority;
        assert_eq!(by_prio.get(&0), Some(&1));
        assert_eq!(by_prio.get(&1), Some(&1));
        assert_eq!(by_prio.get(&5), Some(&1));
        for t in [t1, t2, t3] {
            let _ = t.wait();
        }
        let m = engine.shutdown();
        assert_eq!(m.queue_sheds, 3);
        assert_eq!(m.queue_sheds_by_priority.values().sum::<u64>(), 3);
        assert_eq!(m.requests, 3, "shed requests never reach a worker");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Fleet-wide pipeline registration: every worker compiles and
    /// acks, the name becomes routable and executable (interpreter
    /// backend succeeds even on the stub), re-registration of identical
    /// source dedups, an invalid script is rejected typed before any
    /// worker sees it, and the registered space shards.
    #[test]
    fn register_pipeline_fans_out_and_serves() {
        let (dir, engine) = stub_fleet("pipereg", EngineConfig::default());
        let client = engine.client();
        let fp = client
            .register_pipeline("amx", pipelines::examples::ADD_MUL_EXP)
            .unwrap();
        assert_ne!(fp, 0);
        // identical source: idempotent dedup, same fingerprint
        assert_eq!(
            client
                .register_pipeline("amx", pipelines::examples::ADD_MUL_EXP)
                .unwrap(),
            fp
        );
        // invalid script: typed, client-side, no worker perturbed
        let err = client.register_pipeline("bad", "return z;").err().expect("invalid");
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::InvalidScript { .. })
        ));
        // built-in collision: typed duplicate
        let err = client
            .register_pipeline("waxpby", pipelines::examples::ADD_MUL_EXP)
            .err()
            .expect("built-in name");
        assert!(matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::DuplicatePipeline { .. })
        ));
        // the registered name executes end to end (routed, interp-backed)
        let t = client.submit(SubmitRequest::new("amx", 32, 256).synth(7)).unwrap();
        let res = t.wait().expect("interp execution succeeds on the stub backend");
        assert!(res.env.contains_key("z"));
        // and its space shards like a built-in's
        let planned = client.search_sharded_auto("amx", 32, 256, None).unwrap();
        assert!(planned.predicted > 0.0);
        assert!(client.search_sharded_auto("ghost", 32, 32, None).is_err());
        let m = engine.shutdown();
        assert_eq!(m.requests, 1);
        assert_eq!(m.failures, 0);
        // one registration per worker; the idempotent re-register and
        // both rejections resolved client-side, before any worker
        assert_eq!(m.pipeline_registrations, 2);
        assert_eq!(m.pipeline_rejections, 0, "rejections were client-side");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fleet_metrics_snapshot_fans_out_per_device() {
        let (dir, engine) = stub_fleet("fanout", EngineConfig::default());
        let client = engine.client();
        let ids = client.devices();
        let t = client
            .submit(SubmitRequest::new("vadd", 32, 65536).pin(ids[1].name()))
            .unwrap();
        let _ = t.wait();
        let live = engine.fleet_metrics();
        assert_eq!(live.devices[0].0.index(), 0);
        assert_eq!(live.devices[1].0.index(), 1);
        assert_eq!(live.devices[0].1.requests, 0);
        assert_eq!(live.devices[1].1.requests, 1);
        assert_eq!(live.aggregate().requests, engine.metrics().requests);
        let _ = engine.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
