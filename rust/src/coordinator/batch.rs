//! Drain-and-group batching: turn a queue of requests into multi-input
//! batches keyed the same way the plan cache is keyed.
//!
//! The batch key is deliberately the same shape as
//! [`PlanKey`](super::PlanKey) — `(seq, tile-padded size, device,
//! resolved plan)` — so one `choose_plan` serves every request in a
//! group (the resolver is memoized per padded key for the turn; the
//! plan cache therefore records exactly one miss per cold batch key).
//! Requests that force a variant skip planning entirely and still group
//! with planner-resolved requests when the choices agree.
//!
//! Artifacts are catalogued at *raw* sizes, so requests whose raw sizes
//! differ but pad identically share planning yet execute as separate
//! dispatches ([`Batch::m`]/[`Batch::n`] carry the raw size); in
//! practice catalog sizes are tile multiples and the two granularities
//! coincide.
//!
//! Execution-side, each batch maps onto one `Runtime::resolve` of
//! `(seq, variant, raw size)` — the runtime's resolve cache pins the
//! stage list, slot plan and executables per key, so grouping here and
//! resolving there share the same key discipline.

use super::{PlanChoice, Request};
use crate::ir::elem::ProblemSize;
use anyhow::{anyhow, Error, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Identity of one batch: the plan-cache key shape plus the resolved
/// plan choice. The device name is the context's interned `Arc<str>` —
/// grouping a turn clones a refcount per request, not a `String`.
/// `Ord` compares the interned name's *contents* (via `Arc`'s deref
/// ordering), so the grouping index below is stable across re-interns.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) struct BatchKey {
    pub seq: String,
    /// Tile-padded rows (plan granularity).
    pub m: usize,
    /// Tile-padded columns (plan granularity).
    pub n: usize,
    pub device: Arc<str>,
    pub choice: PlanChoice,
}

/// A group of requests that execute as one multi-input dispatch.
pub(crate) struct Batch {
    pub key: BatchKey,
    /// Raw (unpadded) rows — the granularity artifacts are keyed by.
    pub m: usize,
    /// Raw (unpadded) columns.
    pub n: usize,
    /// Members in arrival order.
    pub reqs: Vec<Request>,
}

/// Group a drained queue into batches, resolving the plan choice once
/// per distinct `(seq, padded size)` via `resolve` (only for requests
/// that do not force a variant). Requests whose resolution fails are
/// returned separately with their error. Batches come back in
/// first-arrival order; members keep arrival order.
pub(crate) fn group(
    reqs: Vec<Request>,
    device: &Arc<str>,
    mut resolve: impl FnMut(&str, usize, usize) -> Result<PlanChoice>,
) -> (Vec<Batch>, Vec<(Request, Error)>) {
    let mut batches: Vec<Batch> = Vec::new();
    let mut failed: Vec<(Request, Error)> = Vec::new();
    // Index of each open batch by (key, raw size) → its position in
    // `batches`. A linear `position` scan here made a wide-key drain
    // O(R·B) — every request walked every batch opened before it; the
    // index keeps membership lookup logarithmic while `batches` itself
    // still records first-arrival order.
    let mut index: BTreeMap<(BatchKey, usize, usize), usize> = BTreeMap::new();
    // One resolver call per padded key per turn — failures included, so
    // a burst of unresolvable requests neither repeats the planner
    // lookup nor inflates the plan cache's miss counter.
    let mut memo: BTreeMap<(String, usize, usize), Result<PlanChoice, String>> = BTreeMap::new();
    for req in reqs {
        let p = ProblemSize::new(req.m, req.n).padded();
        let choice = match req.variant {
            Some(v) => v,
            None => {
                let memo_key = (req.seq.clone(), p.m, p.n);
                let resolved = match memo.get(&memo_key).cloned() {
                    Some(r) => r,
                    None => {
                        let r = resolve(&req.seq, req.m, req.n).map_err(|e| format!("{e:#}"));
                        memo.insert(memo_key, r.clone());
                        r
                    }
                };
                match resolved {
                    Ok(c) => c,
                    Err(msg) => {
                        failed.push((req, anyhow!("{msg}")));
                        continue;
                    }
                }
            }
        };
        let key = BatchKey {
            seq: req.seq.clone(),
            m: p.m,
            n: p.n,
            device: device.clone(),
            choice,
        };
        match index.entry((key, req.m, req.n)) {
            std::collections::btree_map::Entry::Occupied(e) => {
                batches[*e.get()].reqs.push(req);
            }
            std::collections::btree_map::Entry::Vacant(e) => {
                let key = e.key().0.clone();
                e.insert(batches.len());
                batches.push(Batch {
                    key,
                    m: req.m,
                    n: req.n,
                    reqs: vec![req],
                });
            }
        }
    }
    (batches, failed)
}

/// Order a turn's batches for execution: earliest deadline first
/// (taking each batch's most urgent member), batches with no deadline
/// last, and higher maximum priority breaking ties. The sort is stable,
/// so equally-urgent batches keep first-arrival order — EDF-ish rather
/// than a full preemptive EDF, which is all a turn-at-a-time scheduler
/// can express.
pub(crate) fn order_edf(batches: &mut [Batch]) {
    order_edf_counted(batches, &mut 0);
}

/// [`order_edf`] with the key-computation count exposed, so a test can
/// pin the cost contract: the key folds over a batch's *members*
/// (min deadline, max priority), so it must be computed once per batch
/// — `sort_by_cached_key` — not once per comparison, which
/// `sort_by_key` is allowed to do (O(B log B) member folds on a
/// deadline-diverse turn).
pub(crate) fn order_edf_counted(batches: &mut [Batch], key_computations: &mut u64) {
    batches.sort_by_cached_key(|b| {
        *key_computations += 1;
        let deadline = b.reqs.iter().filter_map(|r| r.deadline).min();
        let priority = b.reqs.iter().map(|r| r.priority).max().unwrap_or(0);
        (deadline.is_none(), deadline, std::cmp::Reverse(priority))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Reply, RequestInputs};
    use anyhow::anyhow;
    use std::sync::mpsc;
    use std::time::{Duration, Instant};

    fn req(seq: &str, m: usize, n: usize, variant: Option<PlanChoice>) -> Request {
        // the receiver is dropped — grouping never touches the reply
        let (tx, _rx) = mpsc::channel();
        Request {
            seq: seq.into(),
            m,
            n,
            inputs: RequestInputs::Synth { seed: 0 },
            variant,
            enqueued: Instant::now(),
            deadline: None,
            priority: 0,
            attempts: 0,
            pinned: false,
            lot: None,
            split: None,
            split_block: false,
            admission: None,
            reply: Reply::new(tx, None),
        }
    }

    fn dev(name: &str) -> Arc<str> {
        Arc::from(name)
    }

    #[test]
    fn mixed_key_burst_splits_into_per_key_batches() {
        let reqs = vec![
            req("waxpby", 32, 65536, None),
            req("vadd", 32, 65536, None),
            req("waxpby", 32, 65536, None),
            req("waxpby", 256, 256, None),
            req("vadd", 32, 65536, None),
        ];
        let mut calls = Vec::new();
        let (batches, failed) = group(reqs, &dev("dev0"), |seq, m, n| {
            calls.push((seq.to_string(), m, n));
            Ok(PlanChoice::Fused)
        });
        assert!(failed.is_empty());
        assert_eq!(batches.len(), 3, "three distinct keys → three batches");
        // exactly one plan resolution per distinct (seq, padded size)
        assert_eq!(calls.len(), 3);
        let sizes: Vec<usize> = batches.iter().map(|b| b.reqs.len()).collect();
        assert_eq!(sizes, vec![2, 2, 1], "first-arrival order, members grouped");
        assert_eq!(batches[0].key.seq, "waxpby");
        assert_eq!(batches[1].key.seq, "vadd");
        assert_eq!(batches[2].key.n, 256);
    }

    #[test]
    fn variant_override_skips_planning_and_groups_by_resolved_choice() {
        let reqs = vec![
            req("waxpby", 32, 65536, Some(PlanChoice::Fused)),
            req("waxpby", 32, 65536, None),
            req("waxpby", 32, 65536, Some(PlanChoice::Cublas)),
        ];
        let mut calls = 0;
        let (batches, failed) = group(reqs, &dev("dev0"), |_, _, _| {
            calls += 1;
            Ok(PlanChoice::Fused)
        });
        assert!(failed.is_empty());
        assert_eq!(calls, 1, "only the unforced request plans");
        // forced-Fused and planner-resolved-Fused share one batch; the
        // forced-Cublas request is its own
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].reqs.len(), 2);
        assert_eq!(batches[0].key.choice, PlanChoice::Fused);
        assert_eq!(batches[1].key.choice, PlanChoice::Cublas);
    }

    #[test]
    fn padded_sizes_share_planning_but_raw_sizes_execute_separately() {
        let reqs = vec![req("waxpby", 32, 65530, None), req("waxpby", 32, 65536, None)];
        let mut calls = 0;
        let (batches, failed) = group(reqs, &dev("dev0"), |_, _, _| {
            calls += 1;
            Ok(PlanChoice::Fused)
        });
        assert!(failed.is_empty());
        assert_eq!(calls, 1, "one choose_plan serves the shared padded key");
        assert_eq!(batches.len(), 2, "artifact lookup stays raw-size exact");
        assert_eq!(batches[0].key, batches[1].key);
        assert_eq!(batches[0].n, 65530);
        assert_eq!(batches[1].n, 65536);
    }

    #[test]
    fn resolver_failure_fails_only_those_requests_and_resolves_once() {
        let reqs = vec![
            req("ghost", 32, 32, None),
            req("waxpby", 32, 65536, None),
            req("ghost", 32, 32, None),
        ];
        let mut calls = 0;
        let (batches, failed) = group(reqs, &dev("dev0"), |seq, _, _| {
            calls += 1;
            if seq == "ghost" {
                Err(anyhow!("unknown sequence '{seq}'"))
            } else {
                Ok(PlanChoice::Fused)
            }
        });
        assert_eq!(failed.len(), 2);
        assert_eq!(failed[0].0.seq, "ghost");
        assert!(format!("{:#}", failed[1].1).contains("unknown sequence"));
        assert_eq!(calls, 2, "failures are memoized too — one resolve per key");
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].key.seq, "waxpby");
    }

    #[test]
    fn many_distinct_key_burst_groups_by_index_in_arrival_order() {
        // Regression for the O(R·B) linear `position` scan: a drain
        // whose keys are almost all distinct opened a new batch per
        // request and re-walked every prior batch each time. The
        // indexed grouping must produce the identical result — one
        // batch per distinct (key, raw size) in first-arrival order,
        // repeats appended to their original batch.
        let keys = 200;
        let mut reqs = Vec::new();
        for i in 0..keys {
            // Distinct raw n per i (padding keeps them distinct too);
            // alternate seqs so the key varies in more than one field.
            let seq = if i % 2 == 0 { "waxpby" } else { "vadd" };
            reqs.push(req(seq, 32, 1024 + i * 64, None));
        }
        // A second pass over the same keys: every request must join its
        // existing batch, none may open a new one.
        for i in 0..keys {
            let seq = if i % 2 == 0 { "waxpby" } else { "vadd" };
            reqs.push(req(seq, 32, 1024 + i * 64, None));
        }
        let (batches, failed) = group(reqs, &dev("dev0"), |_, _, _| Ok(PlanChoice::Fused));
        assert!(failed.is_empty());
        assert_eq!(batches.len(), keys, "one batch per distinct key");
        for (i, b) in batches.iter().enumerate() {
            assert_eq!(b.n, 1024 + i * 64, "first-arrival order preserved");
            assert_eq!(b.reqs.len(), 2, "repeat joined its original batch");
        }
    }

    #[test]
    fn order_edf_computes_one_key_per_batch() {
        // The EDF key folds over a batch's members; `sort_by_cached_key`
        // guarantees one fold per batch. `sort_by_key` recomputed it per
        // comparison — this pins the contract with a counter.
        let mut reqs = Vec::new();
        for i in 0..32u64 {
            // Distinct deadlines in scrambled order force real sorting
            // work (no pre-sorted fast path); distinct raw sizes keep
            // the batches distinct.
            let mut r = req("waxpby", 32, 1024 + ((i * 13) % 32) as usize * 64, None);
            r.deadline = Some(Instant::now() + Duration::from_millis((i * 37) % 101));
            reqs.push(r);
        }
        let (mut batches, failed) = group(reqs, &dev("dev0"), |_, _, _| Ok(PlanChoice::Fused));
        assert!(failed.is_empty());
        assert_eq!(batches.len(), 32);
        let mut key_computations = 0;
        order_edf_counted(&mut batches, &mut key_computations);
        assert_eq!(
            key_computations, 32,
            "exactly one key fold per batch, not one per comparison"
        );
        // And the order is still EDF: deadlines ascending.
        let deadlines: Vec<_> = batches
            .iter()
            .map(|b| b.reqs[0].deadline.unwrap())
            .collect();
        assert!(deadlines.windows(2).all(|w| w[0] <= w[1]));
    }

    fn req_slo(seq: &str, deadline: Option<Duration>, priority: u8) -> Request {
        let now = Instant::now();
        let mut r = req(seq, 32, 65536, Some(PlanChoice::Fused));
        r.deadline = deadline.map(|d| now + d);
        r.priority = priority;
        r
    }

    #[test]
    fn edf_orders_urgent_first_and_deadline_free_last() {
        // Arrival order: no-deadline, loose, urgent. Distinct seqs keep
        // them in distinct batches.
        let reqs = vec![
            req_slo("waxpby", None, 0),
            req_slo("vadd", Some(Duration::from_secs(60)), 0),
            req_slo("sscal", Some(Duration::from_millis(5)), 0),
        ];
        let (mut batches, failed) = group(reqs, &dev("dev0"), |_, _, _| Ok(PlanChoice::Fused));
        assert!(failed.is_empty());
        assert_eq!(batches.len(), 3);
        order_edf(&mut batches);
        let order: Vec<&str> = batches.iter().map(|b| b.key.seq.as_str()).collect();
        assert_eq!(order, vec!["sscal", "vadd", "waxpby"]);
    }

    #[test]
    fn edf_batch_urgency_is_its_most_urgent_member() {
        // One batch holds {loose, urgent} members; the other a medium
        // deadline. The mixed batch must rank by its urgent member.
        let reqs = vec![
            req_slo("waxpby", Some(Duration::from_secs(60)), 0),
            req_slo("vadd", Some(Duration::from_secs(1)), 0),
            req_slo("waxpby", Some(Duration::from_millis(2)), 0),
        ];
        let (mut batches, failed) = group(reqs, &dev("dev0"), |_, _, _| Ok(PlanChoice::Fused));
        assert!(failed.is_empty());
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].reqs.len(), 2);
        order_edf(&mut batches);
        assert_eq!(batches[0].key.seq, "waxpby", "urgent member pulls its batch first");
    }

    #[test]
    fn edf_priority_breaks_ties_and_sort_is_stable() {
        // No deadlines anywhere: priority decides, then arrival order.
        let reqs = vec![
            req_slo("waxpby", None, 0),
            req_slo("vadd", None, 3),
            req_slo("sscal", None, 0),
        ];
        let (mut batches, failed) = group(reqs, &dev("dev0"), |_, _, _| Ok(PlanChoice::Fused));
        assert!(failed.is_empty());
        order_edf(&mut batches);
        let order: Vec<&str> = batches.iter().map(|b| b.key.seq.as_str()).collect();
        assert_eq!(order, vec!["vadd", "waxpby", "sscal"]);
    }
}
