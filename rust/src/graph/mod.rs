//! Data-dependency graph over elementary-function calls (paper §4.2:
//! "vertices represent elementary function calls and edges represent
//! data dependency between functions").
//!
//! Edges carry the variable they transport and whether the producer side
//! is a reduction result (in which case a global barrier — a kernel
//! boundary — must separate producer and consumer, §3.2.2).

use crate::ir::program::{CallId, Program, VarId};
use crate::library::Library;
use std::collections::BTreeSet;

/// One data dependency `from → to` via variable `var`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DepEdge {
    pub from: CallId,
    pub to: CallId,
    pub var: VarId,
    /// Producer's output is a reduction result: consuming it inside the
    /// producing kernel is impossible (needs a global barrier).
    pub reduction: bool,
}

/// The dependency graph of a program.
#[derive(Clone, Debug)]
pub struct DepGraph {
    pub n: usize,
    pub edges: Vec<DepEdge>,
    /// `shared_inputs[i]` = calls reading input/intermediate variable i
    /// (used to find fusions that spare *input re-reads*, e.g. BiCGK's A).
    succ: Vec<Vec<usize>>,
    pred: Vec<Vec<usize>>,
}

impl DepGraph {
    pub fn build(prog: &Program, lib: &Library) -> DepGraph {
        let n = prog.calls.len();
        let mut edges = Vec::new();
        let mut succ = vec![Vec::new(); n];
        let mut pred = vec![Vec::new(); n];
        for (ci, call) in prog.calls.iter().enumerate() {
            for &arg in &call.args {
                if let Some(producer) = prog.producer(arg) {
                    let pf = lib.get(prog.call(producer).func);
                    edges.push(DepEdge {
                        from: producer,
                        to: CallId(ci),
                        var: arg,
                        reduction: pf.hof.output_needs_global_barrier(),
                    });
                    succ[producer.0].push(ci);
                    pred[ci].push(producer.0);
                }
            }
        }
        DepGraph {
            n,
            edges,
            succ,
            pred,
        }
    }

    pub fn successors(&self, c: CallId) -> impl Iterator<Item = CallId> + '_ {
        self.succ[c.0].iter().map(|&i| CallId(i))
    }

    pub fn predecessors(&self, c: CallId) -> impl Iterator<Item = CallId> + '_ {
        self.pred[c.0].iter().map(|&i| CallId(i))
    }

    /// Edges internal to a set of calls.
    pub fn internal_edges<'a>(
        &'a self,
        set: &'a BTreeSet<CallId>,
    ) -> impl Iterator<Item = &'a DepEdge> {
        self.edges
            .iter()
            .filter(move |e| set.contains(&e.from) && set.contains(&e.to))
    }

    /// Is the set weakly connected (treating edges as undirected)?
    /// Fusions must be connected to spare any transfer.
    pub fn is_connected(&self, set: &BTreeSet<CallId>) -> bool {
        if set.is_empty() {
            return false;
        }
        let mut seen = BTreeSet::new();
        let start = *set.iter().next().unwrap();
        let mut stack = vec![start];
        seen.insert(start);
        while let Some(c) = stack.pop() {
            for nb in self
                .successors(c)
                .chain(self.predecessors(c))
                .collect::<Vec<_>>()
            {
                if set.contains(&nb) && seen.insert(nb) {
                    stack.push(nb);
                }
            }
        }
        seen.len() == set.len()
    }

    /// Convexity: no path leaves `set` and re-enters it. A non-convex
    /// fusion cannot be scheduled as one kernel (some outside call needs
    /// the fusion's output *and* feeds its input).
    pub fn is_convex(&self, set: &BTreeSet<CallId>) -> bool {
        // For each node reachable *from* the set through outside nodes,
        // check it cannot reach back into the set.
        let mut outside_reached: BTreeSet<usize> = BTreeSet::new();
        let mut stack: Vec<usize> = Vec::new();
        for &c in set {
            for s in self.successors(c) {
                if !set.contains(&s) && outside_reached.insert(s.0) {
                    stack.push(s.0);
                }
            }
        }
        while let Some(u) = stack.pop() {
            if set.contains(&CallId(u)) {
                return false;
            }
            for &v in &self.succ[u] {
                if set.contains(&CallId(v)) {
                    return false;
                }
                if outside_reached.insert(v) {
                    stack.push(v);
                }
            }
        }
        true
    }

    /// Topological order of all calls (scripts are already ordered, but
    /// plans permute within fusions; used for verification).
    pub fn topo_order(&self) -> Vec<CallId> {
        let mut indeg: Vec<usize> = (0..self.n).map(|i| self.pred[i].len()).collect();
        let mut queue: Vec<usize> = (0..self.n).filter(|&i| indeg[i] == 0).collect();
        let mut out = Vec::with_capacity(self.n);
        while let Some(u) = queue.pop() {
            out.push(CallId(u));
            for &v in &self.succ[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        assert_eq!(out.len(), self.n, "dependency cycle (SSA should prevent)");
        out
    }

    /// All topological orders of a *subset* (used to enumerate calling
    /// orders of a fusion, §4.2 "calling order of functions"). Capped to
    /// avoid factorial blowup on large fusions.
    pub fn topo_orders_of(&self, set: &BTreeSet<CallId>, cap: usize) -> Vec<Vec<CallId>> {
        let nodes: Vec<CallId> = set.iter().copied().collect();
        let mut orders = Vec::new();
        let mut cur = Vec::new();
        let mut used = vec![false; nodes.len()];
        self.extend_orders(&nodes, set, &mut used, &mut cur, &mut orders, cap);
        orders
    }

    fn extend_orders(
        &self,
        nodes: &[CallId],
        set: &BTreeSet<CallId>,
        used: &mut Vec<bool>,
        cur: &mut Vec<CallId>,
        orders: &mut Vec<Vec<CallId>>,
        cap: usize,
    ) {
        if orders.len() >= cap {
            return;
        }
        if cur.len() == nodes.len() {
            orders.push(cur.clone());
            return;
        }
        for (i, &cand) in nodes.iter().enumerate() {
            if used[i] {
                continue;
            }
            // all in-set predecessors must already be placed
            let ready = self
                .predecessors(cand)
                .filter(|p| set.contains(p))
                .all(|p| cur.contains(&p));
            if ready {
                used[i] = true;
                cur.push(cand);
                self.extend_orders(nodes, set, used, cur, orders, cap);
                cur.pop();
                used[i] = false;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::script::compile_script;

    fn graph_of(src: &str) -> (Program, Library, DepGraph) {
        let lib = Library::standard();
        let prog = compile_script("t", src, &lib).unwrap();
        let g = DepGraph::build(&prog, &lib);
        (prog, lib, g)
    }

    const AXPYDOT: &str = "
        vector<N> w, v, u, z; scalar r;
        input w, v, u;
        z = waxpby(w, v, alpha=1.0, beta=-2.0);
        r = sdot(z, u);
        return z, r;
    ";

    #[test]
    fn axpydot_edge_is_nonreduction() {
        let (_, _, g) = graph_of(AXPYDOT);
        assert_eq!(g.n, 2);
        assert_eq!(g.edges.len(), 1);
        assert!(!g.edges[0].reduction); // waxpby output is a map result
        assert_eq!(g.edges[0].from, CallId(0));
    }

    const ATAX: &str = "
        matrix<MxN> A; subvector32 x, t, y;
        input A, x;
        t = sgemv(A, x);
        y = sgemtv(A, t);
        return y;
    ";

    #[test]
    fn atax_edge_is_reduction() {
        let (_, _, g) = graph_of(ATAX);
        assert_eq!(g.edges.len(), 1);
        assert!(g.edges[0].reduction); // gemv output needs global barrier
    }

    const BICGK: &str = "
        matrix<MxN> A; vector<N> p, s; vector<M> q, r;
        input A, p, r;
        q = sgemv(A, p);
        s = sgemtv(A, r);
        return q, s;
    ";

    #[test]
    fn bicgk_has_no_edges_but_shares_a() {
        let (prog, _, g) = graph_of(BICGK);
        assert!(g.edges.is_empty()); // independent calls...
        let a = prog.var_id("A").unwrap();
        assert_eq!(prog.consumers(a).len(), 2); // ...sharing input A
    }

    #[test]
    fn connectivity() {
        let (_, _, g) = graph_of(ATAX);
        let both: BTreeSet<CallId> = [CallId(0), CallId(1)].into();
        assert!(g.is_connected(&both));
        let single: BTreeSet<CallId> = [CallId(0)].into();
        assert!(g.is_connected(&single));
        assert!(!g.is_connected(&BTreeSet::new()));
        // BiCGK's two calls share no edge → not connected as a set
        let (_, _, gb) = graph_of(BICGK);
        assert!(!gb.is_connected(&both));
    }

    #[test]
    fn convexity_detects_sandwich() {
        // c0 → c1 → c2 with {c0, c2} non-convex
        let src = "
            vector<N> a, b, c, d;
            input a;
            b = sscal(a, alpha=2.0);
            c = sscal(b, alpha=3.0);
            d = sscal(c, alpha=4.0);
            return d;
        ";
        let (_, _, g) = graph_of(src);
        let sandwich: BTreeSet<CallId> = [CallId(0), CallId(2)].into();
        assert!(!g.is_convex(&sandwich));
        let chain: BTreeSet<CallId> = [CallId(0), CallId(1)].into();
        assert!(g.is_convex(&chain));
    }

    #[test]
    fn topo_orders_of_independent_pair() {
        let (_, _, g) = graph_of(BICGK);
        let both: BTreeSet<CallId> = [CallId(0), CallId(1)].into();
        let orders = g.topo_orders_of(&both, 16);
        assert_eq!(orders.len(), 2); // both orders legal
    }

    #[test]
    fn topo_orders_respect_deps() {
        let (_, _, g) = graph_of(ATAX);
        let both: BTreeSet<CallId> = [CallId(0), CallId(1)].into();
        let orders = g.topo_orders_of(&both, 16);
        assert_eq!(orders, vec![vec![CallId(0), CallId(1)]]);
    }

    #[test]
    fn topo_order_full() {
        let (_, _, g) = graph_of(ATAX);
        let order = g.topo_order();
        let p0 = order.iter().position(|&c| c == CallId(0)).unwrap();
        let p1 = order.iter().position(|&c| c == CallId(1)).unwrap();
        assert!(p0 < p1);
    }

    #[test]
    fn order_cap_respected() {
        let (_, _, g) = graph_of(BICGK);
        let both: BTreeSet<CallId> = [CallId(0), CallId(1)].into();
        assert_eq!(g.topo_orders_of(&both, 1).len(), 1);
    }
}
