//! User-defined pipelines: from client-submitted script source to a
//! first-class servable sequence.
//!
//! The paper's compiler fuses *sequences* of map/reduce BLAS calls; the
//! `script` frontend can compile any such pipeline, but until now only
//! the eleven built-in sequences were servable — the catalog was fixed
//! at manifest parse time. This module is the bridge: [`compile`] takes
//! script source through typecheck → IR → fusion-space enumeration →
//! codegen, and the resulting [`Pipeline`] can be registered into the
//! runtime's *dynamic* catalog ([`crate::runtime::Runtime::register_pipeline`])
//! so the plan cache, resolve-once execution, routing, batching and SLO
//! handling all apply to it exactly as to built-ins.
//!
//! Registrations are content-addressed: [`fingerprint`] hashes the
//! source together with [`Library::fingerprint`], so two workers accept
//! the same submission iff they would compile it identically, and
//! re-submitting identical source is detectable as a dedup hit rather
//! than a conflict.
//!
//! # Execution
//!
//! The offline `xla` stub cannot run HLO, so pipeline stages execute on
//! a pure-Rust interpreter ([`InterpStage`]) with exactly the kernel
//! boundaries the fused plan chose: one stage per fused kernel, tensors
//! crossing stages through the same slot-interned environment the PJRT
//! path uses. Grouping is *structural* — the partition with the fewest
//! parts, ties to the lowest index — so every worker derives the same
//! stage list with no device-dependent planner input, mirroring how
//! built-in artifacts fix kernel structure while the planner retunes
//! fused-vs-cublas per device and size.

pub mod store;

use crate::autotune;
use crate::codegen;
use crate::fusion::implgen::FusionImpl;
use crate::fusion::space::Space;
use crate::fusion::{enumerate_fusions, ImplAxes};
use crate::graph::DepGraph;
use crate::ir::elem::{DimSym, VarType};
use crate::ir::plan::SeqPlan;
use crate::ir::program::{Program, VarDecl, VarId};
use crate::library::Library;
use crate::runtime::Tensor;
use crate::script::{compile_script, ScriptError};
use crate::util::manifest::{ArtifactEntry, DType, TensorSpec};
use crate::util::Prng;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

/// Content address of a pipeline submission: FNV-1a over the
/// length-prefixed source plus the library fingerprint. Two workers
/// agree on a fingerprint iff they hold byte-identical source *and*
/// byte-compatible libraries — the pair that determines compile output.
pub fn fingerprint(src: &str, lib: &Library) -> u64 {
    fn eat(mut h: u64, bytes: &[u8]) -> u64 {
        for &b in bytes {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        h
    }
    let mut h: u64 = 0xcbf29ce484222325;
    h = eat(h, &(src.len() as u64).to_le_bytes());
    h = eat(h, src.as_bytes());
    h = eat(h, &lib.fingerprint().to_le_bytes());
    h
}

/// One interpreted call: a library function applied to named tensors.
/// Function/variable names are resolved at compile time so execution
/// needs no [`Library`] or [`Program`] in hand.
#[derive(Clone, Debug)]
pub struct InterpCall {
    pub func: String,
    pub args: Vec<String>,
    pub outs: Vec<String>,
    pub scalars: BTreeMap<String, f32>,
}

/// One executable stage of a pipeline: the calls of one (possibly
/// fused) kernel, in execution order. The interpreter stands in for the
/// kernel launch — tensors enter and leave through the stage boundary
/// exactly as they would through global memory.
#[derive(Clone, Debug)]
pub struct InterpStage {
    pub calls: Vec<InterpCall>,
}

impl InterpStage {
    /// Run every call against a name → tensor environment. Intra-stage
    /// intermediates stay local to `env`, mirroring registers/shared
    /// memory of a fused kernel.
    pub fn run(&self, env: &mut BTreeMap<String, Tensor>) -> Result<()> {
        for call in &self.calls {
            eval_call(call, env)?;
        }
        Ok(())
    }
}

fn arg<'e>(
    env: &'e BTreeMap<String, Tensor>,
    call: &InterpCall,
    i: usize,
) -> Result<&'e Tensor> {
    let name = &call.args[i];
    env.get(name)
        .ok_or_else(|| anyhow!("interp {}: '{}' not in environment", call.func, name))
}

fn same_len(a: &Tensor, b: &Tensor, func: &str) -> Result<()> {
    if a.data.len() != b.data.len() {
        bail!(
            "interp {func}: input lengths differ ({} vs {})",
            a.data.len(),
            b.data.len()
        );
    }
    Ok(())
}

fn as_matrix(t: &Tensor, func: &str) -> Result<(usize, usize)> {
    if t.dims.len() != 2 {
        bail!("interp {func}: expected a matrix, got dims {:?}", t.dims);
    }
    Ok((t.dims[0], t.dims[1]))
}

fn map1(x: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor {
        dims: x.dims.clone(),
        data: x.data.iter().map(|&v| f(v)).collect(),
    }
}

fn map2(x: &Tensor, y: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    Tensor {
        dims: x.dims.clone(),
        data: x.data.iter().zip(&y.data).map(|(&a, &b)| f(a, b)).collect(),
    }
}

fn matvec(a: &Tensor, x: &[f32], m: usize, n: usize) -> Vec<f32> {
    (0..m)
        .map(|i| {
            let row = &a.data[i * n..(i + 1) * n];
            row.iter().zip(x).map(|(r, v)| r * v).sum()
        })
        .collect()
}

fn matvec_t(a: &Tensor, y: &[f32], m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for i in 0..m {
        let row = &a.data[i * n..(i + 1) * n];
        for j in 0..n {
            out[j] += row[j] * y[i];
        }
    }
    out
}

/// Evaluate one library call. Semantics mirror the doc contracts in
/// `library::blas1`/`blas2` (and the refcheck oracle); reductions sum
/// sequentially so results are deterministic across workers.
fn eval_call(call: &InterpCall, env: &mut BTreeMap<String, Tensor>) -> Result<()> {
    let s = |k: &str| call.scalars.get(k).copied().unwrap_or(1.0);
    let out = match call.func.as_str() {
        "scopy" => arg(env, call, 0)?.clone(),
        "sscal" => {
            let alpha = s("alpha");
            map1(arg(env, call, 0)?, |x| alpha * x)
        }
        "saxpy" => {
            let (x, y) = (arg(env, call, 0)?, arg(env, call, 1)?);
            same_len(x, y, &call.func)?;
            let alpha = s("alpha");
            map2(x, y, |x, y| alpha * x + y)
        }
        "waxpby" => {
            let (x, y) = (arg(env, call, 0)?, arg(env, call, 1)?);
            same_len(x, y, &call.func)?;
            let (alpha, beta) = (s("alpha"), s("beta"));
            map2(x, y, |x, y| alpha * x + beta * y)
        }
        "vadd3" => {
            let (w, y, z) = (arg(env, call, 0)?, arg(env, call, 1)?, arg(env, call, 2)?);
            same_len(w, y, &call.func)?;
            same_len(w, z, &call.func)?;
            Tensor {
                dims: w.dims.clone(),
                data: w
                    .data
                    .iter()
                    .zip(&y.data)
                    .zip(&z.data)
                    .map(|((&w, &y), &z)| w + y + z)
                    .collect(),
            }
        }
        "vadd2" => {
            let (y, z) = (arg(env, call, 0)?, arg(env, call, 1)?);
            same_len(y, z, &call.func)?;
            map2(y, z, |y, z| y + z)
        }
        "vexp" => map1(arg(env, call, 0)?, f32::exp),
        "vshift" => {
            let alpha = s("alpha");
            map1(arg(env, call, 0)?, |x| x + alpha)
        }
        "vclampr" => {
            let (lo, hi) = (s("lo"), s("hi"));
            // max/min instead of clamp: a user-supplied lo > hi must
            // not panic the worker.
            map1(arg(env, call, 0)?, |x| x.round().max(lo).min(hi))
        }
        "sdot" => {
            let (x, y) = (arg(env, call, 0)?, arg(env, call, 1)?);
            same_len(x, y, &call.func)?;
            let r: f32 = x.data.iter().zip(&y.data).map(|(a, b)| a * b).sum();
            Tensor::new(vec![1], vec![r])
        }
        "snrm2sq" => {
            let x = arg(env, call, 0)?;
            let r: f32 = x.data.iter().map(|a| a * a).sum();
            Tensor::new(vec![1], vec![r])
        }
        "sasum" => {
            let x = arg(env, call, 0)?;
            let r: f32 = x.data.iter().map(|a| a.abs()).sum();
            Tensor::new(vec![1], vec![r])
        }
        "mcopy" => arg(env, call, 0)?.clone(),
        "madd" => {
            let (a, b) = (arg(env, call, 0)?, arg(env, call, 1)?);
            same_len(a, b, &call.func)?;
            map2(a, b, |a, b| a + b)
        }
        "sger" => {
            let (a, u, v) = (arg(env, call, 0)?, arg(env, call, 1)?, arg(env, call, 2)?);
            let (m, n) = as_matrix(a, &call.func)?;
            if u.data.len() != m || v.data.len() != n {
                bail!("interp sger: rank-1 vectors don't match {m}x{n}");
            }
            let alpha = s("alpha");
            let mut b = a.data.clone();
            for i in 0..m {
                for j in 0..n {
                    b[i * n + j] += alpha * u.data[i] * v.data[j];
                }
            }
            Tensor::matrix(m, n, b)
        }
        "sger2" => {
            let a = arg(env, call, 0)?;
            let (u1, v1) = (arg(env, call, 1)?, arg(env, call, 2)?);
            let (u2, v2) = (arg(env, call, 3)?, arg(env, call, 4)?);
            let (m, n) = as_matrix(a, &call.func)?;
            if u1.data.len() != m || v1.data.len() != n || u2.data.len() != m || v2.data.len() != n
            {
                bail!("interp sger2: rank-1 vectors don't match {m}x{n}");
            }
            let mut b = a.data.clone();
            for i in 0..m {
                for j in 0..n {
                    b[i * n + j] += u1.data[i] * v1.data[j] + u2.data[i] * v2.data[j];
                }
            }
            Tensor::matrix(m, n, b)
        }
        "sgemv" => {
            let (a, x) = (arg(env, call, 0)?, arg(env, call, 1)?);
            let (m, n) = as_matrix(a, &call.func)?;
            if x.data.len() != n {
                bail!("interp sgemv: x has {} elements, A is {m}x{n}", x.data.len());
            }
            let alpha = s("alpha");
            Tensor::vector(matvec(a, &x.data, m, n).into_iter().map(|v| alpha * v).collect())
        }
        "sgemvpy" => {
            let (a, x, y) = (arg(env, call, 0)?, arg(env, call, 1)?, arg(env, call, 2)?);
            let (m, n) = as_matrix(a, &call.func)?;
            if x.data.len() != n || y.data.len() != m {
                bail!("interp sgemvpy: vector sizes don't match {m}x{n}");
            }
            let (alpha, beta) = (s("alpha"), s("beta"));
            let ax = matvec(a, &x.data, m, n);
            Tensor::vector(
                ax.iter()
                    .zip(&y.data)
                    .map(|(ax, y)| alpha * ax + beta * y)
                    .collect(),
            )
        }
        "sgemtv" => {
            let (a, r) = (arg(env, call, 0)?, arg(env, call, 1)?);
            let (m, n) = as_matrix(a, &call.func)?;
            if r.data.len() != m {
                bail!("interp sgemtv: r has {} elements, A is {m}x{n}", r.data.len());
            }
            let alpha = s("alpha");
            Tensor::vector(
                matvec_t(a, &r.data, m, n)
                    .into_iter()
                    .map(|v| alpha * v)
                    .collect(),
            )
        }
        "sgemtvpz" => {
            let (a, y, z) = (arg(env, call, 0)?, arg(env, call, 1)?, arg(env, call, 2)?);
            let (m, n) = as_matrix(a, &call.func)?;
            if y.data.len() != m || z.data.len() != n {
                bail!("interp sgemtvpz: vector sizes don't match {m}x{n}");
            }
            let beta = s("beta");
            let aty = matvec_t(a, &y.data, m, n);
            Tensor::vector(
                aty.iter()
                    .zip(&z.data)
                    .map(|(a, z)| beta * a + z)
                    .collect(),
            )
        }
        other => bail!("interp: no interpreter for library function '{other}'"),
    };
    if call.outs.len() != 1 {
        bail!("interp {}: expected exactly one output", call.func);
    }
    env.insert(call.outs[0].clone(), out);
    Ok(())
}

/// A compiled, servable user pipeline. Everything execution needs is
/// device-independent and derived deterministically from the source, so
/// every fleet worker holding the same `(source, library)` pair builds
/// a bit-identical `Pipeline`.
#[derive(Clone, Debug)]
pub struct Pipeline {
    pub name: String,
    pub source: String,
    /// Content address: [`fingerprint`] of `(source, Library::fingerprint)`.
    pub fingerprint: u64,
    pub program: Program,
    /// Kernel grouping of the "fused" variant: each group is one
    /// kernel's member calls (indices into `program.calls`) in
    /// execution order.
    pub fused_groups: Vec<Vec<usize>>,
    /// Per-call interpreter templates, parallel to `program.calls`.
    interp_calls: Vec<InterpCall>,
}

impl Pipeline {
    /// The servable variants, mirroring the built-in catalog's labels.
    pub const VARIANTS: [&'static str; 2] = ["fused", "cublas"];

    /// Kernel groups of a variant: the structural fusion choice for
    /// "fused", one call per kernel for "cublas".
    pub fn groups(&self, variant: &str) -> Result<Vec<Vec<usize>>> {
        match variant {
            "fused" => Ok(self.fused_groups.clone()),
            "cublas" => Ok((0..self.program.calls.len()).map(|i| vec![i]).collect()),
            other => bail!(
                "pipeline '{}' has no variant '{other}' (expected fused|cublas)",
                self.name
            ),
        }
    }

    fn spec_dims(&self, decl: &VarDecl, m: usize, n: usize) -> Result<Vec<usize>> {
        fn resolve(sym: &DimSym, m: usize, n: usize) -> Result<usize> {
            match sym.0.as_str() {
                "M" => Ok(m),
                "N" => Ok(n),
                other => bail!("pipeline dimension '{other}' is neither M nor N"),
            }
        }
        match decl.ty {
            VarType::Scalar => Ok(vec![1]),
            _ => decl.dims.iter().map(|s| resolve(s, m, n)).collect(),
        }
    }

    fn spec_of(&self, v: VarId, m: usize, n: usize) -> Result<TensorSpec> {
        let decl = self.program.var(v);
        Ok(TensorSpec {
            name: decl.name.clone(),
            dtype: DType::F32,
            dims: self.spec_dims(decl, m, n)?,
        })
    }

    /// Synthesize the catalog view of one variant at one problem size:
    /// ordered stage entries (keyed like built-in artifacts) paired
    /// with their interpreter stages. This is what the runtime resolves
    /// instead of a manifest lookup — the dynamic half of the catalog.
    pub fn stage_entries(
        &self,
        variant: &str,
        m: usize,
        n: usize,
    ) -> Result<Vec<(ArtifactEntry, InterpStage)>> {
        let groups = self.groups(variant)?;
        let mut out = Vec::with_capacity(groups.len());
        for (k, group) in groups.iter().enumerate() {
            let in_group = |ci: usize| group.contains(&ci);
            // Stage inputs: read before (or without) being produced in
            // this group, first-use order. Outputs: produced here and
            // either consumed by another stage or live-out.
            let mut inputs: Vec<VarId> = Vec::new();
            let mut outputs: Vec<VarId> = Vec::new();
            for &ci in group {
                let call = &self.program.calls[ci];
                for &v in &call.args {
                    let produced_here = self
                        .program
                        .producer(v)
                        .map(|c| in_group(c.0))
                        .unwrap_or(false);
                    if !produced_here && !inputs.contains(&v) {
                        inputs.push(v);
                    }
                }
                for &v in &call.outs {
                    let escapes = self.program.is_output(v)
                        || self.program.consumers(v).iter().any(|c| !in_group(c.0));
                    if escapes && !outputs.contains(&v) {
                        outputs.push(v);
                    }
                }
            }
            let key = format!("{}.{variant}.m{m}n{n}.s{k}", self.name);
            let entry = ArtifactEntry {
                file: PathBuf::from(format!("{key}.interp")),
                seq: self.name.clone(),
                variant: variant.to_string(),
                stage: k,
                inputs: inputs
                    .iter()
                    .map(|&v| self.spec_of(v, m, n))
                    .collect::<Result<_>>()?,
                outputs: outputs
                    .iter()
                    .map(|&v| self.spec_of(v, m, n))
                    .collect::<Result<_>>()?,
                attrs: BTreeMap::from([
                    ("m".to_string(), m.to_string()),
                    ("n".to_string(), n.to_string()),
                    ("backend".to_string(), "interp".to_string()),
                ]),
                m: Some(m),
                n: Some(n),
                key,
            };
            let stage = InterpStage {
                calls: group.iter().map(|&ci| self.interp_calls[ci].clone()).collect(),
            };
            out.push((entry, stage));
        }
        Ok(out)
    }

    /// Deterministic synthetic inputs for the pipeline's free inputs at
    /// one problem size — the demo/bench equivalent of the coordinator's
    /// manifest-driven input synthesis.
    pub fn synth_inputs(&self, m: usize, n: usize, seed: u64) -> Result<BTreeMap<String, Tensor>> {
        let mut rng = Prng::new(seed);
        let mut env = BTreeMap::new();
        for &v in &self.program.inputs {
            let decl = self.program.var(v);
            let dims = self.spec_dims(decl, m, n)?;
            let len = dims.iter().product::<usize>().max(1);
            env.insert(decl.name.clone(), Tensor::new(dims, rng.f32_vec(len)));
        }
        Ok(env)
    }

    /// Run the whole pipeline offline (no runtime, no catalog): bind
    /// inputs, execute every stage of `variant` in order. This is the
    /// reference the serve path is property-tested bit-identical to.
    pub fn run_offline(
        &self,
        variant: &str,
        m: usize,
        n: usize,
        inputs: &BTreeMap<String, Tensor>,
    ) -> Result<BTreeMap<String, Tensor>> {
        let mut env = inputs.clone();
        for (_, stage) in self.stage_entries(variant, m, n)? {
            stage.run(&mut env)?;
        }
        Ok(env)
    }
}

/// The planning-side companion of a [`Pipeline`]: the dependency graph,
/// pruned fusion space and CUBLAS-style baseline plan the coordinator
/// needs to treat the pipeline exactly like a built-in sequence
/// (plan choice, forecasting, sharded search).
pub struct Compiled {
    pub pipeline: Arc<Pipeline>,
    pub graph: DepGraph,
    pub space: Space,
    pub baseline: SeqPlan,
    /// The structurally-fused plan whose kernel boundaries define
    /// [`Pipeline::fused_groups`].
    pub fused: SeqPlan,
}

/// Compile script source end to end: lex/parse/typecheck → IR → fusion
/// enumeration → space build → codegen of the fused and baseline plans.
/// Pure function of `(name, src, lib)` — no device state — so all fleet
/// workers produce interchangeable results.
pub fn compile(name: &str, src: &str, lib: &Library) -> Result<Compiled, ScriptError> {
    let program = compile_script(name, src, lib)?;
    let graph = DepGraph::build(&program, lib);
    let fusions = enumerate_fusions(&program, lib, &graph);
    let space = Space::build(&program, lib, &graph, &fusions, &ImplAxes::minimal());
    let baseline = autotune::baseline_plan(&program, lib);
    // Structural fusion choice: the partition with the fewest kernels
    // (ties → lowest index) whose every part has a surviving impl. The
    // all-singleton partition always qualifies, so this cannot miss.
    let pi = (0..space.partitions.len())
        .filter(|&i| space.impls[i].iter().all(|cands| !cands.is_empty()))
        .min_by_key(|&i| (space.partitions[i].parts.len(), i))
        .ok_or_else(|| ScriptError::new(0, "no implementable fusion partition"))?;
    let impls: Vec<FusionImpl> = space.impls[pi].iter().map(|c| c[0].fi.clone()).collect();
    let fused = codegen::compile_seq(&program, lib, &impls, "fused");
    let fused_groups: Vec<Vec<usize>> = fused
        .kernels
        .iter()
        .map(|k| k.members.iter().map(|c| c.0).collect())
        .collect();
    let interp_calls = program
        .calls
        .iter()
        .map(|c| InterpCall {
            func: lib.get(c.func).name.clone(),
            args: c.args.iter().map(|&v| program.var(v).name.clone()).collect(),
            outs: c.outs.iter().map(|&v| program.var(v).name.clone()).collect(),
            scalars: c.scalar_args.clone(),
        })
        .collect();
    let pipeline = Arc::new(Pipeline {
        name: name.to_string(),
        source: src.to_string(),
        fingerprint: fingerprint(src, lib),
        program,
        fused_groups,
        interp_calls,
    });
    Ok(Compiled {
        pipeline,
        graph,
        space,
        baseline,
        fused,
    })
}

/// The two SNIPPETS exemplar pipelines, used by the demo, the smoke
/// tests and `benches/pipelines.rs`.
pub mod examples {
    /// `z = exp((x + y) * 2)` — a three-call map chain that fuses to a
    /// single kernel.
    pub const ADD_MUL_EXP: &str = "
        vector<N> x, y, s, t, z;
        input x, y;
        s = vadd2(x, y);
        t = sscal(s, alpha=2.0);
        z = vexp(t);
        return z;
    ";

    /// `q = clamp(round(x / scale + zero_point), -128, 127)` — an int8
    /// quantization chain (scale 4.0 → alpha 0.25, zero point 8).
    pub const QUANTIZE_INT8: &str = "
        vector<N> x, s, t, q;
        input x;
        s = sscal(x, alpha=0.25);
        t = vshift(s, alpha=8.0);
        q = vclampr(t, lo=-128.0, hi=127.0);
        return q;
    ";
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lib() -> Library {
        Library::standard()
    }

    #[test]
    fn fingerprint_is_stable_and_content_addressed() {
        let l = lib();
        let a = fingerprint(examples::ADD_MUL_EXP, &l);
        let b = fingerprint(examples::ADD_MUL_EXP, &l);
        assert_eq!(a, b);
        assert_ne!(a, fingerprint(examples::QUANTIZE_INT8, &l));
        // library content participates in the address
        let mut small = Library::new();
        small.register(crate::library::scopy());
        small.register(crate::library::vadd2());
        small.register(crate::library::sscal());
        small.register(crate::library::vexp());
        assert_ne!(a, fingerprint(examples::ADD_MUL_EXP, &small));
    }

    #[test]
    fn add_mul_exp_fuses_to_one_kernel() {
        let l = lib();
        let c = compile("add_mul_exp", examples::ADD_MUL_EXP, &l).unwrap();
        assert_eq!(c.pipeline.program.calls.len(), 3);
        assert_eq!(
            c.pipeline.fused_groups.len(),
            1,
            "three map calls must fuse into one kernel"
        );
        assert_eq!(c.fused.kernels.len(), 1);
        assert_eq!(c.baseline.kernels.len(), 3);
        assert_eq!(c.baseline.variant, "cublas");
    }

    #[test]
    fn interpreter_matches_closed_form() {
        let l = lib();
        let c = compile("add_mul_exp", examples::ADD_MUL_EXP, &l).unwrap();
        let (m, n) = (32, 64);
        let inputs = c.pipeline.synth_inputs(m, n, 7).unwrap();
        let env = c.pipeline.run_offline("fused", m, n, &inputs).unwrap();
        let (x, y) = (&inputs["x"], &inputs["y"]);
        for i in 0..n {
            let want = ((x.data[i] + y.data[i]) * 2.0).exp();
            assert!((env["z"].data[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn quantize_int8_saturates() {
        let l = lib();
        let c = compile("quantize_int8", examples::QUANTIZE_INT8, &l).unwrap();
        let mut inputs = BTreeMap::new();
        inputs.insert(
            "x".to_string(),
            Tensor::vector(vec![0.0, 4.0, -4.0, 1000.0, -1000.0]),
        );
        let env = c.pipeline.run_offline("fused", 32, 5, &inputs).unwrap();
        // x/4 + 8, rounded, clamped to [-128, 127]
        assert_eq!(env["q"].data, vec![8.0, 9.0, 7.0, 127.0, -128.0]);
    }

    #[test]
    fn fused_and_cublas_variants_agree_bitwise() {
        let l = lib();
        for (name, src) in [
            ("add_mul_exp", examples::ADD_MUL_EXP),
            ("quantize_int8", examples::QUANTIZE_INT8),
        ] {
            let c = compile(name, src, &l).unwrap();
            let (m, n) = (32, 96);
            let inputs = c.pipeline.synth_inputs(m, n, 3).unwrap();
            let f = c.pipeline.run_offline("fused", m, n, &inputs).unwrap();
            let u = c.pipeline.run_offline("cublas", m, n, &inputs).unwrap();
            for &v in &c.pipeline.program.outputs {
                let name = &c.pipeline.program.var(v).name;
                let (a, b) = (&f[name], &u[name]);
                assert_eq!(a.dims, b.dims);
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "output '{name}' differs");
                }
            }
        }
    }

    #[test]
    fn stage_entries_chain_outputs_to_inputs() {
        let l = lib();
        let c = compile("quantize_int8", examples::QUANTIZE_INT8, &l).unwrap();
        let stages = c.pipeline.stage_entries("cublas", 32, 64).unwrap();
        assert_eq!(stages.len(), 3);
        // each unfused stage's output feeds the next stage's input
        for w in stages.windows(2) {
            let produced = &w[0].0.outputs[0].name;
            assert!(w[1].0.inputs.iter().any(|i| &i.name == produced));
        }
        // keys follow the artifact naming scheme
        assert_eq!(stages[0].0.key, "quantize_int8.cublas.m32n64.s0");
        assert_eq!(stages[0].0.seq, "quantize_int8");
        // fused collapses to a single stage with only free inputs
        let fused = c.pipeline.stage_entries("fused", 32, 64).unwrap();
        assert_eq!(fused.len(), 1);
        assert_eq!(fused[0].0.inputs.len(), 1);
        assert_eq!(fused[0].0.inputs[0].name, "x");
        assert_eq!(fused[0].0.outputs[0].name, "q");
        assert_eq!(fused[0].0.inputs[0].dims, vec![64]);
    }

    #[test]
    fn unknown_variant_is_an_error() {
        let l = lib();
        let c = compile("add_mul_exp", examples::ADD_MUL_EXP, &l).unwrap();
        let err = c.pipeline.stage_entries("turbo", 32, 64).unwrap_err();
        assert!(err.to_string().contains("no variant"), "{err}");
    }

    #[test]
    fn blas2_pipeline_compiles_and_runs() {
        let l = lib();
        // a BLAS-2 call exercising the matrix interpreter path
        let src = "
            matrix<MxN> A; vector<M> q; vector<N> x;
            input A, x;
            q = sgemv(A, x, alpha=2.0);
            return q;
        ";
        let c = compile("mv2", src, &l).unwrap();
        let (m, n) = (4, 3);
        let mut inputs = BTreeMap::new();
        inputs.insert(
            "A".to_string(),
            Tensor::matrix(m, n, vec![1., 0., 0., 0., 1., 0., 0., 0., 1., 1., 1., 1.]),
        );
        inputs.insert("x".to_string(), Tensor::vector(vec![1.0, 2.0, 3.0]));
        let env = c.pipeline.run_offline("fused", m, n, &inputs).unwrap();
        assert_eq!(env["q"].data, vec![2.0, 4.0, 6.0, 12.0]);
    }

    #[test]
    fn invalid_script_reports_typed_error() {
        let l = lib();
        let err = compile("bad", "vector<N> x;\ninput x;\ny = nosuch(x);\nreturn y;", &l)
            .unwrap_err();
        assert!(err.msg.contains("unknown library function"), "{err}");
        assert_eq!(err.line, 3);
    }
}
