//! Persistent dynamic-pipeline catalog: the `(name, source,
//! fingerprint)` roster of registered script pipelines, written beside
//! the artifacts directory so registrations survive engine restarts.
//!
//! Two paths replay it:
//!
//! * **engine start** re-registers every persisted entry through the
//!   normal fleet-wide registration (compile on every worker,
//!   all-or-nothing), evicting entries whose recomputed fingerprint no
//!   longer matches the recorded one;
//! * **worker respawn** replays the same store onto the rebuilt
//!   coordinator only, verifying each fingerprint against the roster —
//!   a restarted lane must serve exactly what the surviving lanes
//!   serve.
//!
//! The format is deliberately dumb and self-delimiting: a version
//! line, then per entry a header line `name fingerprint byte-len`
//! followed by exactly `byte-len` bytes of source and a newline.
//! Sources contain newlines, so length-prefixing (not line-splitting)
//! is what makes round-trips exact. IO failures never fail serving: a
//! store that cannot be read starts empty, a store that cannot be
//! written keeps the in-memory roster authoritative for this process.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

const FILE_NAME: &str = "pipelines.catalog.txt";
const VERSION_LINE: &str = "fusebla-pipeline-catalog v1";

/// Thread-safe persistent roster of registered pipelines. Cheap enough
/// to rewrite whole on every mutation — registration is a control-plane
/// event, not a hot path.
pub struct CatalogStore {
    /// `None` for in-memory stores (tests, engines without a directory).
    path: Option<PathBuf>,
    entries: Mutex<BTreeMap<String, (String, u64)>>,
}

impl CatalogStore {
    /// Load the catalog persisted beside `dir` (the artifacts
    /// directory), or an empty store bound to that location. Unreadable
    /// or malformed files yield an empty store — the catalog is a
    /// convenience roster, never a correctness input (fingerprints are
    /// re-verified at every replay).
    pub fn load(dir: &Path) -> CatalogStore {
        let path = dir.join(FILE_NAME);
        let entries = std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| parse(&text))
            .unwrap_or_default();
        CatalogStore {
            path: Some(path),
            entries: Mutex::new(entries),
        }
    }

    /// A store with no backing file — registrations live for the
    /// process only.
    pub fn in_memory() -> CatalogStore {
        CatalogStore {
            path: None,
            entries: Mutex::new(BTreeMap::new()),
        }
    }

    /// Snapshot of every entry as `(name, source, fingerprint)`, in
    /// name order (deterministic replay order).
    pub fn entries(&self) -> Vec<(String, String, u64)> {
        self.entries
            .lock()
            .unwrap()
            .iter()
            .map(|(name, (src, fp))| (name.clone(), src.clone(), *fp))
            .collect()
    }

    /// Record (or overwrite) a registration and persist. Write errors
    /// are swallowed: the in-memory roster stays authoritative for this
    /// process, and the next successful write catches the file up.
    pub fn insert(&self, name: &str, src: &str, fingerprint: u64) {
        let mut entries = self.entries.lock().unwrap();
        entries.insert(name.to_string(), (src.to_string(), fingerprint));
        self.persist(&entries);
    }

    /// Drop a registration and persist. Removing an unknown name is a
    /// no-op (no rewrite).
    pub fn remove(&self, name: &str) {
        let mut entries = self.entries.lock().unwrap();
        if entries.remove(name).is_some() {
            self.persist(&entries);
        }
    }

    fn persist(&self, entries: &BTreeMap<String, (String, u64)>) {
        let Some(path) = &self.path else { return };
        let mut out = String::from(VERSION_LINE);
        out.push('\n');
        for (name, (src, fp)) in entries {
            out.push_str(&format!("{name} {fp:#018x} {}\n", src.len()));
            out.push_str(src);
            out.push('\n');
        }
        if let Some(dir) = path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        let _ = std::fs::write(path, out);
    }
}

/// Parse the persisted format; `None` on any structural violation (the
/// caller treats that as an empty catalog).
fn parse(text: &str) -> Option<BTreeMap<String, (String, u64)>> {
    let mut entries = BTreeMap::new();
    let rest = text.strip_prefix(VERSION_LINE)?.strip_prefix('\n')?;
    let mut cursor = rest;
    while !cursor.is_empty() {
        let (header, tail) = cursor.split_once('\n')?;
        let mut parts = header.split_whitespace();
        let name = parts.next()?.to_string();
        let fp_text = parts.next()?;
        let fp = u64::from_str_radix(fp_text.strip_prefix("0x")?, 16).ok()?;
        let len: usize = parts.next()?.parse().ok()?;
        if parts.next().is_some() || !tail.is_char_boundary(len) || tail.len() < len + 1 {
            return None;
        }
        let src = tail[..len].to_string();
        cursor = tail[len..].strip_prefix('\n')?;
        entries.insert(name, (src, fp));
    }
    Some(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fusebla_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn round_trips_multiline_sources_exactly() {
        let dir = scratch("roundtrip");
        let store = CatalogStore::load(&dir);
        assert!(store.entries().is_empty(), "fresh directory starts empty");
        let src = "let a = x + y\nlet b = a * a\nreturn b\n";
        store.insert("amx", src, 0xdead_beef);
        store.insert("other", "return x\n", 7);
        let reloaded = CatalogStore::load(&dir);
        assert_eq!(
            reloaded.entries(),
            vec![
                ("amx".to_string(), src.to_string(), 0xdead_beef),
                ("other".to_string(), "return x\n".to_string(), 7),
            ]
        );
        reloaded.remove("amx");
        assert_eq!(CatalogStore::load(&dir).entries().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn malformed_files_load_as_empty() {
        let dir = scratch("malformed");
        std::fs::write(dir.join(FILE_NAME), "not a catalog\n").unwrap();
        assert!(CatalogStore::load(&dir).entries().is_empty());
        // truncated payload: header promises more bytes than exist
        std::fs::write(
            dir.join(FILE_NAME),
            format!("{VERSION_LINE}\nname 0x0000000000000001 9999\nshort\n"),
        )
        .unwrap();
        assert!(CatalogStore::load(&dir).entries().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn in_memory_store_never_touches_disk() {
        let store = CatalogStore::in_memory();
        store.insert("amx", "return x\n", 1);
        assert_eq!(store.entries().len(), 1);
        store.remove("amx");
        assert!(store.entries().is_empty());
    }
}
